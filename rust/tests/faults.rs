//! Chaos acceptance suite: deterministic fault injection end to end.
//!
//! This is the **only** place the process-global fault plan is armed
//! (`sparsemap::util::faults::arm`); library unit tests use plan-local
//! checks so they can run in parallel. Tests here serialize through one
//! mutex and disarm on every exit path, so each scenario owns the global
//! seams (store-append, checkpoint-write, eval, socket-*) for its whole
//! lifetime.

use sparsemap::api::SearchRequest;
use sparsemap::memory::MemoryStore;
use sparsemap::service::{start, ServerConfig};
use sparsemap::util::faults::{self, FaultPlan};
use sparsemap::util::json::Json;
use sparsemap::util::retry::{retry, Backoff};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

/// Serializes every test in this binary: the fault plan is process
/// state. `unwrap_or_else` keeps later tests running (unpoisoned) even
/// if an earlier one panicked while holding the guard.
static GLOBAL_PLAN: Mutex<()> = Mutex::new(());

fn lock_plan() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_PLAN.lock().unwrap_or_else(|e| e.into_inner())
}

/// Disarms the global plan when a test exits, panic included.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        faults::disarm();
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparsemap_faults_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One raw HTTP exchange; returns (status, body).
fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let msg = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(msg.as_bytes()).unwrap();
    let mut text = String::new();
    stream.read_to_string(&mut text).unwrap();
    let status: u16 = text.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = match text.find("\r\n\r\n") {
        Some(i) => text[i + 4..].to_string(),
        None => String::new(),
    };
    (status, body)
}

fn submit_body(method: &str, budget: usize) -> String {
    SearchRequest::new()
        .workload_named("mm1")
        .platform_named("mobile")
        .method(method)
        .budget(budget)
        .seed(7)
        .to_json()
        .dumps()
}

fn poll_terminal(addr: SocketAddr, id: &str, tries: usize) -> Json {
    for _ in 0..tries {
        let (s, b) = request(addr, "GET", &format!("/jobs/{id}"), "");
        assert_eq!(s, 200, "{b}");
        let j = Json::parse(&b).unwrap();
        let state = j.get("state").and_then(Json::as_str).unwrap();
        if matches!(state, "done" | "failed" | "cancelled" | "suspended") {
            return j;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("job {id} never reached a terminal state");
}

/// A torn store append (crash mid-write) leaves a damaged tail; the next
/// open salvages the intact prefix, quarantines the tail to a `.corrupt`
/// sidecar, and the store keeps working.
#[test]
fn torn_store_append_salvages_on_reopen() {
    let _g = lock_plan();
    let _d = Disarm;
    let dir = tmp_dir("torn_append");
    let store_path = dir.join("memory.bin");

    // A finished search supplies a real elite to deposit.
    let report = SearchRequest::new()
        .workload_named("mm1")
        .platform_named("mobile")
        .method("random")
        .budget(60)
        .seed(7)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(report.outcome.best_edp.is_finite());
    let session = report.request.clone().build().unwrap();

    // Arm AFTER the search: only the store append sees the fault.
    faults::arm(FaultPlan::parse("seed=3;store-append:torn:40@1").unwrap());
    let mut store = MemoryStore::open(&store_path).unwrap();
    let err = store
        .remember(
            session.workload(),
            session.platform(),
            &report.outcome.method,
            &report.outcome,
            report.request.seed,
        )
        .unwrap_err();
    assert!(
        faults::simulates_crash(&err),
        "torn append surfaces as a simulated crash: {err}"
    );
    drop(store);
    faults::disarm();

    // The file on disk has a torn tail (header + 40 partial bytes).
    let torn_len = std::fs::metadata(&store_path).unwrap().len();
    assert!(torn_len > 16, "the torn prefix landed on disk: {torn_len}");

    // Reopen: salvage. No intact record existed, so the store is empty;
    // the damaged bytes are quarantined verbatim, not silently deleted.
    let mut store = MemoryStore::open(&store_path).unwrap();
    assert_eq!(store.len(), 0, "no whole record survived the tear");
    let sidecar = PathBuf::from(format!("{}.corrupt", store_path.display()));
    assert_eq!(
        std::fs::metadata(&sidecar).unwrap().len(),
        torn_len - 16,
        "quarantined tail is exactly the damaged bytes"
    );

    // The salvaged store accepts new appends and round-trips them.
    let recorded = store
        .remember(
            session.workload(),
            session.platform(),
            &report.outcome.method,
            &report.outcome,
            report.request.seed,
        )
        .unwrap();
    assert!(recorded);
    drop(store);
    let reopened = MemoryStore::open(&store_path).unwrap();
    assert_eq!(reopened.len(), 1, "post-salvage appends survive reopen");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A transient checkpoint-write failure is retried with backoff and the
/// write lands; a *torn* write (simulated crash) is not retried and
/// never corrupts the destination file.
#[test]
fn checkpoint_write_faults_retry_or_fail_atomically() {
    let _g = lock_plan();
    let _d = Disarm;
    let dir = tmp_dir("ckpt_write");
    let path = dir.join("job-000001.json");
    std::fs::write(&path, b"previous checkpoint").unwrap();
    let fast = Backoff {
        base: Duration::from_millis(1),
        cap: Duration::from_millis(2),
        ..Default::default()
    };

    // Transient error on the first attempt: the retry wrapper re-runs
    // the atomic write and the new contents land.
    faults::arm(FaultPlan::parse("checkpoint-write:error@1").unwrap());
    retry("persist checkpoint", &fast, || {
        sparsemap::util::atomic_write(&path, b"new checkpoint")
    })
    .unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), b"new checkpoint");
    faults::disarm();

    // Torn write: atomic_write fails, the destination keeps its previous
    // contents bit-for-bit, the torn tmp is gone, and retry declines to
    // mask a simulated crash (attempted exactly once).
    faults::arm(FaultPlan::parse("checkpoint-write:torn:5@1").unwrap());
    let mut attempts = 0;
    let err = retry("persist checkpoint", &fast, || {
        attempts += 1;
        sparsemap::util::atomic_write(&path, b"corrupting write")
    })
    .unwrap_err();
    assert!(faults::simulates_crash(&err), "{err}");
    assert_eq!(attempts, 1, "a dead process does not retry");
    assert_eq!(
        std::fs::read(&path).unwrap(),
        b"new checkpoint",
        "destination untouched by the torn write"
    );
    assert!(!path.with_extension("tmp").exists(), "torn tmp removed");
    let _ = std::fs::remove_dir_all(&dir);
}

/// An injected panic inside eval lands the job in `failed` with the
/// panic message in the detail — and the service keeps serving: health
/// stays green and the next job runs to done.
#[test]
fn eval_panic_fails_the_job_but_not_the_service() {
    let _g = lock_plan();
    let _d = Disarm;
    let handle = start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr;

    faults::arm(FaultPlan::parse("eval:panic@1").unwrap());
    let (s, b) = request(addr, "POST", "/jobs", &submit_body("random", 50));
    assert_eq!(s, 202, "{b}");
    let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
    let detail = poll_terminal(addr, &id, 500);
    assert_eq!(detail.get("state").and_then(Json::as_str), Some("failed"), "{}", detail.pretty());
    let error = detail.get("error").and_then(Json::as_str).unwrap_or_default();
    assert!(error.contains("injected panic"), "panic message surfaces in the detail: {error}");
    faults::disarm();

    // The worker survived the panic: health is green and a second job
    // runs to completion on the same pool.
    let (s, b) = request(addr, "GET", "/health", "");
    assert_eq!(s, 200);
    assert!(b.contains("\"ok\": true") || b.contains("\"ok\":true"), "{b}");
    let (s, b) = request(addr, "POST", "/jobs", &submit_body("random", 50));
    assert_eq!(s, 202, "{b}");
    let id2 = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
    let detail = poll_terminal(addr, &id2, 500);
    assert_eq!(detail.get("state").and_then(Json::as_str), Some("done"), "{}", detail.pretty());

    // Observability saw both the injection and the caught panic.
    let (_, metrics) = request(addr, "GET", "/metrics", "");
    let counter = |name: &str| -> f64 {
        metrics
            .lines()
            .find_map(|l| l.strip_prefix(name).and_then(|v| v.trim().parse().ok()))
            .unwrap_or(0.0)
    };
    assert!(counter("sparsemap_panics_caught_total ") >= 1.0, "{metrics}");
    assert!(counter("sparsemap_faults_injected_total ") >= 1.0, "{metrics}");
}

/// A client that stalls mid-request trips the read timeout, its slot is
/// reclaimed, and the service answers the next request normally.
#[test]
fn slow_client_times_out_without_wedging_the_service() {
    let _g = lock_plan();
    let _d = Disarm;
    let handle = start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        read_timeout: Duration::from_millis(150),
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr;
    // Half a request line, then silence: the server must cut us loose.
    let mut stall = TcpStream::connect(addr).unwrap();
    stall.write_all(b"GET /hea").unwrap();
    let mut text = String::new();
    let _ = stall.read_to_string(&mut text); // server closes (maybe after a 400)
    for _ in 0..200 {
        if handle.live_connections() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(handle.live_connections(), 0, "timed-out client's slot reclaimed");
    let (s, _) = request(addr, "GET", "/health", "");
    assert_eq!(s, 200);
}

/// Kill -9 stand-in for the service checkpoint path: a suspended job's
/// checkpoint written through `atomic_write` + `drain` survives process
/// death by construction (fsync before rename); here we pin that a
/// drained service's checkpoint resumes to the full budget in a brand
/// new service instance — nothing about resume depends on the memory of
/// the process that wrote it.
#[test]
fn drained_checkpoint_resumes_in_a_fresh_service() {
    let _g = lock_plan();
    let _d = Disarm;
    let dir = tmp_dir("drain_resume");
    let handle = start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr;
    let budget = 12_000;
    let (s, b) = request(addr, "POST", "/jobs", &submit_body("sparsemap", budget));
    assert_eq!(s, 202, "{b}");
    let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
    for _ in 0..500 {
        let (_, b) = request(addr, "GET", &format!("/jobs/{id}"), "");
        if Json::parse(&b).unwrap().get("state").and_then(Json::as_str) == Some("running") {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    // Graceful drain = what SIGTERM does: the running job suspends into
    // a durable checkpoint. (The old process would now exit; we just
    // abandon its handle, which is exactly as good — nothing below
    // touches it.)
    handle.drain();
    let file = dir.join(format!("{id}.json"));
    for _ in 0..200 {
        if file.exists() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(file.exists(), "drain persisted the suspension");

    // A brand new service instance over the same directory restores the
    // job and finishes the full budget from the checkpoint.
    let fresh = start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    })
    .unwrap();
    let (s, _) = request(fresh.addr, "POST", &format!("/jobs/{id}/resume"), "");
    assert_eq!(s, 202);
    let detail = poll_terminal(fresh.addr, &id, 3000);
    assert_eq!(detail.get("state").and_then(Json::as_str), Some("done"), "{}", detail.pretty());
    let evals = detail
        .get("report")
        .and_then(|r| r.get("outcome"))
        .and_then(|o| o.get("evals"))
        .and_then(Json::as_u64)
        .unwrap();
    assert_eq!(evals, budget as u64, "resume completes the full budget");
    let _ = std::fs::remove_dir_all(&dir);
}
