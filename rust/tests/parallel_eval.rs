//! The two contracts of the parallel/memoizing evaluation pipeline
//! (see `crate::search` module docs):
//!
//! 1. **Thread-count invariance** — a full SparseMap run produces
//!    bit-identical trajectories (best EDP, best genome, both telemetry
//!    curves) at 1 and 8 threads for the same seed.
//! 2. **Cache budget semantics** — duplicated submissions are served from
//!    the cache (one model call) but every submission debits the budget.

use sparsemap::arch::Platform;
use sparsemap::es::{run_sparsemap, CalibConfig, EsConfig, EsVariant, HshiConfig};
use sparsemap::search::{Backend, EvalContext};
use sparsemap::util::rng::Pcg64;
use sparsemap::util::threadpool::ThreadPool;
use sparsemap::workload::table3;
use std::sync::Arc;

fn ctx(budget: usize, threads: usize) -> EvalContext {
    let w = table3::by_id("mm3").unwrap();
    let c = EvalContext::new(Backend::native(w, Platform::cloud()), budget);
    if threads > 1 {
        c.with_pool(Some(Arc::new(ThreadPool::new(threads))))
    } else {
        c
    }
}

fn small_cfg() -> EsConfig {
    EsConfig {
        population: 24,
        variant: EsVariant::Full,
        calib: CalibConfig { samples_per_gene: 4, trials: 2, pairs: 4, max_evals: 0 },
        hshi: HshiConfig { hypercubes: 24, tries_per_cube: 6 },
        ..Default::default()
    }
}

#[test]
fn serial_and_parallel_trajectories_identical() {
    let a = run_sparsemap(ctx(1_500, 1), small_cfg(), 42);
    let b = run_sparsemap(ctx(1_500, 8), small_cfg(), 42);
    assert_eq!(a.best_edp, b.best_edp);
    assert_eq!(a.best_genome, b.best_genome);
    assert_eq!(a.curve, b.curve);
    assert_eq!(a.population_mean_curve, b.population_mean_curve);
    assert_eq!(a.evals, b.evals);
    assert_eq!(a.valid_evals, b.valid_evals);
    assert_eq!(a.cache_hits, b.cache_hits);
}

#[test]
fn duplicated_batch_one_model_call_full_budget_debit() {
    let mut c = ctx(100, 1);
    let mut rng = Pcg64::seeded(9);
    let g = c.spec.random(&mut rng);
    let batch: Vec<Vec<u32>> = vec![g.clone(); 10];
    let r = c.eval_batch(&batch);
    assert_eq!(r.len(), 10);
    assert_eq!(c.model_calls(), 1, "duplicates within a batch must dedupe to one model call");
    assert_eq!(c.used(), 10, "every submission debits the budget, hit or miss");
    assert_eq!(c.cache_hits(), 9);
    assert!(r.iter().all(|x| *x == r[0]));

    // A later generation re-submitting the same genome is a pure hit.
    let r2 = c.eval_batch(&batch);
    assert_eq!(r2, r);
    assert_eq!(c.model_calls(), 1);
    assert_eq!(c.used(), 20);
    assert_eq!(c.cache_hits(), 19);
}

#[test]
fn cache_hits_reported_in_outcome() {
    let mut c = ctx(60, 4);
    let mut rng = Pcg64::seeded(3);
    let g = c.spec.random(&mut rng);
    c.eval_batch(&vec![g; 30]);
    let o = c.outcome("cache-probe");
    assert_eq!(o.evals, 30);
    assert_eq!(o.cache_hits, 29);
    assert!(o.to_json().dumps().contains("cache_hits"));
}

/// Wall-clock speedup check for the acceptance bar (>= 2x at 4 threads).
/// Timing-sensitive, so it is `#[ignore]`d by default; the same numbers
/// come out of `cargo bench -- population_eval`. Run explicitly with
/// `cargo test --release -- --ignored`.
#[test]
#[ignore]
fn parallel_speedup_at_4_threads() {
    let n = 30_000;
    let mut elapsed = [0.0f64; 2];
    for (slot, threads) in [(0usize, 1usize), (1, 4)] {
        let mut c = ctx(n, threads).with_cache(false);
        let mut rng = Pcg64::seeded(1);
        let genomes: Vec<Vec<u32>> = (0..n).map(|_| c.spec.random(&mut rng)).collect();
        let t0 = std::time::Instant::now();
        let r = c.eval_batch(&genomes);
        elapsed[slot] = t0.elapsed().as_secs_f64();
        assert_eq!(r.len(), n);
    }
    let speedup = elapsed[0] / elapsed[1];
    assert!(
        speedup >= 2.0,
        "4-thread speedup only {speedup:.2}x (serial {:.2}s, parallel {:.2}s)",
        elapsed[0],
        elapsed[1]
    );
}
