//! Jittered-exponential-backoff retry for transient I/O.
//!
//! The service's durability writes (job checkpoints, memory deposits)
//! must survive transient filesystem hiccups — a momentarily-full disk,
//! an NFS blip, an injected `checkpoint-write:error` fault — without
//! wedging a worker or dropping the write. [`retry`] re-runs the
//! operation a bounded number of times with exponentially growing,
//! deterministically jittered sleeps in between. Jitter comes from a
//! seeded [`Pcg64`] keyed on the operation label, so test runs are
//! reproducible wall-clock included.
//!
//! A *simulated-crash* error (an injected torn write — see
//! [`crate::util::faults::simulates_crash`]) is never retried: it models
//! the process dying mid-write, and a dead process does not retry.

use crate::util::faults;
use crate::util::rng::Pcg64;
use std::time::Duration;

/// FNV-1a over the label so each call site gets its own jitter stream.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Retry policy: attempt count and backoff shape.
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    /// Total attempts (first try included). 1 means no retries.
    pub attempts: u32,
    /// Sleep before the first retry; doubles each subsequent retry.
    pub base: Duration,
    /// Upper bound on any single sleep.
    pub cap: Duration,
    /// Jitter seed (mixed with the operation label).
    pub seed: u64,
}

impl Default for Backoff {
    fn default() -> Backoff {
        Backoff {
            attempts: 4,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(250),
            seed: 0x5eed_ba0f,
        }
    }
}

impl Backoff {
    /// The sleep before retry number `retry` (0-based): `base * 2^retry`
    /// capped at `cap`, scaled by a deterministic jitter in [0.5, 1.5).
    fn sleep_for(&self, rng: &mut Pcg64, retry: u32) -> Duration {
        let exp = self.base.saturating_mul(1u32 << retry.min(16)).min(self.cap);
        exp.mul_f64(0.5 + rng.f64())
    }
}

/// Run `op` up to `b.attempts` times, sleeping between failures. Returns
/// the first success or the last error. Each retry attempt bumps the
/// `io_retries` obs counter and logs a one-line warning. Simulated-crash
/// errors short-circuit (see module docs).
pub fn retry<T, E: std::fmt::Display>(
    label: &str,
    b: &Backoff,
    mut op: impl FnMut() -> Result<T, E>,
) -> Result<T, E> {
    let mut rng = Pcg64::seeded(b.seed ^ fnv1a64(label.as_bytes()));
    let mut attempt = 0u32;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                attempt += 1;
                if attempt >= b.attempts.max(1) || faults::simulates_crash(&e) {
                    return Err(e);
                }
                crate::obs::global().io_retries.inc();
                let sleep = b.sleep_for(&mut rng, attempt - 1);
                eprintln!(
                    "warning: {label} failed (attempt {attempt}/{}): {e}; retrying in {:?}",
                    b.attempts, sleep
                );
                std::thread::sleep(sleep);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Backoff {
        Backoff {
            base: Duration::from_millis(1),
            cap: Duration::from_millis(2),
            ..Default::default()
        }
    }

    #[test]
    fn succeeds_after_transient_failures() {
        let mut calls = 0;
        let out: Result<u32, String> = retry("t", &fast(), || {
            calls += 1;
            if calls < 3 {
                Err("transient".to_string())
            } else {
                Ok(42)
            }
        });
        assert_eq!(out.unwrap(), 42);
        assert_eq!(calls, 3);
    }

    #[test]
    fn gives_up_after_the_attempt_budget() {
        let mut calls = 0;
        let out: Result<(), String> = retry("t", &fast(), || {
            calls += 1;
            Err("still broken".to_string())
        });
        assert_eq!(out.unwrap_err(), "still broken");
        assert_eq!(calls, 4, "default budget is 4 attempts");
    }

    #[test]
    fn simulated_crash_is_not_retried() {
        let mut calls = 0;
        let out: Result<(), String> = retry("t", &fast(), || {
            calls += 1;
            Err("injected torn write (simulated crash)".to_string())
        });
        assert!(out.is_err());
        assert_eq!(calls, 1, "a dead process does not retry");
    }

    #[test]
    fn backoff_is_deterministic_per_label() {
        let b = Backoff::default();
        let mut r1 = Pcg64::seeded(b.seed ^ fnv1a64(b"x"));
        let mut r2 = Pcg64::seeded(b.seed ^ fnv1a64(b"x"));
        for i in 0..4 {
            assert_eq!(b.sleep_for(&mut r1, i), b.sleep_for(&mut r2, i));
        }
        let capped = b.sleep_for(&mut r1, 30);
        assert!(capped <= b.cap.mul_f64(1.5), "cap bounds the exponent: {capped:?}");
    }
}
