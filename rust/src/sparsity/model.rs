//! [`DensityModel`] — structured sparsity patterns and their occupancy
//! statistics.
//!
//! Every query is deterministic, allocation-free and cheap (a handful of
//! `powf` calls at worst): density models are evaluated inside every
//! fitness call on the ES hot path (see `benches/bench_main.rs`,
//! `density_model_occupancy_queries`).

use crate::util::json::Json;
use anyhow::{anyhow, ensure, Result};

/// The tail quantile used for buffer provisioning: structured tensors are
/// sized for their 95th-percentile tile occupancy, not the mean (a mean
/// provision under-sizes skewed tensors — Sparseloop's argument for
/// per-tile density models).
pub const SIZING_QUANTILE: f64 = 0.95;

/// Quadrature points for the [`DensityModel::RowSkewed`] occupancy
/// mixture (midpoint rule over the row-density distribution).
const SKEW_QUAD_POINTS: usize = 8;

/// Most histogram buckets a [`DensityModel::Measured`] model may carry:
/// `slot_prob` is O(buckets) with a `powf` per bucket and runs inside
/// every fitness call, so [`DensityModel::measured`] downsamples larger
/// histograms to this many quantile samples.
pub const MAX_MEASURED_BUCKETS: usize = 64;

/// A structural model of where a tensor's nonzeros live.
///
/// The legacy scalar density is [`DensityModel::Uniform`]; its queries
/// reproduce the pre-subsystem arithmetic bit-for-bit (in particular
/// [`DensityModel::sizing_ratio`] is exactly `1.0`), so uniform workloads
/// search identically to older builds. The structured variants change
/// per-rank slot occupancy (compression cost), tail tile occupancy
/// (buffer provisioning) and therefore the search outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum DensityModel {
    /// Every element is nonzero independently with probability `density`.
    Uniform {
        /// Mean nonzero fraction, in `(0, 1]`.
        density: f64,
    },
    /// Nonzeros arrive in fully-dense blocks of `block` consecutive
    /// elements (innermost rank); a block is present with probability
    /// `density` (2:4-style and tile-pruned weights).
    Block {
        /// Elements per dense block, `>= 1`.
        block: u64,
        /// Block presence probability = mean element density, in `(0, 1]`.
        density: f64,
    },
    /// A banded matrix: each length-`cols` row carries one contiguous run
    /// of `bandwidth` nonzeros (stencils, tridiagonal-class operators).
    /// Mean density is `bandwidth / cols`.
    Banded {
        /// Nonzero band width in elements, `>= 1`.
        bandwidth: u64,
        /// Row length (the tensor's innermost extent), `>= 1`.
        cols: u64,
    },
    /// Power-law row occupancy (graph adjacency, attention masks): row
    /// densities follow `d·(1-alpha)·u^(-alpha)` for `u ~ U(0,1]`, so a
    /// few rows are much denser than the mean `d`.
    ///
    /// Row densities are saturated at 1.0, so when `alpha` and `density`
    /// are both large the realized mean of the saturated law sits
    /// somewhat below `density`; [`DensityModel::avg`] keeps returning
    /// the nominal `density` (the figure used for traffic and effectual
    /// MACs), which makes the tail statistics mildly conservative for
    /// extreme parameter pairs. Prefer moderate skews (`alpha <= 0.7`)
    /// at moderate densities.
    RowSkewed {
        /// Skew exponent in `[0, 1)`; `0` degenerates to near-uniform.
        alpha: f64,
        /// Mean nonzero fraction, in `(0, 1]`.
        density: f64,
    },
    /// An empirical per-row-group density histogram, e.g. fitted from a
    /// real tensor file by `sparsemap inspect-tensor`.
    Measured {
        /// Sampled group densities, ascending, each in `[0, 1]`.
        buckets: Vec<f64>,
        /// Cached mean of `buckets` (kept consistent by the constructor).
        avg: f64,
    },
}

impl DensityModel {
    /// Uniform iid occupancy at the given mean density.
    pub fn uniform(density: f64) -> DensityModel {
        DensityModel::Uniform { density }
    }

    /// Dense blocks of `block` elements, present with probability
    /// `density`.
    pub fn block(block: u64, density: f64) -> DensityModel {
        DensityModel::Block { block, density }
    }

    /// A band of `bandwidth` nonzeros per length-`cols` row.
    pub fn banded(bandwidth: u64, cols: u64) -> DensityModel {
        DensityModel::Banded { bandwidth, cols }
    }

    /// Power-law rows with skew `alpha` and mean density `density`.
    pub fn row_skewed(alpha: f64, density: f64) -> DensityModel {
        DensityModel::RowSkewed { alpha, density }
    }

    /// An empirical histogram of group densities (sorted internally;
    /// histograms larger than [`MAX_MEASURED_BUCKETS`] are downsampled
    /// to that many quantile samples to keep occupancy queries cheap on
    /// the search hot path).
    pub fn measured(mut buckets: Vec<f64>) -> DensityModel {
        buckets.sort_by(|a, b| a.total_cmp(b));
        if buckets.len() > MAX_MEASURED_BUCKETS {
            buckets = (0..MAX_MEASURED_BUCKETS)
                .map(|i| {
                    let pos = (buckets.len() - 1) as f64 * i as f64
                        / (MAX_MEASURED_BUCKETS - 1) as f64;
                    buckets[pos.round() as usize]
                })
                .collect();
        }
        let avg = if buckets.is_empty() {
            0.0
        } else {
            buckets.iter().sum::<f64>() / buckets.len() as f64
        };
        DensityModel::Measured { buckets, avg }
    }

    /// Short tag naming the variant (the JSON `kind`).
    pub fn kind_name(&self) -> &'static str {
        match self {
            DensityModel::Uniform { .. } => "uniform",
            DensityModel::Block { .. } => "block",
            DensityModel::Banded { .. } => "banded",
            DensityModel::RowSkewed { .. } => "row_skewed",
            DensityModel::Measured { .. } => "measured",
        }
    }

    /// Is this the legacy scalar (uniform) model?
    pub fn is_uniform(&self) -> bool {
        matches!(self, DensityModel::Uniform { .. })
    }

    /// Mean nonzero fraction of the whole tensor, in `(0, 1]` for valid
    /// models. O(1) — cached where not a stored field.
    pub fn avg(&self) -> f64 {
        match self {
            DensityModel::Uniform { density } => *density,
            DensityModel::Block { density, .. } => *density,
            DensityModel::Banded { bandwidth, cols } => {
                (*bandwidth as f64 / (*cols).max(1) as f64).min(1.0)
            }
            DensityModel::RowSkewed { density, .. } => *density,
            DensityModel::Measured { avg, .. } => *avg,
        }
    }

    /// Check the model parameters, with a typed error naming the problem
    /// (surfaced through workload / API request validation — bad
    /// densities no longer panic inside the cost model).
    pub fn validate(&self) -> Result<()> {
        let check_density = |d: f64| -> Result<()> {
            ensure!(
                d.is_finite() && d > 0.0 && d <= 1.0,
                "density {d} is outside (0, 1]"
            );
            Ok(())
        };
        match self {
            DensityModel::Uniform { density } => check_density(*density),
            DensityModel::Block { block, density } => {
                ensure!(*block >= 1, "block size must be >= 1, got {block}");
                check_density(*density)
            }
            DensityModel::Banded { bandwidth, cols } => {
                ensure!(*bandwidth >= 1, "bandwidth must be >= 1, got {bandwidth}");
                ensure!(*cols >= 1, "banded row length must be >= 1, got {cols}");
                ensure!(
                    bandwidth <= cols,
                    "bandwidth {bandwidth} exceeds the row length {cols} \
                     (the band cannot be wider than the row)"
                );
                Ok(())
            }
            DensityModel::RowSkewed { alpha, density } => {
                ensure!(
                    alpha.is_finite() && (0.0..1.0).contains(alpha),
                    "row-skew alpha {alpha} is outside [0, 1)"
                );
                check_density(*density)
            }
            DensityModel::Measured { buckets, avg } => {
                ensure!(!buckets.is_empty(), "measured histogram has no buckets");
                ensure!(
                    buckets.len() <= MAX_MEASURED_BUCKETS,
                    "measured histogram has {} buckets (max {MAX_MEASURED_BUCKETS}; the \
                     `measured` constructor downsamples automatically)",
                    buckets.len()
                );
                for b in buckets {
                    ensure!(
                        b.is_finite() && (0.0..=1.0).contains(b),
                        "measured bucket {b} is outside [0, 1]"
                    );
                }
                ensure!(avg.is_finite() && *avg > 0.0, "measured histogram is all-zero");
                Ok(())
            }
        }
    }

    /// Probability that a storage slot covering `inner_elems` leaf
    /// elements holds at least one nonzero — the per-rank occupancy the
    /// format storage model ([`crate::sparse::stack_storage_model`])
    /// multiplies through a format stack. Always in `[0, 1]` and
    /// non-decreasing in `inner_elems`.
    pub fn slot_prob(&self, inner_elems: f64) -> f64 {
        let n = inner_elems.max(1.0);
        match self {
            // Bit-for-bit the legacy uniform-iid occupancy:
            // p = 1 - (1-d)^n with d clamped away from zero.
            DensityModel::Uniform { density } => {
                let d = density.clamp(1e-9, 1.0);
                1.0 - (1.0 - d).powf(n)
            }
            // One Bernoulli trial per block touched instead of per
            // element: clustering makes coarse slots emptier.
            DensityModel::Block { block, density } => {
                let d = density.clamp(1e-9, 1.0);
                let trials = (n / (*block).max(1) as f64).max(1.0);
                1.0 - (1.0 - d).powf(trials)
            }
            // A window of n elements within a length-`cols` row
            // intersects the contiguous band in n + bandwidth - 1 of the
            // cols start positions (so slot_prob(1) is exactly the mean
            // density); windows a full row or larger always intersect.
            DensityModel::Banded { bandwidth, cols } => {
                ((n + *bandwidth as f64 - 1.0) / (*cols).max(1) as f64).min(1.0)
            }
            // Mixture over the row-density distribution (midpoint
            // quadrature): occupied-row probability averaged over skew.
            DensityModel::RowSkewed { .. } => {
                let mut acc = 0.0;
                for i in 0..SKEW_QUAD_POINTS {
                    let u = (i as f64 + 0.5) / SKEW_QUAD_POINTS as f64;
                    let d = self.row_density_at(u).clamp(1e-9, 1.0);
                    acc += 1.0 - (1.0 - d).powf(n);
                }
                acc / SKEW_QUAD_POINTS as f64
            }
            // Mixture over the empirical buckets.
            DensityModel::Measured { buckets, .. } => {
                if buckets.is_empty() {
                    return 0.0;
                }
                let mut acc = 0.0;
                for b in buckets {
                    let d = b.clamp(1e-9, 1.0);
                    acc += 1.0 - (1.0 - d).powf(n);
                }
                acc / buckets.len() as f64
            }
        }
    }

    /// Expected nonzero count of a tile of `tile_elems` elements at a
    /// uniformly random position: `avg() * tile_elems`. Monotone in the
    /// tile size for every model.
    pub fn tile_nonzeros(&self, tile_elems: f64) -> f64 {
        self.avg() * tile_elems.max(0.0)
    }

    /// `q`-quantile of the *per-tile* density for tiles of `tile_elems`
    /// elements, in `[0, 1]`: the occupancy a buffer must provision for
    /// to hold a fraction `q` of tiles. The mean is the 50%-ish point;
    /// skewed models have heavy upper tails.
    pub fn occupancy_quantile(&self, tile_elems: f64, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let n = tile_elems.max(1.0);
        match self {
            DensityModel::Uniform { density } => {
                binomial_density_quantile(density.clamp(0.0, 1.0), n, q)
            }
            // One effective trial per block: the per-tile density
            // fluctuates like a binomial over n/block blocks.
            DensityModel::Block { block, density } => {
                let trials = (n / (*block).max(1) as f64).max(1.0);
                binomial_density_quantile(density.clamp(0.0, 1.0), trials, q)
            }
            // Bimodal: a sub-row tile either misses the band (density 0)
            // or holds a dense band segment of min(bandwidth, n) elements.
            DensityModel::Banded { bandwidth, cols } => {
                let cols_f = (*cols).max(1) as f64;
                if n >= cols_f {
                    return self.avg();
                }
                let hit = self.slot_prob(n);
                if q <= 1.0 - hit {
                    0.0
                } else {
                    ((*bandwidth as f64).min(n) / n).min(1.0)
                }
            }
            // Closed-form quantile of the row-density law d·(1-a)·u^(-a)
            // (row-granularity tiles — the conservative aligned case).
            DensityModel::RowSkewed { .. } => {
                self.row_density_at((1.0 - q).max(1e-9)).clamp(0.0, 1.0)
            }
            DensityModel::Measured { buckets, .. } => {
                if buckets.is_empty() {
                    return 0.0;
                }
                let pos = q * (buckets.len() - 1) as f64;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                let frac = pos - lo as f64;
                (buckets[lo] * (1.0 - frac) + buckets[hi] * frac).clamp(0.0, 1.0)
            }
        }
    }

    /// Buffer-provisioning multiplier for a tile of `tile_elems`
    /// elements: P95 tile occupancy over mean occupancy, floored at 1.
    ///
    /// [`DensityModel::Uniform`] returns exactly `1.0` — the legacy
    /// mean-provisioning semantics (and the concentration limit of large
    /// uniform tiles) — which keeps uniform search trajectories
    /// bit-for-bit identical to pre-subsystem builds.
    pub fn sizing_ratio(&self, tile_elems: f64) -> f64 {
        if let DensityModel::Uniform { .. } = self {
            return 1.0;
        }
        let avg = self.avg().max(1e-12);
        (self.occupancy_quantile(tile_elems, SIZING_QUANTILE) / avg).max(1.0)
    }

    /// Row density at quantile position `u ∈ (0, 1]` for the skewed law
    /// (clamped to a density). Only meaningful for `RowSkewed`.
    fn row_density_at(&self, u: f64) -> f64 {
        match self {
            DensityModel::RowSkewed { alpha, density } => {
                (density * (1.0 - alpha) * u.max(1e-9).powf(-alpha)).min(1.0)
            }
            _ => self.avg(),
        }
    }

    /// Human-readable one-liner, e.g. `block(b=64, d=0.125)`.
    pub fn describe(&self) -> String {
        match self {
            DensityModel::Uniform { density } => format!("uniform(d={density:.4})"),
            DensityModel::Block { block, density } => {
                format!("block(b={block}, d={density:.4})")
            }
            DensityModel::Banded { bandwidth, cols } => {
                format!("banded(bw={bandwidth}/{cols}, d={:.4})", self.avg())
            }
            DensityModel::RowSkewed { alpha, density } => {
                format!("row_skewed(alpha={alpha:.2}, d={density:.4})")
            }
            DensityModel::Measured { buckets, avg } => {
                format!("measured({} buckets, d={avg:.4})", buckets.len())
            }
        }
    }

    /// JSON form: a bare number for `Uniform` (the legacy scalar — keeps
    /// existing specs and reports byte-identical), an object with a
    /// `kind` tag otherwise.
    pub fn to_json(&self) -> Json {
        match self {
            DensityModel::Uniform { density } => Json::num(*density),
            DensityModel::Block { block, density } => Json::obj(vec![
                ("kind", Json::str("block")),
                ("block", Json::num(*block as f64)),
                ("density", Json::num(*density)),
            ]),
            // `cols` is re-derived from the tensor's innermost extent on
            // parse, so it is not serialized.
            DensityModel::Banded { bandwidth, .. } => Json::obj(vec![
                ("kind", Json::str("banded")),
                ("bandwidth", Json::num(*bandwidth as f64)),
            ]),
            DensityModel::RowSkewed { alpha, density } => Json::obj(vec![
                ("kind", Json::str("row_skewed")),
                ("alpha", Json::num(*alpha)),
                ("density", Json::num(*density)),
            ]),
            DensityModel::Measured { buckets, .. } => Json::obj(vec![
                ("kind", Json::str("measured")),
                ("buckets", Json::arr_f64(buckets)),
            ]),
        }
    }

    /// Parse the JSON form (number or `kind`-tagged object; inverse of
    /// [`DensityModel::to_json`]). `inner_extent` is the owning tensor's
    /// innermost dimension size, used to resolve `banded` row lengths.
    pub fn from_json(j: &Json, inner_extent: u64) -> Result<DensityModel> {
        if let Some(d) = j.as_f64() {
            let m = DensityModel::uniform(d);
            m.validate()?;
            return Ok(m);
        }
        ensure!(
            j.as_obj().is_some(),
            "density must be a number or an object with a 'kind' tag"
        );
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("density object needs a string 'kind'"))?;
        let num = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("density model '{kind}' needs a number '{key}'"))
        };
        let int = |key: &str| -> Result<u64> {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("density model '{kind}' needs an integer '{key}'"))
        };
        let m = match kind {
            "uniform" => DensityModel::uniform(num("density")?),
            "block" => DensityModel::block(int("block")?, num("density")?),
            "banded" => DensityModel::banded(int("bandwidth")?, inner_extent.max(1)),
            "row_skewed" => DensityModel::row_skewed(num("alpha")?, num("density")?),
            "measured" => {
                let buckets = j
                    .get("buckets")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("density model 'measured' needs a 'buckets' array"))?
                    .iter()
                    .map(|b| {
                        b.as_f64()
                            .ok_or_else(|| anyhow!("'measured' buckets must be numbers"))
                    })
                    .collect::<Result<Vec<f64>>>()?;
                DensityModel::measured(buckets)
            }
            other => {
                return Err(anyhow!(
                    "unknown density model kind '{other}' \
                     (uniform|block|banded|row_skewed|measured)"
                ))
            }
        };
        m.validate()?;
        Ok(m)
    }
}

/// Effectual-MAC fraction of a `P × Q` contraction: the probability both
/// operands of a MAC are nonzero. Operand patterns are modeled as
/// independent, so this is the product of the mean densities — for
/// uniform models, bit-for-bit the legacy `dp * dq`.
pub fn effectual_frac(p: &DensityModel, q: &DensityModel) -> f64 {
    p.avg() * q.avg()
}

/// Expected effectual MACs of a contraction with `total_ops` dense MACs.
pub fn effectual_macs(total_ops: f64, p: &DensityModel, q: &DensityModel) -> f64 {
    total_ops * effectual_frac(p, q)
}

/// `q`-quantile of a binomial *density* (successes/trials) with mean `d`
/// over `trials` trials, via the normal approximation. Clamped to [0, 1].
fn binomial_density_quantile(d: f64, trials: f64, q: f64) -> f64 {
    let sd = (d * (1.0 - d) / trials.max(1.0)).sqrt();
    (d + inv_norm_cdf(q) * sd).clamp(0.0, 1.0)
}

/// Acklam's rational approximation of the standard normal inverse CDF
/// (absolute error < 1.15e-9 — far below modeling error here).
fn inv_norm_cdf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -39.69683028665376,
        220.9460984245205,
        -275.9285104469687,
        138.357751867269,
        -30.66479806614716,
        2.506628277459239,
    ];
    const B: [f64; 5] = [
        -54.47609879822406,
        161.5858368580409,
        -155.6989798598866,
        66.80131188771972,
        -13.28068155288572,
    ];
    const C: [f64; 6] = [
        -0.007784894002430293,
        -0.3223964580411365,
        -2.400758277161838,
        -2.549732539343734,
        4.374664141464968,
        2.938163982698783,
    ];
    const D: [f64; 4] = [
        0.007784695709041462,
        0.3224671290700398,
        2.445134137142996,
        3.754408661907416,
    ];
    const P_LOW: f64 = 0.02425;
    let p = p.clamp(1e-12, 1.0 - 1e-12);
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_models() -> Vec<DensityModel> {
        vec![
            DensityModel::uniform(0.1),
            DensityModel::block(64, 0.1),
            DensityModel::banded(102, 1024),
            DensityModel::row_skewed(0.6, 0.1),
            DensityModel::measured(vec![0.01, 0.05, 0.1, 0.2, 0.4]),
        ]
    }

    #[test]
    fn inv_norm_cdf_reference_points() {
        assert!(inv_norm_cdf(0.5).abs() < 1e-9);
        assert!((inv_norm_cdf(0.95) - 1.6449).abs() < 1e-3);
        assert!((inv_norm_cdf(0.975) - 1.9600).abs() < 1e-3);
        assert!((inv_norm_cdf(0.05) + inv_norm_cdf(0.95)).abs() < 1e-9);
        assert!(inv_norm_cdf(0.001) < -3.0 && inv_norm_cdf(0.999) > 3.0);
    }

    #[test]
    fn uniform_slot_prob_matches_legacy_formula() {
        for d in [1e-6, 0.01, 0.118, 0.5, 1.0] {
            let m = DensityModel::uniform(d);
            for n in [1.0, 7.0, 64.0, 4096.0] {
                let legacy = 1.0 - (1.0 - d.clamp(1e-9, 1.0)).powf(n);
                assert_eq!(m.slot_prob(n).to_bits(), legacy.to_bits());
            }
        }
    }

    #[test]
    fn uniform_sizing_ratio_is_exactly_one() {
        let m = DensityModel::uniform(0.3);
        for t in [1.0, 100.0, 1e6] {
            assert_eq!(m.sizing_ratio(t), 1.0);
        }
    }

    #[test]
    fn structured_models_provision_above_mean() {
        for m in all_models().into_iter().filter(|m| !m.is_uniform()) {
            let r = m.sizing_ratio(256.0);
            assert!(r >= 1.0 && r.is_finite(), "{}: ratio {r}", m.describe());
        }
        // A small-tile banded tensor must provision for the dense band
        // segment, far above the 10% mean.
        let banded = DensityModel::banded(102, 1024);
        assert!(banded.sizing_ratio(128.0) > 3.0);
        // Skewed rows have a heavy tail quantile.
        let skew = DensityModel::row_skewed(0.6, 0.1);
        assert!(skew.occupancy_quantile(1024.0, 0.95) > 2.0 * skew.avg());
    }

    #[test]
    fn block_coarsens_slot_occupancy() {
        let u = DensityModel::uniform(0.1);
        let b = DensityModel::block(64, 0.1);
        // Same mean, but a 64-element slot holds one block-trial instead
        // of 64 element-trials: much likelier to be empty.
        assert_eq!(b.avg(), u.avg());
        assert!(b.slot_prob(64.0) < u.slot_prob(64.0) * 0.2);
    }

    #[test]
    fn banded_rows_always_occupied() {
        let m = DensityModel::banded(16, 512);
        assert_eq!(m.slot_prob(512.0), 1.0);
        assert!(m.slot_prob(4.0) < 0.05);
        assert!((m.avg() - 16.0 / 512.0).abs() < 1e-12);
        // A single-element slot is occupied exactly at the mean density.
        assert_eq!(m.slot_prob(1.0), m.avg());
    }

    #[test]
    fn measured_quantiles_interpolate_sorted_buckets() {
        let m = DensityModel::measured(vec![0.4, 0.1, 0.2, 0.3]);
        assert!((m.avg() - 0.25).abs() < 1e-12);
        assert_eq!(m.occupancy_quantile(64.0, 0.0), 0.1);
        assert_eq!(m.occupancy_quantile(64.0, 1.0), 0.4);
        assert!((m.occupancy_quantile(64.0, 0.5) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn measured_downsamples_large_histograms() {
        let big: Vec<f64> = (0..1000).map(|i| i as f64 / 1000.0).collect();
        let m = DensityModel::measured(big);
        match &m {
            DensityModel::Measured { buckets, .. } => {
                assert_eq!(buckets.len(), MAX_MEASURED_BUCKETS);
                assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "still sorted");
            }
            _ => unreachable!(),
        }
        // The quantile-sampled histogram preserves the mean closely.
        assert!((m.avg() - 0.4995).abs() < 0.01, "avg {}", m.avg());
        assert!(m.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(DensityModel::uniform(0.0).validate().is_err());
        assert!(DensityModel::uniform(1.5).validate().is_err());
        assert!(DensityModel::uniform(f64::NAN).validate().is_err());
        assert!(DensityModel::block(0, 0.5).validate().is_err());
        assert!(DensityModel::banded(0, 64).validate().is_err());
        assert!(DensityModel::banded(128, 64).validate().is_err(), "band wider than row");
        assert!(DensityModel::row_skewed(1.0, 0.5).validate().is_err());
        assert!(DensityModel::row_skewed(-0.1, 0.5).validate().is_err());
        assert!(DensityModel::measured(vec![]).validate().is_err());
        assert!(DensityModel::measured(vec![0.0, 0.0]).validate().is_err());
        for m in all_models() {
            assert!(m.validate().is_ok(), "{}", m.describe());
        }
    }

    #[test]
    fn json_round_trips_every_variant() {
        for m in all_models() {
            let j = m.to_json();
            let parsed = DensityModel::from_json(
                &Json::parse(&j.dumps()).unwrap(),
                1024, // the banded fixture's row length
            )
            .unwrap();
            assert_eq!(parsed, m, "{}", m.describe());
        }
        // The uniform form is a bare number (legacy spec compatibility).
        assert_eq!(DensityModel::uniform(0.25).to_json(), Json::num(0.25));
    }

    #[test]
    fn from_json_rejects_malformed_models() {
        for src in [
            r#"{"kind": "nope", "density": 0.5}"#,
            r#"{"kind": "block", "density": 0.5}"#,
            r#"{"kind": "block", "block": 4, "density": 0}"#,
            r#"{"density": 0.5}"#,
            r#""free-text""#,
            "0",
            "-0.5",
        ] {
            let j = Json::parse(src).unwrap();
            assert!(DensityModel::from_json(&j, 64).is_err(), "{src}");
        }
    }

    #[test]
    fn effectual_frac_is_product_of_means() {
        let p = DensityModel::uniform(0.118);
        let q = DensityModel::block(16, 0.3);
        let f = effectual_frac(&p, &q);
        assert_eq!(f.to_bits(), (0.118f64 * 0.3).to_bits());
        assert_eq!(effectual_macs(1000.0, &p, &q), 1000.0 * f);
    }
}
