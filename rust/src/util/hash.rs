//! An in-tree fast hasher (Fx-style multiply-rotate) for hot-path maps.
//!
//! The offline vendor set has no `rustc-hash`/`ahash`, and `std`'s
//! default SipHash is DoS-resistant but ~5x slower than needed for the
//! evaluation engine, which hashes short `u32` gene slices millions of
//! times per search. Genome keys are attacker-free internal data, so the
//! non-cryptographic Fx construction (the rustc interner's hasher) is the
//! right trade: one rotate + xor + multiply per word.

use std::borrow::Borrow;
use std::hash::{BuildHasher, Hasher};
use std::sync::Arc;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Padding value for the high half of an odd-length tail word.
///
/// Genes are small enum/index values, so `u32::MAX` can never be a real
/// gene; packing it into unused tail halves keeps word-level equality and
/// hashing exact without carrying a separate length (the `[u64]` slice
/// `Hash` impl already prefixes the word count, which together with the
/// sentinel distinguishes `[1]` from `[1, PAD]`-shaped inputs).
pub const PACK_PAD: u32 = u32::MAX;

/// An interned genome (or genome segment) re-laid-out as bit-packed
/// 64-bit words: two `u32` genes per word, first gene in the low half.
///
/// Hashing and equality run over `u64` words — half the `FxHasher::add`
/// rounds of the byte/element-wise `[u32]` path — and the derived `Hash`
/// delegates to the `[u64]` slice impl, so `FxHashMap<PackedWords, _>`
/// can be probed allocation-free by a scratch `&[u64]` via `Borrow`.
#[repr(C)]
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PackedWords(pub Arc<[u64]>);

impl Borrow<[u64]> for PackedWords {
    #[inline]
    fn borrow(&self) -> &[u64] {
        &self.0
    }
}

impl PackedWords {
    /// Packs `genes` into a freshly allocated key (one `Arc` allocation).
    pub fn pack(genes: &[u32]) -> PackedWords {
        let mut buf = Vec::with_capacity(genes.len().div_ceil(2));
        pack_genes_into(genes, &mut buf);
        PackedWords(Arc::from(buf.as_slice()))
    }

    /// Number of packed words.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// Whether the key packs zero genes.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

/// Packs `genes` into `out` (cleared first): two per word, low half
/// first, odd tail padded with [`PACK_PAD`]. Reusing one scratch `Vec`
/// across calls keeps steady-state map probes allocation-free.
#[inline]
pub fn pack_genes_into(genes: &[u32], out: &mut Vec<u64>) {
    out.clear();
    out.reserve(genes.len().div_ceil(2));
    let mut chunks = genes.chunks_exact(2);
    for c in &mut chunks {
        out.push((c[0] as u64) | ((c[1] as u64) << 32));
    }
    if let [last] = chunks.remainder() {
        out.push((*last as u64) | ((PACK_PAD as u64) << 32));
    }
}

/// Multiply-rotate hasher over 8-byte words.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`] — plug into
/// `HashMap::with_hasher(FxBuildHasher::default())`.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

/// A `HashMap` keyed with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of<T: Hash + ?Sized>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_discriminating() {
        let a = vec![1u32, 2, 3, 4];
        let b = vec![1u32, 2, 3, 5];
        assert_eq!(hash_of(&a), hash_of(&a.clone()));
        assert_ne!(hash_of(&a), hash_of(&b));
    }

    #[test]
    fn slice_and_owned_agree() {
        // HashMap<Arc<[u32]>, _> looks up by &[u32] via Borrow: both
        // sides must hash identically.
        let owned: std::sync::Arc<[u32]> = std::sync::Arc::from(&[7u32, 8, 9][..]);
        let slice: &[u32] = &[7, 8, 9];
        assert_eq!(hash_of(&*owned), hash_of(&slice.to_vec()[..]));
        assert_eq!(hash_of(&*owned), {
            let mut h = FxHasher::default();
            slice.hash(&mut h);
            h.finish()
        });
    }

    #[test]
    fn fx_map_works_end_to_end() {
        let mut m: FxHashMap<Vec<u32>, usize> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert(vec![i, i * 2, i * 3], i as usize);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u32 {
            assert_eq!(m.get(&vec![i, i * 2, i * 3]), Some(&(i as usize)));
        }
    }

    #[test]
    fn packed_words_round_trip_and_tail_sentinel() {
        // Even length: exact pairs, low half first.
        let even = PackedWords::pack(&[1, 2, 3, 4]);
        assert_eq!(&*even.0, &[1 | (2u64 << 32), 3 | (4u64 << 32)]);
        // Odd length: the dangling gene gets the sentinel high half.
        let odd = PackedWords::pack(&[1, 2, 3]);
        assert_eq!(&*odd.0, &[1 | (2u64 << 32), 3 | ((PACK_PAD as u64) << 32)]);
        assert_ne!(even, odd);
        assert_eq!(odd.len(), 2);
        assert!(!odd.is_empty());
        assert!(PackedWords::pack(&[]).is_empty());
    }

    #[test]
    fn packed_words_discriminate_lengths_and_orders() {
        // Word packing must not alias different genomes: neighbouring
        // lengths (the classic zero-pad collision) and swapped halves.
        let keys = [
            PackedWords::pack(&[]),
            PackedWords::pack(&[0]),
            PackedWords::pack(&[0, 0]),
            PackedWords::pack(&[0, 0, 0]),
            PackedWords::pack(&[1, 2]),
            PackedWords::pack(&[2, 1]),
            PackedWords::pack(&[1, 2, 3]),
            PackedWords::pack(&[1, 2, 3, 4]),
        ];
        let mut seen = std::collections::HashSet::new();
        for k in &keys {
            assert!(seen.insert(hash_of(k)), "hash collision on {k:?}");
        }
    }

    #[test]
    fn packed_scratch_probe_agrees_with_owned_key() {
        // FxHashMap<PackedWords, _> is probed by a reusable &[u64]
        // scratch via Borrow: both sides must hash and compare equal.
        use std::collections::HashMap;
        let mut m: HashMap<PackedWords, usize, FxBuildHasher> = HashMap::default();
        let mut scratch = Vec::new();
        for i in 0..500u32 {
            let genes = [i, i * 2, i.wrapping_mul(7) % 11];
            m.insert(PackedWords::pack(&genes), i as usize);
        }
        for i in 0..500u32 {
            let genes = [i, i * 2, i.wrapping_mul(7) % 11];
            pack_genes_into(&genes, &mut scratch);
            assert_eq!(m.get(scratch.as_slice()), Some(&(i as usize)));
            assert_eq!(hash_of(&PackedWords::pack(&genes)), hash_of(&scratch[..]));
        }
        pack_genes_into(&[9_999_999, 1, 2], &mut scratch);
        assert_eq!(m.get(scratch.as_slice()), None);
    }

    #[test]
    fn byte_tail_handling() {
        // write() must not collide trivially on short/unaligned inputs.
        // (Non-zero bytes: the zero-padded tail word makes [0x00]
        // indistinguishable from [] by design — callers that care hash a
        // length prefix, as std's slice Hash impls do.)
        let mut seen = std::collections::HashSet::new();
        for len in 0..24usize {
            let bytes: Vec<u8> = (1..=len as u8).collect();
            let mut h = FxHasher::default();
            h.write(&bytes);
            seen.insert(h.finish());
        }
        assert_eq!(seen.len(), 24);
    }
}
