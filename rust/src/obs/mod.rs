//! Observability: lock-free metrics, streaming search traces, and the
//! Prometheus exposition behind the service's `GET /metrics`.
//!
//! Three pillars (see the ROADMAP's "make the speed claims real" item —
//! this module is how every future perf PR carries honest numbers):
//!
//! 1. **Metrics** ([`metrics`]) — atomic counters/gauges and
//!    power-of-two-bucket latency histograms in a fixed-struct registry
//!    ([`Metrics`]): one process-global instance ([`global`], what the
//!    service records and serves) plus per-run `Arc<Metrics>` scopes
//!    attached through [`RunOpts::metrics`](crate::api::RunOpts). With
//!    no registry attached (the library default) the instrumented hot
//!    path is a single branch and stays zero-alloc
//!    (`rust/tests/alloc_steady_state.rs`).
//! 2. **Traces** ([`trace`]) — `sparsemap.trace.v1` NDJSON records
//!    streamed per generation through the
//!    [`SearchObserver`](crate::search::SearchObserver) machinery (`--trace run.ndjson` on `search`/`run-spec`,
//!    [`RunOpts::trace`](crate::api::RunOpts)), deterministic modulo
//!    timestamps, rendered back by `sparsemap trace summarize`.
//! 3. **Exposition** — [`Metrics::render_prometheus`] serves every
//!    series as Prometheus text at the service's auth-exempt
//!    `GET /metrics`.

pub mod metrics;
pub mod trace;

pub use metrics::{
    bucket_bound, global, Counter, Gauge, GaugeF64, HistSnapshot, Histogram, Labeled, Metrics,
    HIST_BUCKETS, HTTP_ROUTES, JOB_EVENTS, STAGE_NAMES,
};
pub use trace::{read_trace, summarize, TraceObserver, TraceWriter, TRACE_SCHEMA};
