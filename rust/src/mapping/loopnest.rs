//! Loop-nest reuse analysis: tile sizes, fetch multiplicities, spatial
//! multicast — the Timeloop-style core of the cost model.
//!
//! Terminology (see DESIGN.md §Cost model):
//! * a *tile* of tensor T at storage level S is the block of T resident in
//!   S for one iteration of the loops above S;
//! * T's tile is *refetched* across the boundary above S once per
//!   iteration of every temporal loop above S that is **relevant** to T
//!   (indexes one of T's dims) — plus once per iteration of irrelevant
//!   loops that are *outer* to a relevant one (the tile sequence repeats).
//!   A trailing run of irrelevant loops immediately above the boundary
//!   keeps the tile stationary (this is what distinguishes OS/IS/WS).

use super::{MapLevel, Mapping};
use crate::arch::Boundary;
use crate::workload::Workload;

/// One loop of the flattened nest.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Loop {
    pub dim: usize,
    pub bound: u64,
    pub level: MapLevel,
}

/// Flatten a mapping into its loop nest, outer→inner. Unit loops are
/// dropped (they carry no information).
pub fn flatten(m: &Mapping) -> Vec<Loop> {
    let mut out = Vec::new();
    for level in MapLevel::ALL {
        let li = level.index();
        for &d in &m.perm[li] {
            let bound = m.tile[li][d];
            if bound > 1 {
                out.push(Loop { dim: d, bound, level });
            }
        }
    }
    out
}

/// Mapping levels whose factors are *inside* a storage level's tile.
pub fn levels_inside(storage_tile_of: Boundary) -> &'static [usize] {
    match storage_tile_of {
        // GLB tile spans everything below L1_T.
        Boundary::DramGlb => &[1, 2, 3, 4],
        // A single PE's tile spans L3_T and L3_S (its own MACs' data);
        // L2_S partitions across PEs so it is excluded.
        Boundary::GlbPe => &[3, 4],
        // A MAC consumes single operands.
        Boundary::PeMac => &[],
    }
}

/// Temporal mapping levels *above* a boundary (whose loops drive
/// refetches across it).
pub fn temporal_levels_above(b: Boundary) -> &'static [usize] {
    match b {
        Boundary::DramGlb => &[0],
        Boundary::GlbPe => &[0, 1],
        Boundary::PeMac => &[0, 1, 3], // L2_S (2) and L3_S (4) are spatial
    }
}

/// Elements of tensor `t`'s tile at the storage level fed by boundary `b`
/// (dense count, padded dims).
pub fn tile_elems(m: &Mapping, w: &Workload, t: usize, b: Boundary) -> f64 {
    let inside = levels_inside(b);
    w.tensors[t]
        .dims
        .iter()
        .map(|&d| inside.iter().map(|&li| m.tile[li][d] as f64).product::<f64>())
        .product()
}

/// The ordered (outer→inner) temporal loops above boundary `b`.
pub fn temporal_loops_above(m: &Mapping, b: Boundary) -> Vec<Loop> {
    temporal_loops_above_from(&flatten(m), b)
}

/// As [`temporal_loops_above`] but reusing an already-flattened nest —
/// the cost-model hot path flattens once and derives all three boundary
/// lists from it.
pub fn temporal_loops_above_from(flat: &[Loop], b: Boundary) -> Vec<Loop> {
    let lvls = temporal_levels_above(b);
    flat.iter().copied().filter(|l| lvls.contains(&l.level.index())).collect()
}

/// Fetch multiplicity of input tensor `t` across boundary `b`: how many
/// times each *tile-sized* transfer happens. Implements the trailing-
/// irrelevant-loop stationarity rule.
pub fn input_multiplicity(m: &Mapping, w: &Workload, t: usize, b: Boundary) -> f64 {
    let loops = temporal_loops_above(m, b);
    multiplicity_with(&loops, |l| w.relevant(t, l.dim))
}

/// [`input_multiplicity`] over a precomputed boundary loop list.
pub fn input_multiplicity_over(loops: &[Loop], w: &Workload, t: usize) -> f64 {
    multiplicity_with(loops, |l| w.relevant(t, l.dim))
}

/// Generic multiplicity: walking inner→outer, skip the trailing loops for
/// which `relevant` is false, then multiply every remaining bound.
fn multiplicity_with(loops: &[Loop], relevant: impl Fn(&Loop) -> bool) -> f64 {
    let mut mult = 1.0;
    let mut seen_relevant = false;
    for l in loops.iter().rev() {
        if !seen_relevant && !relevant(l) {
            continue; // stationary across this loop
        }
        seen_relevant = true;
        mult *= l.bound as f64;
    }
    mult
}

/// Number of *distinct* output (Z) tiles enumerated above boundary `b`:
/// the product of Z-relevant temporal loop bounds. Contraction loops are
/// handled separately by [`psum_passes`] so they are excluded here (they
/// revisit the same tile rather than producing a new one).
pub fn output_tile_changes(m: &Mapping, w: &Workload, b: Boundary) -> f64 {
    output_tile_changes_over(&temporal_loops_above(m, b), w)
}

/// [`output_tile_changes`] over a precomputed boundary loop list.
pub fn output_tile_changes_over(loops: &[Loop], w: &Workload) -> f64 {
    let z = crate::workload::TENSOR_Z;
    loops.iter().filter(|l| w.relevant(z, l.dim)).map(|l| l.bound as f64).product()
}

/// Partial-sum passes per output tile at boundary `b`: the product of
/// contraction-loop bounds that sit *outer* to at least one Z-relevant
/// loop above the boundary. passes == 1 ⇒ output-stationary at this
/// level (psums never spill); passes == p ⇒ each tile crosses the
/// boundary `2p - 1` times (p writes, p-1 read-backs).
pub fn psum_passes(m: &Mapping, w: &Workload, b: Boundary) -> f64 {
    psum_passes_over(&temporal_loops_above(m, b), w)
}

/// [`psum_passes`] over a precomputed boundary loop list.
pub fn psum_passes_over(loops: &[Loop], w: &Workload) -> f64 {
    let z = crate::workload::TENSOR_Z;
    // Position of the innermost Z-relevant loop.
    let last_z = loops.iter().rposition(|l| w.relevant(z, l.dim));
    let Some(last_z) = last_z else {
        return 1.0; // single Z tile above this boundary
    };
    loops[..last_z]
        .iter()
        .filter(|l| w.contraction.contains(&l.dim))
        .map(|l| l.bound as f64)
        .product()
}

/// Total words of Z (dense-equivalent) crossing boundary `b`, counting
/// both psum spills and final writes.
pub fn output_traffic_elems(m: &Mapping, w: &Workload, b: Boundary) -> f64 {
    let z = crate::workload::TENSOR_Z;
    let tile = tile_elems(m, w, z, b);
    let loops = temporal_loops_above(m, b);
    tile * output_tile_changes_over(&loops, w) * (2.0 * psum_passes_over(&loops, w) - 1.0)
}

/// [`output_traffic_elems`] from precomputed pieces.
pub fn output_traffic_elems_over(loops: &[Loop], w: &Workload, tile: f64) -> f64 {
    tile * output_tile_changes_over(loops, w) * (2.0 * psum_passes_over(loops, w) - 1.0)
}

/// Spatial fan-out (number of hardware instances addressed) at a spatial
/// mapping level.
pub fn spatial_fanout(m: &Mapping, level: MapLevel) -> u64 {
    m.fanout(level)
}

/// Number of *distinct* tiles of tensor `t` across a spatial level's
/// instances; fanout / distinct = multicast width (same data broadcast).
pub fn spatial_distinct(m: &Mapping, w: &Workload, t: usize, level: MapLevel) -> u64 {
    debug_assert!(level.is_spatial());
    let li = level.index();
    (0..w.rank())
        .filter(|&d| w.relevant(t, d))
        .map(|d| m.tile[li][d])
        .product::<u64>()
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{TENSOR_P, TENSOR_Q, TENSOR_Z};

    /// M=4, K=8, N=4 SpMM with an easily-hand-checked mapping.
    fn setup() -> (Workload, Mapping) {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let m = Mapping::trivial(&w, MapLevel::L3T);
        (w, m)
    }

    #[test]
    fn flatten_order_and_unit_drop() {
        let (w, mut m) = setup();
        m.tile = vec![
            vec![2, 1, 1], // L1_T: m1=2
            vec![1, 2, 1], // L2_T: k2=2
            vec![1, 1, 2], // L2_S: n3=2
            vec![2, 4, 2], // L3_T
            vec![1, 1, 1], // L3_S
        ];
        assert!(m.respects(&w));
        let loops = flatten(&m);
        assert_eq!(loops.len(), 6);
        assert_eq!(loops[0].level, MapLevel::L1T);
        assert_eq!(loops[0].dim, 0);
        assert!(loops.iter().all(|l| l.bound > 1));
    }

    #[test]
    fn tile_sizes() {
        let (w, mut m) = setup();
        m.tile = vec![
            vec![1, 1, 1],
            vec![2, 2, 2], // L2_T
            vec![1, 1, 1],
            vec![2, 4, 2], // L3_T
            vec![1, 1, 1],
        ];
        // GLB tile of P: (m at L2T..L3S = 2*2) x (k = 2*4) = 4*8 = 32.
        assert_eq!(tile_elems(&m, &w, TENSOR_P, Boundary::DramGlb), 32.0);
        // PE tile of P: levels {L3T,L3S}: 2*4 = 8.
        assert_eq!(tile_elems(&m, &w, TENSOR_P, Boundary::GlbPe), 8.0);
        // MAC operand: 1.
        assert_eq!(tile_elems(&m, &w, TENSOR_P, Boundary::PeMac), 1.0);
    }

    #[test]
    fn stationarity_trailing_irrelevant() {
        let (w, mut m) = setup();
        // L1_T loops: order (n1, k1) outer->inner with bounds 4, 8 — all
        // tiling at L1; inner dims at L3_T unit.
        m.tile = vec![
            vec![4, 8, 4], // everything at L1_T
            vec![1, 1, 1],
            vec![1, 1, 1],
            vec![1, 1, 1],
            vec![1, 1, 1],
        ];
        m.perm[0] = vec![0, 2, 1]; // for m1 { for n1 { for k1 } }
        // P(M,K): k is innermost and relevant, so every loop counts:
        // mult = 4*4*8 = 128.
        assert_eq!(input_multiplicity(&m, &w, TENSOR_P, Boundary::DramGlb), 128.0);
        // Q(K,N): trailing relevant k counts, n relevant, m outer counts:
        // 4*4*8 = 128.
        assert_eq!(input_multiplicity(&m, &w, TENSOR_Q, Boundary::DramGlb), 128.0);
        // Z(M,N): trailing k1 is irrelevant -> stationary; mult = 4*4.
        assert_eq!(input_multiplicity(&m, &w, TENSOR_Z, Boundary::DramGlb), 16.0);

        // Now put k outermost: for k1 { for m1 { for n1 } }.
        m.perm[0] = vec![1, 0, 2];
        // P: trailing n1 irrelevant -> skip; then m1, k1 count: 8*4 = 32.
        assert_eq!(input_multiplicity(&m, &w, TENSOR_P, Boundary::DramGlb), 32.0);
        // Z: m,n relevant (trailing), k outer counts: 8*4*4 = 128.
        assert_eq!(input_multiplicity(&m, &w, TENSOR_Z, Boundary::DramGlb), 128.0);
    }

    #[test]
    fn psum_passes_output_vs_input_stationary() {
        let (w, mut m) = setup();
        m.tile =
            vec![vec![4, 8, 4], vec![1, 1, 1], vec![1, 1, 1], vec![1, 1, 1], vec![1, 1, 1]];
        // OS: k innermost above DRAM boundary -> no Z-relevant loop inside
        // k... k is inner to the last Z loop? order m,n,k: last Z loop is
        // n (pos 1), k at pos 2 is NOT outer to it -> passes 1.
        m.perm[0] = vec![0, 2, 1];
        assert_eq!(psum_passes(&m, &w, Boundary::DramGlb), 1.0);
        // k outermost: passes = 8 (each Z tile revisited per k1 step).
        m.perm[0] = vec![1, 0, 2];
        assert_eq!(psum_passes(&m, &w, Boundary::DramGlb), 8.0);
        // K-outer traffic: 16 distinct Z elements, each crossing
        // 2*8-1 = 15 times (8 spills, 7 read-backs) = 240 words.
        assert_eq!(output_traffic_elems(&m, &w, Boundary::DramGlb), 240.0);
        // OS: every Z element written exactly once.
        m.perm[0] = vec![0, 2, 1];
        assert_eq!(output_traffic_elems(&m, &w, Boundary::DramGlb), 16.0);
        assert_eq!(output_tile_changes(&m, &w, Boundary::DramGlb), 16.0);
    }

    #[test]
    fn spatial_multicast() {
        let (w, mut m) = setup();
        m.tile = vec![
            vec![1, 1, 1],
            vec![1, 1, 1],
            vec![4, 1, 2], // L2_S: m x n over PEs
            vec![1, 8, 2],
            vec![1, 1, 1],
        ];
        assert_eq!(spatial_fanout(&m, MapLevel::L2S), 8);
        // P(M,K): distinct across m=4, broadcast across n=2.
        assert_eq!(spatial_distinct(&m, &w, TENSOR_P, MapLevel::L2S), 4);
        // Q(K,N): distinct across n=2, broadcast across m=4.
        assert_eq!(spatial_distinct(&m, &w, TENSOR_Q, MapLevel::L2S), 2);
        // Z: distinct across both: 8 (no multicast).
        assert_eq!(spatial_distinct(&m, &w, TENSOR_Z, MapLevel::L2S), 8);
    }

    #[test]
    fn no_loops_means_mult_one() {
        let (w, m) = setup(); // everything at L3_T
        for t in [TENSOR_P, TENSOR_Q, TENSOR_Z] {
            assert_eq!(input_multiplicity(&m, &w, t, Boundary::DramGlb), 1.0);
        }
        assert_eq!(psum_passes(&m, &w, Boundary::DramGlb), 1.0);
    }
}
