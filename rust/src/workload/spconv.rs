//! SpConv → implicit GEMM lowering.
//!
//! The paper evaluates SpConv layers (pruned VGG16, Table III) through the
//! same mapping/sparse-strategy machinery as SpMM. We lower a convolution
//! `X[C,H,W] * W[Kout,C,R,S] -> Y[Kout,H',W']` to the implicit GEMM
//!
//! ```text
//!   P[M,K] = weights  reshaped to  [Kout, C·R·S]
//!   Q[K,N] = im2col(X)             [C·R·S, H'·W']
//!   Z[M,N] = Y                     [Kout,  H'·W']
//! ```
//!
//! Stride 1 and 'same' zero padding are assumed for odd kernels (the VGG16
//! convention); even kernels use 'valid'. This matches how the paper's
//! cost environment treats conv workloads: only the GEMM extents and the
//! operand densities matter for DSE.

use super::{Workload, WorkloadKind};

/// Convolution layer description (NCHW, single image).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConvShape {
    /// Input channels.
    pub c: u64,
    /// Input spatial height/width.
    pub h: u64,
    pub w: u64,
    /// Output channels.
    pub kout: u64,
    /// Kernel spatial size.
    pub r: u64,
    pub s: u64,
}

impl ConvShape {
    /// Output spatial extent under stride-1 'same' (odd kernel) or
    /// 'valid' (even kernel) padding.
    pub fn out_hw(&self) -> (u64, u64) {
        let oh = if self.r % 2 == 1 { self.h } else { (self.h + 1).saturating_sub(self.r) };
        let ow = if self.s % 2 == 1 { self.w } else { (self.w + 1).saturating_sub(self.s) };
        (oh.max(1), ow.max(1))
    }

    /// GEMM extents `(M, K, N)` of the implicit-GEMM lowering.
    pub fn gemm_extents(&self) -> (u64, u64, u64) {
        let (oh, ow) = self.out_hw();
        (self.kout, self.c * self.r * self.s, oh * ow)
    }
}

/// Lower a conv layer to a GEMM-shaped [`Workload`].
///
/// `d_act` is the input-activation density, `d_wgt` the weight density
/// (both from Table III). Weights become operand P, activations operand Q
/// — so "weight stationary" designs keep P resident, matching how the
/// paper discusses NVDLA-class accelerators.
pub fn lower_conv(id: &str, shape: ConvShape, d_act: f64, d_wgt: f64) -> Workload {
    let (m, k, n) = shape.gemm_extents();
    let mut w = Workload::spmm(id, m, k, n, d_wgt, d_act);
    w.kind = WorkloadKind::SpConv;
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{TENSOR_P, TENSOR_Q};

    #[test]
    fn same_padding_for_odd_kernels() {
        let s = ConvShape { c: 64, h: 32, w: 32, kout: 256, r: 3, s: 3 };
        assert_eq!(s.out_hw(), (32, 32));
        assert_eq!(s.gemm_extents(), (256, 64 * 9, 32 * 32));
    }

    #[test]
    fn valid_padding_for_even_kernels() {
        let s = ConvShape { c: 128, h: 64, w: 64, kout: 512, r: 4, s: 4 };
        assert_eq!(s.out_hw(), (61, 61));
    }

    #[test]
    fn pointwise_conv() {
        let s = ConvShape { c: 1024, h: 8, w: 8, kout: 256, r: 1, s: 1 };
        assert_eq!(s.gemm_extents(), (256, 1024, 64));
    }

    #[test]
    fn lowering_assigns_densities() {
        let s = ConvShape { c: 3, h: 32, w: 32, kout: 64, r: 3, s: 3 };
        let w = lower_conv("conv1", s, 1.0, 0.546);
        assert_eq!(w.kind, WorkloadKind::SpConv);
        assert!((w.tensors[TENSOR_P].density.avg() - 0.546).abs() < 1e-12); // weights
        assert!((w.tensors[TENSOR_Q].density.avg() - 1.0).abs() < 1e-12); // acts
        assert_eq!(w.dims[0].size, 64);
        assert_eq!(w.dims[1].size, 27);
        assert_eq!(w.dims[2].size, 1024);
    }

    #[test]
    fn degenerate_spatial_floor() {
        let s = ConvShape { c: 8, h: 2, w: 2, kout: 8, r: 4, s: 4 };
        let (oh, ow) = s.out_hw();
        assert!(oh >= 1 && ow >= 1);
    }
}
