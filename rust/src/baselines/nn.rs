//! A minimal fully-connected neural network with SGD — substrate for the
//! DQN and PPO baselines (the offline vendor set has no ML framework).
//!
//! One hidden layer, ReLU, He initialization, mean-squared-error loss,
//! plain SGD with gradient clipping. Sized for the tiny function
//! approximation these baselines need (tens of inputs, tens of outputs).

use crate::util::rng::Pcg64;

/// A 2-layer MLP: `out = W2·relu(W1·x + b1) + b2`.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    w1: Vec<f64>, // [hidden, in]
    b1: Vec<f64>,
    w2: Vec<f64>, // [out, hidden]
    b2: Vec<f64>,
}

impl Mlp {
    pub fn new(n_in: usize, n_hidden: usize, n_out: usize, rng: &mut Pcg64) -> Mlp {
        let he1 = (2.0 / n_in as f64).sqrt();
        let he2 = (2.0 / n_hidden as f64).sqrt();
        Mlp {
            n_in,
            n_hidden,
            n_out,
            w1: (0..n_hidden * n_in).map(|_| rng.normal() * he1).collect(),
            b1: vec![0.0; n_hidden],
            w2: (0..n_out * n_hidden).map(|_| rng.normal() * he2).collect(),
            b2: vec![0.0; n_out],
        }
    }

    /// Forward pass; returns (hidden activations, outputs).
    fn forward_full(&self, x: &[f64]) -> (Vec<f64>, Vec<f64>) {
        debug_assert_eq!(x.len(), self.n_in);
        let mut h = vec![0.0; self.n_hidden];
        for i in 0..self.n_hidden {
            let mut acc = self.b1[i];
            let row = &self.w1[i * self.n_in..(i + 1) * self.n_in];
            for (w, xv) in row.iter().zip(x) {
                acc += w * xv;
            }
            h[i] = acc.max(0.0); // ReLU
        }
        let mut y = vec![0.0; self.n_out];
        for o in 0..self.n_out {
            let mut acc = self.b2[o];
            let row = &self.w2[o * self.n_hidden..(o + 1) * self.n_hidden];
            for (w, hv) in row.iter().zip(&h) {
                acc += w * hv;
            }
            y[o] = acc;
        }
        (h, y)
    }

    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        self.forward_full(x).1
    }

    /// One SGD step on ½‖y − target‖² restricted to output `out_idx`
    /// (Q-learning style single-action update). Returns the squared error
    /// before the update.
    pub fn sgd_step(&mut self, x: &[f64], out_idx: usize, target: f64, lr: f64) -> f64 {
        let (h, y) = self.forward_full(x);
        let err = y[out_idx] - target;
        let g_out = err.clamp(-1.0, 1.0); // gradient clipping (Huber-ish)

        // Output layer grads.
        for j in 0..self.n_hidden {
            let g = g_out * h[j];
            self.w2[out_idx * self.n_hidden + j] -= lr * g;
        }
        self.b2[out_idx] -= lr * g_out;

        // Hidden layer grads (through ReLU).
        for j in 0..self.n_hidden {
            if h[j] <= 0.0 {
                continue;
            }
            let gh = g_out * self.w2[out_idx * self.n_hidden + j];
            for k in 0..self.n_in {
                self.w1[j * self.n_in + k] -= lr * gh * x[k];
            }
            self.b1[j] -= lr * gh;
        }
        err * err
    }
}

/// Numerically-stable softmax.
pub fn softmax(logits: &[f64]) -> Vec<f64> {
    let m = logits.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|l| (l - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Sample an index from a probability vector.
pub fn sample_categorical(probs: &[f64], rng: &mut Pcg64) -> usize {
    let u = rng.f64();
    let mut acc = 0.0;
    for (i, p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_a_linear_function() {
        // y = 2*x0 - x1; the MLP should fit it from samples.
        let mut rng = Pcg64::seeded(5);
        let mut net = Mlp::new(2, 16, 1, &mut rng);
        for _ in 0..4_000 {
            let x = [rng.f64() * 2.0 - 1.0, rng.f64() * 2.0 - 1.0];
            let t = 2.0 * x[0] - x[1];
            net.sgd_step(&x, 0, t, 0.02);
        }
        let mut worst: f64 = 0.0;
        for _ in 0..50 {
            let x = [rng.f64() * 2.0 - 1.0, rng.f64() * 2.0 - 1.0];
            let t = 2.0 * x[0] - x[1];
            worst = worst.max((net.forward(&x)[0] - t).abs());
        }
        assert!(worst < 0.25, "worst abs err = {worst}");
    }

    #[test]
    fn multi_output_independent_updates() {
        let mut rng = Pcg64::seeded(6);
        let mut net = Mlp::new(1, 8, 3, &mut rng);
        for _ in 0..3_000 {
            let x = [rng.f64()];
            net.sgd_step(&x, 1, 5.0, 0.05); // only output 1 trained
        }
        let y = net.forward(&[0.5]);
        assert!((y[1] - 5.0).abs() < 0.5, "y1={}", y[1]);
    }

    #[test]
    fn softmax_properties() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // Stability with huge logits.
        let q = softmax(&[1000.0, 1000.0]);
        assert!((q[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn categorical_sampling_distribution() {
        let mut rng = Pcg64::seeded(7);
        let probs = [0.1, 0.6, 0.3];
        let mut counts = [0usize; 3];
        for _ in 0..6_000 {
            counts[sample_categorical(&probs, &mut rng)] += 1;
        }
        assert!((counts[1] as f64 / 6_000.0 - 0.6).abs() < 0.05);
    }
}
