//! Deterministic ANN index over scenario embeddings.
//!
//! Random-hyperplane LSH with a brute-force fallback, split the
//! classic way: **build** (derive the pinned hyperplane set), **storage**
//! (bucket table + id-indexed embedding list) and **incremental insert**
//! (one signature + one bucket push per record, no rebuild). The
//! hyperplanes are drawn once from a pinned-seed generator, so the same
//! corpus always produces the same index and the same query results —
//! warm-started searches stay reproducible.
//!
//! Small corpora (≤ [`BRUTE_FORCE_LIMIT`]) are answered by exact scan:
//! below that size the LSH machinery saves nothing, and exactness there
//! keeps seeding behaviour easy to reason about. Above it, buckets are
//! probed in growing Hamming radius around the query signature and the
//! candidate set is re-ranked exactly; if probing comes up short the
//! query degrades to the exact scan rather than returning a thin answer.

use super::embed::{dist2, EMBED_DIM};
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// Number of LSH hyperplanes (signature bits).
pub const NUM_PLANES: usize = 16;
/// Corpus size up to which queries are answered by exact scan.
pub const BRUTE_FORCE_LIMIT: usize = 512;
/// Pinned seed for the hyperplane set; part of query determinism.
const PLANES_SEED: u64 = 0x5bab_5e3d_0a11_4c3e;

/// ANN index: hyperplanes are fixed at construction, contents grow by
/// [`AnnIndex::insert`].
pub struct AnnIndex {
    planes: Vec<[f64; EMBED_DIM]>,
    /// Embeddings by record id (insert order).
    embeds: Vec<[f64; EMBED_DIM]>,
    /// LSH signature -> record ids, in increasing id order (ids are
    /// pushed as they are inserted, so incremental insertion and batch
    /// build produce identical tables).
    buckets: BTreeMap<u16, Vec<u32>>,
}

impl AnnIndex {
    /// Build an empty index with the pinned hyperplane set.
    pub fn new() -> AnnIndex {
        let mut rng = Pcg64::seeded(PLANES_SEED);
        let mut planes = Vec::with_capacity(NUM_PLANES);
        for _ in 0..NUM_PLANES {
            let mut p = [0.0f64; EMBED_DIM];
            for x in p.iter_mut() {
                *x = rng.normal();
            }
            planes.push(p);
        }
        AnnIndex { planes, embeds: Vec::new(), buckets: BTreeMap::new() }
    }

    /// Build from a batch of embeddings (equivalent to `new` + inserts).
    pub fn build(embeds: &[[f64; EMBED_DIM]]) -> AnnIndex {
        let mut ix = AnnIndex::new();
        for e in embeds {
            ix.insert(*e);
        }
        ix
    }

    pub fn len(&self) -> usize {
        self.embeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.embeds.is_empty()
    }

    /// Sign-bit signature of an embedding under the pinned planes.
    pub fn signature(&self, e: &[f64; EMBED_DIM]) -> u16 {
        let mut sig = 0u16;
        for (bit, p) in self.planes.iter().enumerate() {
            let dot: f64 = p.iter().zip(e).map(|(a, b)| a * b).sum();
            if dot >= 0.0 {
                sig |= 1 << bit;
            }
        }
        sig
    }

    /// Insert one embedding; returns its id. O(planes) — no rebuild.
    pub fn insert(&mut self, e: [f64; EMBED_DIM]) -> u32 {
        let id = self.embeds.len() as u32;
        let sig = self.signature(&e);
        self.embeds.push(e);
        self.buckets.entry(sig).or_default().push(id);
        id
    }

    /// Ids of the `k` nearest stored embeddings, closest first; ties
    /// broken by id so results are fully deterministic.
    pub fn query(&self, e: &[f64; EMBED_DIM], k: usize) -> Vec<u32> {
        if k == 0 || self.embeds.is_empty() {
            return Vec::new();
        }
        // The process-global metrics split answered queries into ANN
        // bucket probes vs exact scans — the ratio shows when a store
        // has outgrown `BRUTE_FORCE_LIMIT` and the LSH path earns keep.
        let m = crate::obs::global();
        if self.embeds.len() <= BRUTE_FORCE_LIMIT {
            m.memory_exact_scans.inc();
            return self.rank(e, (0..self.embeds.len() as u32).collect(), k);
        }
        // Multi-probe: expand Hamming radius until enough candidates.
        let want = (4 * k).max(32);
        let sig = self.signature(e);
        let mut cands: Vec<u32> = Vec::new();
        for radius in 0..=2u32 {
            for (&bucket_sig, ids) in &self.buckets {
                if (bucket_sig ^ sig).count_ones() == radius {
                    cands.extend_from_slice(ids);
                }
            }
            if cands.len() >= want {
                break;
            }
        }
        if cands.len() < k {
            // Sparse neighbourhood: degrade to exact rather than thin.
            m.memory_exact_scans.inc();
            return self.rank(e, (0..self.embeds.len() as u32).collect(), k);
        }
        m.memory_ann_probes.inc();
        self.rank(e, cands, k)
    }

    fn rank(&self, e: &[f64; EMBED_DIM], mut ids: Vec<u32>, k: usize) -> Vec<u32> {
        ids.sort_unstable();
        ids.dedup();
        ids.sort_by(|&a, &b| {
            let da = dist2(e, &self.embeds[a as usize]);
            let db = dist2(e, &self.embeds[b as usize]);
            da.total_cmp(&db).then(a.cmp(&b))
        });
        ids.truncate(k);
        ids
    }

    /// Exact k-nearest by full scan — the reference answer the ANN path
    /// is tested against.
    pub fn brute_force(&self, e: &[f64; EMBED_DIM], k: usize) -> Vec<u32> {
        self.rank(e, (0..self.embeds.len() as u32).collect(), k.min(self.embeds.len()))
    }
}

impl Default for AnnIndex {
    fn default() -> AnnIndex {
        AnnIndex::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_embed(rng: &mut Pcg64) -> [f64; EMBED_DIM] {
        let mut e = [0.0f64; EMBED_DIM];
        for x in e.iter_mut() {
            *x = rng.normal();
        }
        let n = e.iter().map(|x| x * x).sum::<f64>().sqrt();
        for x in e.iter_mut() {
            *x /= n;
        }
        e
    }

    #[test]
    fn query_matches_brute_force_on_small_corpus() {
        let mut rng = Pcg64::seeded(42);
        let pts: Vec<_> = (0..64).map(|_| rand_embed(&mut rng)).collect();
        let ix = AnnIndex::build(&pts);
        for _ in 0..16 {
            let q = rand_embed(&mut rng);
            assert_eq!(ix.query(&q, 5), ix.brute_force(&q, 5));
        }
    }

    #[test]
    fn incremental_insert_matches_batch_build() {
        let mut rng = Pcg64::seeded(7);
        let pts: Vec<_> = (0..100).map(|_| rand_embed(&mut rng)).collect();
        let batch = AnnIndex::build(&pts);
        let mut inc = AnnIndex::new();
        for p in &pts {
            inc.insert(*p);
        }
        assert_eq!(batch.len(), inc.len());
        let q = rand_embed(&mut rng);
        assert_eq!(batch.query(&q, 9), inc.query(&q, 9));
        assert_eq!(batch.buckets, inc.buckets);
    }

    #[test]
    fn query_is_deterministic_and_ordered() {
        let mut rng = Pcg64::seeded(3);
        let pts: Vec<_> = (0..32).map(|_| rand_embed(&mut rng)).collect();
        let ix = AnnIndex::build(&pts);
        let q = rand_embed(&mut rng);
        let a = ix.query(&q, 8);
        assert_eq!(a, ix.query(&q, 8));
        let dists: Vec<f64> = a.iter().map(|&i| dist2(&q, &pts[i as usize])).collect();
        assert!(dists.windows(2).all(|w| w[0] <= w[1]), "not sorted: {dists:?}");
        // k larger than the corpus returns everything.
        assert_eq!(ix.query(&q, 1000).len(), 32);
        assert!(AnnIndex::new().query(&q, 5).is_empty());
    }

    #[test]
    fn signatures_are_stable_across_instances() {
        // The hyperplane set is pinned: two fresh indices agree on every
        // signature, which is what makes stored files replayable.
        let mut rng = Pcg64::seeded(11);
        let a = AnnIndex::new();
        let b = AnnIndex::new();
        for _ in 0..20 {
            let e = rand_embed(&mut rng);
            assert_eq!(a.signature(&e), b.signature(&e));
        }
    }
}
