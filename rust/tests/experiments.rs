//! Experiment-shape tests: scaled-down versions of every paper artifact,
//! asserting the *qualitative* reproduction targets (who wins, where
//! crossovers fall) rather than absolute numbers.

use sparsemap::arch::Platform;
use sparsemap::baselines::DirectSpec;
use sparsemap::optimizer::run_method;
use sparsemap::report::{fig10, fig17, fig18, fig2, fig7, table4, ExpConfig};
use sparsemap::search::{Backend, EvalContext};
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::table3;

fn cfg(budget: usize, seed: u64) -> ExpConfig {
    ExpConfig {
        budget,
        seed,
        out_dir: std::env::temp_dir().join("sm_experiments"),
        threads: 8,
        ..Default::default()
    }
}

// --- E1 / Fig. 2 -----------------------------------------------------------

#[test]
fn e1_no_universal_winner() {
    let winners = fig2::winners(&cfg(0, 1));
    let distinct: std::collections::HashSet<&str> =
        winners.iter().map(|&(_, a)| a).collect();
    assert!(distinct.len() >= 2, "single universal winner: {winners:?}");
}

// --- E2 / Fig. 7 -----------------------------------------------------------

#[test]
fn e2_invalid_points_dominate_joint_space() {
    let pts = fig7::sample(&cfg(0, 2), 500);
    let valid = pts.iter().filter(|p| p.valid).count();
    assert!(valid > 0);
    assert!(valid < pts.len() / 2, "{valid}/{} valid", pts.len());
}

// --- E3 / Fig. 10 ----------------------------------------------------------

#[test]
fn e3_cantor_beats_random_encoding_majority() {
    let mut wins = 0;
    for seed in [31, 32, 33] {
        let (c, r) = fig10::run_arms(&cfg(1_500, seed));
        if c.best_edp <= r.best_edp * 1.1 {
            wins += 1;
        }
    }
    assert!(wins >= 2, "cantor won {wins}/3");
}

// --- E4 / Fig. 17a ---------------------------------------------------------

#[test]
fn e4_sparsemap_wins_on_vgg_layers() {
    let outcomes = fig17::run_matrix(&cfg(1_200, 4), &Platform::cloud(), &["conv11", "conv12"]);
    for layer in ["conv11", "conv12"] {
        let ours = outcomes
            .iter()
            .find(|o| o.workload == layer && o.method == "sparsemap")
            .unwrap();
        assert!(ours.found_valid(), "sparsemap found nothing on {layer}");
        let mut beaten = 0;
        let mut total = 0;
        for o in outcomes.iter().filter(|o| o.workload == layer && o.method != "sparsemap") {
            total += 1;
            if ours.best_edp <= o.best_edp {
                beaten += 1;
            }
        }
        // At this scaled-down budget SparseMap must beat the majority of
        // baselines per layer (at the paper's 20k budget it wins 12/13
        // layers outright — see EXPERIMENTS.md E4).
        assert!(
            beaten * 10 >= total * 6,
            "{layer}: sparsemap beat only {beaten}/{total} baselines"
        );
    }
}

// --- E5 / Fig. 17b ---------------------------------------------------------

#[test]
fn e5_sparsemap_valid_ratio_leads() {
    let outcomes = fig17::run_matrix(&cfg(1_000, 5), &Platform::cloud(), &["conv11"]);
    let ours = outcomes.iter().find(|o| o.method == "sparsemap").unwrap().valid_ratio();
    let mean_baseline: f64 = outcomes
        .iter()
        .filter(|o| o.method != "sparsemap")
        .map(|o| o.valid_ratio())
        .sum::<f64>()
        / (outcomes.len() - 1) as f64;
    assert!(
        ours >= mean_baseline,
        "sparsemap valid ratio {ours:.3} below baseline mean {mean_baseline:.3}"
    );
}

// --- E6/E9 / Table IV -------------------------------------------------------

#[test]
fn e6_sparsemap_wins_table4_subset_on_all_platforms() {
    let cells = table4::run_matrix(
        &cfg(2_500, 6),
        &vec!["mm3".to_string(), "conv11".to_string(), "mm12".to_string()],
    );
    for plat in ["edge", "mobile", "cloud"] {
        for baseline in ["sage-like", "sparseloop"] {
            let r = table4::reduction(&cells, baseline, plat);
            assert!(
                r >= 0.9,
                "sparsemap lost to {baseline} on {plat}: geomean {r:.3}"
            );
        }
    }
}

// --- E7 / Fig. 18 ----------------------------------------------------------

#[test]
fn e7_ablation_validity_ordering() {
    let cfg = cfg(1_800, 7);
    let w = table3::by_id("mm3").unwrap();
    let run = |m: &str| {
        let ctx = EvalContext::new(Backend::native(w.clone(), Platform::cloud()), cfg.budget);
        run_method(m, ctx, cfg.seed).unwrap()
    };
    let direct = run("es-direct");
    let pfce = run("es-pfce");
    let full = run("sparsemap");
    // PFCE eliminates tiling-dead individuals entirely -> strictly more
    // valid exploration than the direct encoding.
    assert!(pfce.valid_ratio() > direct.valid_ratio() * 1.5);
    // Full SparseMap converges at least as well as the direct arm.
    assert!(full.best_edp <= direct.best_edp);
    let _ = fig18::ABLATION_ARMS;
}

// --- E8 / calibration overhead ----------------------------------------------

#[test]
fn e8_hshi_overhead_under_ten_percent() {
    use sparsemap::es::{run_sparsemap, EsConfig};
    let w = table3::by_id("mm3").unwrap();
    let budget = 5_000;
    let ctx = EvalContext::new(Backend::native(w.clone(), Platform::cloud()), budget);
    // Run calibration alone and check its share.
    let mut ctx2 = EvalContext::new(Backend::native(w, Platform::cloud()), budget);
    let mut rng = Pcg64::seeded(8);
    let mut calib = sparsemap::es::CalibConfig::default();
    calib.max_evals = budget / 10;
    let sens = sparsemap::es::sensitivity::calibrate(&mut ctx2, calib, &mut rng);
    assert!(
        sens.evals_spent <= budget / 10,
        "calibration used {} of {budget}",
        sens.evals_spent
    );
    // And the full search still uses its entire budget productively.
    let o = run_sparsemap(ctx, EsConfig::default(), 8);
    assert!(o.evals >= budget * 9 / 10);
}

// --- paper's 0.000023% direct-encoding argument ------------------------------

#[test]
fn direct_encoding_tiling_hit_rate_is_tiny() {
    // §IV.B: for a 4x8x4 toy the paper counts 7875 valid of 4^5*8^5*4^5.
    // Our uniform sampler over the direct space should land well under 1%.
    let w = sparsemap::workload::Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
    let spec = DirectSpec::new(&w, 3);
    let mut rng = Pcg64::seeded(9);
    let rate = spec.tiling_hit_rate(&w, 5_000, &mut rng);
    assert!(rate < 0.01, "hit rate {rate}");
}
