//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The SparseMap build environment is fully offline, so this in-tree shim
//! provides the subset of the `anyhow` 1.x API the codebase uses:
//!
//! * [`Error`] / [`Result`] with `?`-conversion from any
//!   `std::error::Error + Send + Sync + 'static`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros (format-string
//!   forms),
//! * the [`Context`] extension trait (`.context(..)` / `.with_context(..)`)
//!   on `Result` and `Option`.
//!
//! Errors carry a flat chain of human-readable frames (outermost context
//! first); `Display` prints the outermost frame and `Debug` prints the
//! whole chain `anyhow`-style, so CLI error output stays familiar.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A lightweight error: an ordered chain of message frames, outermost
/// context first.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// Iterate over the frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost frame (the original cause).
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.frames.first().map(|s| s.as_str()).unwrap_or("unknown error"))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.frames.first().map(|s| s.as_str()).unwrap_or("unknown error"))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`, which makes
// this blanket conversion coherent (the same trick the real crate uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            frames.push(s.to_string());
            source = s.source();
        }
        Error { frames }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    /// Attach a context frame to the error, if any.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-evaluated context frame to the error, if any.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tokens:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tokens)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($tokens:tt)*) => {
        if !($cond) {
            $crate::bail!($($tokens)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_stacks_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(e.root_cause(), "missing file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert!(none.context("empty").is_err());
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        fn guarded(v: u32) -> Result<u32> {
            ensure!(v < 10, "v too big: {v}");
            Ok(v)
        }
        assert!(guarded(3).is_ok());
        assert_eq!(guarded(12).unwrap_err().to_string(), "v too big: 12");
    }
}
