//! Integration tests for the `sparsemap::api` front door: JSON
//! round-trips, custom-spec validation, and bit-for-bit parity between
//! the API path and the raw seed-era wiring.

use sparsemap::api::{SearchReport, SearchRequest};
use sparsemap::arch::Platform;
use sparsemap::optimizer::run_method;
use sparsemap::search::{Backend, EvalContext};
use sparsemap::util::json::Json;
use sparsemap::workload::spec::workload_from_spec;
use sparsemap::workload::{table3, Workload, WorkloadKind};

/// A workload/platform pair that exists nowhere in the paper's tables.
fn custom_pair() -> (Workload, Platform) {
    let w = Workload::custom(
        "offmenu_mm",
        WorkloadKind::SpMM,
        vec![("M".into(), 96), ("K".into(), 192), ("N".into(), 80)],
        vec![
            ("P".into(), vec![0, 1], 0.35),
            ("Q".into(), vec![1, 2], 0.15),
            ("Z".into(), vec![0, 2], 0.0),
        ],
        vec![1],
    )
    .unwrap();
    let p = Platform::custom("offmenu", 12, 12, 8, 8 << 10, 2 << 20, 12e9, 6e8, 64.0, 16.0)
        .unwrap();
    (w, p)
}

#[test]
fn api_search_matches_seed_path_bit_for_bit() {
    // The seed-era wiring: hand-built backend + context + run_method.
    let w = table3::by_id("mm3").unwrap();
    let plat = Platform::cloud();
    let ctx = EvalContext::new(Backend::native(w, plat), 400);
    let seed_path = run_method("sparsemap", ctx, 42).unwrap();

    // The same arm through the API.
    let api_path = SearchRequest::new()
        .workload_named("mm3")
        .platform_named("cloud")
        .budget(400)
        .seed(42)
        .build()
        .unwrap()
        .run()
        .unwrap()
        .into_outcome();

    assert_eq!(api_path.best_edp.to_bits(), seed_path.best_edp.to_bits());
    assert_eq!(api_path.best_genome, seed_path.best_genome);
    assert_eq!(api_path.curve, seed_path.curve);
    assert_eq!(api_path.evals, seed_path.evals);
    assert_eq!(api_path.cache_hits, seed_path.cache_hits);
}

#[test]
fn custom_pair_runs_end_to_end_with_json_round_trip() {
    let (w, p) = custom_pair();
    let report = SearchRequest::new()
        .workload(w)
        .platform(p)
        .method("sparsemap")
        .budget(600)
        .seed(3)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(report.outcome.workload, "offmenu_mm");
    assert_eq!(report.outcome.platform, "offmenu");
    assert!(report.outcome.evals <= 600);
    assert!(report.outcome.best_edp.is_finite(), "found a valid design");

    let parsed = SearchReport::from_json(&Json::parse(&report.to_json().pretty()).unwrap())
        .unwrap();
    assert_eq!(parsed.request, report.request);
    assert_eq!(parsed.outcome.best_edp.to_bits(), report.outcome.best_edp.to_bits());
    assert_eq!(parsed.outcome.best_genome, report.outcome.best_genome);
    assert_eq!(parsed.to_json(), report.to_json());
}

#[test]
fn spec_file_request_round_trips_and_runs() {
    // The same shape a `run-spec` file has: custom workload + platform,
    // defined only in JSON.
    let src = r#"{
        "workload": {
            "id": "spec_only",
            "kind": "SpMM",
            "dims": [{"name": "M", "size": 64}, {"name": "K", "size": 96},
                     {"name": "N", "size": 48}],
            "tensors": [
                {"name": "P", "dims": ["M", "K"], "density": 0.4},
                {"name": "Q", "dims": ["K", "N"], "density": 0.3},
                {"name": "Z", "dims": ["M", "N"]}
            ],
            "contraction": ["K"]
        },
        "platform": {
            "name": "spec_plat", "pe_rows": 8, "pe_cols": 16, "macs_per_pe": 2,
            "pe_buf_kib": 4, "glb_kib": 512, "dram_gbps": 6, "clock_ghz": 0.7,
            "glb_bw_words_per_cycle": 48, "pe_bw_words_per_cycle": 8
        },
        "method": "random",
        "budget": 200,
        "seed": 9
    }"#;
    let req = SearchRequest::from_json(&Json::parse(src).unwrap()).unwrap();
    let reparsed = Json::parse(&req.to_json().dumps()).unwrap();
    assert_eq!(SearchRequest::from_json(&reparsed).unwrap(), req);

    let report = req.build().unwrap().run().unwrap();
    assert_eq!(report.outcome.workload, "spec_only");
    assert_eq!(report.outcome.platform, "spec_plat");
    assert_eq!(report.outcome.evals, 200);
    let rt = SearchReport::from_json(&Json::parse(&report.to_json().dumps()).unwrap()).unwrap();
    assert_eq!(rt.to_json(), report.to_json());
}

#[test]
fn structured_density_spec_runs_end_to_end() {
    // Object-form densities (block P, banded Q) through the same JSON
    // path `run-spec` uses: parse, round-trip, search, report round-trip.
    let src = r#"{
        "workload": {
            "id": "block_spec",
            "kind": "SpMM",
            "dims": [{"name": "M", "size": 64}, {"name": "K", "size": 128},
                     {"name": "N", "size": 48}],
            "tensors": [
                {"name": "P", "dims": ["M", "K"],
                 "density": {"kind": "block", "block": 16, "density": 0.2}},
                {"name": "Q", "dims": ["K", "N"],
                 "density": {"kind": "banded", "bandwidth": 12}},
                {"name": "Z", "dims": ["M", "N"]}
            ],
            "contraction": ["K"]
        },
        "platform": "mobile",
        "method": "sparsemap",
        "budget": 300,
        "seed": 7
    }"#;
    let req = SearchRequest::from_json(&Json::parse(src).unwrap()).unwrap();
    let rt = Json::parse(&req.to_json().dumps()).unwrap();
    assert_eq!(SearchRequest::from_json(&rt).unwrap(), req);
    let report = req.build().unwrap().run().unwrap();
    assert_eq!(report.outcome.workload, "block_spec");
    assert!(report.outcome.evals <= 300);
    let parsed =
        SearchReport::from_json(&Json::parse(&report.to_json().pretty()).unwrap()).unwrap();
    assert_eq!(parsed.to_json(), report.to_json());
}

#[test]
fn method_opts_spec_runs_end_to_end_and_round_trips() {
    // The exact shape a tuned `run-spec` file has: method_opts riding
    // next to the method, surviving request -> report -> JSON -> request.
    let src = r#"{
        "workload": "mm1",
        "platform": "mobile",
        "method": "pso",
        "method_opts": {"swarm": 16, "inertia": 0.6},
        "budget": 150,
        "seed": 4
    }"#;
    let req = SearchRequest::from_json(&Json::parse(src).unwrap()).unwrap();
    let reparsed = Json::parse(&req.to_json().dumps()).unwrap();
    assert_eq!(SearchRequest::from_json(&reparsed).unwrap(), req);
    let report = req.build().unwrap().run().unwrap();
    assert_eq!(report.outcome.method, "pso");
    assert_eq!(report.outcome.evals, 150);
    let rt = SearchReport::from_json(&Json::parse(&report.to_json().dumps()).unwrap()).unwrap();
    assert_eq!(rt.request.method_opts, report.request.method_opts);
    assert_eq!(rt.to_json(), report.to_json());

    // Unknown tunables in a spec fail at build with a suggestion.
    let bad = src.replace("swarm", "swarn");
    let req = SearchRequest::from_json(&Json::parse(&bad).unwrap()).unwrap();
    let err = req.build().unwrap_err().to_string();
    assert!(err.contains("swarn"), "{err}");
    assert!(err.contains("did you mean 'swarm'"), "{err}");
}

#[test]
fn portfolio_runs_through_the_api_on_a_custom_scenario() {
    let (w, p) = custom_pair();
    let report = SearchRequest::new()
        .workload(w)
        .platform(p)
        .method("portfolio")
        .method_opts(
            Json::parse(r#"{"members": ["sparsemap", "random"], "rounds": 2}"#).unwrap(),
        )
        .budget(500)
        .seed(6)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(report.outcome.method, "portfolio");
    assert!(report.outcome.evals <= 500);
    let members = report.members();
    assert_eq!(members.len(), 2);
    assert_eq!(members.iter().map(|m| m.evals).sum::<usize>(), report.outcome.evals);
    // Full JSON round trip keeps the member breakdown.
    let parsed =
        SearchReport::from_json(&Json::parse(&report.to_json().pretty()).unwrap()).unwrap();
    assert_eq!(parsed.outcome.members, report.outcome.members);
    assert_eq!(parsed.to_json(), report.to_json());
}

#[test]
fn workload_spec_validation_errors() {
    let base = r#"{
        "id": "v", "kind": "SpMM",
        "dims": [{"name": "M", "size": 8}, {"name": "K", "size": 8},
                 {"name": "N", "size": 8}],
        "tensors": [
            {"name": "P", "dims": ["M", "K"], "density": 0.5},
            {"name": "Q", "dims": ["K", "N"], "density": 0.5},
            {"name": "Z", "dims": ["M", "N"]}
        ],
        "contraction": ["K"]
    }"#;
    assert!(workload_from_spec(&Json::parse(base).unwrap()).is_ok());
    // Bad dim reference.
    let bad_ref = base.replace(r#"["M", "K"]"#, r#"["M", "Bogus"]"#);
    assert!(workload_from_spec(&Json::parse(&bad_ref).unwrap()).is_err());
    // Zero density.
    let zero_density = base.replace("0.5", "0");
    assert!(workload_from_spec(&Json::parse(&zero_density).unwrap()).is_err());
    // Zero-size dimension.
    let zero_dim = base.replace(r#"{"name": "K", "size": 8}"#, r#"{"name": "K", "size": 0}"#);
    assert!(workload_from_spec(&Json::parse(&zero_dim).unwrap()).is_err());
}

#[test]
fn builder_validation_errors() {
    // Zero density through the builder.
    assert!(Workload::custom(
        "w",
        WorkloadKind::SpMM,
        vec![("M".into(), 8), ("K".into(), 8), ("N".into(), 8)],
        vec![
            ("P".into(), vec![0, 1], 0.0),
            ("Q".into(), vec![1, 2], 0.5),
            ("Z".into(), vec![0, 2], 0.0),
        ],
        vec![1],
    )
    .is_err());
    // Out-of-range dim index.
    assert!(Workload::custom(
        "w",
        WorkloadKind::SpMM,
        vec![("M".into(), 8), ("K".into(), 8), ("N".into(), 8)],
        vec![
            ("P".into(), vec![0, 7], 0.5),
            ("Q".into(), vec![1, 2], 0.5),
            ("Z".into(), vec![0, 2], 0.0),
        ],
        vec![1],
    )
    .is_err());
    // Non-positive PE grid.
    assert!(Platform::custom("p", 16, 0, 1, 1 << 10, 128 << 10, 1e9, 2e8, 8.0, 2.0).is_err());
    // A request wrapping an invalid custom platform fails at build().
    let mut bad = Platform::mobile();
    bad.pe_rows = 0;
    assert!(SearchRequest::new().platform(bad).budget(10).build().is_err());
}

#[test]
fn named_request_unknown_ids_fail_at_build() {
    assert!(SearchRequest::new().workload_named("mm999").budget(10).build().is_err());
    assert!(SearchRequest::new().platform_named("datacenter").budget(10).build().is_err());
    assert!(SearchRequest::new().method("annealing").budget(10).build().is_err());
}
