//! Integer factorization helpers for prime-factor genome encoding.
//!
//! Dimension sizes are decomposed into prime factors; each factor becomes
//! one gene that selects the mapping level it is assigned to (§IV.B of the
//! paper). Large prime dimensions are padded to the nearest larger
//! composite so they can be tiled ("input tensors may be padded in
//! practical scenarios").

/// Trial-division primality test; dimension sizes are ≤ ~10^5 so this is
/// more than fast enough.
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    if n % 2 == 0 {
        return n == 2;
    }
    let mut d = 3;
    while d * d <= n {
        if n % d == 0 {
            return false;
        }
        d += 2;
    }
    true
}

/// Prime factorization in non-decreasing order. `factorize(1) == []`.
pub fn factorize(mut n: u64) -> Vec<u64> {
    assert!(n >= 1, "factorize(0)");
    let mut out = Vec::new();
    let mut d = 2;
    while d * d <= n {
        while n % d == 0 {
            out.push(d);
            n /= d;
        }
        d += if d == 2 { 1 } else { 2 };
    }
    if n > 1 {
        out.push(n);
    }
    out
}

/// Pad a dimension size for tiling, following the paper's rule: a *large
/// prime* dimension is replaced by the nearest larger composite number.
/// Small primes (≤ 7) are left alone — they tile fine as a single factor.
pub fn pad_dimension(n: u64) -> u64 {
    if n <= 7 || !is_prime(n) {
        return n;
    }
    let mut m = n + 1;
    while is_prime(m) {
        m += 1;
    }
    m
}

/// Number of trailing padded elements introduced by [`pad_dimension`].
pub fn padding_of(n: u64) -> u64 {
    pad_dimension(n) - n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primality() {
        let primes = [2u64, 3, 5, 7, 11, 73, 9973];
        let composites = [1u64, 4, 6, 9, 100, 730, 9975];
        for p in primes {
            assert!(is_prime(p), "{p}");
        }
        for c in composites {
            assert!(!is_prime(c), "{c}");
        }
    }

    #[test]
    fn factorization_roundtrip() {
        for n in 1..2000u64 {
            let fs = factorize(n);
            assert_eq!(fs.iter().product::<u64>(), n.max(1));
            for f in &fs {
                assert!(is_prime(*f));
            }
            // Non-decreasing.
            assert!(fs.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn padding_rules() {
        assert_eq!(pad_dimension(2), 2); // small primes untouched
        assert_eq!(pad_dimension(7), 7);
        assert_eq!(pad_dimension(11), 12);
        assert_eq!(pad_dimension(12), 12); // composites untouched
        assert_eq!(pad_dimension(73), 74);
        assert_eq!(padding_of(13), 1); // 13 -> 14
    }

    #[test]
    fn padded_always_composite_or_small() {
        for n in 1..5000u64 {
            let p = pad_dimension(n);
            assert!(p >= n);
            assert!(p <= 7 || !is_prime(p), "pad({n}) = {p} is prime");
        }
    }
}
