"""Pallas cost kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps feature magnitudes (traffic counts span ~15 orders of
magnitude across Table III workloads) and batch shapes; assert_allclose
against ref.cost_eval_ref.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cost_kernel, ref


def make_platform(rng):
    """A plausible random platform vector (positive constants)."""
    p = np.zeros(ref.NUM_PLATFORM_FEATURES, dtype=np.float32)
    p[0] = rng.uniform(50, 400)       # e_dram
    p[1] = rng.uniform(2, 40)         # e_glb
    p[2] = rng.uniform(0.5, 3)        # e_pebuf
    p[3] = rng.uniform(0.02, 0.2)     # e_reg
    p[4] = rng.uniform(0.2, 2)        # e_mac
    p[5] = rng.uniform(0.1, 1)        # e_noc
    p[6] = rng.uniform(0.05, 0.3)     # e_meta
    p[7] = rng.uniform(0.001, 64)     # bw_dram
    p[8] = rng.uniform(8, 512)        # bw_glb
    p[9] = rng.uniform(1, 64)         # bw_pe
    p[10] = rng.uniform(2**14, 2**25)  # glb cap words
    p[11] = rng.uniform(2**8, 2**16)   # pe cap words
    p[12] = rng.choice([256, 1024])
    p[13] = rng.choice([1, 64])
    p[14] = 1e9
    return p


def make_features(rng, b, scale):
    f = np.zeros((b, ref.NUM_FEATURES), dtype=np.float32)
    # Traffic features: log-uniform magnitudes.
    for col in range(0, 12):
        f[:, col] = 10 ** rng.uniform(0, scale, size=b)
    # Compression ratios in (0, 2].
    for col in range(12, 18):
        f[:, col] = rng.uniform(0.05, 2.0, size=b)
    # Metadata fractions in [0, 0.5].
    for col in range(18, 24):
        f[:, col] = rng.uniform(0.0, 0.5, size=b)
    # S/G multipliers in (0, 1].
    for col in range(24, 32):
        f[:, col] = rng.uniform(0.05, 1.0, size=b)
    f[:, ref.F_TOTAL_OPS] = 10 ** rng.uniform(3, scale + 3, size=b)
    f[:, ref.F_ACTIVE_MACS] = rng.choice([1, 16, 256, 4096], size=b)
    f[:, ref.F_GLB_TILE_WORDS] = 10 ** rng.uniform(2, 7, size=b)
    f[:, ref.F_PE_TILE_WORDS] = 10 ** rng.uniform(0, 5, size=b)
    f[:, ref.F_STRUCT_VALID] = rng.choice([0.0, 1.0], size=b)
    for col in (ref.F_CTRL_B1, ref.F_CTRL_B2, ref.F_CTRL_C):
        f[:, col] = rng.uniform(0.0, 0.25, size=b)
    f[:, ref.F_ACTIVE_PES] = rng.choice([1, 16, 256], size=b)
    for col in (ref.F_DENSITY_P, ref.F_DENSITY_Q, ref.F_DENSITY_Z):
        f[:, col] = rng.uniform(0.01, 1.0, size=b)
    return f


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 4),
    scale=st.floats(1.0, 9.0),
)
def test_kernel_matches_ref(seed, blocks, scale):
    rng = np.random.default_rng(seed)
    b = blocks * cost_kernel.BLOCK_B
    feats = make_features(rng, b, scale)
    plat = make_platform(rng)
    got = np.asarray(cost_kernel.cost_eval_pallas(feats, plat))
    want = np.asarray(ref.cost_eval_ref(feats, plat))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=0)


def test_outputs_shape_and_columns():
    rng = np.random.default_rng(0)
    b = cost_kernel.BLOCK_B
    feats = make_features(rng, b, 5.0)
    plat = make_platform(rng)
    out = np.asarray(cost_kernel.cost_eval_pallas(feats, plat))
    assert out.shape == (b, 4)
    energy, cycles, edp, valid = out.T
    assert (energy > 0).all()
    assert (cycles >= 1.0).all()
    np.testing.assert_allclose(edp, energy * cycles, rtol=1e-6)
    assert set(np.unique(valid)).issubset({0.0, 1.0})


def test_validity_logic():
    rng = np.random.default_rng(1)
    b = cost_kernel.BLOCK_B
    feats = make_features(rng, b, 4.0)
    plat = make_platform(rng)
    # Force capacity overflow in the first half, fit in the second.
    feats[: b // 2, ref.F_GLB_TILE_WORDS] = plat[10] * 10
    feats[b // 2:, ref.F_GLB_TILE_WORDS] = plat[10] * 0.1
    feats[b // 2:, ref.F_PE_TILE_WORDS] = plat[11] * 0.1
    feats[:, ref.F_STRUCT_VALID] = 1.0
    out = np.asarray(cost_kernel.cost_eval_pallas(feats, plat))
    assert (out[: b // 2, 3] == 0.0).all()
    assert (out[b // 2:, 3] == 1.0).all()
    # Structural invalidity always wins.
    feats[:, ref.F_STRUCT_VALID] = 0.0
    out = np.asarray(cost_kernel.cost_eval_pallas(feats, plat))
    assert (out[:, 3] == 0.0).all()


def test_batch_must_be_block_multiple():
    rng = np.random.default_rng(2)
    feats = make_features(rng, cost_kernel.BLOCK_B, 3.0)[:7]
    plat = make_platform(rng)
    with pytest.raises(AssertionError):
        cost_kernel.cost_eval_pallas(feats, plat)


def test_vmem_footprint_small():
    # One grid step must fit VMEM with generous headroom (<1 MB).
    assert cost_kernel.vmem_footprint_bytes() < 1 << 20
