//! JSON workload specs — the serialization format behind custom
//! scenarios (`sparsemap run-spec`, [`crate::api::SearchRequest`]).
//!
//! Two shapes are accepted:
//!
//! * **Generic einsum** — named dims, per-tensor projections (by dim
//!   name) and densities; works for any contraction the framework can
//!   search:
//!
//! ```json
//! {
//!   "id": "my_spmm",
//!   "kind": "SpMM",
//!   "dims": [{"name": "M", "size": 512}, {"name": "K", "size": 2048},
//!            {"name": "N", "size": 512}],
//!   "tensors": [
//!     {"name": "P", "dims": ["M", "K"], "density": 0.3},
//!     {"name": "Q", "dims": ["K", "N"], "density": 0.5},
//!     {"name": "Z", "dims": ["M", "N"]}
//!   ],
//!   "contraction": ["K"]
//! }
//! ```
//!
//!   The output tensor's density may be omitted (derived from the operand
//!   densities, see [`super::output_density`]). A density may also be a
//!   structured sparsity pattern ([`crate::sparsity::DensityModel`]) in
//!   object form, e.g. `{"kind": "block", "block": 4, "density": 0.3}`,
//!   `{"kind": "banded", "bandwidth": 8}` (band width over the tensor's
//!   innermost dimension), `{"kind": "row_skewed", "alpha": 0.7,
//!   "density": 0.3}` or `{"kind": "measured", "buckets": [..]}` (as
//!   printed by `sparsemap inspect-tensor`).
//!
//! * **SpConv shorthand** — a convolution layer lowered to implicit GEMM
//!   exactly like the Table III conv rows:
//!
//! ```json
//! {
//!   "id": "my_conv",
//!   "kind": "SpConv",
//!   "conv": {"c": 64, "h": 32, "w": 32, "kout": 128, "r": 3, "s": 3},
//!   "density_act": 0.45,
//!   "density_wgt": 0.25
//! }
//! ```

use super::spconv::{lower_conv, ConvShape};
use super::{Workload, WorkloadKind, NUM_TENSORS};
use crate::sparsity::DensityModel;
use crate::util::json::Json;
use anyhow::{anyhow, ensure, Context, Result};

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("workload spec is missing '{key}'"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    req(j, key)?.as_u64().ok_or_else(|| anyhow!("workload spec field '{key}' must be an integer"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?.as_f64().ok_or_else(|| anyhow!("workload spec field '{key}' must be a number"))
}

/// Parse a JSON workload spec (see the module docs for the format).
pub fn workload_from_spec(j: &Json) -> Result<Workload> {
    ensure!(j.as_obj().is_some(), "workload spec must be a JSON object");
    let id = req(j, "id")?
        .as_str()
        .ok_or_else(|| anyhow!("workload spec field 'id' must be a string"))?;
    let kind_str = j.get("kind").and_then(Json::as_str).unwrap_or("SpMM");
    let kind = WorkloadKind::parse(kind_str)
        .ok_or_else(|| anyhow!("unknown workload kind '{kind_str}' (SpMM|SpConv|SpBMM)"))?;

    if let Some(conv) = j.get("conv") {
        ensure!(
            kind == WorkloadKind::SpConv,
            "a 'conv' block requires \"kind\": \"SpConv\" (got {})",
            kind.as_str()
        );
        let shape = ConvShape {
            c: req_u64(conv, "c")?,
            h: req_u64(conv, "h")?,
            w: req_u64(conv, "w")?,
            kout: req_u64(conv, "kout")?,
            r: req_u64(conv, "r")?,
            s: req_u64(conv, "s")?,
        };
        let d_act = req_f64(j, "density_act")?;
        let d_wgt = req_f64(j, "density_wgt")?;
        ensure!(
            d_act > 0.0 && d_act <= 1.0 && d_wgt > 0.0 && d_wgt <= 1.0,
            "conv densities must be in (0, 1]"
        );
        ensure!(
            shape.c >= 1 && shape.h >= 1 && shape.w >= 1 && shape.kout >= 1 && shape.r >= 1
                && shape.s >= 1,
            "conv extents must all be >= 1"
        );
        let w = lower_conv(id, shape, d_act, d_wgt);
        w.validate().with_context(|| format!("conv workload '{id}'"))?;
        return Ok(w);
    }

    let dims_json = req(j, "dims")?
        .as_arr()
        .ok_or_else(|| anyhow!("workload spec field 'dims' must be an array"))?;
    let mut dims: Vec<(String, u64)> = Vec::with_capacity(dims_json.len());
    for d in dims_json {
        let name = req(d, "name")?
            .as_str()
            .ok_or_else(|| anyhow!("dim 'name' must be a string"))?;
        dims.push((name.to_string(), req_u64(d, "size")?));
    }
    let resolve = |name: &str| -> Result<usize> {
        dims.iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| anyhow!("'{name}' does not name a declared dimension"))
    };

    let tensors_json = req(j, "tensors")?
        .as_arr()
        .ok_or_else(|| anyhow!("workload spec field 'tensors' must be an array"))?;
    ensure!(
        tensors_json.len() == NUM_TENSORS,
        "workload spec needs exactly {NUM_TENSORS} tensors (P, Q, Z order), got {}",
        tensors_json.len()
    );
    let default_names = ["P", "Q", "Z"];
    let mut tensors: Vec<(String, Vec<usize>, Option<DensityModel>)> =
        Vec::with_capacity(NUM_TENSORS);
    for (t, tj) in tensors_json.iter().enumerate() {
        let name = tj.get("name").and_then(Json::as_str).unwrap_or(default_names[t]);
        let proj = req(tj, "dims")?
            .as_arr()
            .ok_or_else(|| anyhow!("tensor '{name}' field 'dims' must be an array of dim names"))?;
        let mut refs = Vec::with_capacity(proj.len());
        for p in proj {
            let dim_name = p
                .as_str()
                .ok_or_else(|| anyhow!("tensor '{name}' projections must be dim names"))?;
            refs.push(resolve(dim_name).with_context(|| format!("tensor '{name}'"))?);
        }
        // Banded patterns span the tensor's innermost dimension.
        let inner_extent = refs.last().map_or(1, |&d| dims[d].1);
        // Z's density defaults to "derive from the inputs".
        let density = match tj.get("density") {
            Some(d) => Some(
                DensityModel::from_json(d, inner_extent)
                    .with_context(|| format!("tensor '{name}' density"))?,
            ),
            None if t == NUM_TENSORS - 1 => None,
            None => anyhow::bail!("tensor '{name}' is missing 'density'"),
        };
        tensors.push((name.to_string(), refs, density));
    }

    let contraction_json = req(j, "contraction")?
        .as_arr()
        .ok_or_else(|| anyhow!("workload spec field 'contraction' must be an array of dim names"))?;
    let mut contraction = Vec::with_capacity(contraction_json.len());
    for c in contraction_json {
        let dim_name =
            c.as_str().ok_or_else(|| anyhow!("contraction entries must be dim names"))?;
        contraction.push(resolve(dim_name).context("contraction")?);
    }

    Workload::custom_models(id, kind, dims, tensors, contraction)
        .with_context(|| format!("workload '{id}'"))
}

/// Emit the generic-einsum JSON spec for a workload. Inverse of
/// [`workload_from_spec`]: parsing the result reproduces the workload
/// exactly (densities are emitted explicitly, including the output's —
/// uniform models as bare numbers, structured patterns in object form).
pub fn workload_to_spec(w: &Workload) -> Json {
    Json::obj(vec![
        ("id", Json::str(&w.id)),
        ("kind", Json::str(w.kind.as_str())),
        (
            "dims",
            Json::Arr(
                w.dims
                    .iter()
                    .map(|d| {
                        Json::obj(vec![
                            ("name", Json::str(&d.name)),
                            ("size", Json::num(d.size as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "tensors",
            Json::Arr(
                w.tensors
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("name", Json::str(&t.name)),
                            (
                                "dims",
                                Json::Arr(
                                    t.dims.iter().map(|&d| Json::str(&w.dims[d].name)).collect(),
                                ),
                            ),
                            ("density", t.density.to_json()),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "contraction",
            Json::Arr(w.contraction.iter().map(|&d| Json::str(&w.dims[d].name)).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spmm_spec() -> String {
        r#"{
            "id": "custom_mm",
            "kind": "SpMM",
            "dims": [{"name": "M", "size": 96}, {"name": "K", "size": 128},
                     {"name": "N", "size": 64}],
            "tensors": [
                {"name": "P", "dims": ["M", "K"], "density": 0.3},
                {"name": "Q", "dims": ["K", "N"], "density": 0.5},
                {"name": "Z", "dims": ["M", "N"]}
            ],
            "contraction": ["K"]
        }"#
        .to_string()
    }

    #[test]
    fn parses_generic_spmm() {
        let w = workload_from_spec(&Json::parse(&spmm_spec()).unwrap()).unwrap();
        assert_eq!(w.id, "custom_mm");
        assert_eq!(w.rank(), 3);
        assert_eq!(w.tensors[0].dims, vec![0, 1]);
        assert_eq!(w.contraction, vec![1]);
        assert!(w.tensors[2].density.avg() > 0.0, "derived output density");
    }

    #[test]
    fn round_trips_through_spec_json() {
        let w = workload_from_spec(&Json::parse(&spmm_spec()).unwrap()).unwrap();
        let j = workload_to_spec(&w);
        let w2 = workload_from_spec(&Json::parse(&j.dumps()).unwrap()).unwrap();
        assert_eq!(w, w2);
        // Table III rows round-trip too.
        for w in crate::workload::table3::all().into_iter().take(4) {
            let j = workload_to_spec(&w);
            assert_eq!(workload_from_spec(&j).unwrap(), w);
        }
    }

    #[test]
    fn parses_conv_shorthand() {
        let src = r#"{
            "id": "c", "kind": "SpConv",
            "conv": {"c": 64, "h": 16, "w": 16, "kout": 128, "r": 3, "s": 3},
            "density_act": 0.45, "density_wgt": 0.25
        }"#;
        let w = workload_from_spec(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(w.kind, WorkloadKind::SpConv);
        assert_eq!(w.dims[0].size, 128); // Kout becomes GEMM M
    }

    #[test]
    fn parses_and_round_trips_structured_densities() {
        let src = r#"{
            "id": "blocky", "kind": "SpMM",
            "dims": [{"name": "M", "size": 64}, {"name": "K", "size": 512},
                     {"name": "N", "size": 64}],
            "tensors": [
                {"name": "P", "dims": ["M", "K"],
                 "density": {"kind": "block", "block": 16, "density": 0.2}},
                {"name": "Q", "dims": ["K", "N"],
                 "density": {"kind": "banded", "bandwidth": 8}},
                {"name": "Z", "dims": ["M", "N"]}
            ],
            "contraction": ["K"]
        }"#;
        let w = workload_from_spec(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(w.tensors[0].density, DensityModel::block(16, 0.2));
        // The banded row length resolves to Q's innermost dim (N = 64).
        assert_eq!(w.tensors[1].density, DensityModel::banded(8, 64));
        let j = workload_to_spec(&w);
        assert_eq!(workload_from_spec(&Json::parse(&j.dumps()).unwrap()).unwrap(), w);
    }

    #[test]
    fn rejects_bad_structured_density() {
        let mk = |density: &str| {
            format!(
                r#"{{
                    "id": "v", "kind": "SpMM",
                    "dims": [{{"name": "M", "size": 8}}, {{"name": "K", "size": 8}},
                             {{"name": "N", "size": 8}}],
                    "tensors": [
                        {{"name": "P", "dims": ["M", "K"], "density": {density}}},
                        {{"name": "Q", "dims": ["K", "N"], "density": 0.5}},
                        {{"name": "Z", "dims": ["M", "N"]}}
                    ],
                    "contraction": ["K"]
                }}"#
            )
        };
        for bad in [
            r#"{"kind": "block", "block": 0, "density": 0.5}"#,
            r#"{"kind": "block", "block": 4, "density": 1.5}"#,
            r#"{"kind": "warp", "density": 0.5}"#,
            r#"{"block": 4}"#,
            "true",
        ] {
            let j = Json::parse(&mk(bad)).unwrap();
            assert!(workload_from_spec(&j).is_err(), "{bad}");
        }
        assert!(workload_from_spec(&Json::parse(&mk("0.5")).unwrap()).is_ok());
    }

    #[test]
    fn rejects_bad_dim_ref() {
        let src = spmm_spec().replace("\"contraction\": [\"K\"]", "\"contraction\": [\"X\"]");
        let err = workload_from_spec(&Json::parse(&src).unwrap()).unwrap_err();
        assert!(err.root_cause().contains('X'), "{err:?}");
    }

    #[test]
    fn rejects_zero_density() {
        let src = spmm_spec().replace("\"density\": 0.3", "\"density\": 0.0");
        assert!(workload_from_spec(&Json::parse(&src).unwrap()).is_err());
    }

    #[test]
    fn rejects_contracted_output_dim() {
        let src = spmm_spec().replace("\"dims\": [\"M\", \"N\"]", "\"dims\": [\"M\", \"K\"]");
        assert!(workload_from_spec(&Json::parse(&src).unwrap()).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        for src in ["{}", r#"{"id": "x"}"#, r#"{"id": "x", "kind": "nope"}"#] {
            assert!(workload_from_spec(&Json::parse(src).unwrap()).is_err(), "{src}");
        }
    }
}
