//! The cost formula — energy, latency, EDP and validity from a feature
//! vector.
//!
//! **This arithmetic is the contract with `python/compile/model.py`.** The
//! Python module implements the identical formula in JAX (lowered to the
//! AOT artifact the Rust runtime executes); `rust/tests/runtime_xla.rs`
//! cross-validates the two to f32 tolerance. Keep them in lock-step.

use super::features::*;
use crate::arch::Platform;

/// Full cost breakdown of one design point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostBreakdown {
    /// Total energy, picojoules.
    pub energy_pj: f64,
    /// Total latency, cycles.
    pub cycles: f64,
    /// Energy-delay product, pJ·cycles (the paper's objective).
    pub edp: f64,
    /// 1.0 if valid, 0.0 otherwise.
    pub valid: f64,
    /// GLB / PE-buffer utilization (diagnostics; >1 ⇒ invalid).
    pub glb_util: f64,
    pub pe_util: f64,
    /// Energy split (diagnostics and Fig. 2-style breakdowns).
    pub energy_dram_pj: f64,
    pub energy_onchip_pj: f64,
    pub energy_compute_pj: f64,
    /// Latency split.
    pub cycles_compute: f64,
    pub cycles_dram: f64,
    pub cycles_glb: f64,
    pub cycles_pe: f64,
}

/// Platform vector layout (see `Platform::to_feature_vector`):
/// `[e_dram, e_glb, e_pebuf, e_reg, e_mac, e_noc, e_meta,
///   bw_dram, bw_glb, bw_pe, glb_words, pe_words, n_pes, macs_per_pe,
///   clock, reserved]`.
pub fn evaluate_features(f: &Features, p: &[f64]) -> CostBreakdown {
    let (e_dram, e_glb, e_pebuf, e_reg, e_mac, e_noc, e_meta) =
        (p[0], p[1], p[2], p[3], p[4], p[5], p[6]);
    let (bw_dram, bw_glb, bw_pe) = (p[7], p[8], p[9]);
    let (glb_cap, pe_cap) = (p[10], p[11]);
    let n_pes = p[12];
    let macs_per_pe = p[13];

    // ---- boundary 0: DRAM <-> GLB (compressed words) --------------------
    let w0_p = f[F_P_WORDS_B0] * f[F_CR_P_B0];
    let w0_q = f[F_Q_WORDS_B0] * f[F_CR_Q_B0];
    let w0_z = f[F_Z_WORDS_B0] * f[F_CR_Z_B0];
    let w0 = w0_p + w0_q + w0_z;
    let meta0 = f[F_P_WORDS_B0] * f[F_META_P_B0]
        + f[F_Q_WORDS_B0] * f[F_META_Q_B0]
        + f[F_Z_WORDS_B0] * f[F_META_Z_B0];
    let energy_b0 = w0 * (e_dram + e_glb) + meta0 * e_meta;

    // ---- boundary 1: GLB -> PE (S/G at the GLB filters the stream) ------
    let glb_reads = f[F_P_GLB_READS_B1] * f[F_CR_P_B1] * f[F_SG_P_ENERGY_B1]
        + f[F_Q_GLB_READS_B1] * f[F_CR_Q_B1] * f[F_SG_Q_ENERGY_B1]
        + f[F_Z_GLB_WORDS_B1] * f[F_CR_Z_B1];
    let noc_words = f[F_P_NOC_WORDS_B1] * f[F_CR_P_B1] * f[F_SG_P_ENERGY_B1]
        + f[F_Q_NOC_WORDS_B1] * f[F_CR_Q_B1] * f[F_SG_Q_ENERGY_B1]
        + f[F_Z_NOC_WORDS_B1] * f[F_CR_Z_B1];
    let meta1 = f[F_P_NOC_WORDS_B1] * f[F_META_P_B1]
        + f[F_Q_NOC_WORDS_B1] * f[F_META_Q_B1]
        + f[F_Z_NOC_WORDS_B1] * f[F_META_Z_B1];
    let energy_b1 = glb_reads * e_glb
        + noc_words * (e_noc + e_pebuf)
        + meta1 * e_meta
        + noc_words * f[F_CTRL_B1];

    // ---- boundary 2: PE buffer -> MAC operands --------------------------
    let w2 = f[F_P_WORDS_B2] * f[F_SG_P_ENERGY_B2]
        + f[F_Q_WORDS_B2] * f[F_SG_Q_ENERGY_B2]
        + f[F_Z_WORDS_B2];
    let energy_b2 = w2 * (e_pebuf + e_reg) + w2 * f[F_CTRL_B2];

    // ---- compute ---------------------------------------------------------
    let effectual_macs = f[F_TOTAL_OPS] * f[F_MAC_ENERGY_FRAC];
    let energy_mac = effectual_macs * e_mac + f[F_TOTAL_OPS] * f[F_CTRL_C];

    let energy_pj = energy_b0 + energy_b1 + energy_b2 + energy_mac;

    // ---- latency: overlapped pipeline, bottleneck stage wins ------------
    let cycles_compute =
        f[F_TOTAL_OPS] / f[F_ACTIVE_MACS].max(1.0) * f[F_COMPUTE_CYCLE_FRAC];
    let cycles_dram = w0 / bw_dram.max(1e-12);
    let cycles_glb = glb_reads * f[F_SG_CYCLES_B1] / bw_glb.max(1e-12);
    let cycles_pe = w2 * f[F_SG_CYCLES_B2]
        / (bw_pe.max(1e-12) * f[F_ACTIVE_PES].max(1.0));
    let cycles = cycles_compute.max(cycles_dram).max(cycles_glb).max(cycles_pe).max(1.0);

    // ---- validity ---------------------------------------------------------
    let glb_util = f[F_GLB_TILE_WORDS] / glb_cap.max(1.0);
    let pe_util = f[F_PE_TILE_WORDS] / pe_cap.max(1.0);
    let fits = if glb_util <= 1.0 && pe_util <= 1.0 { 1.0 } else { 0.0 };
    let valid = f[F_STRUCT_VALID] * fits;

    let _ = (n_pes, macs_per_pe);
    CostBreakdown {
        energy_pj,
        cycles,
        edp: energy_pj * cycles,
        valid,
        glb_util,
        pe_util,
        energy_dram_pj: energy_b0,
        energy_onchip_pj: energy_b1 + energy_b2,
        energy_compute_pj: energy_mac,
        cycles_compute,
        cycles_dram,
        cycles_glb,
        cycles_pe,
    }
}

/// Platform vector in f64 (native path).
pub fn platform_vector(plat: &Platform) -> Vec<f64> {
    plat.to_feature_vector().iter().map(|&x| x as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{decode, GenomeSpec};
    use crate::model::features::extract;
    use crate::util::rng::Pcg64;
    use crate::workload::Workload;

    fn eval_genome(genome: &[u32]) -> (CostBreakdown, Workload) {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let p = Platform::edge();
        let spec = GenomeSpec::for_workload(&w);
        let d = decode(&spec, &w, genome);
        let f = extract(&d, &w, &p);
        (evaluate_features(&f, &platform_vector(&p)), w)
    }

    /// Mapping genes 1, strategy segments cleared.
    fn dense_genome(spec: &GenomeSpec) -> Vec<u32> {
        let mut g = vec![1u32; spec.len()];
        for i in spec.format_start..spec.len() {
            g[i] = 0;
        }
        g
    }

    #[test]
    fn dense_design_costs_are_positive() {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let spec = GenomeSpec::for_workload(&w);
        let (cb, _) = eval_genome(&dense_genome(&spec));
        assert!(cb.energy_pj > 0.0);
        assert!(cb.cycles >= 1.0);
        assert!((cb.edp - cb.energy_pj * cb.cycles).abs() < 1e-6);
        assert!(cb.valid == 1.0 || cb.valid == 0.0);
    }

    #[test]
    fn energy_split_sums_to_total() {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let spec = GenomeSpec::for_workload(&w);
        let (cb, _) = eval_genome(&dense_genome(&spec));
        let sum = cb.energy_dram_pj + cb.energy_onchip_pj + cb.energy_compute_pj;
        assert!((sum - cb.energy_pj).abs() / cb.energy_pj < 1e-12);
    }

    #[test]
    fn latency_is_max_of_stages() {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let spec = GenomeSpec::for_workload(&w);
        let (cb, _) = eval_genome(&dense_genome(&spec));
        let stage_max =
            cb.cycles_compute.max(cb.cycles_dram).max(cb.cycles_glb).max(cb.cycles_pe);
        assert!((cb.cycles - stage_max.max(1.0)).abs() < 1e-9);
    }

    #[test]
    fn random_designs_never_nan() {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let p = Platform::mobile();
        let spec = GenomeSpec::for_workload(&w);
        let pv = platform_vector(&p);
        let mut rng = Pcg64::seeded(21);
        for _ in 0..300 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            let f = extract(&d, &w, &p);
            let cb = evaluate_features(&f, &pv);
            assert!(cb.energy_pj.is_finite() && cb.cycles.is_finite() && cb.edp.is_finite());
            assert!(cb.energy_pj >= 0.0 && cb.cycles >= 1.0);
        }
    }

    #[test]
    fn capacity_violation_invalidates() {
        // All tiling at L2_T: the whole workload must sit in the GLB. On
        // edge (128 KB) a 16x32 + 32x16 + 16x16 tile fits, so make the
        // workload big instead.
        let w = Workload::spmm("big", 1024, 1024, 1024, 0.9, 0.9);
        let p = Platform::edge();
        let spec = GenomeSpec::for_workload(&w);
        let mut g = dense_genome(&spec);
        for i in spec.factor_start..spec.format_start {
            g[i] = 2; // everything at L2_T
        }
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &p);
        let cb = evaluate_features(&f, &platform_vector(&p));
        assert!(cb.glb_util > 1.0);
        assert_eq!(cb.valid, 0.0);
    }

    #[test]
    fn block_pattern_changes_compressed_cost() {
        use crate::sparsity::DensityModel;
        use crate::workload::WorkloadKind;
        // Same mean density, clustered vs uniform nonzeros: clustered
        // coordinates compress better, so the same compressed design is
        // cheaper — the pattern is decision-relevant, not cosmetic.
        let mk = |model: DensityModel| {
            Workload::custom_models(
                "t",
                WorkloadKind::SpMM,
                vec![("M".into(), 32), ("K".into(), 64), ("N".into(), 32)],
                vec![
                    ("P".into(), vec![0, 1], Some(model)),
                    ("Q".into(), vec![1, 2], Some(DensityModel::uniform(0.3))),
                    ("Z".into(), vec![0, 2], None),
                ],
                vec![1],
            )
            .unwrap()
        };
        let w_u = mk(DensityModel::uniform(0.1));
        let w_b = mk(DensityModel::block(16, 0.1));
        let p = Platform::mobile();
        let spec = GenomeSpec::for_workload(&w_u);
        let mut g = vec![1u32; spec.len()];
        for i in spec.format_start..spec.len() {
            g[i] = 0;
        }
        for i in spec.factor_start..spec.format_start {
            g[i] = 2; // tile at L2_T so ranks materialize in the GLB
        }
        for s in 0..5 {
            g[spec.format_start + s] = 3; // P: coordinate payload
        }
        let pv = platform_vector(&p);
        let c_u = evaluate_features(&extract(&decode(&spec, &w_u, &g), &w_u, &p), &pv);
        let c_b = evaluate_features(&extract(&decode(&spec, &w_b, &g), &w_b, &p), &pv);
        assert!(
            c_b.energy_pj < c_u.energy_pj,
            "block {} vs uniform {}",
            c_b.energy_pj,
            c_u.energy_pj
        );
    }

    #[test]
    fn gating_saves_energy_not_cycles() {
        let w = Workload::spmm("t", 32, 32, 32, 0.3, 0.3);
        let p = Platform::mobile();
        let spec = GenomeSpec::for_workload(&w);
        let mut g = dense_genome(&spec);
        for i in spec.factor_start..spec.format_start {
            g[i] = 4; // all at L3_T: pure temporal in-PE execution
        }
        let d_none = decode(&spec, &w, &g);
        let mut g_gate = g.clone();
        g_gate[spec.sg_start + 2] = 3; // Gate P<->Q at compute
        let d_gate = decode(&spec, &w, &g_gate);
        let pv = platform_vector(&p);
        let c_none = evaluate_features(&extract(&d_none, &w, &p), &pv);
        let c_gate = evaluate_features(&extract(&d_gate, &w, &p), &pv);
        assert!(c_gate.energy_pj < c_none.energy_pj);
        assert!((c_gate.cycles - c_none.cycles).abs() < 1e-9);
    }
}
