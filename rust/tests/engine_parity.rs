//! Engine parity: the staged, interned evaluation engine must reproduce
//! the from-scratch pipeline **bit-for-bit** — same best-EDP curves,
//! same eval counts, same cache hits — for SparseMap, both ES variants
//! and the baselines, serial and pooled. `EvalContext::with_staging(false)`
//! is the old-path-equivalent: every result-cache miss runs the
//! monolithic decode → extract → cost chain.

use sparsemap::arch::Platform;
use sparsemap::optimizer::run_method;
use sparsemap::search::{Backend, EvalContext, Outcome, StageEngine};
use sparsemap::util::rng::Pcg64;
use sparsemap::util::threadpool::ThreadPool;
use sparsemap::workload::Workload;
use std::sync::Arc;

fn workload() -> Workload {
    Workload::spmm("mm", 64, 128, 64, 0.2, 0.2)
}

fn ctx(budget: usize, threads: usize, staged: bool) -> EvalContext {
    let c = EvalContext::new(Backend::native(workload(), Platform::mobile()), budget)
        .with_staging(staged);
    if threads > 1 {
        c.with_pool(Some(Arc::new(ThreadPool::new(threads))))
    } else {
        c
    }
}

fn assert_outcomes_identical(a: &Outcome, b: &Outcome, label: &str) {
    assert_eq!(a.best_edp, b.best_edp, "{label}: best_edp");
    assert_eq!(a.best_genome, b.best_genome, "{label}: best_genome");
    assert_eq!(a.curve, b.curve, "{label}: best-EDP curve");
    assert_eq!(a.population_mean_curve, b.population_mean_curve, "{label}: mean curve");
    assert_eq!(a.evals, b.evals, "{label}: evals");
    assert_eq!(a.valid_evals, b.valid_evals, "{label}: valid_evals");
    assert_eq!(a.cache_hits, b.cache_hits, "{label}: cache_hits");
    assert_eq!(a.interned, b.interned, "{label}: interned");
}

/// Seed-config searches through the old-path-equivalent and the staged
/// engine, 1 and 4 threads: identical `Outcome` telemetry everywhere.
/// Covers SparseMap proper, the standard-ES ablation, and baselines from
/// both evaluation paths (`pso` → `eval_batch`, `es-direct` → the
/// foreign-encoding `eval_designs`).
#[test]
fn trajectories_bit_identical_across_methods_and_threads() {
    for method in ["sparsemap", "es-pfce", "random", "pso", "es-direct"] {
        let budget = 600;
        let reference = run_method(method, ctx(budget, 1, false), 42).unwrap();
        for threads in [1usize, 4] {
            let staged = run_method(method, ctx(budget, threads, true), 42).unwrap();
            assert_outcomes_identical(
                &reference,
                &staged,
                &format!("{method} @ {threads} threads"),
            );
        }
    }
}

/// Raw per-genome parity on a large random sample (no search loop in the
/// way): every staged result equals the from-scratch result exactly.
#[test]
fn random_population_bitwise_parity() {
    let mut staged = ctx(3_000, 1, true);
    let mut scratch = ctx(3_000, 1, false);
    let mut pooled = ctx(3_000, 8, true);
    let mut rng = Pcg64::seeded(7);
    let genomes: Vec<Vec<u32>> = (0..1_500).map(|_| staged.spec.random(&mut rng)).collect();
    let a = staged.eval_batch(&genomes);
    let b = scratch.eval_batch(&genomes);
    let c = pooled.eval_batch(&genomes);
    assert_eq!(a, b);
    assert_eq!(a, c);
    assert_eq!(staged.telemetry.curve, scratch.telemetry.curve);
    assert_eq!(staged.telemetry.curve, pooled.telemetry.curve);
}

/// Offspring that share parent segments exercise the stage caches hard;
/// the trajectory must still match the from-scratch path and the stage
/// counters must show the reuse actually happened.
#[test]
fn segment_sharing_population_parity_and_reuse() {
    let mut staged = ctx(5_000, 1, true);
    let mut scratch = ctx(5_000, 1, false);
    let mut rng = Pcg64::seeded(9);
    let spec = staged.spec.clone();
    let parents: Vec<Vec<u32>> = (0..20).map(|_| spec.random(&mut rng)).collect();
    let mut pop = Vec::new();
    for p in &parents {
        for _ in 0..10 {
            let mut g = p.clone();
            // Mutate only the S/G genes: mapping + format stages reused.
            for i in spec.sg_start..spec.len() {
                g[i] = rng.range_u32(spec.ranges[i].lo, spec.ranges[i].hi);
            }
            pop.push(g);
        }
    }
    assert_eq!(staged.eval_batch(&pop), scratch.eval_batch(&pop));
    assert!(
        staged.stage_hits() > pop.len(),
        "sg-only offspring should hit mapping+format stages, saw {}",
        staged.stage_hits()
    );
    assert_eq!(scratch.stage_hits(), 0);
}

/// The acceptance microbench (timing-sensitive, so `#[ignore]`d like the
/// thread-speedup test; run with `cargo test --release -- --ignored`):
/// on a 100-genome offspring population whose stages are warm, the
/// staged engine must be ≥ 2x faster single-threaded than a from-scratch
/// re-evaluation loop. `cargo bench -- staged` reports the same numbers.
#[test]
#[ignore]
fn staged_engine_2x_faster_than_scratch_loop_single_thread() {
    let eval = Arc::new(sparsemap::model::NativeEvaluator::new(
        workload(),
        Platform::mobile(),
    ));
    let mut engine = StageEngine::new(Arc::clone(&eval), 1_000_000);
    let mut rng = Pcg64::seeded(3);
    let spec = eval.spec.clone();
    // 100-genome population: 10 parents x 10 strategy-gene variants.
    let parents: Vec<Vec<u32>> = (0..10).map(|_| spec.random(&mut rng)).collect();
    let mut pop: Vec<Vec<u32>> = Vec::new();
    for p in &parents {
        for _ in 0..10 {
            let mut g = p.clone();
            for i in spec.sg_start..spec.len() {
                g[i] = rng.range_u32(spec.ranges[i].lo, spec.ranges[i].hi);
            }
            pop.push(g);
        }
    }
    let arcs: Vec<Arc<[u32]>> = pop.iter().map(|g| Arc::from(g.as_slice())).collect();
    engine.eval_batch(&arcs, None); // warm the stage caches

    let rounds = 200;
    let t0 = std::time::Instant::now();
    for _ in 0..rounds {
        std::hint::black_box(engine.eval_batch(&arcs, None));
    }
    let staged_s = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    for _ in 0..rounds {
        for g in &pop {
            std::hint::black_box(eval.eval_genome(g));
        }
    }
    let scratch_s = t1.elapsed().as_secs_f64();

    let speedup = scratch_s / staged_s;
    assert!(
        speedup >= 2.0,
        "staged engine only {speedup:.2}x faster (staged {staged_s:.3}s vs scratch {scratch_s:.3}s)"
    );
}
