//! Sparsity-pattern sweep — does the *shape* of sparsity (not just its
//! amount) change which accelerator design wins?
//!
//! Three search arms share one GEMM, one platform, one budget and one
//! seed; only operand P's [`DensityModel`] differs — uniform, block and
//! banded at the *same mean density* (12.5%). The legacy scalar model
//! cannot tell these apart; with the structured models the compression
//! statistics and buffer provisioning differ, so the ES converges to
//! different designs (asserted by the tests — the subsystem is
//! decision-relevant, not cosmetic).

use super::{write_csv, ExpConfig};
use crate::api::SearchRequest;
use crate::genome::{decode, GenomeSpec};
use crate::search::Outcome;
use crate::sparsity::DensityModel;
use crate::util::table::{sci, Table};
use crate::workload::{Workload, WorkloadKind};

/// Shared GEMM extents: `P[M,K] × Q[K,N]`.
const M: u64 = 256;
const K: u64 = 1024;
const N: u64 = 256;
/// Mean density of P under every pattern (128/1024 for the banded arm).
const DP: f64 = 0.125;
/// Uniform density of Q in every arm.
const DQ: f64 = 0.4;

/// The sweep arms: P's sparsity pattern at equal mean density.
pub fn arms() -> Vec<(&'static str, DensityModel)> {
    vec![
        ("uniform", DensityModel::uniform(DP)),
        ("block64", DensityModel::block(64, DP)),
        ("banded", DensityModel::banded((DP * K as f64) as u64, K)),
    ]
}

/// The sweep workload with P's pattern swapped in.
pub fn workload_for(name: &str, model: DensityModel) -> Workload {
    Workload::custom_models(
        &format!("pat_{name}"),
        WorkloadKind::SpMM,
        vec![("M".into(), M), ("K".into(), K), ("N".into(), N)],
        vec![
            ("P".into(), vec![0, 1], Some(model)),
            ("Q".into(), vec![1, 2], Some(DensityModel::uniform(DQ))),
            ("Z".into(), vec![0, 2], None),
        ],
        vec![1],
    )
    .expect("pattern-sweep workload validates")
}

/// Run the three arms (same budget/seed/platform; only P's pattern
/// differs) and return `(arm name, outcome)` in [`arms`] order.
pub fn run_arms(cfg: &ExpConfig) -> Vec<(&'static str, Outcome)> {
    arms()
        .into_iter()
        .map(|(name, model)| {
            let outcome = SearchRequest::new()
                .workload(workload_for(name, model))
                .platform_named("mobile")
                .method("sparsemap")
                .budget(cfg.budget)
                .seed(cfg.seed)
                .threads(cfg.threads)
                .pjrt(cfg.use_pjrt)
                .build()
                .expect("pattern-sweep request validates")
                .run()
                .expect("pattern-sweep arm runs")
                .into_outcome();
            (name, outcome)
        })
        .collect()
}

/// Render the sweep report and write `patterns.csv`.
pub fn run(cfg: &ExpConfig) -> anyhow::Result<String> {
    let results = run_arms(cfg);
    let baseline = results[0].1.best_edp;
    let mut table =
        Table::new(&["pattern", "P model", "best EDP", "vs uniform", "best strategy"]);
    let mut csv = String::from("pattern,model,best_edp,edp_vs_uniform,valid_ratio\n");
    for ((name, outcome), (_, model)) in results.iter().zip(arms()) {
        let strategy = outcome
            .best_genome
            .as_ref()
            .map(|g| {
                let w = workload_for(name, model.clone());
                let spec = GenomeSpec::for_workload(&w);
                decode(&spec, &w, g).strategy.describe()
            })
            .unwrap_or_else(|| "-".to_string());
        table.row(vec![
            name.to_string(),
            model.describe(),
            sci(outcome.best_edp),
            format!("{:.3}x", outcome.best_edp / baseline),
            strategy,
        ]);
        csv.push_str(&format!(
            "{},{},{:.6e},{:.4},{:.4}\n",
            name,
            model.kind_name(),
            outcome.best_edp,
            outcome.best_edp / baseline,
            outcome.valid_ratio()
        ));
    }
    write_csv(&cfg.out_dir, "patterns.csv", &csv)?;
    Ok(format!(
        "Sparsity-pattern sweep — {M}x{K}x{N} GEMM on mobile, dP={DP} under three \
         patterns, dQ={DQ}\n{}",
        table.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg(budget: usize) -> ExpConfig {
        ExpConfig {
            budget,
            seed: 42,
            out_dir: std::env::temp_dir().join("sparsemap_patterns"),
            use_pjrt: false,
            threads: 1,
        }
    }

    #[test]
    fn arms_share_mean_density() {
        for (name, model) in arms() {
            assert!(
                (model.avg() - DP).abs() < 1e-12,
                "{name}: avg {} != {DP}",
                model.avg()
            );
            let w = workload_for(name, model);
            assert!(w.validate().is_ok());
        }
    }

    #[test]
    fn structured_patterns_change_the_search_outcome() {
        // The acceptance bar for the subsystem: at equal mean density a
        // block-sparse spec must steer the ES to a *different best
        // design* than the uniform spec (and different EDP numbers).
        let outcomes = run_arms(&test_cfg(2_500));
        let uniform = &outcomes[0].1;
        assert!(uniform.found_valid(), "uniform arm found no valid design");
        for (name, outcome) in &outcomes[1..] {
            assert!(outcome.found_valid(), "{name} arm found no valid design");
            assert_ne!(
                outcome.best_edp.to_bits(),
                uniform.best_edp.to_bits(),
                "{name} best EDP identical to uniform"
            );
        }
        let design_shifted = outcomes[1..]
            .iter()
            .any(|(_, o)| o.best_genome != uniform.best_genome);
        assert!(
            design_shifted,
            "every structured arm converged to the uniform arm's design"
        );
    }

    #[test]
    fn run_renders_report_and_csv() {
        let cfg = test_cfg(400);
        let report = run(&cfg).unwrap();
        assert!(report.contains("uniform"), "{report}");
        assert!(report.contains("block"), "{report}");
        assert!(cfg.out_dir.join("patterns.csv").exists());
    }
}
