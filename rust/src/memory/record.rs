//! The `sparsemap.memory.v1` on-disk format: a versioned, fixed-layout,
//! append-only record file.
//!
//! ## File layout
//!
//! ```text
//! header (16 bytes):
//!   magic        8  b"SPMEMV1\n"
//!   version      4  u32 LE  (== MEMORY_VERSION)
//!   embed_dim    4  u32 LE  (== EMBED_DIM)
//! record (repeated, one per persisted elite design):
//!   payload_len  4  u32 LE  — bytes that follow, checksum included
//!   tag         48  scenario tag, UTF-8, zero-padded
//!   best_edp     8  f64 LE bit pattern (bit-exact through disk)
//!   evals        4  u32 LE
//!   valid_evals  4  u32 LE
//!   seed         8  u64 LE
//!   embed      280  EMBED_DIM × f64 LE bit patterns
//!   genome_len   4  u32 LE
//!   genome       4 × genome_len  u32 LE genes
//!   checksum     4  FNV-1a over every preceding payload byte
//! ```
//!
//! Every scalar is little-endian and every field has a fixed offset
//! within its record (only the genome segment varies, behind an explicit
//! length), following the fixed-length feature-vector discipline: a
//! reader either understands the exact layout or refuses the file.
//! Decoding **rejects** rather than misreads — bad magic, a future
//! version, a foreign embedding width, a truncated record, an oversized
//! length field or a checksum mismatch are all hard errors.

use super::embed::EMBED_DIM;
use anyhow::{anyhow, bail, ensure, Result};

/// Schema tag of the store format (reported by `memory stats`/`export`).
pub const MEMORY_SCHEMA: &str = "sparsemap.memory.v1";
/// On-disk version number; bump on any layout change.
pub const MEMORY_VERSION: u32 = 1;
/// File magic.
pub const MAGIC: [u8; 8] = *b"SPMEMV1\n";
/// Bytes reserved for the scenario tag.
pub const TAG_LEN: usize = 48;
/// Upper bound on persisted genome length (a sanity cap far above any
/// real [`crate::genome::GenomeSpec`]; a larger length field means the
/// record is corrupt).
pub const MAX_GENOME_LEN: usize = 4096;
/// Header size in bytes.
pub const HEADER_LEN: usize = 16;

/// Fixed payload bytes before the genome segment.
const FIXED_LEN: usize = TAG_LEN + 8 + 4 + 4 + 8 + EMBED_DIM * 8 + 4;
/// Checksum trailer size.
const SUM_LEN: usize = 4;

/// One persisted elite design: where it was found (scenario embedding +
/// tag), what it is (the genome) and how good it was (outcome summary).
#[derive(Clone, Debug, PartialEq)]
pub struct MemRecord {
    /// Scenario tag `workload@platform#method` (truncated to
    /// [`TAG_LEN`] bytes on a UTF-8 boundary).
    pub tag: String,
    /// Best valid EDP of the run that produced this genome.
    pub best_edp: f64,
    /// Budget submissions the run spent.
    pub evals: u32,
    pub valid_evals: u32,
    /// RNG seed of the producing run (provenance).
    pub seed: u64,
    /// Scenario embedding ([`super::embed::scenario_embedding`]).
    pub embed: [f64; EMBED_DIM],
    /// The elite genome itself.
    pub genome: Vec<u32>,
}

/// The 16-byte file header.
pub fn header_bytes() -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(&MAGIC);
    h[8..12].copy_from_slice(&MEMORY_VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&(EMBED_DIM as u32).to_le_bytes());
    h
}

/// Validate a file header, rejecting foreign, future or corrupt files.
pub fn check_header(bytes: &[u8]) -> Result<()> {
    ensure!(bytes.len() >= HEADER_LEN, "memory store file is shorter than its header");
    ensure!(bytes[..8] == MAGIC, "not a {MEMORY_SCHEMA} file (bad magic)");
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    ensure!(
        version == MEMORY_VERSION,
        "memory store version {version} is not supported (this build reads v{MEMORY_VERSION})"
    );
    let dim = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    ensure!(
        dim == EMBED_DIM,
        "memory store embeds {dim}-dim scenarios, this build uses {EMBED_DIM}"
    );
    Ok(())
}

/// FNV-1a 32-bit checksum.
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

impl MemRecord {
    /// Serialize to the wire form (length prefix through checksum).
    pub fn encode(&self) -> Vec<u8> {
        let payload_len = FIXED_LEN + self.genome.len() * 4 + SUM_LEN;
        let mut out = Vec::with_capacity(4 + payload_len);
        out.extend_from_slice(&(payload_len as u32).to_le_bytes());
        let mut tag = [0u8; TAG_LEN];
        let mut cut = self.tag.len().min(TAG_LEN);
        while !self.tag.is_char_boundary(cut) {
            cut -= 1;
        }
        tag[..cut].copy_from_slice(&self.tag.as_bytes()[..cut]);
        out.extend_from_slice(&tag);
        out.extend_from_slice(&self.best_edp.to_bits().to_le_bytes());
        out.extend_from_slice(&self.evals.to_le_bytes());
        out.extend_from_slice(&self.valid_evals.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        for x in &self.embed {
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        out.extend_from_slice(&(self.genome.len() as u32).to_le_bytes());
        for &g in &self.genome {
            out.extend_from_slice(&g.to_le_bytes());
        }
        let sum = fnv1a(&out[4..]);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Decode one record from the head of `bytes`; returns the record
    /// and the total bytes consumed. Any structural problem is an error
    /// — a truncated tail must never silently yield a partial record.
    pub fn decode(bytes: &[u8]) -> Result<(MemRecord, usize)> {
        ensure!(bytes.len() >= 4, "truncated record (missing length prefix)");
        let payload_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
        let max_payload = FIXED_LEN + MAX_GENOME_LEN * 4 + SUM_LEN;
        ensure!(
            (FIXED_LEN + SUM_LEN..=max_payload).contains(&payload_len),
            "record length {payload_len} is outside the valid range (corrupt file)"
        );
        ensure!(bytes.len() >= 4 + payload_len, "truncated record (file ends mid-record)");
        let payload = &bytes[4..4 + payload_len];
        let stored_sum = u32::from_le_bytes(payload[payload_len - SUM_LEN..].try_into().unwrap());
        let computed = fnv1a(&payload[..payload_len - SUM_LEN]);
        ensure!(
            stored_sum == computed,
            "record checksum mismatch ({stored_sum:08x} != {computed:08x}): corrupt file"
        );

        let mut off = 0usize;
        let tag_raw = &payload[off..off + TAG_LEN];
        off += TAG_LEN;
        let end = tag_raw.iter().position(|&b| b == 0).unwrap_or(TAG_LEN);
        let tag = std::str::from_utf8(&tag_raw[..end])
            .map_err(|_| anyhow!("record tag is not UTF-8 (corrupt file)"))?
            .to_string();
        let f64_at =
            |o: usize| f64::from_bits(u64::from_le_bytes(payload[o..o + 8].try_into().unwrap()));
        let u32_at = |o: usize| u32::from_le_bytes(payload[o..o + 4].try_into().unwrap());
        let best_edp = f64_at(off);
        off += 8;
        let evals = u32_at(off);
        off += 4;
        let valid_evals = u32_at(off);
        off += 4;
        let seed = u64::from_le_bytes(payload[off..off + 8].try_into().unwrap());
        off += 8;
        let mut embed = [0.0f64; EMBED_DIM];
        for e in embed.iter_mut() {
            *e = f64_at(off);
            off += 8;
        }
        let genome_len = u32_at(off) as usize;
        off += 4;
        if genome_len > MAX_GENOME_LEN {
            bail!("record genome length {genome_len} exceeds the cap (corrupt file)");
        }
        ensure!(
            payload_len == FIXED_LEN + genome_len * 4 + SUM_LEN,
            "record length {payload_len} disagrees with its genome length {genome_len}"
        );
        let mut genome = Vec::with_capacity(genome_len);
        for _ in 0..genome_len {
            genome.push(u32_at(off));
            off += 4;
        }
        Ok((
            MemRecord { tag, best_edp, evals, valid_evals, seed, embed, genome },
            4 + payload_len,
        ))
    }
}

/// Decode a whole store file (header + records). Empty record section is
/// fine; anything structurally wrong rejects the file.
pub fn decode_file(bytes: &[u8]) -> Result<Vec<MemRecord>> {
    check_header(bytes)?;
    let mut records = Vec::new();
    let mut off = HEADER_LEN;
    while off < bytes.len() {
        let (rec, used) = MemRecord::decode(&bytes[off..])
            .map_err(|e| anyhow!("record {} (at byte {off}): {e}", records.len()))?;
        records.push(rec);
        off += used;
    }
    Ok(records)
}

/// Result of a salvage pass over a store file: every record decodable
/// from the head, the byte length of that valid prefix, and — when the
/// file does not decode cleanly to its end — what was wrong with the
/// damaged tail.
pub struct Salvage {
    /// The intact record prefix (whole records only, in file order).
    pub records: Vec<MemRecord>,
    /// Bytes of header + intact records; the damaged tail starts here.
    pub valid_len: usize,
    /// `None` when the whole file decoded; otherwise why decoding
    /// stopped (torn tail, flipped bytes, …).
    pub damage: Option<String>,
}

/// Salvage a store file: recover the longest decodable record prefix
/// instead of rejecting the whole file. This is the crash-recovery read
/// path — a `kill -9` mid-append leaves a torn final record, and the
/// elites before it are perfectly good. Guarantees:
///
/// - a damaged or missing **header** is still a hard error (there is
///   nothing trustworthy to salvage under a wrong magic/version/dim);
/// - a returned record always decoded with its checksum intact — salvage
///   never yields a partial or bit-flipped record (pinned by proptests
///   over every cut point in `tests/proptests.rs`).
pub fn salvage_file(bytes: &[u8]) -> Result<Salvage> {
    check_header(bytes)?;
    let mut records = Vec::new();
    let mut off = HEADER_LEN;
    while off < bytes.len() {
        match MemRecord::decode(&bytes[off..]) {
            Ok((rec, used)) => {
                records.push(rec);
                off += used;
            }
            Err(e) => {
                let damage = format!(
                    "record {} (at byte {off}, {} tail bytes): {e}",
                    records.len(),
                    bytes.len() - off
                );
                return Ok(Salvage { records, valid_len: off, damage: Some(damage) });
            }
        }
    }
    Ok(Salvage { records, valid_len: off, damage: None })
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample(tag: &str, edp: f64, genome: Vec<u32>) -> MemRecord {
        let mut embed = [0.0f64; EMBED_DIM];
        for (i, e) in embed.iter_mut().enumerate() {
            *e = (i as f64 + 0.5) / EMBED_DIM as f64;
        }
        MemRecord {
            tag: tag.to_string(),
            best_edp: edp,
            evals: 600,
            valid_evals: 432,
            seed: 0xdead_beef_cafe_f00d,
            embed,
            genome,
        }
    }

    #[test]
    fn record_round_trips_bit_exactly() {
        let rec = sample("mm1@mobile#es-std", 1.25e9, vec![1, 2, 3, 4, 5, 0, 4, 6]);
        let bytes = rec.encode();
        let (back, used) = MemRecord::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, rec);
        assert_eq!(back.best_edp.to_bits(), rec.best_edp.to_bits());
        // Non-finite EDP sentinels survive too (bit-pattern encoding).
        let inf = sample("x@y#z", f64::INFINITY, vec![7]);
        let (back, _) = MemRecord::decode(&inf.encode()).unwrap();
        assert_eq!(back.best_edp.to_bits(), f64::INFINITY.to_bits());
    }

    #[test]
    fn file_round_trips() {
        let mut bytes = header_bytes().to_vec();
        let recs = vec![sample("a@p#m", 1.0, vec![1, 2]), sample("b@p#m", 2.0, (0..40).collect())];
        for r in &recs {
            bytes.extend_from_slice(&r.encode());
        }
        assert_eq!(decode_file(&bytes).unwrap(), recs);
        assert_eq!(decode_file(&header_bytes()).unwrap(), Vec::new());
    }

    #[test]
    fn bad_magic_and_versions_rejected() {
        let mut bytes = header_bytes().to_vec();
        bytes[0] = b'X';
        assert!(decode_file(&bytes).unwrap_err().to_string().contains("bad magic"));
        // A future version must be refused, not misread.
        let mut future = header_bytes().to_vec();
        future[8..12].copy_from_slice(&2u32.to_le_bytes());
        assert!(decode_file(&future).unwrap_err().to_string().contains("not supported"));
        // A foreign embedding width likewise.
        let mut wide = header_bytes().to_vec();
        wide[12..16].copy_from_slice(&99u32.to_le_bytes());
        assert!(decode_file(&wide).unwrap_err().to_string().contains("99-dim"));
        // And a header-less stub.
        assert!(decode_file(&[1, 2, 3]).is_err());
    }

    #[test]
    fn truncation_and_corruption_rejected() {
        let rec = sample("mm1@mobile#es-std", 3.5, vec![9, 8, 7]);
        let mut bytes = header_bytes().to_vec();
        bytes.extend_from_slice(&rec.encode());
        // Every proper prefix that cuts into the record must fail.
        for cut in HEADER_LEN + 1..bytes.len() {
            assert!(decode_file(&bytes[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        // A flipped byte anywhere in the payload fails the checksum (or
        // a structural check) — never yields a different record.
        for i in HEADER_LEN..bytes.len() {
            let mut evil = bytes.clone();
            evil[i] ^= 0x40;
            if let Ok(recs) = decode_file(&evil) {
                assert_eq!(recs, vec![rec.clone()], "flip at byte {i} changed data");
            }
        }
    }

    #[test]
    fn salvage_recovers_the_intact_prefix() {
        let r1 = sample("a@p#m", 1.0, vec![1, 2, 3]);
        let r2 = sample("b@p#m", 2.0, vec![4, 5]);
        let mut bytes = header_bytes().to_vec();
        bytes.extend_from_slice(&r1.encode());
        let r2_start = bytes.len();
        bytes.extend_from_slice(&r2.encode());

        // A cut inside the second record keeps exactly the first.
        let torn = &bytes[..r2_start + 10];
        let s = salvage_file(torn).unwrap();
        assert_eq!(s.records, vec![r1.clone()]);
        assert_eq!(s.valid_len, r2_start);
        assert!(s.damage.as_deref().unwrap().contains("record 1"), "{:?}", s.damage);

        // A clean file salvages whole with no damage.
        let s = salvage_file(&bytes).unwrap();
        assert_eq!(s.records, vec![r1.clone(), r2.clone()]);
        assert_eq!(s.valid_len, bytes.len());
        assert!(s.damage.is_none());

        // A bit flip in the tail record drops it but keeps the prefix.
        let mut evil = bytes.clone();
        evil[r2_start + 60] ^= 0xff;
        let s = salvage_file(&evil).unwrap();
        assert_eq!(s.records, vec![r1]);
        assert_eq!(s.valid_len, r2_start);
        assert!(s.damage.is_some());

        // Header damage is still a hard error, never a salvage.
        let mut bad = bytes;
        bad[0] = b'X';
        assert!(salvage_file(&bad).is_err());
        assert!(salvage_file(&[1, 2]).is_err());
    }

    #[test]
    fn oversized_genome_length_rejected() {
        let rec = sample("t@p#m", 1.0, vec![1]);
        let mut bytes = rec.encode();
        // Claim a huge payload length.
        bytes[..4].copy_from_slice(&(u32::MAX / 2).to_le_bytes());
        assert!(MemRecord::decode(&bytes).is_err());
    }

    #[test]
    fn long_tags_truncate_on_char_boundaries() {
        let long = "w".repeat(100) + "é";
        let rec = sample(&long, 1.0, vec![1]);
        let (back, _) = MemRecord::decode(&rec.encode()).unwrap();
        assert_eq!(back.tag.len(), TAG_LEN);
        assert!(long.starts_with(&back.tag));
    }
}
