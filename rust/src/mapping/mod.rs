//! Mapping representation: five mapping levels over the 3-level storage
//! template (Fig. 4), per-level dimension tiling + loop permutations.

pub mod loopnest;
pub mod permutation;

use crate::workload::Workload;

/// The five mapping levels, outer to inner (Fig. 4):
/// `L1_T` (DRAM→GLB temporal), `L2_T` (GLB temporal), `L2_S` (spatial
/// across PEs), `L3_T` (PE-buffer temporal), `L3_S` (spatial across MACs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MapLevel {
    L1T,
    L2T,
    L2S,
    L3T,
    L3S,
}

pub const NUM_MAP_LEVELS: usize = 5;

impl MapLevel {
    pub const ALL: [MapLevel; NUM_MAP_LEVELS] =
        [MapLevel::L1T, MapLevel::L2T, MapLevel::L2S, MapLevel::L3T, MapLevel::L3S];

    pub fn index(self) -> usize {
        match self {
            MapLevel::L1T => 0,
            MapLevel::L2T => 1,
            MapLevel::L2S => 2,
            MapLevel::L3T => 3,
            MapLevel::L3S => 4,
        }
    }

    pub fn from_index(i: usize) -> MapLevel {
        Self::ALL[i]
    }

    pub fn is_spatial(self) -> bool {
        matches!(self, MapLevel::L2S | MapLevel::L3S)
    }

    pub fn name(self) -> &'static str {
        match self {
            MapLevel::L1T => "L1_T",
            MapLevel::L2T => "L2_T",
            MapLevel::L2S => "L2_S",
            MapLevel::L3T => "L3_T",
            MapLevel::L3S => "L3_S",
        }
    }
}

/// A fully specified mapping for a workload: per-level tile factor of
/// every dimension, plus a per-level loop permutation.
#[derive(Clone, Debug, PartialEq)]
pub struct Mapping {
    /// `tile[level][dim]` — iteration count of dim `dim` at mapping level
    /// `level`. For every dim, the product across levels equals the padded
    /// dimension size.
    pub tile: Vec<Vec<u64>>,
    /// `perm[level]` — order (outer→inner) of dim indices at this level.
    pub perm: Vec<Vec<usize>>,
}

impl Mapping {
    /// Fresh mapping with all factors 1 at every level except `home`,
    /// which gets the full dim size, and identity permutations.
    pub fn trivial(w: &Workload, home: MapLevel) -> Mapping {
        let d = w.rank();
        let mut tile = vec![vec![1u64; d]; NUM_MAP_LEVELS];
        for (i, dim) in w.dims.iter().enumerate() {
            tile[home.index()][i] = dim.padded;
        }
        Mapping { tile, perm: vec![(0..d).collect(); NUM_MAP_LEVELS] }
    }

    pub fn rank(&self) -> usize {
        self.tile[0].len()
    }

    /// Product of a dim's factors across all levels (should equal the
    /// padded size).
    pub fn dim_product(&self, dim: usize) -> u64 {
        self.tile.iter().map(|lvl| lvl[dim]).product()
    }

    /// Does this mapping tile every dim to exactly its padded size?
    pub fn respects(&self, w: &Workload) -> bool {
        w.dims.iter().enumerate().all(|(i, d)| self.dim_product(i) == d.padded)
    }

    /// Spatial fan-out at a spatial level: product of all dims' factors.
    pub fn fanout(&self, level: MapLevel) -> u64 {
        debug_assert!(level.is_spatial());
        self.tile[level.index()].iter().product()
    }

    /// Pretty multi-line loop-nest rendering (Fig. 4 style).
    pub fn render(&self, w: &Workload) -> String {
        let mut out = String::new();
        let mut indent = 0;
        for level in MapLevel::ALL {
            let li = level.index();
            for &d in &self.perm[li] {
                let bound = self.tile[li][d];
                if bound == 1 {
                    continue;
                }
                for _ in 0..indent {
                    out.push_str("  ");
                }
                let kw = if level.is_spatial() { "par-for" } else { "for" };
                out.push_str(&format!(
                    "{kw} {}{} in [0,{})   # {}\n",
                    w.dims[d].name.to_lowercase(),
                    li + 1,
                    bound,
                    level.name()
                ));
                indent += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl() -> Workload {
        Workload::spmm("t", 4, 8, 4, 0.5, 0.5)
    }

    #[test]
    fn trivial_respects_workload() {
        let w = wl();
        for lvl in MapLevel::ALL {
            let m = Mapping::trivial(&w, lvl);
            assert!(m.respects(&w));
        }
    }

    #[test]
    fn fanout_counts_spatial_product() {
        let w = wl();
        let mut m = Mapping::trivial(&w, MapLevel::L1T);
        m.tile[MapLevel::L2S.index()] = vec![2, 1, 2];
        assert_eq!(m.fanout(MapLevel::L2S), 4);
        assert_eq!(m.fanout(MapLevel::L3S), 1);
    }

    #[test]
    fn render_skips_unit_loops() {
        let w = wl();
        let m = Mapping::trivial(&w, MapLevel::L2T);
        let r = m.render(&w);
        assert_eq!(r.lines().count(), 3); // only the three L2_T loops
        assert!(r.contains("for m2 in [0,4)"));
        assert!(r.contains("for k2 in [0,8)"));
    }

    #[test]
    fn level_indices() {
        for (i, l) in MapLevel::ALL.iter().enumerate() {
            assert_eq!(l.index(), i);
            assert_eq!(MapLevel::from_index(i), *l);
        }
        assert!(MapLevel::L2S.is_spatial());
        assert!(!MapLevel::L3T.is_spatial());
    }
}
