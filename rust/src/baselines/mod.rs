//! Prior-work and classical-optimizer baselines (§III.C, §V): random /
//! Sparseloop-Mapper-like / SAGE-like sampling arms, PSO, MCTS, TBPSA,
//! PPO, DQN, and the direct-encoding standard ES ablation.
//!
//! Each module exposes its algorithm two ways:
//!
//! * an owning convenience function (`pso(ctx, seed) -> Outcome`) for
//!   bespoke drivers, and
//! * a config-parameterized core (`pso_with(&mut ctx, &PsoConfig, seed)`)
//!   that the [`crate::optimizer`] registry builds [`Optimizer`]s from —
//!   method dispatch, name validation and `method_opts` all live there,
//!   not here.
//!
//! [`Optimizer`]: crate::optimizer::Optimizer

pub mod common;
pub mod direct;
pub mod es_direct;
pub mod mcts;
pub mod nn;
pub mod pso;
pub mod rl;
pub mod samplers;
pub mod space;
pub mod tbpsa;

pub use direct::DirectSpec;
pub use es_direct::es_direct;
pub use mcts::mcts;
pub use pso::pso;
pub use rl::{dqn, ppo};
pub use samplers::{pure_random, sage_like, sparseloop_mapper};
pub use tbpsa::tbpsa;

// Historical home of method dispatch; re-exported so seed-era imports
// keep working. The registry in `crate::optimizer` is the source of
// truth now.
pub use crate::optimizer::{run_method, ALL_METHODS};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::{Backend, EvalContext};
    use crate::workload::Workload;

    #[test]
    fn methods_identical_serial_vs_parallel() {
        // Parallel evaluation must not perturb any arm's trajectory:
        // `pso` exercises `eval_batch`, `es-direct` the foreign-encoding
        // `eval_designs` path.
        for m in ["pso", "es-direct"] {
            let w = Workload::spmm("t", 16, 16, 16, 0.5, 0.5);
            let serial_ctx = EvalContext::new(Backend::native(w.clone(), Platform::mobile()), 200);
            let serial = run_method(m, serial_ctx, 9).unwrap();
            let pool = std::sync::Arc::new(crate::util::threadpool::ThreadPool::new(4));
            let par_ctx = EvalContext::new(Backend::native(w, Platform::mobile()), 200)
                .with_pool(Some(pool));
            let par = run_method(m, par_ctx, 9).unwrap();
            assert_eq!(serial.best_edp, par.best_edp, "{m}");
            assert_eq!(serial.best_genome, par.best_genome, "{m}");
            assert_eq!(serial.curve, par.curve, "{m}");
        }
    }

    #[test]
    fn owning_wrappers_match_registry_dispatch() {
        // The convenience functions and the registry build the exact
        // same searches from defaults.
        let mk = || {
            let w = Workload::spmm("t", 16, 16, 16, 0.5, 0.5);
            EvalContext::new(Backend::native(w, Platform::mobile()), 150)
        };
        let a = pso(mk(), 4);
        let b = run_method("pso", mk(), 4).unwrap();
        assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits());
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.evals, b.evals);
    }
}
