//! Quickstart: search one workload on one platform and print the winning
//! accelerator design.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sparsemap::arch::Platform;
use sparsemap::es::{run_sparsemap, EsConfig};
use sparsemap::genome::{decode, describe, GenomeSpec};
use sparsemap::search::{Backend, EvalContext};
use sparsemap::workload::table3;

fn main() -> anyhow::Result<()> {
    // 1. Pick a workload (DeepBench bibd-class SpMM) and a platform.
    let workload = table3::by_id("mm3").expect("table III workload");
    let platform = Platform::cloud();
    println!(
        "searching {} ({}) on {} ...",
        workload.id,
        workload.kind.as_str(),
        platform.name
    );

    // 2. Run the SparseMap evolution strategy with a 10k-sample budget.
    let ctx = EvalContext::new(Backend::native(workload.clone(), platform), 10_000);
    let outcome = run_sparsemap(ctx, EsConfig::default(), 42);

    // 3. Report.
    println!(
        "best EDP: {:.4e} pJ*cycles  ({} evals, {:.1}% of explored points valid)",
        outcome.best_edp,
        outcome.evals,
        100.0 * outcome.valid_ratio()
    );
    let genome = outcome.best_genome.expect("no valid design found");
    let spec = GenomeSpec::for_workload(&workload);
    let design = decode(&spec, &workload, &genome);
    println!("--- winning design ---\n{}", describe(&design, &workload));

    println!("convergence (evals -> best EDP):");
    for (e, v) in outcome.curve.iter().take(12) {
        println!("  {:>6} -> {:.4e}", e, v);
    }
    Ok(())
}
