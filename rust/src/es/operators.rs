//! Customized evolutionary operators (§IV.E): annealing mutation and
//! sensitivity-aware crossover.

use crate::genome::{ops, GenomeSpec};
use crate::util::rng::Pcg64;

/// Eq. 6: probability that a mutation lands in the *high-sensitivity*
/// segment at generation `g` of `total` — starts at 0.8 and anneals to 0.
pub fn p_high(g: usize, total: usize) -> f64 {
    let phi = if total == 0 { 1.0 } else { g as f64 / total as f64 };
    (0.8 * (-phi).exp() * (1.0 - phi)).clamp(0.0, 1.0)
}

/// Annealing mutation: choose the high- or low-sensitivity segment with
/// probability `p_high(g)` / `1 - p_high(g)` (Eq. 6/7), then mutate one
/// gene of that segment uniformly within its range.
pub fn annealing_mutation(
    spec: &GenomeSpec,
    genome: &mut [u32],
    high: &[usize],
    low: &[usize],
    g: usize,
    total: usize,
    rng: &mut Pcg64,
) {
    let use_high = !high.is_empty() && (low.is_empty() || rng.chance(p_high(g, total)));
    let segment = if use_high { high } else { low };
    if segment.is_empty() {
        // No segmentation available: plain point mutation.
        ops::point_mutation(spec, genome, 0.0, rng);
        return;
    }
    let idx = *rng.choose(segment);
    ops::mutate_gene(spec, genome, idx, rng);
}

/// Crossover cut points aligned with the *natural boundaries of
/// high-sensitivity segments*: positions where gene sensitivity class
/// changes. Cutting there never fragments a contiguous high-sensitivity
/// run, which is what produces dead offspring (§IV.E).
pub fn sensitivity_boundaries(len: usize, high: &[usize]) -> Vec<usize> {
    let is_high: Vec<bool> = {
        let mut v = vec![false; len];
        for &i in high {
            if i < len {
                v[i] = true;
            }
        }
        v
    };
    (1..len).filter(|&i| is_high[i] != is_high[i - 1]).collect()
}

/// Sensitivity-aware crossover: single cut at a sensitivity boundary.
pub fn sensitivity_aware_crossover(
    a: &[u32],
    b: &[u32],
    high: &[usize],
    rng: &mut Pcg64,
) -> (Vec<u32>, Vec<u32>) {
    let bounds = sensitivity_boundaries(a.len(), high);
    ops::boundary_crossover(a, b, &bounds, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Workload;

    #[test]
    fn p_high_anneals_to_zero() {
        assert!((p_high(0, 100) - 0.8).abs() < 1e-12);
        assert!(p_high(50, 100) < p_high(10, 100));
        assert!(p_high(100, 100) < 1e-12);
        // Monotone decreasing.
        let vals: Vec<f64> = (0..=100).map(|g| p_high(g, 100)).collect();
        assert!(vals.windows(2).all(|w| w[1] <= w[0] + 1e-15));
    }

    #[test]
    fn early_mutations_prefer_high_segment() {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let spec = GenomeSpec::for_workload(&w);
        let high: Vec<usize> = (0..5).collect(); // pretend perms are high
        let low: Vec<usize> = (5..spec.len()).collect();
        let mut rng = Pcg64::seeded(2);
        let base = spec.random(&mut rng);
        let mut high_hits = 0;
        let n = 400;
        for _ in 0..n {
            let mut g = base.clone();
            annealing_mutation(&spec, &mut g, &high, &low, 0, 100, &mut rng);
            let changed: Vec<usize> =
                (0..g.len()).filter(|&i| g[i] != base[i]).collect();
            assert!(changed.len() <= 1);
            if changed.first().map(|&i| i < 5).unwrap_or(false) {
                high_hits += 1;
            }
        }
        // P_h(0) = 0.8 — expect roughly 80% (allowing sampling noise and
        // same-value re-rolls).
        assert!(high_hits > n / 2, "high_hits = {high_hits}/{n}");
    }

    #[test]
    fn late_mutations_prefer_low_segment() {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let spec = GenomeSpec::for_workload(&w);
        let high: Vec<usize> = (0..5).collect();
        let low: Vec<usize> = (5..spec.len()).collect();
        let mut rng = Pcg64::seeded(3);
        let base = spec.random(&mut rng);
        let mut high_hits = 0;
        for _ in 0..400 {
            let mut g = base.clone();
            annealing_mutation(&spec, &mut g, &high, &low, 95, 100, &mut rng);
            if (0..5).any(|i| g[i] != base[i]) {
                high_hits += 1;
            }
        }
        assert!(high_hits < 40, "high_hits = {high_hits}");
    }

    #[test]
    fn boundaries_at_class_changes() {
        // genes: L L H H L  -> boundaries at 2 and 4.
        let b = sensitivity_boundaries(5, &[2, 3]);
        assert_eq!(b, vec![2, 4]);
        // All low: no boundaries.
        assert!(sensitivity_boundaries(5, &[]).is_empty());
    }

    #[test]
    fn crossover_never_splits_high_run() {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let spec = GenomeSpec::for_workload(&w);
        let high: Vec<usize> = vec![6, 7, 8]; // a contiguous high run
        let mut rng = Pcg64::seeded(4);
        let a: Vec<u32> = spec.ranges.iter().map(|r| r.lo).collect();
        let b: Vec<u32> = spec.ranges.iter().map(|r| r.hi).collect();
        for _ in 0..60 {
            let (c1, _) = sensitivity_aware_crossover(&a, &b, &high, &mut rng);
            // Within the high run, all genes must come from one parent.
            let from_a = high.iter().filter(|&&i| c1[i] == a[i]).count();
            assert!(from_a == 0 || from_a == high.len(), "run fragmented");
        }
    }
}
