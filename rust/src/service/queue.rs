//! The job queue (priority + submission order) and per-tenant budget
//! quota accounting.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

/// Heap entry: higher `priority` first; FIFO (lower `seq`) within a
/// priority, so equal-priority jobs run in submission order.
#[derive(Debug, PartialEq, Eq)]
pub struct QueueEntry {
    pub priority: i64,
    pub seq: u64,
    pub job_id: String,
}

impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.priority.cmp(&other.priority).then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A max-heap of [`QueueEntry`] — the pending-job order.
#[derive(Default)]
pub struct JobQueue {
    heap: BinaryHeap<QueueEntry>,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    pub fn push(&mut self, entry: QueueEntry) {
        self.heap.push(entry);
    }

    pub fn pop(&mut self) -> Option<QueueEntry> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Per-tenant eval-budget accounting. Every accepted submission charges
/// its full sample budget against the tenant; once a tenant's total
/// would exceed the limit, further submissions are rejected (HTTP 429).
/// `limit == 0` disables quotas.
pub struct QuotaBook {
    limit: usize,
    spent: HashMap<String, usize>,
}

impl QuotaBook {
    pub fn new(limit: usize) -> QuotaBook {
        QuotaBook { limit, spent: HashMap::new() }
    }

    /// Charge `budget` evals to `tenant`, or explain why not.
    pub fn try_charge(&mut self, tenant: &str, budget: usize) -> Result<(), String> {
        if self.limit == 0 {
            return Ok(());
        }
        let used = self.spent.entry(tenant.to_string()).or_insert(0);
        if *used + budget > self.limit {
            return Err(format!(
                "tenant '{tenant}' over quota: {} of {} evals already granted, \
                 {budget} more requested",
                *used, self.limit
            ));
        }
        *used += budget;
        Ok(())
    }

    pub fn spent(&self, tenant: &str) -> usize {
        self.spent.get(tenant).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(priority: i64, seq: u64) -> QueueEntry {
        QueueEntry { priority, seq, job_id: format!("job-{seq}") }
    }

    #[test]
    fn higher_priority_first_fifo_within() {
        let mut q = JobQueue::new();
        q.push(entry(0, 1));
        q.push(entry(5, 2));
        q.push(entry(0, 3));
        q.push(entry(5, 4));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.seq).collect();
        assert_eq!(order, [2, 4, 1, 3], "priority 5 first, each tier in submission order");
        assert!(q.is_empty());
    }

    #[test]
    fn quota_charges_per_tenant_and_rejects_past_limit() {
        let mut book = QuotaBook::new(100);
        assert!(book.try_charge("a", 60).is_ok());
        assert!(book.try_charge("b", 90).is_ok(), "tenants are independent");
        let err = book.try_charge("a", 60).unwrap_err();
        assert!(err.contains("over quota"), "{err}");
        assert_eq!(book.spent("a"), 60, "rejected charges are not booked");
        assert!(book.try_charge("a", 40).is_ok(), "up to the limit exactly is fine");
        assert_eq!(book.spent("a"), 100);
    }

    #[test]
    fn zero_limit_disables_quota() {
        let mut book = QuotaBook::new(0);
        assert!(book.try_charge("a", usize::MAX / 2).is_ok());
        assert_eq!(book.spent("a"), 0, "disabled quotas book nothing");
    }
}
