//! Direct-value genome encoding — the encoding the paper *argues against*
//! (§IV.B), implemented as the ablation baseline for Fig. 18 and Fig. 10.
//!
//! Genes: five permutation genes under a *random* (scrambled) code→perm
//! table, then one gene per (mapping level, dim) holding the tile factor
//! value directly in `[1, dim]`, then the same strategy genes as PFCE.
//! Dimension-tiling constraints (`∏ tiles == dim`) are NOT guaranteed —
//! violating genomes decode to dead designs, exactly the failure mode
//! prime-factor encoding eliminates.

use crate::genome::spec::{FORMAT_GENES_PER_TENSOR, SG_SITES};
use crate::genome::Design;
use crate::mapping::permutation::factorial;
use crate::mapping::{Mapping, NUM_MAP_LEVELS};
use crate::sparse::{RankFormat, SgMechanism, SparseStrategy};
use crate::util::rng::Pcg64;
use crate::workload::Workload;

/// Direct-encoding genome layout.
#[derive(Clone, Debug)]
pub struct DirectSpec {
    pub rank: usize,
    pub dim_sizes: Vec<u64>,
    /// Scrambled permutation table (random encoding, Fig. 10a): maps gene
    /// value-1 → permutation.
    pub perm_table: Vec<Vec<usize>>,
    pub tile_start: usize,
    pub format_start: usize,
    pub sg_start: usize,
    pub len: usize,
}

impl DirectSpec {
    pub fn new(w: &Workload, seed: u64) -> DirectSpec {
        let rank = w.rank();
        let nperm = factorial(rank) as usize;
        let mut table: Vec<Vec<usize>> =
            (0..nperm).map(|c| crate::mapping::permutation::decode(c as u64 + 1, rank)).collect();
        // Random encoding: scramble the code→permutation assignment.
        let mut rng = Pcg64::new(seed, 0x5eed1234);
        rng.shuffle(&mut table);
        let tile_start = NUM_MAP_LEVELS;
        let format_start = tile_start + NUM_MAP_LEVELS * rank;
        let sg_start = format_start + 3 * FORMAT_GENES_PER_TENSOR;
        DirectSpec {
            rank,
            dim_sizes: w.dims.iter().map(|d| d.padded).collect(),
            perm_table: table,
            tile_start,
            format_start,
            sg_start,
            len: sg_start + SG_SITES,
        }
    }

    /// Uniform random genome (tile genes uniform in `[1, dim]` — almost
    /// never multiplying to the dim size, the paper's 0.000023% point).
    pub fn random(&self, rng: &mut Pcg64) -> Vec<u32> {
        let mut g = Vec::with_capacity(self.len);
        for _ in 0..NUM_MAP_LEVELS {
            g.push(rng.range_u32(1, self.perm_table.len() as u32));
        }
        for level in 0..NUM_MAP_LEVELS {
            let _ = level;
            for &size in &self.dim_sizes {
                g.push(rng.range_u32(1, size as u32));
            }
        }
        for _ in 0..3 * FORMAT_GENES_PER_TENSOR {
            g.push(rng.range_u32(0, 4));
        }
        for _ in 0..SG_SITES {
            g.push(rng.range_u32(0, 6));
        }
        g
    }

    /// Mutate one random gene within its (direct) range.
    pub fn mutate(&self, genome: &mut [u32], rng: &mut Pcg64) {
        let i = rng.index(self.len);
        if i < NUM_MAP_LEVELS {
            genome[i] = rng.range_u32(1, self.perm_table.len() as u32);
        } else if i < self.format_start {
            let dim = (i - self.tile_start) % self.rank;
            genome[i] = rng.range_u32(1, self.dim_sizes[dim] as u32);
        } else if i < self.sg_start {
            genome[i] = rng.range_u32(0, 4);
        } else {
            genome[i] = rng.range_u32(0, 6);
        }
    }

    /// Decode. Returns `None` when the tiling constraint is violated —
    /// a *dead individual* (fitness 0) in the paper's terms.
    pub fn decode(&self, w: &Workload, genome: &[u32]) -> Option<Design> {
        // Tiling constraint check first.
        let mut tile = vec![vec![1u64; self.rank]; NUM_MAP_LEVELS];
        for level in 0..NUM_MAP_LEVELS {
            for dim in 0..self.rank {
                tile[level][dim] =
                    genome[self.tile_start + level * self.rank + dim] as u64;
            }
        }
        for dim in 0..self.rank {
            let prod: u64 = (0..NUM_MAP_LEVELS).map(|l| tile[l][dim]).product();
            if prod != self.dim_sizes[dim] {
                return None;
            }
        }
        let perm: Vec<Vec<usize>> = (0..NUM_MAP_LEVELS)
            .map(|l| {
                let code = (genome[l] as usize - 1) % self.perm_table.len();
                self.perm_table[code].clone()
            })
            .collect();
        let mapping = Mapping { tile, perm };

        let mut formats: [Vec<RankFormat>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for (t, fmts) in formats.iter_mut().enumerate() {
            let ranks = crate::genome::tensor_ranks(&mapping, w, t);
            let start = self.format_start + t * FORMAT_GENES_PER_TENSOR;
            let genes = &genome[start..][..FORMAT_GENES_PER_TENSOR];
            let k = ranks.len();
            *fmts = if k <= FORMAT_GENES_PER_TENSOR {
                genes[FORMAT_GENES_PER_TENSOR - k..]
                    .iter()
                    .map(|&x| RankFormat::from_gene(x))
                    .collect()
            } else {
                let mut v: Vec<RankFormat> =
                    genes.iter().map(|&x| RankFormat::from_gene(x)).collect();
                let pad = k - FORMAT_GENES_PER_TENSOR;
                v.extend(std::iter::repeat(RankFormat::Uncompressed).take(pad));
                v
            };
        }
        let sg = [
            SgMechanism::from_gene(genome[self.sg_start]),
            SgMechanism::from_gene(genome[self.sg_start + 1]),
            SgMechanism::from_gene(genome[self.sg_start + 2]),
        ];
        Some(Design { mapping, strategy: SparseStrategy { formats, sg } })
    }

    /// Fraction of random genomes satisfying the tiling constraint —
    /// reproduces the paper's "0.000023%" style argument quantitatively.
    pub fn tiling_hit_rate(&self, w: &Workload, samples: usize, rng: &mut Pcg64) -> f64 {
        let mut hits = 0;
        for _ in 0..samples {
            let g = self.random(rng);
            if self.decode(w, &g).is_some() {
                hits += 1;
            }
        }
        hits as f64 / samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Workload, DirectSpec) {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let s = DirectSpec::new(&w, 1);
        (w, s)
    }

    #[test]
    fn layout() {
        let (_, s) = setup();
        assert_eq!(s.tile_start, 5);
        assert_eq!(s.format_start, 5 + 15);
        assert_eq!(s.len, 5 + 15 + 15 + 3);
    }

    #[test]
    fn most_random_genomes_are_dead() {
        let (w, s) = setup();
        let mut rng = Pcg64::seeded(2);
        let rate = s.tiling_hit_rate(&w, 3_000, &mut rng);
        // Even for this tiny 4x8x4 workload the hit rate is tiny.
        assert!(rate < 0.05, "rate={rate}");
    }

    #[test]
    fn valid_direct_genome_decodes() {
        let (w, s) = setup();
        let mut g = vec![1u32; s.len];
        // Put the full size at level 0 (L1_T), ones elsewhere.
        for dim in 0..s.rank {
            g[s.tile_start + dim] = s.dim_sizes[dim] as u32;
        }
        for i in s.format_start..s.len {
            g[i] = 0;
        }
        let d = s.decode(&w, &g).expect("should satisfy tiling");
        assert!(d.mapping.respects(&w));
    }

    #[test]
    fn perm_table_is_scrambled_but_complete() {
        let (_, s) = setup();
        assert_eq!(s.perm_table.len(), 6);
        let mut sorted = s.perm_table.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 6); // all distinct permutations present
        // Different seeds give different scrambles (random encoding).
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let s2 = DirectSpec::new(&w, 2);
        assert_ne!(s.perm_table, s2.perm_table);
    }

    #[test]
    fn mutate_stays_interpretable() {
        let (w, s) = setup();
        let mut rng = Pcg64::seeded(3);
        let mut g = s.random(&mut rng);
        for _ in 0..200 {
            s.mutate(&mut g, &mut rng);
        }
        // Decode either succeeds or reports dead — never panics.
        let _ = s.decode(&w, &g);
    }
}
