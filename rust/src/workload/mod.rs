//! Sparse tensor algebra workload definitions.
//!
//! A [`Workload`] is an einsum-like contraction `P ⊙ Q → Z` described by a
//! list of named iteration dimensions, per-tensor dimension projections and
//! sparsity patterns ([`DensityModel`] — a plain scalar density is the
//! `Uniform` model). SpMM is the native form; SpConv is lowered to an
//! implicit GEMM ([`spconv`]). The paper's full benchmark suite (Table III)
//! is provided by [`table3`]; arbitrary custom contractions are built with
//! [`Workload::custom`] / [`Workload::custom_models`] or parsed from a
//! JSON spec ([`spec`]).

pub mod factorize;
pub mod spconv;
pub mod spec;
pub mod table3;

use crate::sparsity::DensityModel;
use crate::util::json::Json;
use anyhow::Context;
use factorize::{factorize, pad_dimension};

/// One iteration-space dimension of a workload.
#[derive(Clone, Debug, PartialEq)]
pub struct Dim {
    /// Human-readable name ("M", "K", "N", "B", ...).
    pub name: String,
    /// Logical size as given by the workload.
    pub size: u64,
    /// Size after padding prime dimensions to composites (what the mapping
    /// actually tiles).
    pub padded: u64,
    /// Prime factors of `padded`, non-decreasing. One genome gene each.
    pub factors: Vec<u64>,
}

impl Dim {
    pub fn new(name: &str, size: u64) -> Self {
        let padded = pad_dimension(size);
        Dim { name: name.to_string(), size, padded, factors: factorize(padded) }
    }
}

/// Role of a tensor in the contraction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorRole {
    /// First input operand (paper's P).
    InputA,
    /// Second input operand (paper's Q).
    InputB,
    /// Output (paper's Z); written with partial-sum accumulation.
    Output,
}

/// Index of a tensor in [`Workload::tensors`]; fixed order P, Q, Z.
pub const TENSOR_P: usize = 0;
pub const TENSOR_Q: usize = 1;
pub const TENSOR_Z: usize = 2;
pub const NUM_TENSORS: usize = 3;

/// A tensor participating in the workload.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub role: TensorRole,
    /// Indices into [`Workload::dims`] this tensor is projected onto,
    /// ordered from its outermost to innermost logical rank.
    pub dims: Vec<usize>,
    /// Sparsity pattern of this tensor. The mean nonzero fraction is
    /// `density.avg()`, in `(0, 1]`; a bare scalar density is
    /// [`DensityModel::Uniform`].
    pub density: DensityModel,
}

/// Kind tag, used for reporting only — both kinds evaluate through the
/// same GEMM-shaped model after lowering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    SpMM,
    SpConv,
    SpBMM,
}

impl WorkloadKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            WorkloadKind::SpMM => "SpMM",
            WorkloadKind::SpConv => "SpConv",
            WorkloadKind::SpBMM => "SpBMM",
        }
    }

    /// Parse a kind tag (case-insensitive). Inverse of [`Self::as_str`].
    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s.to_ascii_lowercase().as_str() {
            "spmm" => Some(WorkloadKind::SpMM),
            "spconv" => Some(WorkloadKind::SpConv),
            "spbmm" => Some(WorkloadKind::SpBMM),
            _ => None,
        }
    }
}

/// Largest supported iteration-space rank: permutation genes store 1-based
/// Cantor codes in a `u32`, and `12! < 2^32 < 13!`.
pub const MAX_RANK: usize = 12;

/// A sparse tensor algebra workload (einsum contraction with densities).
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    pub id: String,
    pub kind: WorkloadKind,
    pub dims: Vec<Dim>,
    /// Exactly three tensors: P, Q, Z (see `TENSOR_*`).
    pub tensors: Vec<TensorSpec>,
    /// Indices of contracted (reduction) dimensions.
    pub contraction: Vec<usize>,
}

impl Workload {
    /// Plain SpMM: `P[M,K] × Q[K,N] = Z[M,N]` with uniform densities.
    ///
    /// Out-of-range densities no longer panic here; every custom / spec
    /// / API path rejects them with a typed error via
    /// [`Workload::validate`]. Direct constructor calls defer that check
    /// to the caller (the Table III suite is valid by construction) —
    /// call `validate()` before evaluating hand-built workloads with
    /// untrusted densities.
    pub fn spmm(id: &str, m: u64, k: u64, n: u64, dp: f64, dq: f64) -> Workload {
        let dims = vec![Dim::new("M", m), Dim::new("K", k), Dim::new("N", n)];
        let dz = output_density(dp, dq, k);
        Workload {
            id: id.to_string(),
            kind: WorkloadKind::SpMM,
            tensors: vec![
                TensorSpec {
                    name: "P".into(),
                    role: TensorRole::InputA,
                    dims: vec![0, 1],
                    density: DensityModel::uniform(dp),
                },
                TensorSpec {
                    name: "Q".into(),
                    role: TensorRole::InputB,
                    dims: vec![1, 2],
                    density: DensityModel::uniform(dq),
                },
                TensorSpec {
                    name: "Z".into(),
                    role: TensorRole::Output,
                    dims: vec![0, 2],
                    density: DensityModel::uniform(dz),
                },
            ],
            dims,
            contraction: vec![1],
        }
    }

    /// Batched SpMM: `P[B,M,K] × Q[B,K,N] = Z[B,M,N]` — the 4-dimension
    /// example of Fig. 15 (multi-dimensional workload support).
    pub fn spbmm(id: &str, b: u64, m: u64, k: u64, n: u64, dp: f64, dq: f64) -> Workload {
        let mut w = Workload::spmm(id, m, k, n, dp, dq);
        w.kind = WorkloadKind::SpBMM;
        w.dims.insert(0, Dim::new("B", b));
        for t in &mut w.tensors {
            for d in &mut t.dims {
                *d += 1;
            }
            t.dims.insert(0, 0); // every tensor carries the batch dim
        }
        w.contraction = vec![2];
        w
    }

    /// Validated constructor for arbitrary einsum-shaped contractions —
    /// the entry point for custom (non-Table-III) scenarios.
    ///
    /// `dims` are the named iteration dimensions; `tensors` are exactly
    /// three `(name, dim indices, density)` triples in P, Q, Z order,
    /// with uniform scalar densities. A non-positive Z density means
    /// "derive it from the operand densities" (see [`output_density`]).
    /// `contraction` lists the reduced dims. For structured sparsity
    /// patterns use [`Workload::custom_models`].
    pub fn custom(
        id: &str,
        kind: WorkloadKind,
        dims: Vec<(String, u64)>,
        tensors: Vec<(String, Vec<usize>, f64)>,
        contraction: Vec<usize>,
    ) -> anyhow::Result<Workload> {
        let tensors = tensors
            .into_iter()
            .map(|(name, dims, density)| {
                let model =
                    if density <= 0.0 { None } else { Some(DensityModel::uniform(density)) };
                (name, dims, model)
            })
            .collect();
        Workload::custom_models(id, kind, dims, tensors, contraction)
    }

    /// Like [`Workload::custom`], but with a full [`DensityModel`] per
    /// tensor. `None` is only valid for the output tensor Z and derives a
    /// uniform density from the operands' mean densities.
    pub fn custom_models(
        id: &str,
        kind: WorkloadKind,
        dims: Vec<(String, u64)>,
        tensors: Vec<(String, Vec<usize>, Option<DensityModel>)>,
        contraction: Vec<usize>,
    ) -> anyhow::Result<Workload> {
        anyhow::ensure!(tensors.len() == NUM_TENSORS, "expected exactly 3 tensors (P, Q, Z)");
        let built_dims: Vec<Dim> = dims.iter().map(|(n, s)| Dim::new(n, *s)).collect();
        let contracted_sizes: f64 = contraction
            .iter()
            .map(|&d| dims.get(d).map_or(1.0, |&(_, s)| s as f64))
            .product();
        let roles = [TensorRole::InputA, TensorRole::InputB, TensorRole::Output];
        let dp = tensors[TENSOR_P].2.as_ref().map_or(0.0, DensityModel::avg);
        let dq = tensors[TENSOR_Q].2.as_ref().map_or(0.0, DensityModel::avg);
        let mut specs = Vec::with_capacity(NUM_TENSORS);
        for ((name, dims, model), role) in tensors.into_iter().zip(roles) {
            let density = match (model, role) {
                (Some(m), _) => m,
                (None, TensorRole::Output) => DensityModel::uniform(output_density(
                    dp,
                    dq,
                    contracted_sizes.max(1.0) as u64,
                )),
                (None, _) => anyhow::bail!("tensor '{name}' is missing a density"),
            };
            specs.push(TensorSpec { name, role, dims, density });
        }
        let w = Workload {
            id: id.to_string(),
            kind,
            dims: built_dims,
            tensors: specs,
            contraction,
        };
        w.validate()?;
        Ok(w)
    }

    /// Check the structural invariants every search path relies on. The
    /// hard-coded constructors satisfy these by construction; custom
    /// workloads (builder or JSON spec) are rejected with a message here.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        ensure!(!self.id.is_empty(), "workload id must not be empty");
        ensure!(!self.dims.is_empty(), "workload needs at least one dimension");
        ensure!(
            self.rank() <= MAX_RANK,
            "rank {} exceeds the supported maximum {MAX_RANK} (Cantor permutation \
             codes must fit a u32 gene)",
            self.rank()
        );
        let mut names = std::collections::HashSet::new();
        for d in &self.dims {
            ensure!(!d.name.is_empty(), "dimension names must not be empty");
            ensure!(d.size >= 1, "dimension '{}' has size 0", d.name);
            ensure!(names.insert(d.name.as_str()), "duplicate dimension name '{}'", d.name);
        }
        ensure!(
            self.tensors.len() == NUM_TENSORS,
            "expected exactly {NUM_TENSORS} tensors (P, Q, Z), got {}",
            self.tensors.len()
        );
        let roles = [TensorRole::InputA, TensorRole::InputB, TensorRole::Output];
        for (t, (spec, role)) in self.tensors.iter().zip(roles).enumerate() {
            ensure!(
                spec.role == role,
                "tensor {t} ('{}') must have role {role:?} (fixed P, Q, Z order)",
                spec.name
            );
            ensure!(
                !spec.dims.is_empty(),
                "tensor '{}' is projected onto no dimensions",
                spec.name
            );
            let mut seen = std::collections::HashSet::new();
            for &d in &spec.dims {
                ensure!(
                    d < self.rank(),
                    "tensor '{}' references dimension index {d}, but the workload has \
                     only {} dims",
                    spec.name,
                    self.rank()
                );
                ensure!(seen.insert(d), "tensor '{}' repeats dimension index {d}", spec.name);
            }
            spec.density
                .validate()
                .with_context(|| format!("tensor '{}' density model", spec.name))?;
            // Banded row lengths are defined as (and re-derived on spec
            // parse from) the tensor's innermost dimension — enforce the
            // match so serialization round-trips are lossless.
            if let DensityModel::Banded { cols, .. } = spec.density {
                let inner = self.dims[*spec.dims.last().unwrap()].size;
                ensure!(
                    cols == inner,
                    "tensor '{}': banded row length {cols} must equal the innermost \
                     dimension size {inner}",
                    spec.name
                );
            }
        }
        ensure!(!self.contraction.is_empty(), "at least one contracted dimension is required");
        let mut contracted = std::collections::HashSet::new();
        for &d in &self.contraction {
            ensure!(
                d < self.rank(),
                "contraction references dimension index {d}, but the workload has only {} dims",
                self.rank()
            );
            ensure!(contracted.insert(d), "contraction repeats dimension '{}'", self.dims[d].name);
            ensure!(
                !self.tensors[TENSOR_Z].dims.contains(&d),
                "contracted dimension '{}' must not be projected onto the output",
                self.dims[d].name
            );
        }
        for (i, d) in self.dims.iter().enumerate() {
            ensure!(
                self.tensors.iter().any(|t| t.dims.contains(&i)),
                "dimension '{}' is projected onto no tensor",
                d.name
            );
        }
        Ok(())
    }

    /// Number of iteration dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total MAC operations of the dense iteration space (padded sizes).
    pub fn total_ops(&self) -> f64 {
        self.dims.iter().map(|d| d.padded as f64).product()
    }

    /// Dense element count of tensor `t` (padded).
    pub fn tensor_elems(&self, t: usize) -> f64 {
        self.tensors[t].dims.iter().map(|&d| self.dims[d].padded as f64).product()
    }

    /// Is dimension `d` relevant to (projected onto) tensor `t`?
    pub fn relevant(&self, t: usize, d: usize) -> bool {
        self.tensors[t].dims.contains(&d)
    }

    /// Mean nonzero fraction of tensor `t` (`density.avg()`) — the
    /// scalar the legacy model consumed everywhere.
    pub fn density(&self, t: usize) -> f64 {
        self.tensors[t].density.avg()
    }

    /// Total number of prime-factor genes across all dims.
    pub fn num_factor_genes(&self) -> usize {
        self.dims.iter().map(|d| d.factors.len()).sum()
    }

    /// Lightweight JSON description (used by telemetry dumps).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("kind", Json::str(self.kind.as_str())),
            (
                "dims",
                Json::Arr(
                    self.dims
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("name", Json::str(&d.name)),
                                ("size", Json::num(d.size as f64)),
                                ("padded", Json::num(d.padded as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "tensors",
                Json::Arr(
                    self.tensors
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::str(&t.name)),
                                ("density", Json::num(t.density.avg())),
                                ("pattern", Json::str(t.density.kind_name())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Expected output density of a length-`k` dot product with operand
/// densities `dp`, `dq` under a uniform-random occupancy model:
/// `1 - (1 - dp*dq)^k`, clamped away from 0.
pub fn output_density(dp: f64, dq: f64, k: u64) -> f64 {
    let p = 1.0 - (1.0 - dp * dq).powf(k as f64);
    p.clamp(1e-6, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmm_shape() {
        let w = Workload::spmm("t", 32, 64, 48, 0.5, 0.25);
        assert_eq!(w.rank(), 3);
        assert_eq!(w.tensors[TENSOR_P].dims, vec![0, 1]);
        assert_eq!(w.tensors[TENSOR_Q].dims, vec![1, 2]);
        assert_eq!(w.tensors[TENSOR_Z].dims, vec![0, 2]);
        assert_eq!(w.contraction, vec![1]);
        assert_eq!(w.total_ops(), (32 * 64 * 48) as f64);
        assert_eq!(w.tensor_elems(TENSOR_P), (32 * 64) as f64);
    }

    #[test]
    fn prime_dim_padded() {
        let w = Workload::spmm("t", 31, 64, 48, 0.5, 0.5);
        assert_eq!(w.dims[0].size, 31);
        assert_eq!(w.dims[0].padded, 32);
        assert_eq!(w.dims[0].factors, vec![2, 2, 2, 2, 2]);
    }

    #[test]
    fn output_density_monotone() {
        // Denser inputs and longer dot products -> denser output.
        assert!(output_density(0.5, 0.5, 64) > output_density(0.1, 0.1, 64));
        assert!(output_density(0.1, 0.1, 1024) > output_density(0.1, 0.1, 4));
        assert!(output_density(1.0, 1.0, 1) == 1.0);
    }

    #[test]
    fn bmm_has_four_dims() {
        let w = Workload::spbmm("b", 8, 16, 32, 16, 0.5, 0.5);
        assert_eq!(w.rank(), 4);
        assert_eq!(w.dims[0].name, "B");
        // Batch dim is relevant to every tensor, K only to P and Q.
        for t in 0..NUM_TENSORS {
            assert!(w.relevant(t, 0));
        }
        assert!(w.relevant(TENSOR_P, 2) && w.relevant(TENSOR_Q, 2) && !w.relevant(TENSOR_Z, 2));
        assert_eq!(w.contraction, vec![2]);
    }

    #[test]
    fn factor_gene_count() {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        // 4 = 2*2 (2 genes), 8 = 2*2*2 (3), 4 = 2*2 (2)
        assert_eq!(w.num_factor_genes(), 7);
    }

    #[test]
    fn zero_density_rejected_by_validate() {
        // Construction no longer panics; validation (run by every custom
        // / spec / API path) reports a typed error instead.
        let w = Workload::spmm("t", 4, 4, 4, 0.0, 0.5);
        let err = w.validate().unwrap_err();
        assert!(format!("{err:?}").contains("density"), "{err:?}");
    }

    #[test]
    fn structured_models_flow_through_custom_models() {
        let w = Workload::custom_models(
            "t",
            WorkloadKind::SpMM,
            vec![("M".into(), 32), ("K".into(), 64), ("N".into(), 48)],
            vec![
                ("P".into(), vec![0, 1], Some(DensityModel::block(16, 0.25))),
                ("Q".into(), vec![1, 2], Some(DensityModel::banded(8, 48))),
                ("Z".into(), vec![0, 2], None),
            ],
            vec![1],
        )
        .unwrap();
        assert_eq!(w.density(TENSOR_P), 0.25);
        assert!((w.density(TENSOR_Q) - 8.0 / 48.0).abs() < 1e-12);
        // The derived output density comes from the operands' means.
        assert_eq!(
            w.tensors[TENSOR_Z].density,
            DensityModel::uniform(output_density(0.25, 8.0 / 48.0, 64))
        );
        // A missing input density is a typed error, not a panic.
        assert!(Workload::custom_models(
            "t",
            WorkloadKind::SpMM,
            vec![("M".into(), 8), ("K".into(), 8), ("N".into(), 8)],
            vec![
                ("P".into(), vec![0, 1], None),
                ("Q".into(), vec![1, 2], Some(DensityModel::uniform(0.5))),
                ("Z".into(), vec![0, 2], None),
            ],
            vec![1],
        )
        .is_err());
        // A banded row length that disagrees with the tensor's innermost
        // dimension would not survive a spec round-trip — rejected.
        assert!(Workload::custom_models(
            "t",
            WorkloadKind::SpMM,
            vec![("M".into(), 8), ("K".into(), 8), ("N".into(), 8)],
            vec![
                ("P".into(), vec![0, 1], Some(DensityModel::banded(2, 1024))),
                ("Q".into(), vec![1, 2], Some(DensityModel::uniform(0.5))),
                ("Z".into(), vec![0, 2], None),
            ],
            vec![1],
        )
        .is_err());
    }

    #[test]
    fn custom_matches_spmm_constructor() {
        let built = Workload::custom(
            "t",
            WorkloadKind::SpMM,
            vec![("M".into(), 32), ("K".into(), 64), ("N".into(), 48)],
            vec![
                ("P".into(), vec![0, 1], 0.5),
                ("Q".into(), vec![1, 2], 0.25),
                ("Z".into(), vec![0, 2], 0.0),
            ],
            vec![1],
        )
        .unwrap();
        assert_eq!(built, Workload::spmm("t", 32, 64, 48, 0.5, 0.25));
    }

    #[test]
    fn builtin_constructors_validate() {
        assert!(Workload::spmm("t", 32, 64, 48, 0.5, 0.25).validate().is_ok());
        assert!(Workload::spbmm("b", 8, 16, 32, 16, 0.5, 0.5).validate().is_ok());
    }

    #[test]
    fn custom_rejects_structural_errors() {
        let dims = || vec![("M".to_string(), 8), ("K".to_string(), 8), ("N".to_string(), 8)];
        let tensors = || {
            vec![
                ("P".to_string(), vec![0, 1], 0.5),
                ("Q".to_string(), vec![1, 2], 0.5),
                ("Z".to_string(), vec![0, 2], 0.0),
            ]
        };
        // Contracted dim projected onto the output.
        assert!(Workload::custom("t", WorkloadKind::SpMM, dims(), tensors(), vec![0]).is_err());
        // No contraction at all.
        assert!(Workload::custom("t", WorkloadKind::SpMM, dims(), tensors(), vec![]).is_err());
        // Repeated contraction entries (would skew the derived density).
        assert!(Workload::custom("t", WorkloadKind::SpMM, dims(), tensors(), vec![1, 1]).is_err());
        // Duplicate dim names.
        let mut dd = dims();
        dd[2].0 = "M".to_string();
        assert!(Workload::custom("t", WorkloadKind::SpMM, dd, tensors(), vec![1]).is_err());
        // Rank above the Cantor-code ceiling.
        let many: Vec<(String, u64)> = (0..=MAX_RANK).map(|i| (format!("D{i}"), 2)).collect();
        let wide = vec![
            ("P".to_string(), (0..MAX_RANK).collect::<Vec<_>>(), 0.5),
            ("Q".to_string(), vec![MAX_RANK - 1, MAX_RANK], 0.5),
            ("Z".to_string(), (0..MAX_RANK - 1).chain([MAX_RANK]).collect(), 1.0),
        ];
        assert!(Workload::custom("t", WorkloadKind::SpMM, many, wide, vec![MAX_RANK - 1])
            .is_err());
    }
}
