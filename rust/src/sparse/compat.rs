//! Sparse-strategy ⇄ mapping compatibility rules.
//!
//! §III.B-2 of the paper: a large share of the joint design space is
//! *invalid* — either resources are over-subscribed or the mapping and
//! sparse strategy are mutually inconsistent. These rules define the
//! inconsistency half (capacity/fanout checks live in `model::validity`):
//!
//! 1. **Skipping needs metadata.** A skip mechanism driven by operand X
//!    requires X to have at least one compressing rank at (or above) the
//!    site — otherwise there is no nonzero-location metadata to jump with.
//! 2. **UOP needs a compressed child.** `UOP` encodes segment offsets
//!    *into* a compressed child rank; it is invalid at the innermost rank
//!    of a stack and invalid directly above an uncompressed rank (there
//!    are no variable-length segments to offset into). Plain uncompressed
//!    ranks under Bitmask/RLE/CP are fine — that is ordinary block-sparse
//!    storage (dense payload blocks under sparse outer coordinates).

use super::format::RankFormat;
use super::saf::SgMechanism;

/// Why a strategy/mapping combination is invalid. Used for diagnostics
/// and for Fig. 7-style invalid-point analysis.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Incompat {
    /// Skip mechanism at `site` drives on a tensor with no compressed rank.
    SkipNeedsCompressedDriver { site: &'static str, tensor: &'static str },
    /// UOP at the innermost rank of the tensor's stack.
    UopAtLeaf { tensor: &'static str },
    /// UOP directly above an uncompressed rank (no segments to index).
    UopNeedsCompressedChild { tensor: &'static str },
}

impl std::fmt::Display for Incompat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Incompat::SkipNeedsCompressedDriver { site, tensor } => {
                write!(f, "skip at {site} drives on uncompressed tensor {tensor}")
            }
            Incompat::UopAtLeaf { tensor } => {
                write!(f, "UOP at innermost rank of {tensor}")
            }
            Incompat::UopNeedsCompressedChild { tensor } => {
                write!(f, "UOP above an uncompressed rank in {tensor}")
            }
        }
    }
}

/// Check a per-tensor format stack (outer→inner ranks) for structural
/// validity (rule 2 in both halves).
pub fn check_stack(tensor: &'static str, stack: &[RankFormat]) -> Vec<Incompat> {
    let mut problems = Vec::new();
    for (i, f) in stack.iter().enumerate() {
        if *f != RankFormat::UncompressedOffsetPair {
            continue;
        }
        match stack.get(i + 1) {
            // UOP at the innermost rank: nothing to offset into.
            None => {
                problems.push(Incompat::UopAtLeaf { tensor });
                break;
            }
            // UOP above a dense rank: segments are fixed-length, the
            // offset array is meaningless (and the hardware indexer
            // expects variable-length children).
            Some(child) if !child.compressing() => {
                problems.push(Incompat::UopNeedsCompressedChild { tensor });
                break;
            }
            Some(_) => {}
        }
    }
    problems
}

/// Check S/G mechanisms against the P/Q format stacks (rule 1). `sites`
/// pairs a site name with its mechanism.
pub fn check_saf(
    sites: &[(&'static str, SgMechanism)],
    p_compressed: bool,
    q_compressed: bool,
) -> Vec<Incompat> {
    let mut problems = Vec::new();
    for &(site, m) in sites {
        if !m.is_skip() {
            continue;
        }
        let (needs_p, needs_q) = m.drivers();
        if needs_p && !p_compressed {
            problems.push(Incompat::SkipNeedsCompressedDriver { site, tensor: "P" });
        }
        if needs_q && !q_compressed {
            problems.push(Incompat::SkipNeedsCompressedDriver { site, tensor: "Q" });
        }
    }
    problems
}

#[cfg(test)]
mod tests {
    use super::*;
    use RankFormat::*;

    #[test]
    fn csr_is_valid() {
        assert!(check_stack("P", &[UncompressedOffsetPair, CoordinatePayload]).is_empty());
    }

    #[test]
    fn uop_leaf_invalid() {
        let p = check_stack("P", &[Bitmask, UncompressedOffsetPair]);
        assert_eq!(p, vec![Incompat::UopAtLeaf { tensor: "P" }]);
        // UOP alone is also a leaf.
        assert!(!check_stack("Q", &[UncompressedOffsetPair]).is_empty());
    }

    #[test]
    fn uop_over_dense_invalid_but_blocksparse_fine() {
        let p = check_stack("P", &[UncompressedOffsetPair, Uncompressed]);
        assert!(p.contains(&Incompat::UopNeedsCompressedChild { tensor: "P" }));
        // Block-sparse: compressed outer rank over dense payload — valid.
        assert!(check_stack("P", &[Bitmask, Uncompressed]).is_empty());
        assert!(check_stack("P", &[Uncompressed, Bitmask]).is_empty());
    }

    #[test]
    fn fully_uncompressed_valid() {
        assert!(check_stack("Z", &[Uncompressed, Uncompressed]).is_empty());
    }

    #[test]
    fn skip_requires_driver_metadata() {
        let sites = [("GLB", SgMechanism::SkipPfromQ)];
        // Q uncompressed -> invalid.
        let p = check_saf(&sites, true, false);
        assert_eq!(p.len(), 1);
        // Q compressed -> fine.
        assert!(check_saf(&sites, false, true).is_empty());
    }

    #[test]
    fn gate_never_needs_metadata() {
        let sites = [("C", SgMechanism::GateBoth)];
        assert!(check_saf(&sites, false, false).is_empty());
    }

    #[test]
    fn double_sided_skip_needs_both() {
        let sites = [("PEBuf", SgMechanism::SkipBoth)];
        assert_eq!(check_saf(&sites, false, false).len(), 2);
        assert_eq!(check_saf(&sites, true, false).len(), 1);
        assert!(check_saf(&sites, true, true).is_empty());
    }
}
