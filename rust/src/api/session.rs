//! [`SearchSession`] — a validated request, ready to run.

use super::report::SearchReport;
use super::request::SearchRequest;
use crate::arch::Platform;
use crate::memory::MemoryStore;
use crate::obs::{Metrics, TraceObserver, TraceWriter};
use crate::optimizer::{self, Checkpoint};
use crate::search::{Backend, EvalContext, SearchObserver};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use crate::workload::Workload;
use anyhow::{ensure, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Options for [`SearchSession::run_opts`] — the one run entry point.
/// Every field defaults to off, so `RunOpts::default()` is a plain
/// uninterrupted run.
#[derive(Default)]
pub struct RunOpts {
    /// Streaming observer: called after every evaluated batch with
    /// evals, cache hits and best-so-far EDP; returning
    /// [`crate::search::SearchControl::Stop`] ends the run early.
    pub observer: Option<Box<dyn SearchObserver>>,
    /// Cooperative suspend flag: store `true` (from any thread) and the
    /// optimizer pauses at its next safe point; the report then carries
    /// a [`SearchReport::checkpoint`] to resume from. Unlike the cancel
    /// token, suspension preserves the exact search trajectory — a
    /// resumed run finishes bit-identical to an uninterrupted one.
    pub suspend: Option<Arc<AtomicBool>>,
    /// Resume from a checkpoint captured by a previous suspended run
    /// (same method and budget; the evaluation ledger and the
    /// optimizer's own state are both restored).
    pub resume: Option<Checkpoint>,
    /// A host-supplied design-memory store for warm-starting (the
    /// service shares one across jobs this way). Only consulted when the
    /// request carries a `warm_start` block; takes precedence over the
    /// block's own `store` path.
    pub memory: Option<Arc<Mutex<MemoryStore>>>,
    /// Stream a `sparsemap.trace.v1` NDJSON trace of the run to this
    /// path (CLI: `--trace run.ndjson`; render with `sparsemap trace
    /// summarize`): a `start` header, one `generation` record per
    /// evaluated batch, checkpoint/resume markers, a final per-stage
    /// latency snapshot and a `finish` summary. Composes with
    /// [`RunOpts::observer`] — the trace tees each batch before
    /// delegating. Deterministic modulo the `ms` timestamps; trace IO
    /// errors after file creation never abort the search.
    pub trace: Option<PathBuf>,
    /// Metrics scope to record into (see [`crate::obs`]): per-stage
    /// latency histograms, eval/cache/stage-memo counters and the
    /// best-EDP gauge. The service passes [`crate::obs::global`] so
    /// `GET /metrics` sees every job; `None` (the library default)
    /// records nothing and keeps the evaluation hot path zero-alloc. A
    /// traced run without an explicit scope gets a private one so its
    /// `stages` snapshot carries data.
    pub metrics: Option<Arc<Metrics>>,
    /// Run-local fault plan for deterministic chaos tests (see
    /// [`crate::util::faults`]): arms the `eval` fault point for this
    /// run only, without touching the process-global plan. `None` (the
    /// default) leaves behavior — and the zero-alloc hot path —
    /// unchanged.
    pub faults: Option<Arc<crate::util::faults::FaultPlan>>,
}

/// A validated search arm. Created by [`SearchRequest::build`]; run with
/// [`SearchSession::run_opts`] (or the [`SearchSession::run`] /
/// [`SearchSession::run_observed`] conveniences). The session owns a
/// cancel token so a run can be aborted from another thread
/// ([`SearchSession::cancel_token`]).
pub struct SearchSession {
    request: SearchRequest,
    workload: Workload,
    platform: Platform,
    stop: Arc<AtomicBool>,
}

impl SearchSession {
    pub(crate) fn new(request: SearchRequest) -> Result<SearchSession> {
        ensure!(request.budget >= 1, "search budget must be at least 1 sample");
        // The registry is the one method-validation path (names, aliases,
        // nearest-match suggestions, and the method_opts schema).
        // Building (and discarding) the optimizer also runs the method's
        // own cross-field checks — e.g. the portfolio rejecting
        // member_opts entries that match none of its members — so every
        // bad request fails here, not mid-run.
        optimizer::resolve(&request.method)?.build(&request.method_opts)?;
        if let Some(ws) = &request.warm_start {
            ws.validate()?;
        }
        let (workload, platform) = request.resolve()?;
        Ok(SearchSession {
            request,
            workload,
            platform,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn request(&self) -> &SearchRequest {
        &self.request
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// Shared cancel token: store `true` (from any thread) and the run
    /// winds down through the algorithms' normal budget-exhausted path,
    /// still returning a well-formed report with `stopped_early` set.
    pub fn cancel_token(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    #[cfg(feature = "xla")]
    fn backend(&self) -> Backend {
        if self.request.use_pjrt {
            match crate::runtime::Runtime::from_default_dir().and_then(|rt| {
                Backend::pjrt(&rt, self.workload.clone(), self.platform.clone())
            }) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("warning: PJRT backend unavailable ({e}); using native");
                    Backend::native(self.workload.clone(), self.platform.clone())
                }
            }
        } else {
            Backend::native(self.workload.clone(), self.platform.clone())
        }
    }

    #[cfg(not(feature = "xla"))]
    fn backend(&self) -> Backend {
        if self.request.use_pjrt {
            eprintln!("warning: built without the `xla` feature; using the native backend");
        }
        Backend::native(self.workload.clone(), self.platform.clone())
    }

    fn make_context(&self, observer: Option<Box<dyn SearchObserver>>) -> EvalContext {
        let pool = if self.request.threads > 1 {
            Some(Arc::new(ThreadPool::new(self.request.threads)))
        } else {
            None
        };
        EvalContext::new(self.backend(), self.request.budget)
            .with_cache(self.request.cache)
            .with_pool(pool)
            .with_stop_flag(Some(Arc::clone(&self.stop)))
            .with_observer(observer)
    }

    /// Lower the session into a raw [`EvalContext`] — the escape hatch
    /// for drivers that run their own loop over the evaluator (gene
    /// calibration, the Fig. 10 encoding study) rather than a method
    /// from [`crate::optimizer::ALL_METHODS`].
    pub fn into_context(self) -> EvalContext {
        self.make_context(None)
    }

    /// Run the arm to completion (budget exhausted or cancelled).
    ///
    /// Convenience over [`SearchSession::run_opts`] with everything off
    /// — prefer `run_opts` in new code; it additionally covers progress
    /// streaming, cooperative suspension and checkpoint resume.
    pub fn run(self) -> Result<SearchReport> {
        self.run_opts(RunOpts::default())
    }

    /// Run with a streaming observer.
    ///
    /// Convenience over [`SearchSession::run_opts`] with only the
    /// observer set — prefer `run_opts` in new code.
    pub fn run_observed(self, observer: Box<dyn SearchObserver>) -> Result<SearchReport> {
        self.run_opts(RunOpts { observer: Some(observer), ..Default::default() })
    }

    /// The one run entry point: observer streaming, cooperative
    /// suspension and checkpoint resume in any combination (see
    /// [`RunOpts`]).
    ///
    /// When the suspend flag is raised mid-run, the optimizer pauses at
    /// its next safe point and the report comes back with
    /// `stopped_early` set and [`SearchReport::checkpoint`] holding a
    /// serialized [`Checkpoint`] (optimizer state + evaluation ledger).
    /// Feeding that checkpoint back through [`RunOpts::resume`] on a
    /// fresh session with the same request finishes the search
    /// bit-identical to one that was never interrupted.
    pub fn run_opts(self, opts: RunOpts) -> Result<SearchReport> {
        let spec = optimizer::resolve(&self.request.method)?;
        let mut opt = spec.build(&self.request.method_opts)?;

        // Warm-start: pull the k nearest prior scenarios out of the
        // design memory, re-validate their genomes against *this*
        // scenario's genome spec, and offer them to the optimizer before
        // it runs. A missing store file is an empty store (zero hits, run
        // proceeds cold) — only having no store *configured at all* is an
        // error, since the caller explicitly asked to warm-start.
        let mut memory_hits = 0usize;
        let mut seeded_from: Vec<String> = Vec::new();
        if let Some(ws) = &self.request.warm_start {
            ws.validate()?;
            let gspec = crate::genome::GenomeSpec::for_workload(&self.workload);
            let pull = |store: &MemoryStore| {
                let hits = store.seed(&self.workload, &self.platform, ws.k);
                let genomes = MemoryStore::validated_seed_genomes(&hits, &gspec);
                let mut tags: Vec<String> = Vec::new();
                for h in &hits {
                    if h.genome.len() == gspec.len() && !tags.contains(&h.tag) {
                        tags.push(h.tag.clone());
                    }
                }
                (genomes, tags)
            };
            let (genomes, tags) = if let Some(shared) = &opts.memory {
                let store = shared.lock().unwrap_or_else(|e| e.into_inner());
                pull(&store)
            } else if let Some(path) = &ws.store {
                pull(&MemoryStore::open(path)?)
            } else {
                anyhow::bail!(
                    "warm_start has no store: set warm_start.store, or run through a host \
                     that supplies one (the service's --memory-store, or the CLI's --memory)"
                );
            };
            memory_hits = genomes.len();
            seeded_from = tags;
            if !genomes.is_empty() {
                opt.warm_start(&genomes, ws.fraction);
            }
        }

        // Observability plumbing: a traced run always has a metrics
        // scope (the caller's, or a private one) so its final `stages`
        // snapshot carries real timings; a metrics scope without a
        // trace just records. File *creation* errors fail the run (the
        // caller asked for a trace it would never get); IO errors on an
        // open trace are swallowed — tracing must never abort a search.
        let metrics = match (&opts.metrics, &opts.trace) {
            (Some(m), _) => Some(Arc::clone(m)),
            (None, Some(_)) => Some(Arc::new(Metrics::new())),
            (None, None) => None,
        };
        let trace = match &opts.trace {
            None => None,
            Some(path) => {
                let mut w = TraceWriter::create(path).map_err(|e| {
                    anyhow::anyhow!("cannot create trace file '{}': {e}", path.display())
                })?;
                let _ = w.start(
                    &self.workload.id,
                    &self.platform.name,
                    spec.name,
                    self.request.budget,
                    self.request.seed,
                );
                Some(Arc::new(Mutex::new(w)))
            }
        };
        let observer = match &trace {
            Some(t) => Some(Box::new(TraceObserver::new(Arc::clone(t), opts.observer))
                as Box<dyn SearchObserver>),
            None => opts.observer,
        };

        let mut ctx = self.make_context(observer);
        ctx.set_metrics(metrics.clone());
        ctx.set_suspend_flag(opts.suspend.clone());
        ctx.set_faults(opts.faults.clone());
        let mut resumed_from = None;
        if let Some(cp) = &opts.resume {
            ensure!(
                cp.method == spec.name,
                "checkpoint was captured by method '{}', request asks for '{}'",
                cp.method,
                spec.name
            );
            ctx.restore_eval_state(&cp.eval)?;
            opt.resume(&cp.state)?;
            resumed_from = Some(ctx.used());
            if let Some(t) = &trace {
                if let Ok(mut w) = t.lock() {
                    let _ = w.marker("resume", vec![("evals", Json::num(ctx.used() as f64))]);
                }
            }
        }
        let t0 = std::time::Instant::now();
        opt.run(&mut ctx, self.request.seed);
        // A raised suspend flag with budget left means the optimizer
        // paused mid-search: capture both halves of the checkpoint
        // before `outcome()` consumes the context.
        let suspended = ctx.suspend_requested() && ctx.remaining() > 0;
        let checkpoint = if suspended {
            match opt.suspend() {
                Some(state) => Some(
                    Checkpoint {
                        method: spec.name.to_string(),
                        state,
                        eval: ctx.capture_eval_state()?,
                    }
                    .to_json(),
                ),
                // The method cannot checkpoint its state (registry
                // `resumable: false`); the partial report stands alone.
                None => None,
            }
        } else {
            None
        };
        let stopped_early = self.stop.load(Ordering::SeqCst) || suspended;
        let evals_used = ctx.used();
        let mut outcome = ctx.outcome(spec.name);
        opt.annotate(&mut outcome);
        outcome.memory_hits = memory_hits;
        outcome.seeded_from = seeded_from;
        let wall_s = t0.elapsed().as_secs_f64();
        if let Some(t) = &trace {
            if let Ok(mut w) = t.lock() {
                if checkpoint.is_some() {
                    let _ =
                        w.marker("checkpoint", vec![("evals", Json::num(evals_used as f64))]);
                }
                if let Some(m) = &metrics {
                    let _ = w.stages(m);
                }
                let _ = w.finish(outcome.best_edp, outcome.evals, wall_s, stopped_early);
            }
        }
        Ok(SearchReport {
            request: self.request,
            outcome,
            wall_s,
            stopped_early,
            checkpoint,
            resumed_from,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{Progress, SearchControl};

    fn tiny() -> SearchRequest {
        SearchRequest::new().workload_named("mm1").platform_named("mobile").budget(120).seed(3)
    }

    #[test]
    fn build_validates_method_and_budget() {
        assert!(tiny().method("gradient-descent").build().is_err());
        assert!(tiny().budget(0).build().is_err());
        assert!(tiny().build().is_ok());
        // Typos get a nearest-match suggestion from the registry.
        let err = tiny().method("spasemap").build().unwrap_err().to_string();
        assert!(err.contains("did you mean 'sparsemap'"), "{err}");
    }

    #[test]
    fn build_validates_method_opts_and_aliases_run() {
        use crate::util::json::Json;
        // Unknown tunable key fails at build, with a suggestion.
        let bad = tiny().method_opts(Json::parse(r#"{"populaton": 40}"#).unwrap());
        let err = bad.build().unwrap_err().to_string();
        assert!(err.contains("did you mean 'population'"), "{err}");
        // A valid alias + opts combination runs under the canonical name.
        let report = tiny()
            .method("rand")
            .method_opts(Json::parse(r#"{"batch": 32}"#).unwrap())
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.outcome.method, "random");
        assert_eq!(report.outcome.evals, 120);
    }

    #[test]
    fn run_produces_report() {
        let report = tiny().build().unwrap().run().unwrap();
        assert_eq!(report.outcome.workload, "mm1");
        assert_eq!(report.outcome.platform, "mobile");
        assert!(report.outcome.evals <= 120);
        assert!(!report.stopped_early);
        assert!(report.wall_s >= 0.0);
    }

    #[test]
    fn observer_can_stop_early() {
        let report = tiny()
            .budget(5_000)
            .build()
            .unwrap()
            .run_observed(Box::new(|p: &Progress| {
                if p.evals >= 100 {
                    SearchControl::Stop
                } else {
                    SearchControl::Continue
                }
            }))
            .unwrap();
        assert!(report.stopped_early);
        assert!(report.outcome.evals < 5_000, "stopped well before the budget");
    }

    #[test]
    fn pre_cancelled_session_returns_empty_report() {
        let session = tiny().method("random").build().unwrap();
        session.cancel_token().store(true, Ordering::SeqCst);
        let report = session.run().unwrap();
        assert!(report.stopped_early);
        assert_eq!(report.outcome.evals, 0);
    }

    #[test]
    fn into_context_carries_request_knobs() {
        let ctx = tiny().threads(3).build().unwrap().into_context();
        assert_eq!(ctx.budget, 120);
        assert_eq!(ctx.threads(), 3);
    }

    #[test]
    fn run_opts_suspends_and_resumes_to_identical_outcome() {
        use crate::util::json::Json;

        let mk = || tiny().method("sparsemap").budget(800).seed(17);
        let full = mk().build().unwrap().run().unwrap();

        // Same arm, but an observer raises the suspend flag halfway in.
        let flag = Arc::new(AtomicBool::new(false));
        let obs_flag = Arc::clone(&flag);
        let half = mk()
            .build()
            .unwrap()
            .run_opts(RunOpts {
                observer: Some(Box::new(move |p: &Progress| {
                    if p.evals >= 400 {
                        obs_flag.store(true, Ordering::SeqCst);
                    }
                    SearchControl::Continue
                })),
                suspend: Some(Arc::clone(&flag)),
                ..Default::default()
            })
            .unwrap();
        assert!(half.stopped_early, "a suspended run is an early stop");
        assert!(half.outcome.evals < 800, "paused before the budget");
        assert!(half.resumed_from.is_none());
        let cp_json = half.checkpoint.expect("suspended run must carry a checkpoint");

        // Round-trip the checkpoint through text (as the service does)
        // and finish the search in a fresh session.
        let cp =
            crate::optimizer::Checkpoint::from_json(&Json::parse(&cp_json.dumps()).unwrap())
                .unwrap();
        let resumed = mk()
            .build()
            .unwrap()
            .run_opts(RunOpts { resume: Some(cp), ..Default::default() })
            .unwrap();
        assert_eq!(resumed.resumed_from, Some(half.outcome.evals));
        assert!(resumed.checkpoint.is_none(), "the resumed run completed");
        assert!(!resumed.stopped_early);
        assert_eq!(resumed.outcome.evals, full.outcome.evals);
        assert_eq!(resumed.outcome.best_edp.to_bits(), full.outcome.best_edp.to_bits());
        assert_eq!(resumed.outcome.best_genome, full.outcome.best_genome);
        assert_eq!(resumed.outcome.curve, full.outcome.curve);
    }

    #[test]
    fn run_opts_trace_streams_valid_ndjson_and_fills_metrics_scope() {
        use crate::util::json::Json;
        let path = std::env::temp_dir()
            .join(format!("sparsemap-session-trace-{}.ndjson", std::process::id()));
        let metrics = Arc::new(crate::obs::Metrics::new());
        let report = tiny()
            .build()
            .unwrap()
            .run_opts(RunOpts {
                trace: Some(path.clone()),
                metrics: Some(Arc::clone(&metrics)),
                ..Default::default()
            })
            .unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let records = crate::obs::read_trace(&text).unwrap();
        let ev = |r: &Json| r.get("ev").and_then(Json::as_str).unwrap_or("");
        assert_eq!(records[0].get("ev").and_then(Json::as_str), Some("start"));
        assert_eq!(records[0].get("workload").and_then(Json::as_str), Some("mm1"));
        assert!(records.iter().filter(|r| ev(r) == "generation").count() >= 1);
        assert!(records.iter().any(|r| ev(r) == "stages"));
        let fin = records.iter().rev().find(|r| ev(r) == "finish").expect("finish record");
        assert_eq!(
            fin.get("evals").and_then(Json::as_u64),
            Some(report.outcome.evals as u64)
        );

        // The caller's metrics scope saw the whole run, and the trace
        // renders back into the human summary.
        assert_eq!(metrics.evals.get(), report.outcome.evals as u64);
        assert!(metrics.stage_ns[0].snapshot().count >= 1, "decode timings recorded");
        let summary = crate::obs::summarize(&text).unwrap();
        assert!(summary.contains("convergence"), "{summary}");
        assert!(summary.contains("finished: best_edp="), "{summary}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn traced_suspend_resume_leaves_lifecycle_markers() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir();
        let p1 = dir.join(format!("sparsemap-trace-half-{}.ndjson", std::process::id()));
        let p2 = dir.join(format!("sparsemap-trace-rest-{}.ndjson", std::process::id()));
        let mk = || tiny().method("sparsemap").budget(800).seed(17);

        let flag = Arc::new(AtomicBool::new(false));
        let obs_flag = Arc::clone(&flag);
        let half = mk()
            .build()
            .unwrap()
            .run_opts(RunOpts {
                observer: Some(Box::new(move |p: &Progress| {
                    if p.evals >= 400 {
                        obs_flag.store(true, Ordering::SeqCst);
                    }
                    SearchControl::Continue
                })),
                suspend: Some(Arc::clone(&flag)),
                trace: Some(p1.clone()),
                ..Default::default()
            })
            .unwrap();
        let cp_json = half.checkpoint.expect("suspended run must carry a checkpoint");
        let cp = crate::optimizer::Checkpoint::from_json(&cp_json).unwrap();
        let resumed = mk()
            .build()
            .unwrap()
            .run_opts(RunOpts { resume: Some(cp), trace: Some(p2.clone()), ..Default::default() })
            .unwrap();
        assert!(!resumed.stopped_early);

        let marker_kinds = |path: &std::path::Path| -> Vec<String> {
            let records =
                crate::obs::read_trace(&std::fs::read_to_string(path).unwrap()).unwrap();
            records
                .iter()
                .filter(|r| r.get("ev").and_then(Json::as_str) == Some("marker"))
                .map(|r| r.get("kind").and_then(Json::as_str).unwrap_or("?").to_string())
                .collect()
        };
        assert_eq!(marker_kinds(&p1), vec!["checkpoint"]);
        assert_eq!(marker_kinds(&p2), vec!["resume"]);
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn resume_rejects_method_mismatch() {
        use crate::util::json::Json;
        let cp = crate::optimizer::Checkpoint {
            method: "pso".to_string(),
            state: Json::Null,
            eval: Json::Null,
        };
        let err = tiny()
            .method("random")
            .build()
            .unwrap()
            .run_opts(RunOpts { resume: Some(cp), ..Default::default() })
            .unwrap_err()
            .to_string();
        assert!(err.contains("captured by method 'pso'"), "{err}");
    }
}
