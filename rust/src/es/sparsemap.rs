//! The SparseMap search loop (§IV.H, Fig. 16) and its ablation variants.

use super::hypercube::{initialize, HshiConfig};
use super::operators::{annealing_mutation, sensitivity_aware_crossover};
use super::population::{
    evaluate_all, lhs_init, mean_valid_edp, select_top, top_indices, Individual,
};
use super::sensitivity::{calibrate, CalibConfig, Sensitivity};
use crate::genome::ops;
use crate::search::{EvalContext, Outcome};
use crate::util::rng::Pcg64;

/// Which feature set to run — the Fig. 18 ablation arms.
///
/// * `Standard` — plain ES over the PFCE genome with LHS initialization,
///   uniform one-point crossover and uniform mutation. (The paper's
///   "standard ES" additionally uses a *direct value* encoding; that arm
///   lives in `baselines::es_direct` since it needs a different genome.)
/// * `Pfce` — `Standard` + nothing else (encoding is already PFCE here);
///   kept as an explicit alias for experiment scripts.
/// * `Full` — PFCE + high-sensitivity hypercube initialization +
///   annealing mutation + sensitivity-aware crossover (SparseMap proper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EsVariant {
    Standard,
    Pfce,
    Full,
}

impl EsVariant {
    pub fn name(self) -> &'static str {
        match self {
            EsVariant::Standard => "es-std",
            EsVariant::Pfce => "es-pfce",
            EsVariant::Full => "sparsemap",
        }
    }
}

/// ES hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct EsConfig {
    pub population: usize,
    /// Fraction of the population selected as parents.
    pub parent_frac: f64,
    /// Probability an offspring is mutated.
    pub mutation_prob: f64,
    pub variant: EsVariant,
    pub calib: CalibConfig,
    pub hshi: HshiConfig,
    /// Worker threads for population evaluation: 0 leaves the context's
    /// pool untouched (serial unless the caller attached one); `>= 2`
    /// attaches a fresh pool when the context has none. Trajectories are
    /// bit-identical across thread counts (see `crate::search`).
    pub threads: usize,
}

impl Default for EsConfig {
    fn default() -> Self {
        EsConfig {
            population: 100,
            parent_frac: 0.25,
            mutation_prob: 0.6,
            variant: EsVariant::Full,
            calib: CalibConfig::default(),
            hshi: HshiConfig::default(),
            threads: 0,
        }
    }
}

/// The SparseMap searcher. Borrows its [`EvalContext`] so a caller (the
/// `portfolio` meta-optimizer, bespoke drivers) can run it over a slice
/// of a shared budget; [`run_sparsemap`] is the owning convenience form.
pub struct SparseMapSearch<'a> {
    pub ctx: &'a mut EvalContext,
    pub cfg: EsConfig,
    rng: Pcg64,
}

impl<'a> SparseMapSearch<'a> {
    pub fn new(ctx: &'a mut EvalContext, cfg: EsConfig, seed: u64) -> SparseMapSearch<'a> {
        if cfg.threads > 1 && ctx.pool().is_none() {
            let pool = crate::util::threadpool::ThreadPool::new(cfg.threads);
            ctx.set_pool(Some(std::sync::Arc::new(pool)));
        }
        SparseMapSearch { ctx, cfg, rng: Pcg64::seeded(seed) }
    }

    /// Run until the context budget (or fence) is exhausted.
    pub fn run(mut self) {
        let spec = self.ctx.spec.clone();
        let full = self.cfg.variant == EsVariant::Full;
        // Scale to what this run may actually spend: identical to
        // `ctx.budget` on a fresh context (every standalone path), and to
        // the slice allocation when a portfolio fence is set.
        let budget = self.ctx.remaining();
        // Scale the population and initialization overhead to the budget:
        // calibration ≤ ~10% (E8), HSHI ≤ ~20%.
        let population = self.cfg.population.min((budget / 8).max(8));
        self.cfg.population = population;

        // --- initialization -------------------------------------------------
        let sens: Option<Sensitivity> = if full {
            let mut calib = self.cfg.calib;
            if calib.max_evals == 0 {
                calib.max_evals = (budget / 10).max(40);
            }
            Some(calibrate(self.ctx, calib, &mut self.rng))
        } else {
            None
        };
        let mut init_genomes = if let Some(s) = &sens {
            let mut h = self.cfg.hshi;
            h.hypercubes = population;
            h.tries_per_cube =
                h.tries_per_cube.min((budget / 5 / population.max(1)).max(1));
            let r = initialize(self.ctx, s, h, &mut self.rng);
            let mut pop = r.population;
            // Top up with random genomes if HSHI under-filled.
            while pop.len() < population {
                pop.push(spec.random(&mut self.rng));
            }
            pop
        } else {
            lhs_init(&spec, population, &mut self.rng)
        };
        if full && !init_genomes.is_empty() {
            // Warm-start seeds: when resources are extremely tight (edge
            // platform, huge workloads) the valid region can be too thin
            // for stratified random search — inject the deterministic
            // heuristic mapping (with and without the manual sparse
            // strategy) so the population never starts fully dead.
            let workload = self.ctx.workload().clone();
            let mapping = crate::baselines::common::heuristic_mapping_genes(&spec, &workload);
            let manual = crate::baselines::common::manual_strategy_genes(&spec, &workload);
            let mut seed1 = vec![0u32; spec.len()];
            for i in 0..spec.len() {
                seed1[i] = spec.ranges[i].lo;
            }
            crate::baselines::common::apply(&mut seed1, &mapping);
            let mut seed2 = seed1.clone();
            crate::baselines::common::apply(&mut seed2, &manual);
            let k = init_genomes.len();
            init_genomes[k - 1] = seed1;
            if k >= 2 {
                init_genomes[k - 2] = seed2;
            }
        }
        let init_genomes = init_genomes;
        let mut pop: Vec<Individual> = evaluate_all(self.ctx, init_genomes);
        if let Some(m) = mean_valid_edp(&pop) {
            self.ctx.telemetry.push_population_mean(m);
        }

        let (high, low) = match &sens {
            Some(s) => (s.high.clone(), s.low.clone()),
            None => (Vec::new(), (0..spec.len()).collect()),
        };

        // --- generations -----------------------------------------------------
        // Estimate total generations from the remaining budget so the
        // annealing schedule spans the whole run.
        let per_gen = self.cfg.population.max(1);
        let total_gens = (self.ctx.remaining() / per_gen).max(1);
        let mut gen = 0;
        while !self.ctx.exhausted() && gen < total_gens * 4 {
            let n_parents =
                ((pop.len() as f64 * self.cfg.parent_frac) as usize).max(2);
            // Parents are only read: select by index instead of cloning
            // every genome per generation (same stable order as
            // `select_top`, so the rng stream and trajectory are
            // untouched — see `top_indices`).
            let parents = top_indices(&pop, n_parents);

            // Crossover: fill a fresh offspring pool.
            let mut offspring = Vec::with_capacity(self.cfg.population);
            while offspring.len() < self.cfg.population {
                let pa = &pop[parents[self.rng.index(parents.len())]].genome;
                let pb = &pop[parents[self.rng.index(parents.len())]].genome;
                let (mut c1, mut c2) = if full {
                    sensitivity_aware_crossover(pa, pb, &high, &mut self.rng)
                } else {
                    ops::onepoint_crossover(pa, pb, &mut self.rng)
                };
                // Mutation.
                for c in [&mut c1, &mut c2] {
                    if self.rng.chance(self.cfg.mutation_prob) {
                        if full {
                            annealing_mutation(
                                &spec, c, &high, &low, gen, total_gens, &mut self.rng,
                            );
                        } else {
                            ops::point_mutation(&spec, c, 0.05, &mut self.rng);
                        }
                    }
                }
                offspring.push(c1);
                if offspring.len() < self.cfg.population {
                    offspring.push(c2);
                }
            }

            let children = evaluate_all(self.ctx, offspring);
            if children.is_empty() {
                break; // budget exhausted mid-generation
            }
            // (μ+λ) survival: parents compete with offspring.
            pop.extend(children);
            pop = select_top(pop, self.cfg.population);
            if let Some(m) = mean_valid_edp(&pop) {
                self.ctx.telemetry.push_population_mean(m);
            }
            gen += 1;
        }
    }
}

/// Run one ES search against a borrowed context (telemetry accumulates
/// in the context; the caller finalizes the outcome). This is the form
/// the optimizer registry and the portfolio meta-optimizer drive.
pub fn run_sparsemap_with(ctx: &mut EvalContext, cfg: &EsConfig, seed: u64) {
    SparseMapSearch::new(ctx, *cfg, seed).run();
}

/// Convenience one-call API.
pub fn run_sparsemap(mut ctx: EvalContext, cfg: EsConfig, seed: u64) -> Outcome {
    let method = cfg.variant.name();
    run_sparsemap_with(&mut ctx, &cfg, seed);
    ctx.outcome(method)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("mm", 64, 128, 64, 0.2, 0.2);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    fn small_cfg(variant: EsVariant) -> EsConfig {
        EsConfig {
            population: 24,
            variant,
            calib: CalibConfig { samples_per_gene: 4, trials: 2, pairs: 4, max_evals: 0 },
            hshi: HshiConfig { hypercubes: 24, tries_per_cube: 6 },
            ..Default::default()
        }
    }

    #[test]
    fn full_sparsemap_finds_valid_design() {
        let o = run_sparsemap(ctx(3_000), small_cfg(EsVariant::Full), 7);
        assert!(o.found_valid(), "no valid design found");
        assert!(o.evals <= 3_000);
        assert_eq!(o.method, "sparsemap");
        assert!(!o.curve.is_empty());
    }

    #[test]
    fn standard_es_runs_too() {
        let o = run_sparsemap(ctx(2_000), small_cfg(EsVariant::Standard), 7);
        assert_eq!(o.method, "es-std");
        assert!(o.evals <= 2_000);
    }

    #[test]
    fn search_improves_over_random_sampling() {
        // Same budget: SparseMap's best should beat pure random's best
        // (with overwhelming probability at this budget).
        let budget = 3_000;
        let o = run_sparsemap(ctx(budget), small_cfg(EsVariant::Full), 11);
        let mut random_ctx = ctx(budget);
        let mut rng = Pcg64::seeded(11);
        let genomes: Vec<_> =
            (0..budget).map(|_| random_ctx.spec.random(&mut rng)).collect();
        random_ctx.eval_batch(&genomes);
        let random_best = random_ctx.outcome("random").best_edp;
        assert!(
            o.best_edp <= random_best * 1.5,
            "sparsemap {:.3e} vs random {:.3e}",
            o.best_edp,
            random_best
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_sparsemap(ctx(1_200), small_cfg(EsVariant::Full), 42);
        let b = run_sparsemap(ctx(1_200), small_cfg(EsVariant::Full), 42);
        assert_eq!(a.best_edp, b.best_edp);
        assert_eq!(a.best_genome, b.best_genome);
    }

    #[test]
    fn threads_config_does_not_change_results() {
        let serial = run_sparsemap(ctx(800), small_cfg(EsVariant::Full), 42);
        let par_cfg = EsConfig { threads: 4, ..small_cfg(EsVariant::Full) };
        let par = run_sparsemap(ctx(800), par_cfg, 42);
        assert_eq!(serial.best_edp, par.best_edp);
        assert_eq!(serial.best_genome, par.best_genome);
        assert_eq!(serial.curve, par.curve);
    }

    #[test]
    fn population_mean_curve_recorded() {
        let o = run_sparsemap(ctx(2_000), small_cfg(EsVariant::Full), 3);
        assert!(o.population_mean_curve.len() >= 2);
    }
}
