//! Shared heuristics for the prior-work baselines: the "manually
//! specified sparse strategy" that Sparseloop Mapper explores mappings
//! under, and the "fixed mapping" that SAGE-like explores formats under.

use crate::genome::{Genome, GenomeSpec};
use crate::workload::{Workload, TENSOR_P, TENSOR_Q};

/// A hand-crafted sparse strategy in gene form (what an engineer would
/// specify for Sparseloop): CP formats for very sparse operands, bitmask
/// for moderately sparse, uncompressed for dense; skip at the GLB when
/// both operands are sparse, gate at compute otherwise.
pub fn manual_strategy_genes(spec: &GenomeSpec, w: &Workload) -> Vec<(usize, u32)> {
    let mut out = Vec::new();
    let fmt_for = |density: f64| -> u32 {
        if density >= 0.99 {
            0 // uncompressed
        } else if density < 0.15 {
            3 // coordinate payload
        } else {
            1 // bitmask
        }
    };
    let dp = w.density(TENSOR_P);
    let dq = w.density(TENSOR_Q);
    for slot in 0..5 {
        out.push((spec.format_start + slot, fmt_for(dp)));
        out.push((spec.format_start + 5 + slot, fmt_for(dq)));
        out.push((spec.format_start + 10 + slot, 0)); // Z uncompressed
    }
    // S/G: GLB skip driven by the sparser operand; compute gate both.
    let glb_sg = if dp >= 0.99 && dq >= 0.99 {
        0
    } else if dp <= dq {
        5 // Skip Q<-P (P sparser)
    } else {
        4 // Skip P<-Q
    };
    out.push((spec.sg_start, glb_sg));
    out.push((spec.sg_start + 1, 0));
    out.push((spec.sg_start + 2, 3)); // Gate P<->Q at MAC
    out
}

/// Apply gene overrides.
pub fn apply(genome: &mut Genome, overrides: &[(usize, u32)]) {
    for &(i, v) in overrides {
        genome[i] = v;
    }
}

/// A reasonable fixed mapping in gene form (what SAGE assumes): an
/// output-stationary mapping with factors split between L2_T (GLB
/// tiling), L2_S (PE parallelism over M/N) and L3_T. Deterministic.
pub fn heuristic_mapping_genes(spec: &GenomeSpec, w: &Workload) -> Vec<(usize, u32)> {
    let mut out = Vec::new();
    // Permutations: identity (M outer, K inner at every level) — an
    // output-stationary flavour since K ends up innermost.
    for level in 0..5 {
        out.push((level, 1));
    }
    // Factor assignment: walk each dim's factors; alternate M/N factors
    // between L2_S (spatial) and L2_T, push K factors to L3_T, overflow
    // to L1_T.
    let mut gene = spec.factor_start;
    for (dim, dspec) in w.dims.iter().enumerate() {
        let is_contraction = w.contraction.contains(&dim);
        for (idx, _prime) in dspec.factors.iter().enumerate() {
            let level = if is_contraction {
                if idx < 3 {
                    4 // L3_T... gene value 4 = L3_T (1-based level index)
                } else {
                    1 // L1_T
                }
            } else if idx == 0 {
                3 // L2_S
            } else if idx < 3 {
                2 // L2_T
            } else {
                1 // L1_T
            };
            out.push((gene, level));
            gene += 1;
        }
    }
    out
}

/// Gene indices of the mapping segment (perms + factors).
pub fn mapping_gene_indices(spec: &GenomeSpec) -> Vec<usize> {
    (0..spec.format_start).collect()
}

/// Gene indices of the sparse-strategy segment (formats + S/G).
pub fn strategy_gene_indices(spec: &GenomeSpec) -> Vec<usize> {
    (spec.format_start..spec.len()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::GenomeSpec;

    fn setup() -> (Workload, GenomeSpec) {
        let w = Workload::spmm("t", 16, 32, 16, 0.1, 0.5);
        let s = GenomeSpec::for_workload(&w);
        (w, s)
    }

    #[test]
    fn manual_strategy_respects_densities() {
        let (w, spec) = setup();
        let genes = manual_strategy_genes(&spec, &w);
        let mut g = vec![0u32; spec.len()];
        apply(&mut g, &genes);
        // P at 10% -> CP (3); Q at 50% -> bitmask (1).
        assert_eq!(g[spec.format_start], 3);
        assert_eq!(g[spec.format_start + 5], 1);
        // P sparser -> Skip Q<-P at the GLB (gene 5).
        assert_eq!(g[spec.sg_start], 5);
    }

    #[test]
    fn dense_workload_gets_no_sg() {
        let w = Workload::spmm("d", 16, 16, 16, 1.0, 1.0);
        let spec = GenomeSpec::for_workload(&w);
        let genes = manual_strategy_genes(&spec, &w);
        let mut g = vec![9u32; spec.len()];
        apply(&mut g, &genes);
        assert_eq!(g[spec.sg_start], 0);
        assert_eq!(g[spec.format_start], 0);
    }

    #[test]
    fn heuristic_mapping_is_complete_and_in_range() {
        let (w, spec) = setup();
        let genes = heuristic_mapping_genes(&spec, &w);
        // Covers all perm + factor genes exactly once.
        let idxs: Vec<usize> = genes.iter().map(|&(i, _)| i).collect();
        assert_eq!(idxs.len(), spec.format_start);
        let mut sorted = idxs.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), spec.format_start);
        for &(i, v) in &genes {
            assert!(v >= spec.ranges[i].lo && v <= spec.ranges[i].hi, "gene {i}={v}");
        }
    }

    #[test]
    fn segment_indices_partition_genome() {
        let (_, spec) = setup();
        let m = mapping_gene_indices(&spec);
        let s = strategy_gene_indices(&spec);
        assert_eq!(m.len() + s.len(), spec.len());
        assert_eq!(m.last().unwrap() + 1, s[0]);
    }
}
