//! Per-rank 1D compression formats (Fig. 5) and their storage models.
//!
//! A multi-dimensional sparse tensor is compressed by stacking 1D formats
//! rank by rank (outer→inner); e.g. `UOP(M)-CP(K)` is CSR. The storage
//! model below estimates, per rank, metadata bits and kept-slot counts
//! under a uniform-random occupancy assumption — the same modelling class
//! Sparseloop uses for its format primitives.

use crate::arch::WORD_BITS;
use crate::sparsity::DensityModel;

/// The five per-rank format choices, in genome order (gene value 0..4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RankFormat {
    /// Uncompressed: all slots stored, no metadata (gene 0).
    Uncompressed,
    /// Bitmask: one presence bit per slot (gene 1).
    Bitmask,
    /// Run-length encoding of zero runs (gene 2).
    Rle,
    /// Coordinate payload: explicit coordinate per kept slot (gene 3).
    CoordinatePayload,
    /// Uncompressed offset pairs: per-slot start offsets into the child
    /// rank — the CSR row-pointer array (gene 4).
    UncompressedOffsetPair,
}

pub const NUM_RANK_FORMATS: u32 = 5;

impl RankFormat {
    pub fn from_gene(g: u32) -> RankFormat {
        match g % NUM_RANK_FORMATS {
            0 => RankFormat::Uncompressed,
            1 => RankFormat::Bitmask,
            2 => RankFormat::Rle,
            3 => RankFormat::CoordinatePayload,
            _ => RankFormat::UncompressedOffsetPair,
        }
    }

    pub fn gene(self) -> u32 {
        match self {
            RankFormat::Uncompressed => 0,
            RankFormat::Bitmask => 1,
            RankFormat::Rle => 2,
            RankFormat::CoordinatePayload => 3,
            RankFormat::UncompressedOffsetPair => 4,
        }
    }

    pub fn short_name(self) -> &'static str {
        match self {
            RankFormat::Uncompressed => "U",
            RankFormat::Bitmask => "B",
            RankFormat::Rle => "RLE",
            RankFormat::CoordinatePayload => "CP",
            RankFormat::UncompressedOffsetPair => "UOP",
        }
    }

    /// Does this format drop empty slots (i.e., provide compression and
    /// nonzero-location metadata usable for intersection)?
    pub fn compressing(self) -> bool {
        !matches!(self, RankFormat::Uncompressed)
    }
}

/// ceil(log2(n)) with a floor of 1 bit.
pub fn bits_for(n: u64) -> u64 {
    (64 - n.max(2).saturating_sub(1).leading_zeros()) as u64
}

/// Storage model of one rank within a format stack.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankCost {
    /// Expected number of slots kept (passed to the child rank) per full
    /// tile traversal.
    pub kept_slots: f64,
    /// Metadata bits for this rank across the tile.
    pub metadata_bits: f64,
}

/// Evaluate the storage of a format stack over ranks with extents
/// `extents[i]` (outer→inner) at uniform overall tensor density
/// `density` — the legacy scalar entry point, equivalent to
/// [`stack_storage_model`] with [`DensityModel::Uniform`].
pub fn stack_storage(extents: &[u64], formats: &[RankFormat], density: f64) -> (f64, f64) {
    stack_storage_model(extents, formats, &DensityModel::uniform(density))
}

/// Evaluate the storage of a format stack over ranks with extents
/// `extents[i]` (outer→inner) under a sparsity-pattern model.
///
/// Occupancy model: a rank-i slot is *occupied* if any element beneath
/// it is nonzero, with probability [`DensityModel::slot_prob`] of the
/// slot's leaf count — for `Uniform` the classic iid
/// `p_i = 1 - (1-d)^(inner_elems_i)`, for structured patterns the
/// clustered/banded/skewed equivalents.
///
/// Returns `(data_words, metadata_words)` for the tile.
pub fn stack_storage_model(
    extents: &[u64],
    formats: &[RankFormat],
    model: &DensityModel,
) -> (f64, f64) {
    assert_eq!(extents.len(), formats.len());
    let d = model.avg().clamp(1e-9, 1.0);
    let total_elems: f64 = extents.iter().map(|&e| e as f64).product();
    if extents.is_empty() {
        return (0.0, 0.0);
    }

    let mut fibers = 1.0f64; // number of fibers entering this rank
    let mut metadata_bits = 0.0f64;
    let mut any_compressing = false;

    for (i, (&e, &fmt)) in extents.iter().zip(formats).enumerate() {
        let inner_elems: f64 = extents[i + 1..].iter().map(|&x| x as f64).product();
        // Probability a slot at this rank is occupied.
        let p = model.slot_prob(inner_elems.max(1.0));
        let e_f = e as f64;
        let kept = e_f * p; // expected occupied slots per fiber
        match fmt {
            RankFormat::Uncompressed => {
                // Keeps every slot; no metadata.
                fibers *= e_f;
            }
            RankFormat::Bitmask => {
                metadata_bits += fibers * e_f; // 1 bit per slot
                fibers *= kept;
                any_compressing = true;
            }
            RankFormat::Rle => {
                // One run-length token per kept slot. Token width is
                // sized for the *typical* zero-run (≈ 1/density), plus an
                // escape bit for longer runs — so RLE beats CP when the
                // tensor is relatively dense (short runs, narrow tokens)
                // and loses to CP when extremely sparse (long runs).
                let typical_run = ((1.0 / d).ceil() as u64).clamp(1, e.max(1));
                let token_bits = (bits_for(typical_run + 1) + 1) as f64;
                metadata_bits += fibers * kept * token_bits;
                fibers *= kept;
                any_compressing = true;
            }
            RankFormat::CoordinatePayload => {
                metadata_bits += fibers * kept * bits_for(e) as f64;
                fibers *= kept;
                any_compressing = true;
            }
            RankFormat::UncompressedOffsetPair => {
                // (e+1) offsets per fiber, wide enough to index all
                // children beneath this rank.
                let child_count = (kept * inner_elems).max(1.0);
                metadata_bits += fibers * (e_f + 1.0) * bits_for(child_count as u64 + 1) as f64;
                fibers *= kept;
                any_compressing = true;
            }
        }
    }

    // Data payload: leaf slots that survived the stack. With at least one
    // compressing rank the payload is (approx) the nonzeros beneath the
    // kept slots; fully uncompressed stacks store everything.
    let data_words = if any_compressing {
        // `fibers` is now the expected number of stored leaf slots.
        fibers.min(total_elems)
    } else {
        total_elems
    };
    let metadata_words = metadata_bits / WORD_BITS as f64;
    (data_words, metadata_words)
}

/// Convenience: compressed words (data + metadata) of a tile.
pub fn stack_words(extents: &[u64], formats: &[RankFormat], density: f64) -> f64 {
    let (d, m) = stack_storage(extents, formats, density);
    d + m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gene_roundtrip() {
        for g in 0..NUM_RANK_FORMATS {
            assert_eq!(RankFormat::from_gene(g).gene(), g);
        }
        assert_eq!(RankFormat::from_gene(7), RankFormat::Rle); // wraps
    }

    #[test]
    fn bits_for_sane() {
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(8), 3);
        assert_eq!(bits_for(9), 4);
        assert_eq!(bits_for(1), 1);
    }

    #[test]
    fn uncompressed_stores_everything() {
        let (d, m) = stack_storage(
            &[16, 16],
            &[RankFormat::Uncompressed, RankFormat::Uncompressed],
            0.1,
        );
        assert_eq!(d, 256.0);
        assert_eq!(m, 0.0);
    }

    #[test]
    fn csr_like_vs_dense() {
        // CSR = UOP(M)-CP(K) on a 64x64 @ 5% tile: far smaller than dense.
        let csr = stack_words(
            &[64, 64],
            &[RankFormat::UncompressedOffsetPair, RankFormat::CoordinatePayload],
            0.05,
        );
        assert!(csr < 64.0 * 64.0 * 0.25, "csr={csr}");
        // ...but larger than the bare nonzero count (metadata overhead).
        assert!(csr > 64.0 * 64.0 * 0.05);
    }

    #[test]
    fn bitmask_overhead_dominates_when_dense() {
        // At 90% density CP coordinates cost more than bitmask bits.
        let bm = stack_words(&[1, 256], &[RankFormat::Uncompressed, RankFormat::Bitmask], 0.9);
        let cp = stack_words(
            &[1, 256],
            &[RankFormat::Uncompressed, RankFormat::CoordinatePayload],
            0.9,
        );
        assert!(bm < cp, "bm={bm} cp={cp}");
    }

    #[test]
    fn cp_wins_when_very_sparse() {
        let bm = stack_words(&[1, 4096], &[RankFormat::Uncompressed, RankFormat::Bitmask], 0.01);
        let cp = stack_words(
            &[1, 4096],
            &[RankFormat::Uncompressed, RankFormat::CoordinatePayload],
            0.01,
        );
        assert!(cp < bm, "cp={cp} bm={bm}");
    }

    #[test]
    fn density_monotone() {
        let f = [RankFormat::Bitmask, RankFormat::CoordinatePayload];
        let lo = stack_words(&[32, 32], &f, 0.05);
        let hi = stack_words(&[32, 32], &f, 0.5);
        assert!(lo < hi);
    }

    #[test]
    fn uniform_model_path_equals_legacy_scalar_path() {
        for d in [0.01, 0.118, 0.5, 1.0] {
            let f = [RankFormat::UncompressedOffsetPair, RankFormat::Bitmask];
            let a = stack_storage(&[32, 128], &f, d);
            let b = stack_storage_model(&[32, 128], &f, &DensityModel::uniform(d));
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
    }

    #[test]
    fn block_model_shrinks_coarse_rank_metadata() {
        let f = [RankFormat::CoordinatePayload, RankFormat::CoordinatePayload];
        let (_, uni_meta) = stack_storage_model(&[64, 64], &f, &DensityModel::uniform(0.05));
        let (_, blk_meta) = stack_storage_model(&[64, 64], &f, &DensityModel::block(16, 0.05));
        // Clustered nonzeros leave far fewer outer slots occupied, so the
        // outer CP rank stores fewer coordinates at equal mean density.
        assert!(blk_meta < uni_meta, "block {blk_meta} vs uniform {uni_meta}");
    }

    #[test]
    fn storage_never_negative_or_nan() {
        let fmts = [
            RankFormat::Uncompressed,
            RankFormat::Bitmask,
            RankFormat::Rle,
            RankFormat::CoordinatePayload,
            RankFormat::UncompressedOffsetPair,
        ];
        for &f1 in &fmts {
            for &f2 in &fmts {
                for d in [1e-6, 0.01, 0.5, 1.0] {
                    let (dw, mw) = stack_storage(&[8, 128], &[f1, f2], d);
                    assert!(dw.is_finite() && dw >= 0.0);
                    assert!(mw.is_finite() && mw >= 0.0);
                }
            }
        }
    }
}
