//! The public programmatic surface of SparseMap — the front door every
//! consumer (CLI, experiment drivers, examples, services) goes through.
//!
//! * [`SearchRequest`] — a typed, JSON-round-trippable description of one
//!   search arm: workload × platform × method plus budget, seed, threads,
//!   backend and cache policy. Methods come from the
//!   [`crate::optimizer`] registry (names or aliases), and their
//!   hyper-parameters ride along as a `method_opts` JSON object
//!   validated against the method's tunable schema — including the
//!   `portfolio` meta-method that races several members over one shared
//!   budget. Workloads and platforms are either the
//!   paper's named suites (Table III / Table II) or **fully custom**
//!   scenarios built with [`crate::workload::Workload::custom`] /
//!   [`crate::arch::Platform::custom`] or parsed from JSON specs — any
//!   einsum-shaped contraction on any PE-array geometry is searchable.
//! * [`SearchSession`] — the validated, runnable form. One entry point,
//!   [`SearchSession::run_opts`], covers progress streaming through
//!   [`crate::search::SearchObserver`], early stop from the observer,
//!   cancellation from other threads, **cooperative suspension** into a
//!   [`crate::optimizer::Checkpoint`] and bit-identical **resume** from
//!   one; it also lowers to a raw [`crate::search::EvalContext`] for
//!   drivers with bespoke loops. [`RunOpts`] additionally attaches the
//!   observability layer: `trace` streams a `sparsemap.trace.v1` NDJSON
//!   trace of the run and `metrics` scopes the run into a
//!   [`crate::obs::Metrics`] registry (see [`crate::obs`]).
//! * [`SearchReport`] — the typed result, `to_json`/`from_json`
//!   round-trippable for storage and services (schema
//!   [`REPORT_SCHEMA`]; the v1 form still parses).
//! * [`methods`] / [`methods_json`] — the optimizer registry listing,
//!   including each method's `resumable` flag.
//! * [`run_batch`] — many arms over a shared worker pool.
//!
//! ```no_run
//! use sparsemap::api::SearchRequest;
//! use sparsemap::workload::{Workload, WorkloadKind};
//!
//! // A scenario that exists nowhere in the paper's tables:
//! let workload = Workload::custom(
//!     "my_spmm",
//!     WorkloadKind::SpMM,
//!     vec![("M".into(), 384), ("K".into(), 4096), ("N".into(), 384)],
//!     vec![
//!         ("P".into(), vec![0, 1], 0.25),
//!         ("Q".into(), vec![1, 2], 0.60),
//!         ("Z".into(), vec![0, 2], 0.0), // derive the output density
//!     ],
//!     vec![1],
//! )?;
//! let report = SearchRequest::new()
//!     .workload(workload)
//!     .platform_named("mobile")
//!     .budget(5_000)
//!     .build()?
//!     .run()?;
//! println!("{}", report.to_json().pretty());
//! # Ok::<(), anyhow::Error>(())
//! ```

mod report;
mod request;
mod session;

pub use report::{SearchReport, REPORT_SCHEMA, REPORT_SCHEMA_V1};
pub use request::{PlatformSel, SearchRequest, WarmStart, WorkloadSel};
pub use session::{RunOpts, SearchSession};

use crate::optimizer::MethodSpec;
use crate::util::json::Json;
use crate::util::threadpool::{parallel_map, ThreadPool};
use anyhow::Result;

/// Every registered search method, in registry order — the same table
/// [`crate::optimizer::registry`] serves, re-exported here so API
/// consumers never need the optimizer module directly.
pub fn methods() -> &'static [MethodSpec] {
    crate::optimizer::registry()
}

/// The method listing as JSON: per method its canonical name, aliases,
/// one-line summary, whether it supports suspend/resume
/// ([`MethodSpec::resumable`]), and the full tunable schema with
/// defaults. This is what the `sparsemap methods --json` CLI and the
/// search service's `GET /methods` endpoint serve.
pub fn methods_json() -> Json {
    Json::Arr(methods().iter().map(MethodSpec::to_json).collect())
}

/// Run a batch of arms, fanned out `threads` at a time over a shared
/// worker pool. Every request is validated up front (an invalid one
/// fails the whole batch before any search starts); reports come back in
/// request order. Arms default to serial evaluation inside (request
/// `threads` = 1) — that is the right shape here, where the parallelism
/// is across arms.
pub fn run_batch(requests: Vec<SearchRequest>, threads: usize) -> Result<Vec<SearchReport>> {
    let sessions: Vec<SearchSession> =
        requests.into_iter().map(SearchRequest::build).collect::<Result<_>>()?;
    if threads <= 1 || sessions.len() <= 1 {
        return sessions.into_iter().map(SearchSession::run).collect();
    }
    let pool = ThreadPool::new(threads.min(sessions.len()));
    parallel_map(&pool, sessions, SearchSession::run).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_preserves_request_order() {
        let mut requests = Vec::new();
        for wl in ["mm1", "mm12"] {
            for plat in ["edge", "mobile"] {
                requests.push(
                    SearchRequest::new()
                        .workload_named(wl)
                        .platform_named(plat)
                        .method("random")
                        .budget(60)
                        .seed(2),
                );
            }
        }
        let reports = run_batch(requests.clone(), 4).unwrap();
        assert_eq!(reports.len(), 4);
        for (req, rep) in requests.iter().zip(&reports) {
            assert_eq!(rep.request, *req);
            assert!(rep.outcome.evals <= 60);
        }
    }

    #[test]
    fn batch_fails_fast_on_invalid_request() {
        let requests = vec![
            SearchRequest::new().budget(50),
            SearchRequest::new().workload_named("not-a-workload"),
        ];
        assert!(run_batch(requests, 2).is_err());
    }

    #[test]
    fn methods_json_lists_every_method_with_resumable_flag() {
        use crate::util::json::Json;
        let listing = methods_json();
        let arr = listing.as_arr().unwrap();
        assert_eq!(arr.len(), crate::optimizer::ALL_METHODS.len());
        for (entry, spec) in arr.iter().zip(methods()) {
            assert_eq!(entry.get("name").and_then(Json::as_str), Some(spec.name));
            assert_eq!(
                entry.get("resumable").and_then(Json::as_bool),
                Some(spec.resumable),
                "method '{}' must advertise its resumable flag",
                spec.name
            );
            assert!(entry.get("tunables").and_then(Json::as_arr).is_some());
        }
        // The checkpointable family is exactly the one the optimizer
        // overhaul made suspendable.
        let resumable: Vec<&str> =
            methods().iter().filter(|m| m.resumable).map(|m| m.name).collect();
        assert_eq!(resumable, ["sparsemap", "es-pfce", "random", "pso", "es-std", "portfolio"]);
    }

    #[test]
    fn batch_matches_sequential_runs() {
        let mk = || {
            SearchRequest::new()
                .workload_named("mm1")
                .platform_named("mobile")
                .method("random")
                .budget(100)
                .seed(11)
        };
        let solo = mk().build().unwrap().run().unwrap();
        let batch = run_batch(vec![mk(), mk()], 2).unwrap();
        for rep in &batch {
            assert_eq!(rep.outcome.best_edp, solo.outcome.best_edp);
            assert_eq!(rep.outcome.curve, solo.outcome.curve);
        }
    }
}
