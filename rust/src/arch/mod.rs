//! Accelerator architecture templates: memory hierarchy, platform
//! resource constraints (Table II) and 12 nm energy constants.
//!
//! The architecture is the paper's 3-level template (Fig. 3): off-chip
//! DRAM → on-chip Global Buffer (GLB) → PE array (each PE with a local
//! buffer and a MAC array).

pub mod energy;
pub mod platform;

pub use energy::EnergyTable;
pub use platform::{Platform, WORD_BITS, WORD_BYTES};

/// Storage levels of the 3-level template, outer to inner.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StorageLevel {
    Dram,
    Glb,
    PeBuf,
}

impl StorageLevel {
    pub const ALL: [StorageLevel; 3] = [StorageLevel::Dram, StorageLevel::Glb, StorageLevel::PeBuf];

    pub fn index(self) -> usize {
        match self {
            StorageLevel::Dram => 0,
            StorageLevel::Glb => 1,
            StorageLevel::PeBuf => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            StorageLevel::Dram => "DRAM",
            StorageLevel::Glb => "GLB",
            StorageLevel::PeBuf => "PEBuf",
        }
    }
}

/// Data-transfer boundaries between adjacent storage levels (plus the
/// operand feed into the MACs). S/G mechanisms attach to these (Fig. 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Boundary {
    /// DRAM ⇄ GLB.
    DramGlb,
    /// GLB ⇄ PE buffers (via NoC).
    GlbPe,
    /// PE buffer ⇄ MAC operand registers.
    PeMac,
}

impl Boundary {
    pub const ALL: [Boundary; 3] = [Boundary::DramGlb, Boundary::GlbPe, Boundary::PeMac];

    pub fn index(self) -> usize {
        match self {
            Boundary::DramGlb => 0,
            Boundary::GlbPe => 1,
            Boundary::PeMac => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Boundary::DramGlb => "DRAM-GLB",
            Boundary::GlbPe => "GLB-PE",
            Boundary::PeMac => "PE-MAC",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_stable() {
        for (i, s) in StorageLevel::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
        for (i, b) in Boundary::ALL.iter().enumerate() {
            assert_eq!(b.index(), i);
        }
    }
}
