//! A deliberately tiny HTTP/1.1 server core: the request parsing and
//! response writing the service needs and nothing more. Generic over
//! `BufRead`/`Write` so it unit-tests without sockets.

use crate::util::json::Json;
use std::io::{self, BufRead, Read, Write};

/// Largest accepted request body (a search request is a few KB).
const MAX_BODY: usize = 1 << 20;
/// Largest accepted request/header line.
const MAX_LINE: usize = 8 << 10;

/// One parsed request: method, path (query string stripped), raw body,
/// and the `Authorization` header value when present (case-preserved —
/// bearer tokens are case-sensitive even though header names are not).
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    pub authorization: Option<String>,
}

fn malformed(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Parse one request off the wire. Only what the service needs: the
/// request line, a `Content-Length` header, and the body it promises.
pub fn read_request<R: BufRead>(reader: &mut R) -> io::Result<HttpRequest> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.len() > MAX_LINE {
        return Err(malformed("request line too long"));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || !path.starts_with('/') {
        return Err(malformed("malformed request line"));
    }
    let mut content_length = 0usize;
    let mut authorization = None;
    loop {
        let mut header = String::new();
        let n = reader.read_line(&mut header)?;
        if n == 0 || header.trim().is_empty() {
            break;
        }
        if header.len() > MAX_LINE {
            return Err(malformed("header line too long"));
        }
        let lower = header.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length =
                v.trim().parse().map_err(|_| malformed("bad content-length"))?;
        } else if lower.starts_with("authorization:") {
            // Take the value from the *original* line: the scheme is
            // case-insensitive but the credential itself is not.
            authorization = Some(header["authorization:".len()..].trim().to_string());
        }
    }
    if content_length > MAX_BODY {
        return Err(malformed("request body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    let path = path.split('?').next().unwrap_or("/").to_string();
    Ok(HttpRequest { method, path, body, authorization })
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// Write a complete response with a known body.
pub fn respond<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> io::Result<()> {
    respond_with(w, status, content_type, &[], body)
}

/// [`respond`] with extra headers, each a complete `Name: value` pair.
pub fn respond_with<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[String],
    body: &[u8],
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
        reason(status),
        body.len()
    );
    for h in extra_headers {
        head.push_str(h);
        head.push_str("\r\n");
    }
    head.push_str("Connection: close\r\n\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Load-shedding refusal: `503` with a `Retry-After` hint so well-
/// behaved clients back off instead of hammering a saturated or
/// draining service.
pub fn unavailable<W: Write>(w: &mut W, msg: &str, retry_after_secs: u64) -> io::Result<()> {
    let body = format!("{}\n", Json::obj(vec![("error", Json::str(msg))]).pretty());
    respond_with(
        w,
        503,
        "application/json",
        &[format!("Retry-After: {retry_after_secs}")],
        body.as_bytes(),
    )
}

pub fn respond_json<W: Write>(w: &mut W, status: u16, j: &Json) -> io::Result<()> {
    respond(w, status, "application/json", format!("{}\n", j.pretty()).as_bytes())
}

pub fn error_json<W: Write>(w: &mut W, status: u16, msg: &str) -> io::Result<()> {
    respond_json(w, status, &Json::obj(vec![("error", Json::str(msg))]))
}

/// Start an NDJSON stream: headers only, no `Content-Length` — the
/// connection closing marks the end of the stream.
pub fn start_ndjson<W: Write>(w: &mut W) -> io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_line_headers_and_body() {
        let raw = "POST /jobs?verbose=1 HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/jobs", "query string is stripped");
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn get_without_body_parses() {
        let req = read_request(&mut Cursor::new("GET /health HTTP/1.1\r\n\r\n")).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/health");
        assert!(req.body.is_empty());
    }

    #[test]
    fn authorization_header_captured_case_preserving() {
        let raw = "GET /jobs HTTP/1.1\r\nAuthorization: Bearer SeCrEt42\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.authorization.as_deref(), Some("Bearer SeCrEt42"));
        // Header name matching is case-insensitive; the value is not
        // normalized.
        let raw = "GET / HTTP/1.1\r\nAUTHORIZATION:   bearer abc  \r\n\r\n";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.authorization.as_deref(), Some("bearer abc"));
        let none = read_request(&mut Cursor::new("GET / HTTP/1.1\r\n\r\n")).unwrap();
        assert!(none.authorization.is_none());
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(read_request(&mut Cursor::new("not-http\r\n\r\n")).is_err());
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(read_request(&mut Cursor::new(huge)).is_err());
        let bad_len = "POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n";
        assert!(read_request(&mut Cursor::new(bad_len)).is_err());
    }

    #[test]
    fn responses_carry_status_and_length() {
        let mut out = Vec::new();
        respond_json(&mut out, 202, &Json::obj(vec![("id", Json::str("job-1"))])).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 202 Accepted\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json"));
        assert!(text.contains("job-1"));
        let mut err = Vec::new();
        error_json(&mut err, 429, "quota exceeded").unwrap();
        let text = String::from_utf8(err).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("quota exceeded"));
    }

    #[test]
    fn unavailable_carries_retry_after() {
        let mut out = Vec::new();
        unavailable(&mut out, "server at connection capacity", 3).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Retry-After: 3\r\n"), "{text}");
        assert!(text.contains("connection capacity"), "{text}");
        // Headers stay well-formed: the extra header lands before the
        // blank line separating headers from body.
        let head_end = text.find("\r\n\r\n").unwrap();
        assert!(text[..head_end].contains("Retry-After"), "{text}");
    }
}
