//! End-to-end design-memory acceptance: a warm-started search must reach
//! the cold run's final best cost in at most half the evals, stay
//! deterministic for a fixed (store, seed, thread count), and degrade to
//! an exactly-cold run when the store is empty.

use sparsemap::api::{RunOpts, SearchReport, SearchRequest, WarmStart};
use sparsemap::memory::MemoryStore;
use sparsemap::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

fn store_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("sparsemap_memory_accept");
    let _ = std::fs::create_dir_all(&dir);
    dir.join(format!("{}_{}.bin", name, std::process::id()))
}

fn arm(seed: u64, threads: usize) -> SearchRequest {
    SearchRequest::new()
        .workload_named("mm1")
        .platform_named("mobile")
        .method("es-std")
        .method_opts(Json::parse(r#"{"population": 16}"#).unwrap())
        .budget(600)
        .seed(seed)
        .threads(threads)
}

/// Deposit a finished run's elite into a fresh store at `path`.
fn deposit(path: &Path, report: &SearchReport) {
    let session = report.request.clone().build().unwrap();
    let mut store = MemoryStore::open(path).unwrap();
    let recorded = store
        .remember(
            session.workload(),
            session.platform(),
            &report.outcome.method,
            &report.outcome,
            report.request.seed,
        )
        .unwrap();
    assert!(recorded, "a finite-best run must deposit a record");
}

fn file_store(path: &Path) -> WarmStart {
    WarmStart { store: Some(path.display().to_string()), ..Default::default() }
}

/// First curve point at or below `target`, by submission count.
fn evals_to_reach(report: &SearchReport, target: f64) -> Option<usize> {
    report.outcome.curve.iter().find(|&&(_, v)| v <= target).map(|&(e, _)| e)
}

#[test]
fn warm_started_run_reaches_cold_best_in_half_the_evals() {
    let path = store_path("half_evals");
    let _ = std::fs::remove_file(&path);

    let cold = arm(5, 1).build().unwrap().run().unwrap();
    assert!(cold.outcome.best_edp.is_finite(), "cold run found a valid design");
    assert_eq!(cold.memory_hits(), 0, "no warm-start requested");
    deposit(&path, &cold);

    // Same scenario, different seed, seeded from the store.
    let warm = arm(9, 1).warm_start(file_store(&path)).build().unwrap().run().unwrap();
    assert!(warm.memory_hits() > 0, "the store held a usable neighbour");
    assert!(
        warm.seeded_from().iter().any(|t| t.starts_with("mm1@mobile")),
        "provenance names the source scenario: {:?}",
        warm.seeded_from()
    );
    assert!(
        warm.outcome.best_edp <= cold.outcome.best_edp,
        "a seeded population can only improve on its seed"
    );

    // The acceptance bound: the warm run touches the cold run's final
    // best within half the evals the cold run spent (in practice within
    // the first population, since the seed *is* the cold elite).
    let reach =
        evals_to_reach(&warm, cold.outcome.best_edp).expect("warm run reaches the cold best");
    assert!(
        reach * 2 <= cold.outcome.evals,
        "cold best reached only at eval {reach} of {}",
        cold.outcome.evals
    );

    let _ = std::fs::remove_file(&path);
}

#[test]
fn warm_start_is_deterministic_for_fixed_store_seed_and_threads() {
    let path = store_path("determinism");
    let _ = std::fs::remove_file(&path);
    let cold = arm(3, 1).build().unwrap().run().unwrap();
    deposit(&path, &cold);

    let run = |threads| {
        arm(11, threads).warm_start(file_store(&path)).build().unwrap().run().unwrap()
    };
    let a = run(1);
    let b = run(2);
    let c = run(1);
    // Bit-identical across repeats AND across thread counts (parallel
    // evaluation preserves trajectories; seeding must not break that).
    for other in [&b, &c] {
        assert_eq!(a.outcome.best_edp.to_bits(), other.outcome.best_edp.to_bits());
        assert_eq!(a.outcome.best_genome, other.outcome.best_genome);
        assert_eq!(a.outcome.curve, other.outcome.curve);
        assert_eq!(a.memory_hits(), other.memory_hits());
        assert_eq!(a.seeded_from(), other.seeded_from());
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn host_store_supplies_seeds_and_missing_file_runs_cold() {
    let path = store_path("host");
    let _ = std::fs::remove_file(&path);
    let cold = arm(7, 1).build().unwrap().run().unwrap();
    deposit(&path, &cold);

    // A `warm_start` block with no store path seeds from the
    // host-supplied shared store (the service's arrangement).
    let shared = Arc::new(Mutex::new(MemoryStore::open(&path).unwrap()));
    let warm = arm(13, 1)
        .warm_start(WarmStart::default())
        .build()
        .unwrap()
        .run_opts(RunOpts { memory: Some(shared), ..Default::default() })
        .unwrap();
    assert!(warm.memory_hits() > 0, "host store supplied the seeds");

    // A configured-but-missing store file is an *empty* store: zero
    // hits, and the trajectory is bit-identical to a plain cold run.
    let missing = store_path("does_not_exist");
    let _ = std::fs::remove_file(&missing);
    let empty = arm(13, 1).warm_start(file_store(&missing)).build().unwrap().run().unwrap();
    assert_eq!(empty.memory_hits(), 0);
    assert!(empty.seeded_from().is_empty());
    let plain = arm(13, 1).build().unwrap().run().unwrap();
    assert_eq!(empty.outcome.best_edp.to_bits(), plain.outcome.best_edp.to_bits());
    assert_eq!(empty.outcome.curve, plain.outcome.curve);

    // With no store configured anywhere, an explicit warm-start request
    // has nothing to honor and errors instead of silently running cold.
    let err = arm(13, 1)
        .warm_start(WarmStart::default())
        .build()
        .unwrap()
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("warm_start has no store"), "{err}");

    let _ = std::fs::remove_file(&path);
}
