"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: `cost_eval_ref` mirrors the Rust
native formula in `rust/src/model/cost.rs` (FEATURE_SCHEMA_V1) and the
Pallas kernel in `cost_kernel.py` must match it exactly; `spmm_gated_ref`
is the dense oracle for the gated-SpMM demo kernel.
"""

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# FEATURE_SCHEMA_V1 column indices — keep in sync with
# rust/src/model/features.rs.
# ---------------------------------------------------------------------------
NUM_FEATURES = 48
NUM_PLATFORM_FEATURES = 16

F_P_WORDS_B0 = 0
F_Q_WORDS_B0 = 1
F_Z_WORDS_B0 = 2
F_P_GLB_READS_B1 = 3
F_Q_GLB_READS_B1 = 4
F_Z_GLB_WORDS_B1 = 5
F_P_NOC_WORDS_B1 = 6
F_Q_NOC_WORDS_B1 = 7
F_Z_NOC_WORDS_B1 = 8
F_P_WORDS_B2 = 9
F_Q_WORDS_B2 = 10
F_Z_WORDS_B2 = 11
F_CR_P_B0 = 12
F_CR_Q_B0 = 13
F_CR_Z_B0 = 14
F_CR_P_B1 = 15
F_CR_Q_B1 = 16
F_CR_Z_B1 = 17
F_META_P_B0 = 18
F_META_Q_B0 = 19
F_META_Z_B0 = 20
F_META_P_B1 = 21
F_META_Q_B1 = 22
F_META_Z_B1 = 23
F_SG_P_ENERGY_B1 = 24
F_SG_Q_ENERGY_B1 = 25
F_SG_CYCLES_B1 = 26
F_SG_P_ENERGY_B2 = 27
F_SG_Q_ENERGY_B2 = 28
F_SG_CYCLES_B2 = 29
F_MAC_ENERGY_FRAC = 30
F_COMPUTE_CYCLE_FRAC = 31
F_TOTAL_OPS = 32
F_ACTIVE_MACS = 33
F_GLB_TILE_WORDS = 34
F_PE_TILE_WORDS = 35
F_STRUCT_VALID = 36
F_CTRL_B1 = 37
F_CTRL_B2 = 38
F_CTRL_C = 39
F_ACTIVE_PES = 40
F_DENSITY_P = 41
F_DENSITY_Q = 42
F_DENSITY_Z = 43


def cost_eval_ref(feats, plat):
    """Evaluate the cost formula for a feature batch.

    Args:
      feats: f32[B, NUM_FEATURES] — FEATURE_SCHEMA_V1 rows.
      plat:  f32[NUM_PLATFORM_FEATURES] — platform vector.

    Returns:
      f32[B, 4]: columns (energy_pj, cycles, edp, valid).
    """
    f = feats
    e_dram, e_glb, e_pebuf, e_reg = plat[0], plat[1], plat[2], plat[3]
    e_mac, e_noc, e_meta = plat[4], plat[5], plat[6]
    bw_dram, bw_glb, bw_pe = plat[7], plat[8], plat[9]
    glb_cap, pe_cap = plat[10], plat[11]

    # ---- boundary 0: DRAM <-> GLB (compressed words) ----------------------
    w0 = (f[:, F_P_WORDS_B0] * f[:, F_CR_P_B0]
          + f[:, F_Q_WORDS_B0] * f[:, F_CR_Q_B0]
          + f[:, F_Z_WORDS_B0] * f[:, F_CR_Z_B0])
    meta0 = (f[:, F_P_WORDS_B0] * f[:, F_META_P_B0]
             + f[:, F_Q_WORDS_B0] * f[:, F_META_Q_B0]
             + f[:, F_Z_WORDS_B0] * f[:, F_META_Z_B0])
    energy_b0 = w0 * (e_dram + e_glb) + meta0 * e_meta

    # ---- boundary 1: GLB -> PE over the NoC --------------------------------
    glb_reads = (f[:, F_P_GLB_READS_B1] * f[:, F_CR_P_B1] * f[:, F_SG_P_ENERGY_B1]
                 + f[:, F_Q_GLB_READS_B1] * f[:, F_CR_Q_B1] * f[:, F_SG_Q_ENERGY_B1]
                 + f[:, F_Z_GLB_WORDS_B1] * f[:, F_CR_Z_B1])
    noc_words = (f[:, F_P_NOC_WORDS_B1] * f[:, F_CR_P_B1] * f[:, F_SG_P_ENERGY_B1]
                 + f[:, F_Q_NOC_WORDS_B1] * f[:, F_CR_Q_B1] * f[:, F_SG_Q_ENERGY_B1]
                 + f[:, F_Z_NOC_WORDS_B1] * f[:, F_CR_Z_B1])
    meta1 = (f[:, F_P_NOC_WORDS_B1] * f[:, F_META_P_B1]
             + f[:, F_Q_NOC_WORDS_B1] * f[:, F_META_Q_B1]
             + f[:, F_Z_NOC_WORDS_B1] * f[:, F_META_Z_B1])
    energy_b1 = (glb_reads * e_glb + noc_words * (e_noc + e_pebuf)
                 + meta1 * e_meta + noc_words * f[:, F_CTRL_B1])

    # ---- boundary 2: PE buffer -> MAC operands -----------------------------
    w2 = (f[:, F_P_WORDS_B2] * f[:, F_SG_P_ENERGY_B2]
          + f[:, F_Q_WORDS_B2] * f[:, F_SG_Q_ENERGY_B2]
          + f[:, F_Z_WORDS_B2])
    energy_b2 = w2 * (e_pebuf + e_reg) + w2 * f[:, F_CTRL_B2]

    # ---- compute ------------------------------------------------------------
    energy_mac = (f[:, F_TOTAL_OPS] * f[:, F_MAC_ENERGY_FRAC] * e_mac
                  + f[:, F_TOTAL_OPS] * f[:, F_CTRL_C])

    energy = energy_b0 + energy_b1 + energy_b2 + energy_mac

    # ---- latency: bottleneck pipeline stage --------------------------------
    cycles_compute = (f[:, F_TOTAL_OPS] / jnp.maximum(f[:, F_ACTIVE_MACS], 1.0)
                      * f[:, F_COMPUTE_CYCLE_FRAC])
    cycles_dram = w0 / jnp.maximum(bw_dram, 1e-12)
    cycles_glb = glb_reads * f[:, F_SG_CYCLES_B1] / jnp.maximum(bw_glb, 1e-12)
    cycles_pe = (w2 * f[:, F_SG_CYCLES_B2]
                 / (jnp.maximum(bw_pe, 1e-12) * jnp.maximum(f[:, F_ACTIVE_PES], 1.0)))
    cycles = jnp.maximum(
        jnp.maximum(jnp.maximum(cycles_compute, cycles_dram),
                    jnp.maximum(cycles_glb, cycles_pe)),
        1.0,
    )

    # ---- validity -----------------------------------------------------------
    glb_util = f[:, F_GLB_TILE_WORDS] / jnp.maximum(glb_cap, 1.0)
    pe_util = f[:, F_PE_TILE_WORDS] / jnp.maximum(pe_cap, 1.0)
    fits = jnp.where((glb_util <= 1.0) & (pe_util <= 1.0), 1.0, 0.0)
    valid = f[:, F_STRUCT_VALID] * fits

    edp = energy * cycles
    return jnp.stack([energy, cycles, edp, valid], axis=-1)


def spmm_gated_ref(p, q, pmask, qmask):
    """Oracle for the gated-SpMM demo: zero out gated operands, multiply.

    Returns (z, effectual_macs) where effectual_macs counts MAC operations
    whose both operands are nonzero (Gate P<->Q semantics, Fig. 14).
    """
    pz = p * pmask
    qz = q * qmask
    z = pz @ qz
    effectual = jnp.sum(pmask @ qmask)
    return z, effectual
