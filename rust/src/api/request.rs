//! [`SearchRequest`] — the typed, JSON-round-trippable description of one
//! search arm.

use super::session::SearchSession;
use crate::arch::Platform;
use crate::util::json::Json;
use crate::workload::{spec, table3, Workload};
use anyhow::{anyhow, Result};

/// Largest integer `Json`'s f64 numbers hold exactly.
const JSON_EXACT_INT_MAX: u64 = 1 << 53;

/// Emit a `u64` losslessly: as a JSON number when f64 holds it exactly,
/// as a decimal string above 2^53 (seeds are arbitrary u64s).
fn u64_to_json(x: u64) -> Json {
    if x <= JSON_EXACT_INT_MAX {
        Json::num(x as f64)
    } else {
        Json::Str(x.to_string())
    }
}

/// Inverse of [`u64_to_json`]: accepts both encodings.
fn u64_from_json(j: &Json, field: &str) -> Result<u64> {
    match j {
        Json::Str(s) => s.parse::<u64>().map_err(|_| {
            anyhow!("request field '{field}' must be a non-negative integer, got '{s}'")
        }),
        other => other
            .as_u64()
            .ok_or_else(|| anyhow!("request field '{field}' must be a non-negative integer")),
    }
}

/// Workload selector: a Table III id or a fully custom contraction.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadSel {
    Named(String),
    Custom(Workload),
}

/// Platform selector: a Table II name or a fully custom geometry.
#[derive(Clone, Debug, PartialEq)]
pub enum PlatformSel {
    Named(String),
    Custom(Platform),
}

/// Warm-start configuration: seed part of the initial population from a
/// [`crate::memory::MemoryStore`] of prior elite designs. Off by default
/// (`SearchRequest::warm_start` is `None`), and **omitted from the wire
/// when unset** so legacy request JSON stays byte-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct WarmStart {
    /// Path to the memory store file. `None` means "use the store the
    /// host supplies" — the service injects its shared store through
    /// [`super::RunOpts::memory`]; a standalone run without either is a
    /// build-time error.
    pub store: Option<String>,
    /// Fraction of the initial population eligible for memory seeds,
    /// in `(0, 1]`.
    pub fraction: f64,
    /// How many nearest prior scenarios to consult.
    pub k: usize,
}

impl Default for WarmStart {
    fn default() -> Self {
        WarmStart { store: None, fraction: 0.25, k: 8 }
    }
}

impl WarmStart {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.fraction.is_finite() && self.fraction > 0.0 && self.fraction <= 1.0,
            "warm_start fraction must be in (0, 1], got {}",
            self.fraction
        );
        anyhow::ensure!(self.k >= 1, "warm_start k must be >= 1");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("fraction", Json::num(self.fraction)),
            ("k", Json::num(self.k as f64)),
        ];
        if let Some(path) = &self.store {
            fields.insert(0, ("store", Json::str(path)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<WarmStart> {
        anyhow::ensure!(j.as_obj().is_some(), "request field 'warm_start' must be a JSON object");
        let mut ws = WarmStart::default();
        if let Some(s) = j.get("store") {
            ws.store = Some(
                s.as_str()
                    .ok_or_else(|| anyhow!("warm_start field 'store' must be a string path"))?
                    .to_string(),
            );
        }
        if let Some(f) = j.get("fraction") {
            ws.fraction = f
                .as_f64()
                .ok_or_else(|| anyhow!("warm_start field 'fraction' must be a number"))?;
        }
        if let Some(k) = j.get("k") {
            ws.k = k.as_u64().ok_or_else(|| anyhow!("warm_start field 'k' must be an integer"))?
                as usize;
        }
        ws.validate()?;
        Ok(ws)
    }
}

/// One search arm: what to search (workload × platform), how (method),
/// and with which resources (budget, seed, threads, backend, cache).
///
/// Build with the fluent setters, then [`SearchRequest::build`] validates
/// everything into a runnable [`SearchSession`]:
///
/// ```no_run
/// use sparsemap::api::SearchRequest;
///
/// let report = SearchRequest::new()
///     .workload_named("mm3")
///     .platform_named("cloud")
///     .budget(10_000)
///     .seed(42)
///     .build()?
///     .run()?;
/// println!("best EDP {:.4e}", report.outcome.best_edp);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SearchRequest {
    pub workload: WorkloadSel,
    pub platform: PlatformSel,
    /// A method name or alias from [`crate::optimizer::registry()`]
    /// (see [`crate::optimizer::ALL_METHODS`]; CLI: `sparsemap methods`).
    pub method: String,
    /// Method hyper-parameters as a JSON object, validated at
    /// [`SearchRequest::build`] against the method's tunable schema
    /// (unknown keys and out-of-range values are rejected). Empty =
    /// paper defaults. E.g. `{"population": 200, "mutation_prob": 0.4}`
    /// for `sparsemap`, `{"swarm": 24}` for `pso`, or
    /// `{"members": ["sparsemap", "pso"]}` for `portfolio`.
    pub method_opts: Json,
    /// Sample budget (the paper uses 20 000).
    pub budget: usize,
    pub seed: u64,
    /// Worker threads for population evaluation inside the arm
    /// (trajectories are bit-identical for any count; 0/1 = serial).
    pub threads: usize,
    /// Evaluate through the AOT PJRT artifact instead of the native
    /// model (falls back to native when unavailable).
    pub use_pjrt: bool,
    /// Memoize repeated genomes (on by default; results never change).
    pub cache: bool,
    /// Seed the initial population from a design-memory store of prior
    /// elite designs ([`crate::memory`]). `None` (the default) reads and
    /// writes nothing and keeps trajectories bit-identical to a build
    /// without the memory subsystem.
    pub warm_start: Option<WarmStart>,
}

impl Default for SearchRequest {
    fn default() -> Self {
        SearchRequest {
            workload: WorkloadSel::Named("mm3".to_string()),
            platform: PlatformSel::Named("cloud".to_string()),
            method: "sparsemap".to_string(),
            method_opts: Json::Obj(Default::default()),
            budget: 20_000,
            seed: 42,
            threads: 1,
            use_pjrt: false,
            cache: true,
            warm_start: None,
        }
    }
}

impl SearchRequest {
    pub fn new() -> SearchRequest {
        SearchRequest::default()
    }

    /// Search a Table III workload by id (see `sparsemap workloads`).
    pub fn workload_named(mut self, id: &str) -> Self {
        self.workload = WorkloadSel::Named(id.to_string());
        self
    }

    /// Search a custom workload (validated at [`SearchRequest::build`]).
    pub fn workload(mut self, w: Workload) -> Self {
        self.workload = WorkloadSel::Custom(w);
        self
    }

    /// Target a Table II platform by name (edge | mobile | cloud).
    pub fn platform_named(mut self, name: &str) -> Self {
        self.platform = PlatformSel::Named(name.to_string());
        self
    }

    /// Target a custom platform (validated at [`SearchRequest::build`]).
    pub fn platform(mut self, p: Platform) -> Self {
        self.platform = PlatformSel::Custom(p);
        self
    }

    pub fn method(mut self, method: &str) -> Self {
        self.method = method.to_string();
        self
    }

    /// Set the method's hyper-parameters (a JSON object; validated at
    /// [`SearchRequest::build`] against the method's tunable schema —
    /// run `sparsemap methods` for every method's knobs).
    pub fn method_opts(mut self, opts: Json) -> Self {
        self.method_opts = opts;
        self
    }

    pub fn budget(mut self, budget: usize) -> Self {
        self.budget = budget;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn pjrt(mut self, use_pjrt: bool) -> Self {
        self.use_pjrt = use_pjrt;
        self
    }

    pub fn cache(mut self, cache: bool) -> Self {
        self.cache = cache;
        self
    }

    /// Enable design-memory warm-starting (validated at
    /// [`SearchRequest::build`]).
    pub fn warm_start(mut self, ws: WarmStart) -> Self {
        self.warm_start = Some(ws);
        self
    }

    /// Resolve the selectors into concrete, validated values.
    pub fn resolve(&self) -> Result<(Workload, Platform)> {
        let workload = match &self.workload {
            WorkloadSel::Named(id) => table3::by_id(id).ok_or_else(|| {
                anyhow!("unknown workload '{id}' (see `sparsemap workloads`, or pass a spec)")
            })?,
            WorkloadSel::Custom(w) => {
                w.validate()?;
                w.clone()
            }
        };
        let platform = match &self.platform {
            PlatformSel::Named(name) => Platform::by_name(name)?,
            PlatformSel::Custom(p) => {
                p.validate()?;
                p.clone()
            }
        };
        Ok((workload, platform))
    }

    /// Validate the whole request into a runnable [`SearchSession`].
    pub fn build(self) -> Result<SearchSession> {
        SearchSession::new(self)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            (
                "workload",
                match &self.workload {
                    WorkloadSel::Named(id) => Json::str(id),
                    WorkloadSel::Custom(w) => spec::workload_to_spec(w),
                },
            ),
            (
                "platform",
                match &self.platform {
                    PlatformSel::Named(name) => Json::str(name),
                    PlatformSel::Custom(p) => p.to_spec_json(),
                },
            ),
            ("method", Json::str(&self.method)),
            ("budget", u64_to_json(self.budget as u64)),
            ("seed", u64_to_json(self.seed)),
            ("threads", Json::num(self.threads as f64)),
            ("pjrt", Json::Bool(self.use_pjrt)),
            ("cache", Json::Bool(self.cache)),
        ]);
        // Default (empty) opts stay off the wire so request/report JSON
        // from before the optimizer-registry revision is byte-identical.
        if self.method_opts.as_obj().is_some_and(|o| !o.is_empty()) {
            if let Json::Obj(map) = &mut j {
                map.insert("method_opts".to_string(), self.method_opts.clone());
            }
        }
        // Same discipline for warm_start: unset stays off the wire.
        if let Some(ws) = &self.warm_start {
            if let Json::Obj(map) = &mut j {
                map.insert("warm_start".to_string(), ws.to_json());
            }
        }
        j
    }

    /// Parse a request; absent fields take the [`Default`] values, so a
    /// minimal spec file only needs the parts it wants to change.
    pub fn from_json(j: &Json) -> Result<SearchRequest> {
        anyhow::ensure!(j.as_obj().is_some(), "search request must be a JSON object");
        let mut req = SearchRequest::default();
        if let Some(w) = j.get("workload") {
            req.workload = match w {
                Json::Str(id) => WorkloadSel::Named(id.clone()),
                other => WorkloadSel::Custom(spec::workload_from_spec(other)?),
            };
        }
        if let Some(p) = j.get("platform") {
            req.platform = match p {
                Json::Str(name) => PlatformSel::Named(name.clone()),
                other => PlatformSel::Custom(Platform::from_spec(other)?),
            };
        }
        if let Some(m) = j.get("method") {
            req.method = m
                .as_str()
                .ok_or_else(|| anyhow!("request field 'method' must be a string"))?
                .to_string();
        }
        if let Some(mo) = j.get("method_opts") {
            anyhow::ensure!(
                mo.as_obj().is_some(),
                "request field 'method_opts' must be a JSON object"
            );
            req.method_opts = mo.clone();
        }
        if let Some(b) = j.get("budget") {
            req.budget = u64_from_json(b, "budget")? as usize;
        }
        if let Some(s) = j.get("seed") {
            req.seed = u64_from_json(s, "seed")?;
        }
        if let Some(t) = j.get("threads") {
            req.threads = t
                .as_u64()
                .ok_or_else(|| anyhow!("request field 'threads' must be an integer"))?
                as usize;
        }
        if let Some(p) = j.get("pjrt") {
            req.use_pjrt =
                p.as_bool().ok_or_else(|| anyhow!("request field 'pjrt' must be a bool"))?;
        }
        if let Some(c) = j.get("cache") {
            req.cache =
                c.as_bool().ok_or_else(|| anyhow!("request field 'cache' must be a bool"))?;
        }
        if let Some(ws) = j.get("warm_start") {
            req.warm_start = Some(WarmStart::from_json(ws)?);
        }
        Ok(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_setters() {
        let r = SearchRequest::new()
            .workload_named("conv4")
            .platform_named("edge")
            .method("pso")
            .budget(500)
            .seed(7)
            .threads(4);
        assert_eq!(r.workload, WorkloadSel::Named("conv4".to_string()));
        assert_eq!(r.method, "pso");
        assert_eq!(r.budget, 500);
        assert!(r.cache, "cache defaults on");
    }

    #[test]
    fn named_request_json_round_trips() {
        let r = SearchRequest::new().workload_named("mm5").platform_named("mobile").seed(9);
        let j = Json::parse(&r.to_json().dumps()).unwrap();
        assert_eq!(SearchRequest::from_json(&j).unwrap(), r);
    }

    #[test]
    fn custom_request_json_round_trips() {
        let w = Workload::spmm("custom", 64, 128, 32, 0.4, 0.2);
        let p = Platform::custom("pico", 8, 8, 2, 4 << 10, 512 << 10, 4e9, 4e8, 32.0, 8.0)
            .unwrap();
        let r = SearchRequest::new().workload(w).platform(p).budget(300);
        let j = Json::parse(&r.to_json().dumps()).unwrap();
        assert_eq!(SearchRequest::from_json(&j).unwrap(), r);
    }

    #[test]
    fn structured_density_round_trips_and_bad_density_is_typed_error() {
        use crate::sparsity::DensityModel;
        use crate::workload::WorkloadKind;
        let w = Workload::custom_models(
            "blocky",
            WorkloadKind::SpMM,
            vec![("M".into(), 64), ("K".into(), 256), ("N".into(), 64)],
            vec![
                ("P".into(), vec![0, 1], Some(DensityModel::block(16, 0.2))),
                ("Q".into(), vec![1, 2], Some(DensityModel::row_skewed(0.5, 0.4))),
                ("Z".into(), vec![0, 2], None),
            ],
            vec![1],
        )
        .unwrap();
        let r = SearchRequest::new().workload(w).budget(100);
        let j = Json::parse(&r.to_json().dumps()).unwrap();
        assert_eq!(SearchRequest::from_json(&j).unwrap(), r);

        // A bad density reaches the API as a typed validation error (it
        // used to be an assert panic in the workload constructor).
        let bad = Workload::spmm("bad", 8, 8, 8, 0.0, 0.5);
        let err = SearchRequest::new()
            .workload(bad)
            .budget(10)
            .build()
            .err()
            .expect("bad density must fail request validation");
        assert!(format!("{err:?}").contains("density"), "{err:?}");
    }

    #[test]
    fn method_opts_round_trip_and_default_stays_off_the_wire() {
        let opts = Json::parse(r#"{"population": 200, "mutation_prob": 0.4}"#).unwrap();
        let r = SearchRequest::new().workload_named("mm1").method_opts(opts.clone());
        let j = Json::parse(&r.to_json().dumps()).unwrap();
        let r2 = SearchRequest::from_json(&j).unwrap();
        assert_eq!(r2.method_opts, opts);
        assert_eq!(r2, r);
        // Default empty opts are not serialized at all (legacy JSON
        // byte-compatibility).
        let plain = SearchRequest::new().workload_named("mm1");
        assert!(!plain.to_json().dumps().contains("method_opts"));
        // Non-object method_opts is a parse-time error.
        let bad = Json::parse(r#"{"workload": "mm1", "method_opts": [1]}"#).unwrap();
        assert!(SearchRequest::from_json(&bad).is_err());
    }

    #[test]
    fn warm_start_round_trips_and_unset_stays_off_the_wire() {
        let ws = WarmStart { store: Some("/tmp/mem.bin".into()), fraction: 0.5, k: 4 };
        let r = SearchRequest::new().workload_named("mm1").warm_start(ws.clone());
        let j = Json::parse(&r.to_json().dumps()).unwrap();
        let r2 = SearchRequest::from_json(&j).unwrap();
        assert_eq!(r2.warm_start, Some(ws));
        assert_eq!(r2, r);
        // Unset warm-start is not serialized at all (legacy JSON
        // byte-compatibility, same rule as method_opts).
        let plain = SearchRequest::new().workload_named("mm1");
        assert!(!plain.to_json().dumps().contains("warm_start"));
        // Defaults fill absent sub-fields.
        let min = Json::parse(r#"{"workload": "mm1", "warm_start": {}}"#).unwrap();
        let parsed = SearchRequest::from_json(&min).unwrap().warm_start.unwrap();
        assert_eq!(parsed, WarmStart::default());
        // Out-of-range knobs are parse-time errors.
        for bad in [
            r#"{"warm_start": {"fraction": 0.0}}"#,
            r#"{"warm_start": {"fraction": 1.5}}"#,
            r#"{"warm_start": {"k": 0}}"#,
            r#"{"warm_start": [1]}"#,
        ] {
            assert!(SearchRequest::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn huge_seed_round_trips_losslessly() {
        let r = SearchRequest::new().seed(u64::MAX).workload_named("mm1");
        let j = Json::parse(&r.to_json().dumps()).unwrap();
        let r2 = SearchRequest::from_json(&j).unwrap();
        assert_eq!(r2.seed, u64::MAX);
        assert_eq!(r2, r);
    }

    #[test]
    fn minimal_spec_takes_defaults() {
        let r =
            SearchRequest::from_json(&Json::parse(r#"{"workload": "mm1"}"#).unwrap()).unwrap();
        assert_eq!(r.workload, WorkloadSel::Named("mm1".to_string()));
        assert_eq!(r.budget, 20_000);
        assert_eq!(r.method, "sparsemap");
    }

    #[test]
    fn resolve_rejects_unknown_names() {
        assert!(SearchRequest::new().workload_named("nope").resolve().is_err());
        assert!(SearchRequest::new().platform_named("laptop").resolve().is_err());
    }
}
