//! The static method registry — one [`MethodSpec`] per search arm, plus
//! the generic adapter that lifts the config-parameterized cores in
//! [`crate::es`] / [`crate::baselines`] into [`Optimizer`]s.
//!
//! Default tunable values here ARE the paper constants the free
//! functions used to hard-wire; `rust/tests/golden_trajectories.rs` pins
//! that an empty options object reproduces every pre-registry trajectory
//! bit-for-bit.

use super::portfolio;
use super::{opt_f64, opt_usize, MethodSpec, Optimizer, Tunable, TunableKind};
use crate::baselines::es_direct::{es_direct_with, EsDirectConfig};
use crate::baselines::mcts::{mcts_with, MctsConfig};
use crate::baselines::pso::{PsoConfig, PsoOpt};
use crate::baselines::rl::{dqn_with, ppo_with, DqnConfig, PpoConfig};
use crate::baselines::samplers::{
    sage_like_with, sparseloop_mapper_with, RandomConfig, RandomOpt, SageConfig, SparseloopConfig,
};
use crate::baselines::tbpsa::{tbpsa_with, TbpsaConfig};
use crate::es::{EsConfig, EsOpt, EsVariant};
use crate::search::EvalContext;
use crate::util::json::Json;
use anyhow::Result;

/// Adapter: a typed config + the matching `*_with` core = an Optimizer.
struct ConfiguredOpt<C: 'static> {
    label: &'static str,
    cfg: C,
    run_fn: fn(&mut EvalContext, &C, u64),
}

impl<C> Optimizer for ConfiguredOpt<C> {
    fn label(&self) -> &str {
        self.label
    }

    fn run(&mut self, ctx: &mut EvalContext, seed: u64) {
        (self.run_fn)(ctx, &self.cfg, seed)
    }
}

// --- builders (opts are pre-validated against the tunable tables) ----------

fn build_es(variant: EsVariant, opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = EsConfig::default();
    let cfg = EsConfig {
        population: opt_usize(opts, "population", d.population),
        parent_frac: opt_f64(opts, "parent_frac", d.parent_frac),
        mutation_prob: opt_f64(opts, "mutation_prob", d.mutation_prob),
        variant,
        ..d
    };
    Ok(Box::new(EsOpt::new(cfg)))
}

fn build_sparsemap(opts: &Json) -> Result<Box<dyn Optimizer>> {
    build_es(EsVariant::Full, opts)
}

fn build_es_pfce(opts: &Json) -> Result<Box<dyn Optimizer>> {
    build_es(EsVariant::Pfce, opts)
}

fn build_es_std(opts: &Json) -> Result<Box<dyn Optimizer>> {
    build_es(EsVariant::Standard, opts)
}

fn build_es_direct(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = EsDirectConfig::default();
    let cfg = EsDirectConfig {
        population: opt_usize(opts, "population", d.population),
        parent_frac: opt_f64(opts, "parent_frac", d.parent_frac),
        mutation_prob: opt_f64(opts, "mutation_prob", d.mutation_prob),
    };
    Ok(Box::new(ConfiguredOpt { label: "es-direct", cfg, run_fn: es_direct_with }))
}

fn build_random(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = RandomConfig::default();
    let cfg = RandomConfig { batch: opt_usize(opts, "batch", d.batch) };
    Ok(Box::new(RandomOpt::new(cfg)))
}

fn build_sparseloop(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = SparseloopConfig::default();
    let cfg = SparseloopConfig {
        batch: opt_usize(opts, "batch", d.batch),
        manual_prob: opt_f64(opts, "manual_prob", d.manual_prob),
    };
    Ok(Box::new(ConfiguredOpt { label: "sparseloop", cfg, run_fn: sparseloop_mapper_with }))
}

fn build_sage(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = SageConfig::default();
    let cfg = SageConfig {
        population: opt_usize(opts, "population", d.population),
        mutations: opt_usize(opts, "mutations", d.mutations),
    };
    Ok(Box::new(ConfiguredOpt { label: "sage-like", cfg, run_fn: sage_like_with }))
}

fn build_pso(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = PsoConfig::default();
    let cfg = PsoConfig {
        swarm: opt_usize(opts, "swarm", d.swarm),
        inertia: opt_f64(opts, "inertia", d.inertia),
        c1: opt_f64(opts, "c1", d.c1),
        c2: opt_f64(opts, "c2", d.c2),
    };
    Ok(Box::new(PsoOpt::new(cfg)))
}

fn build_mcts(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = MctsConfig::default();
    let cfg = MctsConfig { c_uct: opt_f64(opts, "c_uct", d.c_uct) };
    Ok(Box::new(ConfiguredOpt { label: "mcts", cfg, run_fn: mcts_with }))
}

fn build_tbpsa(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = TbpsaConfig::default();
    let cfg = TbpsaConfig {
        lambda: opt_usize(opts, "lambda", d.lambda),
        mu: opt_usize(opts, "mu", d.mu),
    };
    Ok(Box::new(ConfiguredOpt { label: "tbpsa", cfg, run_fn: tbpsa_with }))
}

fn build_ppo(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = PpoConfig::default();
    let cfg = PpoConfig {
        clip: opt_f64(opts, "clip", d.clip),
        lr: opt_f64(opts, "lr", d.lr),
        batch: opt_usize(opts, "batch", d.batch),
    };
    Ok(Box::new(ConfiguredOpt { label: "ppo", cfg, run_fn: ppo_with }))
}

fn build_dqn(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let d = DqnConfig::default();
    let cfg = DqnConfig {
        gamma: opt_f64(opts, "gamma", d.gamma),
        lr: opt_f64(opts, "lr", d.lr),
        hidden: opt_usize(opts, "hidden", d.hidden),
    };
    Ok(Box::new(ConfiguredOpt { label: "dqn", cfg, run_fn: dqn_with }))
}

// --- tunable tables --------------------------------------------------------

const PARENT_FRAC_TUNABLE: Tunable = Tunable {
    key: "parent_frac",
    kind: TunableKind::Float { min: 0.01, max: 1.0 },
    default: "0.25",
    help: "fraction of the population selected as parents",
};

const MUTATION_PROB_TUNABLE: Tunable = Tunable {
    key: "mutation_prob",
    kind: TunableKind::Float { min: 0.0, max: 1.0 },
    default: "0.6",
    help: "probability an offspring is mutated",
};

const ES_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "population",
        kind: TunableKind::Int { min: 2, max: 10_000 },
        default: "100",
        help: "population size (capped at budget/8 at runtime)",
    },
    PARENT_FRAC_TUNABLE,
    MUTATION_PROB_TUNABLE,
];

// es-direct shares the ES knobs but NOT the budget/8 runtime cap, so it
// documents its population honestly.
const ES_DIRECT_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "population",
        kind: TunableKind::Int { min: 2, max: 10_000 },
        default: "100",
        help: "population size (uncapped; offspring are clipped to the remaining budget)",
    },
    PARENT_FRAC_TUNABLE,
    MUTATION_PROB_TUNABLE,
];

const BATCH_TUNABLE: Tunable = Tunable {
    key: "batch",
    kind: TunableKind::Int { min: 1, max: 1_000_000 },
    default: "256",
    help: "genomes submitted per evaluation batch",
};

const RANDOM_TUNABLES: &[Tunable] = &[BATCH_TUNABLE];

const SPARSELOOP_TUNABLES: &[Tunable] = &[
    BATCH_TUNABLE,
    Tunable {
        key: "manual_prob",
        kind: TunableKind::Float { min: 0.0, max: 1.0 },
        default: "0.8",
        help: "probability a sample pins the manual sparse strategy",
    },
];

const SAGE_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "population",
        kind: TunableKind::Int { min: 2, max: 10_000 },
        default: "40",
        help: "population of the format/strategy evolutionary loop",
    },
    Tunable {
        key: "mutations",
        kind: TunableKind::Int { min: 0, max: 64 },
        default: "2",
        help: "strategy genes re-sampled per child",
    },
];

const PSO_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "swarm",
        kind: TunableKind::Int { min: 1, max: 10_000 },
        default: "40",
        help: "number of particles",
    },
    Tunable {
        key: "inertia",
        kind: TunableKind::Float { min: 0.0, max: 2.0 },
        default: "0.729",
        help: "velocity inertia (Clerc constriction)",
    },
    Tunable {
        key: "c1",
        kind: TunableKind::Float { min: 0.0, max: 8.0 },
        default: "1.494",
        help: "cognitive (personal-best) acceleration",
    },
    Tunable {
        key: "c2",
        kind: TunableKind::Float { min: 0.0, max: 8.0 },
        default: "1.494",
        help: "social (global-best) acceleration",
    },
];

const MCTS_TUNABLES: &[Tunable] = &[Tunable {
    key: "c_uct",
    kind: TunableKind::Float { min: 0.0, max: 16.0 },
    default: "1.4",
    help: "UCB1 exploration constant",
}];

const TBPSA_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "lambda",
        kind: TunableKind::Int { min: 1, max: 10_000 },
        default: "30",
        help: "samples drawn per iteration",
    },
    Tunable {
        key: "mu",
        kind: TunableKind::Int { min: 1, max: 10_000 },
        default: "8",
        help: "elites the distribution recenters on (capped at lambda)",
    },
];

const PPO_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "clip",
        kind: TunableKind::Float { min: 0.0, max: 1.0 },
        default: "0.2",
        help: "trust-region clip for the surrogate ratio",
    },
    Tunable {
        key: "lr",
        kind: TunableKind::Float { min: 1e-6, max: 10.0 },
        default: "0.15",
        help: "policy learning rate",
    },
    Tunable {
        key: "batch",
        kind: TunableKind::Int { min: 1, max: 10_000 },
        default: "24",
        help: "episodes sampled per update",
    },
];

const DQN_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "gamma",
        kind: TunableKind::Float { min: 0.0, max: 1.0 },
        default: "0.98",
        help: "per-step discount inside the backward TD sweep",
    },
    Tunable {
        key: "lr",
        kind: TunableKind::Float { min: 1e-6, max: 10.0 },
        default: "0.01",
        help: "Q-network learning rate",
    },
    Tunable {
        key: "hidden",
        kind: TunableKind::Int { min: 1, max: 4_096 },
        default: "32",
        help: "hidden width of the in-tree MLP",
    },
];

const PORTFOLIO_TUNABLES: &[Tunable] = &[
    Tunable {
        key: "members",
        kind: TunableKind::MethodList,
        default: "[\"sparsemap\", \"es-pfce\", \"pso\", \"random\"]",
        help: "registry methods racing for the shared budget",
    },
    Tunable {
        key: "member_opts",
        kind: TunableKind::OptsByMethod,
        default: "{}",
        help: "per-member method_opts, validated against each member's schema",
    },
    Tunable {
        key: "alloc",
        kind: TunableKind::Choice { options: &["ucb", "halving"] },
        default: "ucb",
        help: "budget allocation policy: UCB1 bandit pulls or fixed successive halving",
    },
    Tunable {
        key: "ucb_c",
        kind: TunableKind::Float { min: 0.0, max: 16.0 },
        default: "1.4",
        help: "UCB1 exploration constant (alloc=ucb)",
    },
    Tunable {
        key: "pulls",
        kind: TunableKind::Int { min: 1, max: 4_096 },
        default: "16",
        help: "bandit pulls the budget is split across (alloc=ucb)",
    },
    Tunable {
        key: "rounds",
        kind: TunableKind::Int { min: 1, max: 64 },
        default: "3",
        help: "successive-halving rounds over the shared budget (alloc=halving)",
    },
    Tunable {
        key: "eta",
        kind: TunableKind::Int { min: 2, max: 16 },
        default: "2",
        help: "elimination factor: each round keeps ceil(alive/eta) members (alloc=halving)",
    },
];

// --- the registry ----------------------------------------------------------

const METHOD_COUNT: usize = 13;

/// The canonical method table. Order is user-facing (`sparsemap
/// methods`, error messages): the paper's eleven arms first (in their
/// historical `ALL_METHODS` order), then the post-paper additions.
const METHODS: [MethodSpec; METHOD_COUNT] = [
    MethodSpec {
        name: "sparsemap",
        aliases: &["sm", "es-full"],
        summary: "full SparseMap ES: PFCE encoding + sensitivity calibration + HSHI + \
                  annealing/sensitivity-aware operators",
        tunables: ES_TUNABLES,
        resumable: true,
        builder: build_sparsemap,
    },
    MethodSpec {
        name: "es-pfce",
        aliases: &["pfce"],
        summary: "ablation: plain ES over the PFCE encoding (LHS init, uniform operators)",
        tunables: ES_TUNABLES,
        resumable: true,
        builder: build_es_pfce,
    },
    MethodSpec {
        name: "es-direct",
        aliases: &["direct-es"],
        summary: "ablation: standard ES over the direct-value encoding (dead-offspring-ridden)",
        tunables: ES_DIRECT_TUNABLES,
        resumable: false,
        builder: build_es_direct,
    },
    MethodSpec {
        name: "random",
        aliases: &["rand", "pure-random"],
        summary: "uniform random search over the full joint genome",
        tunables: RANDOM_TUNABLES,
        resumable: true,
        builder: build_random,
    },
    MethodSpec {
        name: "sparseloop",
        aliases: &["sparseloop-mapper"],
        summary: "Sparseloop-Mapper-like: random mapping search under the manual sparse strategy",
        tunables: SPARSELOOP_TUNABLES,
        resumable: false,
        builder: build_sparseloop,
    },
    MethodSpec {
        name: "sage-like",
        aliases: &["sage"],
        summary: "SAGE-like: format/strategy evolution under a fixed heuristic mapping",
        tunables: SAGE_TUNABLES,
        resumable: false,
        builder: build_sage,
    },
    MethodSpec {
        name: "pso",
        aliases: &[],
        summary: "global-best particle swarm over the raw direct-encoded space",
        tunables: PSO_TUNABLES,
        resumable: true,
        builder: build_pso,
    },
    MethodSpec {
        name: "mcts",
        aliases: &[],
        summary: "Monte Carlo tree search, gene-by-gene, over the raw space",
        tunables: MCTS_TUNABLES,
        resumable: false,
        builder: build_mcts,
    },
    MethodSpec {
        name: "tbpsa",
        aliases: &[],
        summary: "test-based population-size-adaptation ES (Nevergrad) over the raw space",
        tunables: TBPSA_TUNABLES,
        resumable: false,
        builder: build_tbpsa,
    },
    MethodSpec {
        name: "ppo",
        aliases: &[],
        summary: "PPO: factored categorical policy with clipped-surrogate updates",
        tunables: PPO_TUNABLES,
        resumable: false,
        builder: build_ppo,
    },
    MethodSpec {
        name: "dqn",
        aliases: &[],
        summary: "DQN: MLP Q-function over sequential gene assignment",
        tunables: DQN_TUNABLES,
        resumable: false,
        builder: build_dqn,
    },
    MethodSpec {
        name: "es-std",
        aliases: &[],
        summary: "ablation: plain ES over the PFCE genome (alias arm of the Fig. 18 study)",
        tunables: ES_TUNABLES,
        resumable: true,
        builder: build_es_std,
    },
    MethodSpec {
        name: "portfolio",
        aliases: &["race"],
        summary: "meta-optimizer: UCB1-bandit (or successive-halving) race of member \
                  methods over one shared budget/cache/pool",
        tunables: PORTFOLIO_TUNABLES,
        resumable: true,
        builder: portfolio::build,
    },
];

/// One shared instance of the table (the `const` above exists so
/// [`ALL_METHODS`] can be derived at compile time; const reads of
/// `static`s are not allowed).
static METHODS_STATIC: [MethodSpec; METHOD_COUNT] = METHODS;

/// Every registered method, in registry order.
pub fn registry() -> &'static [MethodSpec] {
    &METHODS_STATIC
}

/// All canonical method names, derived from the registry at compile time
/// (the registry is the single source of truth — see the consistency
/// test in `super::tests`).
pub static ALL_METHODS: &[&str] = &{
    let mut names = [""; METHOD_COUNT];
    let mut i = 0;
    while i < METHOD_COUNT {
        names[i] = METHODS[i].name;
        i += 1;
    }
    names
};
