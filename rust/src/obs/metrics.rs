//! Lock-free metrics primitives and the fixed-series registry.
//!
//! [`Counter`], [`Gauge`] and the power-of-two-bucket [`Histogram`] are
//! plain atomics: recording is wait-free, allocation-free and `&self`
//! (share them behind an `Arc` or the process-global [`global`] handle).
//! A [`Metrics`] registry is a *fixed struct* of named series rather
//! than a dynamic name → series map: registration cannot fail, lookups
//! are field accesses, and the disabled path (no registry attached) is a
//! single `Option` branch — the eval hot path stays zero-alloc with
//! metrics compiled in (`rust/tests/alloc_steady_state.rs`).
//!
//! Two scopes exist: [`global`] (one process-wide registry — the
//! service records here and serves it at `GET /metrics` in Prometheus
//! text exposition via [`Metrics::render_prometheus`]) and per-run
//! instances (`Arc<Metrics>` attached to one
//! [`EvalContext`](crate::search::EvalContext) through
//! [`RunOpts::metrics`](crate::api::RunOpts), so a traced CLI run
//! snapshots its own stage timings without cross-talk from concurrent
//! searches). The only locked series is [`Labeled`] (per-tenant
//! counters): labels are dynamic strings, so it lives off the hot path
//! (the service bumps it once per finished job).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotone counter (wait-free increments, `Relaxed` ordering — series
/// are statistics, not synchronization).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge for non-negative integral values (queue depth,
/// cache sizes).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins `f64` gauge (stored as bits; starts at `+∞`, the
/// "no valid design yet" sentinel the search layer already uses).
pub struct GaugeF64(AtomicU64);

impl GaugeF64 {
    pub fn new() -> GaugeF64 {
        GaugeF64(AtomicU64::new(f64::INFINITY.to_bits()))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl Default for GaugeF64 {
    fn default() -> GaugeF64 {
        GaugeF64::new()
    }
}

/// Bucket count of the fixed power-of-two histogram: upper bounds
/// `1, 2, 4, …, 2^30`, plus a final overflow bucket (`+∞`). With
/// nanosecond samples that spans 1 ns to ~1 s before overflow — wide
/// enough for every stage/request latency this crate produces.
pub const HIST_BUCKETS: usize = 32;

/// Fixed-bucket histogram with power-of-two upper bounds. Recording is
/// two wait-free atomic adds and one increment; no locks, no allocation,
/// `&self`. Values are raw `u64` sample units (nanoseconds for latency
/// series; any integer unit works — `memory stats` feeds it scaled
/// embedding distances).
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// Bucket index for a sample: the smallest `i` with `v ≤ 2^i`, clamped
/// into the overflow bucket.
fn bucket_index(v: u64) -> usize {
    ((64 - v.saturating_sub(1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Upper bound of bucket `i` (`u64::MAX` marks the overflow bucket).
pub fn bucket_bound(i: usize) -> u64 {
    if i >= HIST_BUCKETS - 1 {
        u64::MAX
    } else {
        1u64 << i
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// A consistent-enough point-in-time copy (buckets are read
    /// independently; a concurrent recorder can skew count vs buckets by
    /// at most the in-flight samples).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Plain-data snapshot of a [`Histogram`] — `Copy`, comparable,
/// serializable; everything downstream (trace records, `memory stats`,
/// the Prometheus renderer) consumes this, never the live atomics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket holding the `q`-quantile sample
    /// (0 when empty). Resolution is the bucket width — a factor of two.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_bound(i);
            }
        }
        bucket_bound(HIST_BUCKETS - 1)
    }

    /// Upper bound of the highest non-empty bucket (0 when empty).
    pub fn max_bound(&self) -> u64 {
        self.buckets
            .iter()
            .rposition(|&n| n > 0)
            .map_or(0, bucket_bound)
    }

    /// JSON summary with only the non-empty buckets, bounds scaled by
    /// `scale` (e.g. `1e-9` to render nanosecond samples in seconds).
    /// Deterministic for deterministic inputs.
    pub fn to_json(&self, scale: f64) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| {
                let le = if i >= HIST_BUCKETS - 1 {
                    Json::str("+Inf")
                } else {
                    Json::num(bucket_bound(i) as f64 * scale)
                };
                Json::obj(vec![("le", le), ("n", Json::num(n as f64))])
            })
            .collect();
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64 * scale)),
            ("mean", Json::num(self.mean() * scale)),
            ("p50", Json::num(self.quantile(0.50) as f64 * scale)),
            ("p95", Json::num(self.quantile(0.95) as f64 * scale)),
            ("max", Json::num(self.max_bound() as f64 * scale)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Dynamically-labeled counter family (the one locked series — see the
/// module docs). Labels are sorted on export, so rendering is
/// deterministic for a given state.
#[derive(Default)]
pub struct Labeled(Mutex<BTreeMap<String, u64>>);

impl Labeled {
    pub fn new() -> Labeled {
        Labeled(Mutex::new(BTreeMap::new()))
    }

    pub fn add(&self, label: &str, n: u64) {
        let mut m = self.0.lock().unwrap();
        *m.entry(label.to_string()).or_insert(0) += n;
    }

    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.0.lock().unwrap().iter().map(|(k, &v)| (k.clone(), v)).collect()
    }
}

/// Names of the staged engine's four timed phases, in pipeline order
/// (indexes into [`Metrics::stage_ns`]).
pub const STAGE_NAMES: [&str; 4] = ["decode", "mapping", "format", "assemble"];
pub const STAGE_DECODE: usize = 0;
pub const STAGE_MAPPING: usize = 1;
pub const STAGE_FORMAT: usize = 2;
pub const STAGE_ASSEMBLE: usize = 3;

/// Route labels for the service's per-endpoint latency histograms
/// (indexes into [`Metrics::http_ns`]).
pub const HTTP_ROUTES: [&str; 10] = [
    "health",
    "metrics",
    "methods",
    "jobs_submit",
    "jobs_list",
    "jobs_get",
    "jobs_events",
    "jobs_cancel",
    "jobs_resume",
    "other",
];

/// Job lifecycle transitions counted by the service (indexes into
/// [`Metrics::job_events`]).
pub const JOB_EVENTS: [&str; 7] =
    ["submitted", "started", "done", "failed", "cancelled", "suspended", "resumed"];
pub const JOB_SUBMITTED: usize = 0;
pub const JOB_STARTED: usize = 1;
pub const JOB_DONE: usize = 2;
pub const JOB_FAILED: usize = 3;
pub const JOB_CANCELLED: usize = 4;
pub const JOB_SUSPENDED: usize = 5;
pub const JOB_RESUMED: usize = 6;

/// The registry: every series this crate emits, as a fixed struct.
/// All series are independent atomics — `Metrics` is `Sync` and shared
/// by plain reference or `Arc`.
pub struct Metrics {
    // --- staged engine / eval pipeline ----------------------------------
    /// Per-batch wall time of each engine phase, nanoseconds
    /// (one sample per [`StageEngine::eval_batch`](crate::search::StageEngine)
    /// call, indexed by `STAGE_*`).
    pub stage_ns: [Histogram; STAGE_NAMES.len()],
    /// Submissions per [`StageEngine::eval_batch`](crate::search::StageEngine)
    /// call (one sample per batch — the brood size the batched SoA path
    /// amortizes over).
    pub brood_size: Histogram,
    /// Wall time of the batched SoA cost-model sweep (phase 4's
    /// contiguous-slice evaluation), nanoseconds; one sample per batch
    /// that staged at least one genome in batched mode.
    pub soa_slice_ns: Histogram,
    /// Budget submissions evaluated.
    pub evals: Counter,
    /// Submissions that produced a valid design.
    pub valid_evals: Counter,
    /// Submissions served from the per-genome result cache.
    pub eval_cache_hits: Counter,
    /// Stage-level cache hits / computed stages (see [`crate::search::engine`]).
    pub stage_hits: Counter,
    pub stage_misses: Counter,
    /// Batches (≈ generations) evaluated.
    pub batches: Counter,
    /// Distinct genomes interned (hash-cons store size).
    pub interned: Gauge,
    /// Best valid EDP seen so far (`+∞` until one exists).
    pub best_edp: GaugeF64,
    // --- design memory ---------------------------------------------------
    /// Warm-start lookups answered by the LSH index vs the exact scan.
    pub memory_ann_probes: Counter,
    pub memory_exact_scans: Counter,
    /// Seeds handed to optimizers from memory.
    pub memory_seeds: Counter,
    /// Records in the attached store.
    pub memory_records: Gauge,
    // --- service ----------------------------------------------------------
    /// Per-endpoint request latency, nanoseconds (indexed like
    /// [`HTTP_ROUTES`]).
    pub http_ns: [Histogram; HTTP_ROUTES.len()],
    /// Jobs waiting in the priority queue.
    pub queue_depth: Gauge,
    /// Jobs currently in the running / suspended states.
    pub jobs_running: Gauge,
    pub jobs_suspended: Gauge,
    /// Lifecycle transition counts (indexed like [`JOB_EVENTS`]).
    pub job_events: [Counter; JOB_EVENTS.len()],
    /// Budget submissions evaluated per tenant (finished jobs).
    pub tenant_evals: Labeled,
    // --- fault tolerance --------------------------------------------------
    /// Armed fault-plan injections that fired ([`crate::util::faults`]).
    pub faults_injected: Counter,
    /// Transient-I/O retry attempts ([`crate::util::retry`]).
    pub io_retries: Counter,
    /// Worker panics contained by the job harness (job landed `failed`).
    pub panics_caught: Counter,
    /// Store opens that salvaged a torn tail into a `.corrupt` sidecar.
    pub memory_salvages: Counter,
    /// Connections refused with 503 at the connection cap.
    pub conns_shed: Counter,
    /// Currently open service connections.
    pub live_connections: Gauge,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            stage_ns: std::array::from_fn(|_| Histogram::new()),
            brood_size: Histogram::new(),
            soa_slice_ns: Histogram::new(),
            evals: Counter::new(),
            valid_evals: Counter::new(),
            eval_cache_hits: Counter::new(),
            stage_hits: Counter::new(),
            stage_misses: Counter::new(),
            batches: Counter::new(),
            interned: Gauge::new(),
            best_edp: GaugeF64::new(),
            memory_ann_probes: Counter::new(),
            memory_exact_scans: Counter::new(),
            memory_seeds: Counter::new(),
            memory_records: Gauge::new(),
            http_ns: std::array::from_fn(|_| Histogram::new()),
            queue_depth: Gauge::new(),
            jobs_running: Gauge::new(),
            jobs_suspended: Gauge::new(),
            job_events: std::array::from_fn(|_| Counter::new()),
            tenant_evals: Labeled::new(),
            faults_injected: Counter::new(),
            io_retries: Counter::new(),
            panics_caught: Counter::new(),
            memory_salvages: Counter::new(),
            conns_shed: Counter::new(),
            live_connections: Gauge::new(),
        }
    }

    /// Render every series as Prometheus text exposition
    /// (`text/plain; version=0.0.4`). Latency histograms are exported in
    /// seconds per Prometheus convention; all series carry the
    /// `sparsemap_` prefix.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(8192);
        counter_line(
            &mut out,
            "sparsemap_evals_total",
            "Budget submissions evaluated.",
            self.evals.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_valid_evals_total",
            "Submissions that produced a valid design.",
            self.valid_evals.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_eval_cache_hits_total",
            "Submissions served from the per-genome result cache.",
            self.eval_cache_hits.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_stage_hits_total",
            "Stage-level cache hits in the staged engine.",
            self.stage_hits.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_stage_misses_total",
            "Stages computed by the staged engine.",
            self.stage_misses.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_batches_total",
            "Batches (generations) evaluated.",
            self.batches.get(),
        );
        gauge_line(
            &mut out,
            "sparsemap_interned_genomes",
            "Distinct genomes in the hash-cons store.",
            self.interned.get() as f64,
        );
        gauge_line(&mut out, "sparsemap_best_edp", "Best valid EDP seen so far.", self.best_edp.get());
        hist_family(
            &mut out,
            "sparsemap_stage_seconds",
            "Staged-engine phase wall time per batch.",
            "stage",
            &STAGE_NAMES,
            &self.stage_ns,
        );
        hist_single(
            &mut out,
            "sparsemap_brood_size",
            "Submissions per staged-engine batch (brood size).",
            1.0,
            &self.brood_size,
        );
        hist_single(
            &mut out,
            "sparsemap_soa_slice_seconds",
            "Batched SoA cost-model sweep wall time per batch.",
            1e-9,
            &self.soa_slice_ns,
        );

        counter_line(
            &mut out,
            "sparsemap_memory_ann_probes_total",
            "Design-memory lookups answered by the LSH index.",
            self.memory_ann_probes.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_memory_exact_scans_total",
            "Design-memory lookups answered by the exact-scan fallback.",
            self.memory_exact_scans.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_memory_seeds_total",
            "Warm-start seeds handed to optimizers from memory.",
            self.memory_seeds.get(),
        );
        gauge_line(
            &mut out,
            "sparsemap_memory_records",
            "Records in the attached design-memory store.",
            self.memory_records.get() as f64,
        );

        hist_family(
            &mut out,
            "sparsemap_http_request_seconds",
            "Service request latency by route.",
            "route",
            &HTTP_ROUTES,
            &self.http_ns,
        );
        gauge_line(
            &mut out,
            "sparsemap_queue_depth",
            "Jobs waiting in the priority queue.",
            self.queue_depth.get() as f64,
        );
        gauge_line(
            &mut out,
            "sparsemap_jobs_running",
            "Jobs currently running.",
            self.jobs_running.get() as f64,
        );
        gauge_line(
            &mut out,
            "sparsemap_jobs_suspended",
            "Jobs currently suspended.",
            self.jobs_suspended.get() as f64,
        );
        out.push_str("# HELP sparsemap_jobs_total Job lifecycle transitions.\n");
        out.push_str("# TYPE sparsemap_jobs_total counter\n");
        for (i, ev) in JOB_EVENTS.iter().enumerate() {
            out.push_str(&format!(
                "sparsemap_jobs_total{{event=\"{ev}\"}} {}\n",
                self.job_events[i].get()
            ));
        }
        counter_line(
            &mut out,
            "sparsemap_faults_injected_total",
            "Armed fault-plan injections that fired.",
            self.faults_injected.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_io_retries_total",
            "Transient-I/O retry attempts.",
            self.io_retries.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_panics_caught_total",
            "Worker panics contained by the job harness.",
            self.panics_caught.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_memory_salvage_total",
            "Store opens that salvaged a torn tail into a .corrupt sidecar.",
            self.memory_salvages.get(),
        );
        counter_line(
            &mut out,
            "sparsemap_conns_shed_total",
            "Connections refused with 503 at the connection cap.",
            self.conns_shed.get(),
        );
        gauge_line(
            &mut out,
            "sparsemap_live_connections",
            "Currently open service connections.",
            self.live_connections.get() as f64,
        );
        let tenants = self.tenant_evals.snapshot();
        if !tenants.is_empty() {
            out.push_str(
                "# HELP sparsemap_tenant_evals_total Budget submissions evaluated per tenant.\n",
            );
            out.push_str("# TYPE sparsemap_tenant_evals_total counter\n");
            for (tenant, n) in tenants {
                out.push_str(&format!("sparsemap_tenant_evals_total{{tenant=\"{tenant}\"}} {n}\n"));
            }
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

/// The process-global registry. The service records and serves this one;
/// library callers get no global recording unless they attach it
/// themselves ([`RunOpts::metrics`](crate::api::RunOpts)).
pub fn global() -> Arc<Metrics> {
    static GLOBAL: OnceLock<Arc<Metrics>> = OnceLock::new();
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(Metrics::new())))
}

fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else {
        format!("{v}")
    }
}

fn counter_line(out: &mut String, name: &str, help: &str, v: u64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
}

fn gauge_line(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {}\n",
        fmt_value(v)
    ));
}

/// One unlabeled `# TYPE … histogram` family. `scale` converts raw
/// sample units for export (`1e-9` for nanosecond series rendered in
/// seconds, `1.0` for dimensionless counts like brood size).
fn hist_single(out: &mut String, name: &str, help: &str, scale: f64, h: &Histogram) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    let s = h.snapshot();
    let mut cum = 0u64;
    for (i, &n) in s.buckets.iter().enumerate() {
        cum += n;
        if n == 0 && i < HIST_BUCKETS - 1 {
            continue;
        }
        let le = if i >= HIST_BUCKETS - 1 {
            "+Inf".to_string()
        } else {
            fmt_value(bucket_bound(i) as f64 * scale)
        };
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!(
        "{name}_sum {}\n{name}_count {}\n",
        fmt_value(s.sum as f64 * scale),
        s.count
    ));
}

/// One `# TYPE … histogram` family with a label per member histogram.
/// Sample units are nanoseconds; bounds and sums are exported in seconds.
fn hist_family(
    out: &mut String,
    name: &str,
    help: &str,
    label: &str,
    labels: &[&str],
    hists: &[Histogram],
) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    for (lv, h) in labels.iter().zip(hists) {
        let s = h.snapshot();
        let mut cum = 0u64;
        for (i, &n) in s.buckets.iter().enumerate() {
            cum += n;
            // Skip interior empty prefixes? Prometheus wants the full
            // cumulative series, but 32 buckets × routes is noisy; emit
            // every bucket that changes the cumulative count plus +Inf.
            if n == 0 && i < HIST_BUCKETS - 1 {
                continue;
            }
            let le = if i >= HIST_BUCKETS - 1 {
                "+Inf".to_string()
            } else {
                fmt_value(bucket_bound(i) as f64 * 1e-9)
            };
            out.push_str(&format!("{name}_bucket{{{label}=\"{lv}\",le=\"{le}\"}} {cum}\n"));
        }
        out.push_str(&format!(
            "{name}_sum{{{label}=\"{lv}\"}} {}\n{name}_count{{{label}=\"{lv}\"}} {}\n",
            fmt_value(s.sum as f64 * 1e-9),
            s.count
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        assert_eq!(g.get(), 7);
        let f = GaugeF64::new();
        assert!(f.get().is_infinite(), "f64 gauge starts at the +inf sentinel");
        f.set(1.5);
        assert_eq!(f.get(), 1.5);
    }

    #[test]
    fn histogram_bucket_math() {
        // v ≤ 1 lands in bucket 0 (le=1); powers of two land exactly.
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 20), 20);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_bound(0), 1);
        assert_eq!(bucket_bound(3), 8);
        assert_eq!(bucket_bound(HIST_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn histogram_snapshot_quantiles() {
        let h = Histogram::new();
        for v in [1u64, 2, 2, 4, 100] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 109);
        assert_eq!(s.mean(), 21.8);
        assert_eq!(s.quantile(0.5), 2, "median sample is 2, bucket bound 2");
        assert_eq!(s.quantile(1.0), 128, "max sample 100 rounds up to 128");
        assert_eq!(s.max_bound(), 128);
        let empty = Histogram::new().snapshot();
        assert_eq!(empty.quantile(0.5), 0);
        assert_eq!(empty.max_bound(), 0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn histogram_snapshot_json_is_compact() {
        let h = Histogram::new();
        h.record(3);
        h.record(1000);
        let j = h.snapshot().to_json(1.0);
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(2));
        let buckets = j.get("buckets").and_then(Json::as_arr).unwrap();
        assert_eq!(buckets.len(), 2, "only non-empty buckets serialize");
        assert_eq!(buckets[0].get("le").and_then(Json::as_f64), Some(4.0));
        assert_eq!(buckets[0].get("n").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn labeled_counters_sorted_and_summed() {
        let l = Labeled::new();
        l.add("b", 2);
        l.add("a", 1);
        l.add("b", 3);
        assert_eq!(
            l.snapshot(),
            vec![("a".to_string(), 1), ("b".to_string(), 5)]
        );
    }

    #[test]
    fn prometheus_rendering_covers_all_families() {
        let m = Metrics::new();
        m.evals.add(10);
        m.valid_evals.add(8);
        m.stage_ns[STAGE_MAPPING].record(1_000);
        m.brood_size.record(48);
        m.soa_slice_ns.record(2_000);
        m.http_ns[1].record(50_000);
        m.job_events[JOB_SUBMITTED].inc();
        m.tenant_evals.add("ci", 10);
        m.best_edp.set(2.5);
        let text = m.render_prometheus();
        for series in [
            "sparsemap_evals_total 10",
            "sparsemap_valid_evals_total 8",
            "sparsemap_stage_seconds_bucket{stage=\"mapping\",le=\"0.000001024\"} 1",
            "sparsemap_stage_seconds_count{stage=\"mapping\"} 1",
            "sparsemap_brood_size_bucket{le=\"64\"} 1",
            "sparsemap_brood_size_sum 48",
            "sparsemap_brood_size_count 1",
            "sparsemap_soa_slice_seconds_bucket{le=\"0.000002048\"} 1",
            "sparsemap_soa_slice_seconds_count 1",
            "sparsemap_http_request_seconds_count{route=\"metrics\"} 1",
            "sparsemap_jobs_total{event=\"submitted\"} 1",
            "sparsemap_tenant_evals_total{tenant=\"ci\"} 10",
            "sparsemap_best_edp 2.5",
            "sparsemap_queue_depth 0",
            "sparsemap_faults_injected_total 0",
            "sparsemap_io_retries_total 0",
            "sparsemap_panics_caught_total 0",
            "sparsemap_memory_salvage_total 0",
            "sparsemap_conns_shed_total 0",
            "sparsemap_live_connections 0",
        ] {
            assert!(text.contains(series), "missing series line: {series}\n---\n{text}");
        }
        // The untouched f64 gauge renders as a Prometheus-legal +Inf.
        assert!(Metrics::new().render_prometheus().contains("sparsemap_best_edp +Inf"));
    }

    #[test]
    fn global_registry_is_one_instance() {
        let a = global();
        let b = global();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
