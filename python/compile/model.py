"""L2 JAX model: the batched fitness evaluator the Rust coordinator calls.

`evaluate_batch` is the function that gets AOT-lowered (see `aot.py`) into
`artifacts/cost_model.hlo.txt` and executed by `rust/src/runtime/` through
the PJRT CPU client on every generation of every search. Its arithmetic is
the FEATURE_SCHEMA_V1 contract shared with `rust/src/model/cost.rs`; its
hot-spot is the fused Pallas kernel in `kernels/cost_kernel.py`.

Python runs at build time only — the Rust binary executes the lowered HLO.
"""

import jax.numpy as jnp

from .kernels import cost_kernel, ref, spmm_gated

# Static batch size of the AOT executable. Rust pads partial batches.
AOT_BATCH = 256
# Static tile of the gated-SpMM demo artifact.
DEMO_M, DEMO_K, DEMO_N = 64, 64, 64

SCHEMA_VERSION = 1


def evaluate_batch(feats, plat):
    """Evaluate a population: f32[B,48] × f32[16] → f32[B,4].

    Output columns: (energy_pj, cycles, edp, valid).
    """
    return (cost_kernel.cost_eval_pallas(feats, plat),)


def evaluate_batch_ref(feats, plat):
    """Pure-jnp reference path (no Pallas) — pytest oracle."""
    return (ref.cost_eval_ref(feats, plat),)


def spmm_demo(p, q, pmask, qmask):
    """The instantiated-design demo computation (Fig. 14)."""
    z, eff = spmm_gated.spmm_gated_pallas(p, q, pmask, qmask)
    return z, jnp.reshape(eff, (1,))


def example_args():
    """Example (shape-defining) arguments for AOT lowering."""
    import jax

    feats = jax.ShapeDtypeStruct((AOT_BATCH, ref.NUM_FEATURES), jnp.float32)
    plat = jax.ShapeDtypeStruct((ref.NUM_PLATFORM_FEATURES,), jnp.float32)
    return feats, plat


def demo_args():
    import jax

    p = jax.ShapeDtypeStruct((DEMO_M, DEMO_K), jnp.float32)
    q = jax.ShapeDtypeStruct((DEMO_K, DEMO_N), jnp.float32)
    return p, q, p, q
