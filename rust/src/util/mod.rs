//! Self-contained utility substrates.
//!
//! The build environment is fully offline and the vendored crate set only
//! provides `xla` + `anyhow`, so the conveniences a project would normally
//! pull from crates.io are implemented here: a PCG64 RNG ([`rng`]), a JSON
//! codec ([`json`]), a CLI parser ([`cli`]), a thread pool ([`threadpool`]),
//! descriptive statistics ([`stats`]), power-iteration PCA ([`pca`]) and
//! ASCII/CSV table rendering ([`table`]).

pub mod cli;
pub mod hash;
pub mod json;
pub mod pca;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
