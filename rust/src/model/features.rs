//! FEATURE_SCHEMA_V1 — the Rust ⇄ JAX evaluator contract.
//!
//! [`extract`] turns a decoded design into a fixed-length numeric feature
//! vector. Everything *combinatorial* (loop-order reuse analysis, rank
//! enumeration, format storage models, S/G multipliers, fan-outs) is
//! resolved here; everything *arithmetic* (traffic scaling, energy sums,
//! bandwidth-bound latency, capacity checks, EDP) happens in the shared
//! cost formula — implemented twice, once in `model::cost` (f64, native)
//! and once in `python/compile/model.py` (f32, the AOT/PJRT hot path),
//! and cross-validated by tests.
//!
//! Any change here must bump [`SCHEMA_VERSION`] and be mirrored in
//! `python/compile/model.py`.

use crate::arch::{Boundary, Platform};
use crate::genome::{tensor_ranks, Design};
use crate::mapping::{loopnest, MapLevel};
use crate::sparse::{control_overhead, effect, stack_storage_model, RankFormat};
use crate::sparsity::effectual_frac;
use crate::workload::{Workload, NUM_TENSORS, TENSOR_P, TENSOR_Q, TENSOR_Z};

use super::validity::structural_problems;

/// Schema version — serialized into `artifacts/meta.json` by the Python
/// AOT pipeline and asserted by the Rust runtime at load time.
pub const SCHEMA_VERSION: u32 = 1;

/// Feature vector length per design.
pub const NUM_FEATURES: usize = 48;
/// Platform vector length.
pub const NUM_PLATFORM_FEATURES: usize = 16;

// --- feature indices (keep in sync with python/compile/model.py) --------
pub const F_P_WORDS_B0: usize = 0;
pub const F_Q_WORDS_B0: usize = 1;
pub const F_Z_WORDS_B0: usize = 2;
pub const F_P_GLB_READS_B1: usize = 3;
pub const F_Q_GLB_READS_B1: usize = 4;
pub const F_Z_GLB_WORDS_B1: usize = 5;
pub const F_P_NOC_WORDS_B1: usize = 6;
pub const F_Q_NOC_WORDS_B1: usize = 7;
pub const F_Z_NOC_WORDS_B1: usize = 8;
pub const F_P_WORDS_B2: usize = 9;
pub const F_Q_WORDS_B2: usize = 10;
pub const F_Z_WORDS_B2: usize = 11;
pub const F_CR_P_B0: usize = 12;
pub const F_CR_Q_B0: usize = 13;
pub const F_CR_Z_B0: usize = 14;
pub const F_CR_P_B1: usize = 15;
pub const F_CR_Q_B1: usize = 16;
pub const F_CR_Z_B1: usize = 17;
pub const F_META_P_B0: usize = 18;
pub const F_META_Q_B0: usize = 19;
pub const F_META_Z_B0: usize = 20;
pub const F_META_P_B1: usize = 21;
pub const F_META_Q_B1: usize = 22;
pub const F_META_Z_B1: usize = 23;
pub const F_SG_P_ENERGY_B1: usize = 24;
pub const F_SG_Q_ENERGY_B1: usize = 25;
pub const F_SG_CYCLES_B1: usize = 26;
pub const F_SG_P_ENERGY_B2: usize = 27;
pub const F_SG_Q_ENERGY_B2: usize = 28;
pub const F_SG_CYCLES_B2: usize = 29;
pub const F_MAC_ENERGY_FRAC: usize = 30;
pub const F_COMPUTE_CYCLE_FRAC: usize = 31;
pub const F_TOTAL_OPS: usize = 32;
pub const F_ACTIVE_MACS: usize = 33;
pub const F_GLB_TILE_WORDS: usize = 34;
pub const F_PE_TILE_WORDS: usize = 35;
pub const F_STRUCT_VALID: usize = 36;
pub const F_CTRL_B1: usize = 37;
pub const F_CTRL_B2: usize = 38;
pub const F_CTRL_C: usize = 39;
pub const F_ACTIVE_PES: usize = 40;
pub const F_DENSITY_P: usize = 41;
pub const F_DENSITY_Q: usize = 42;
pub const F_DENSITY_Z: usize = 43;
// 44..48 reserved (zero).

/// Extracted feature vector (f64 precision; the runtime casts to f32).
pub type Features = [f64; NUM_FEATURES];

/// Compression statistics of a tensor's tile at a boundary, given the
/// tensor's (precomputed) materialized ranks.
fn tile_compression(
    design: &Design,
    w: &Workload,
    t: usize,
    ranks: &[crate::genome::RankId],
    b: Boundary,
) -> (f64 /* cr */, f64 /* meta_frac */) {
    let inside = loopnest::levels_inside(b);
    let mut extents: Vec<u64> = Vec::new();
    let mut formats: Vec<RankFormat> = Vec::new();
    for (rank, fmt) in ranks.iter().zip(&design.strategy.formats[t]) {
        if inside.contains(&rank.level) {
            extents.push(rank.extent);
            formats.push(*fmt);
        }
    }
    let dense: f64 = extents.iter().map(|&e| e as f64).product();
    if extents.is_empty() || dense <= 1.0 {
        return (1.0, 0.0);
    }
    let (data, meta) = stack_storage_model(&extents, &formats, &w.tensors[t].density);
    ((data + meta) / dense, meta / dense)
}

/// Extract FEATURE_SCHEMA_V1 for one design.
pub fn extract(design: &Design, w: &Workload, plat: &Platform) -> Features {
    let mut f = [0.0f64; NUM_FEATURES];
    let m = &design.mapping;
    // S/G effects and the density features consume the mean densities;
    // the structured pattern shape enters through per-rank slot
    // occupancy (tile_compression) and tail-quantile tile provisioning
    // (capacity accounting below).
    let dp = w.density(TENSOR_P);
    let dq = w.density(TENSOR_Q);
    let dz = w.density(TENSOR_Z);

    // Hot path: flatten the nest once and derive the three boundary loop
    // lists and per-tensor rank lists from it (profiling showed repeated
    // flatten/rank walks dominated extraction — see EXPERIMENTS.md §Perf).
    let flat = loopnest::flatten(m);
    let loops_b0 = loopnest::temporal_loops_above_from(&flat, Boundary::DramGlb);
    let loops_b1 = loopnest::temporal_loops_above_from(&flat, Boundary::GlbPe);
    let loops_b2 = loopnest::temporal_loops_above_from(&flat, Boundary::PeMac);
    let ranks: [Vec<crate::genome::RankId>; 3] = [
        tensor_ranks(m, w, 0),
        tensor_ranks(m, w, 1),
        tensor_ranks(m, w, 2),
    ];

    // --- boundary 0: DRAM -> GLB (dense-equivalent words) ---------------
    for (t, idx) in [(TENSOR_P, F_P_WORDS_B0), (TENSOR_Q, F_Q_WORDS_B0)] {
        f[idx] = loopnest::tile_elems(m, w, t, Boundary::DramGlb)
            * loopnest::input_multiplicity_over(&loops_b0, w, t);
    }
    f[F_Z_WORDS_B0] = loopnest::output_traffic_elems_over(
        &loops_b0,
        w,
        loopnest::tile_elems(m, w, TENSOR_Z, Boundary::DramGlb),
    );

    // --- boundary 1: GLB -> PEs over the NoC -----------------------------
    let pe_fanout = m.fanout(MapLevel::L2S) as f64;
    for (t, ridx, nidx) in [
        (TENSOR_P, F_P_GLB_READS_B1, F_P_NOC_WORDS_B1),
        (TENSOR_Q, F_Q_GLB_READS_B1, F_Q_NOC_WORDS_B1),
    ] {
        let tile = loopnest::tile_elems(m, w, t, Boundary::GlbPe);
        let mult = loopnest::input_multiplicity_over(&loops_b1, w, t);
        let distinct = loopnest::spatial_distinct(m, w, t, MapLevel::L2S) as f64;
        // GLB is read once per distinct tile (multicast on the NoC)...
        f[ridx] = tile * mult * distinct;
        // ...but every PE receives its copy.
        f[nidx] = tile * mult * pe_fanout;
    }
    {
        // Output at boundary 1: per-PE psum traffic plus cross-PE
        // reduction when contraction dims are spatial at L2_S.
        let tile = loopnest::tile_elems(m, w, TENSOR_Z, Boundary::GlbPe);
        let base = loopnest::output_traffic_elems_over(&loops_b1, w, tile);
        let distinct_z =
            loopnest::spatial_distinct(m, w, TENSOR_Z, MapLevel::L2S) as f64;
        let spatial_k = pe_fanout / distinct_z; // reduction width across PEs
        f[F_Z_GLB_WORDS_B1] = base * distinct_z * spatial_k.max(1.0);
        f[F_Z_NOC_WORDS_B1] = base * pe_fanout.max(1.0);
    }

    // --- boundary 2: PE buffer -> MACs -----------------------------------
    let mac_fanout = m.fanout(MapLevel::L3S) as f64;
    for (t, idx) in [(TENSOR_P, F_P_WORDS_B2), (TENSOR_Q, F_Q_WORDS_B2)] {
        let mult = loopnest::input_multiplicity_over(&loops_b2, w, t);
        let distinct = loopnest::spatial_distinct(m, w, t, MapLevel::L3S) as f64;
        f[idx] = mult * distinct * pe_fanout;
    }
    {
        let base = loopnest::output_traffic_elems_over(&loops_b2, w, 1.0);
        let distinct_z =
            loopnest::spatial_distinct(m, w, TENSOR_Z, MapLevel::L3S) as f64;
        let spatial_k = mac_fanout / distinct_z;
        f[F_Z_WORDS_B2] = base * distinct_z * spatial_k.max(1.0) * pe_fanout;
    }

    // --- compression ratios and metadata fractions ----------------------
    // Computed once per (tensor, boundary) and reused by the capacity
    // accounting below (stack_storage is the second-hottest call).
    let mut crs = [[0.0f64; 2]; NUM_TENSORS];
    let mut metas = [[0.0f64; 2]; NUM_TENSORS];
    for t in 0..NUM_TENSORS {
        let (cr_b0, meta_b0) = tile_compression(design, w, t, &ranks[t], Boundary::DramGlb);
        let (cr_b1, meta_b1) = tile_compression(design, w, t, &ranks[t], Boundary::GlbPe);
        crs[t] = [cr_b0, cr_b1];
        metas[t] = [meta_b0, meta_b1];
    }
    for (t, cr0, cr1, me0, me1) in [
        (TENSOR_P, F_CR_P_B0, F_CR_P_B1, F_META_P_B0, F_META_P_B1),
        (TENSOR_Q, F_CR_Q_B0, F_CR_Q_B1, F_META_Q_B0, F_META_Q_B1),
        (TENSOR_Z, F_CR_Z_B0, F_CR_Z_B1, F_META_Z_B0, F_META_Z_B1),
    ] {
        f[cr0] = crs[t][0];
        f[cr1] = crs[t][1];
        f[me0] = metas[t][0];
        f[me1] = metas[t][1];
    }

    // --- S/G multipliers --------------------------------------------------
    let sg_l2 = effect(design.strategy.sg[0], dp, dq);
    let sg_l3 = effect(design.strategy.sg[1], dp, dq);
    let sg_c = effect(design.strategy.sg[2], dp, dq);
    f[F_SG_P_ENERGY_B1] = sg_l2.p_energy;
    f[F_SG_Q_ENERGY_B1] = sg_l2.q_energy;
    f[F_SG_CYCLES_B1] = sg_l2.cycles;
    f[F_SG_P_ENERGY_B2] = sg_l3.p_energy;
    f[F_SG_Q_ENERGY_B2] = sg_l3.q_energy;
    f[F_SG_CYCLES_B2] = sg_l3.cycles;
    f[F_MAC_ENERGY_FRAC] = sg_c.p_energy.min(sg_c.q_energy);
    // Skips anywhere shorten the effectual compute stream; floor at the
    // intrinsic effectual-MAC fraction of the operand patterns (for
    // uniform models exactly the legacy dp*dq).
    f[F_COMPUTE_CYCLE_FRAC] = (sg_l2.cycles * sg_l3.cycles * sg_c.cycles)
        .max(effectual_frac(
            &w.tensors[TENSOR_P].density,
            &w.tensors[TENSOR_Q].density,
        ))
        .min(1.0);
    f[F_CTRL_B1] = control_overhead(design.strategy.sg[0]);
    f[F_CTRL_B2] = control_overhead(design.strategy.sg[1]);
    f[F_CTRL_C] = control_overhead(design.strategy.sg[2]);

    // --- compute / occupancy / validity ----------------------------------
    f[F_TOTAL_OPS] = w.total_ops();
    f[F_ACTIVE_PES] = pe_fanout.max(1.0);
    f[F_ACTIVE_MACS] = (pe_fanout * mac_fanout).max(1.0);
    // Buffers are provisioned for the tail-quantile tile occupancy of
    // each tensor's sparsity pattern ([`DensityModel::sizing_ratio`]):
    // a mean-sized buffer under-provisions banded/skewed tensors whose
    // hot tiles are locally dense. Uniform models have ratio exactly 1.
    let mut glb_words = 0.0;
    let mut pe_words = 0.0;
    for t in 0..NUM_TENSORS {
        let dm = &w.tensors[t].density;
        let tile_b0 = loopnest::tile_elems(m, w, t, Boundary::DramGlb);
        let tile_b1 = loopnest::tile_elems(m, w, t, Boundary::GlbPe);
        glb_words += tile_b0 * crs[t][0] * dm.sizing_ratio(tile_b0);
        pe_words += tile_b1 * crs[t][1] * dm.sizing_ratio(tile_b1);
    }
    f[F_GLB_TILE_WORDS] = glb_words;
    f[F_PE_TILE_WORDS] = pe_words;
    f[F_STRUCT_VALID] =
        if structural_problems(design, w, plat).is_empty() { 1.0 } else { 0.0 };
    f[F_DENSITY_P] = dp;
    f[F_DENSITY_Q] = dq;
    f[F_DENSITY_Z] = dz;
    f
}

/// Cast features to the f32 row consumed by the PJRT executable.
pub fn to_f32_row(f: &Features) -> Vec<f32> {
    f.iter().map(|&x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{decode, GenomeSpec};
    use crate::util::rng::Pcg64;

    fn setup() -> (Workload, Platform, GenomeSpec) {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let p = Platform::edge();
        let s = GenomeSpec::for_workload(&w);
        (w, p, s)
    }

    /// All-ones mapping genes with *cleared* strategy segments (formats
    /// uncompressed, no S/G) — the dense reference genome.
    fn dense_genome(spec: &GenomeSpec) -> Vec<u32> {
        let mut g = vec![1u32; spec.len()];
        for i in spec.format_start..spec.len() {
            g[i] = 0;
        }
        g
    }

    #[test]
    fn features_finite_for_random_designs() {
        let (w, p, spec) = setup();
        let mut rng = Pcg64::seeded(9);
        for _ in 0..200 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            let f = extract(&d, &w, &p);
            for (i, v) in f.iter().enumerate() {
                assert!(v.is_finite() && *v >= 0.0, "feature {i} = {v}");
            }
        }
    }

    #[test]
    fn dense_uncompressed_baseline() {
        let (w, p, spec) = setup();
        let g = dense_genome(&spec); // all tiling at L1_T, no formats
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &p);
        // No compression: all ratios 1, no metadata.
        for idx in [F_CR_P_B0, F_CR_Q_B0, F_CR_Z_B0] {
            assert_eq!(f[idx], 1.0);
        }
        for idx in [F_META_P_B0, F_META_Q_B0] {
            assert_eq!(f[idx], 0.0);
        }
        // No S/G: all multipliers 1.
        assert_eq!(f[F_SG_CYCLES_B1], 1.0);
        assert_eq!(f[F_MAC_ENERGY_FRAC], 1.0);
        assert_eq!(f[F_TOTAL_OPS], (16 * 32 * 16) as f64);
        assert_eq!(f[F_STRUCT_VALID], 1.0);
        assert_eq!(f[F_ACTIVE_MACS], 1.0); // no spatial mapping at all
    }

    #[test]
    fn compression_reduces_traffic_ratio_when_sparse() {
        let (w, p, spec) = setup();
        let mut g = dense_genome(&spec);
        // Tile M,K at L2_T so P has materialized ranks inside the GLB.
        for i in spec.factor_start..spec.format_start {
            g[i] = 2;
        }
        // P formats: bitmask everywhere.
        for s in 0..5 {
            g[spec.format_start + s] = 1;
        }
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &p);
        // P density 0.5, bitmask: cr < 1 (0.5 data + 1/16 metadata bits).
        assert!(f[F_CR_P_B0] < 1.0, "cr={}", f[F_CR_P_B0]);
        assert!(f[F_META_P_B0] > 0.0);
        // Q left uncompressed.
        assert_eq!(f[F_CR_Q_B0], 1.0);
    }

    #[test]
    fn spatial_mapping_populates_fanout() {
        let (w, p, spec) = setup();
        let mut g = dense_genome(&spec);
        // Put all of M (16 = 2^4) at L2_S: fanout 16.
        for i in 0..4 {
            g[spec.factor_start + i] = 3;
        }
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &p);
        assert_eq!(f[F_ACTIVE_PES], 16.0);
        assert_eq!(f[F_STRUCT_VALID], 1.0); // 16 <= 256 PEs
        // Q (K,N) has no M dim: broadcast to all 16 PEs, one GLB read.
        assert!(f[F_Q_NOC_WORDS_B1] >= 16.0 * f[F_Q_GLB_READS_B1] / 16.0);
        assert!(f[F_Q_GLB_READS_B1] * 16.0 == f[F_Q_NOC_WORDS_B1]);
    }

    #[test]
    fn structured_pattern_inflates_capacity_provisioning() {
        use crate::sparsity::DensityModel;
        use crate::workload::WorkloadKind;
        // Banded vs uniform P at the same mean density (4/32 = 0.125):
        // the banded tensor must provision buffers for locally-dense
        // band tiles, so its tile-words features grow.
        let mk = |model: DensityModel| {
            Workload::custom_models(
                "t",
                WorkloadKind::SpMM,
                vec![("M".into(), 16), ("K".into(), 32), ("N".into(), 16)],
                vec![
                    ("P".into(), vec![0, 1], Some(model)),
                    ("Q".into(), vec![1, 2], Some(DensityModel::uniform(0.25))),
                    ("Z".into(), vec![0, 2], None),
                ],
                vec![1],
            )
            .unwrap()
        };
        let w_uni = mk(DensityModel::uniform(0.125));
        let w_band = mk(DensityModel::banded(4, 32));
        let p = Platform::edge();
        let spec = GenomeSpec::for_workload(&w_uni);
        let mut g = dense_genome(&spec);
        for i in spec.factor_start..spec.format_start {
            g[i] = 2; // tile everything at L2_T so GLB tiles materialize
        }
        let f_uni = extract(&decode(&spec, &w_uni, &g), &w_uni, &p);
        let f_band = extract(&decode(&spec, &w_band, &g), &w_band, &p);
        // Small PE tiles sit inside a band row: P95 occupancy is the
        // dense band segment, far above the 12.5% mean.
        assert!(
            f_band[F_PE_TILE_WORDS] > f_uni[F_PE_TILE_WORDS],
            "banded {} vs uniform {}",
            f_band[F_PE_TILE_WORDS],
            f_uni[F_PE_TILE_WORDS]
        );
        // GLB tiles span whole rows, where banded occupancy concentrates
        // to the mean — provisioning matches the uniform case there.
        assert_eq!(f_band[F_GLB_TILE_WORDS], f_uni[F_GLB_TILE_WORDS]);
        // Mean-density features are identical — only provisioning and
        // compression statistics change.
        assert_eq!(f_band[F_DENSITY_P], f_uni[F_DENSITY_P]);
        for v in f_band.iter() {
            assert!(v.is_finite() && *v >= 0.0);
        }
    }

    #[test]
    fn schema_row_is_f32_sized() {
        let (w, p, spec) = setup();
        let d = decode(&spec, &w, &dense_genome(&spec));
        let row = to_f32_row(&extract(&d, &w, &p));
        assert_eq!(row.len(), NUM_FEATURES);
    }
}
