//! A small fixed-size thread pool with a parallel-map primitive.
//!
//! No `tokio`/`rayon` in the offline vendor set; search drivers only need
//! fork–join over independent work items (e.g. one search arm per seed, or
//! chunked population evaluation), which this covers with `std::thread` +
//! channels.

use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// A queued unit of work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `n` threads (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("sparsemap-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender) }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    /// Submit a fire-and-forget job.
    ///
    /// If the pool can no longer accept work (every worker has died, or
    /// the pool is shutting down), the job is handed back in `Err` so the
    /// caller can run it inline or drop it — submission never panics or
    /// aborts a search mid-flight.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), Job> {
        let job: Job = Box::new(f);
        match &self.sender {
            Some(tx) => tx.send(job).map_err(|e| e.0),
            None => Err(job),
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ThreadPool({} workers)", self.workers.len())
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Apply `f` to every item of `items` in parallel on `pool`, preserving
/// order. `f` must be cloneable across threads (wrap captured state in
/// `Arc`). Results are collected via a channel. If the pool has stopped
/// accepting work (all workers dead), rejected jobs degrade to running
/// inline on the calling thread, so the map still completes. A panic
/// *inside a running job* loses that result and surfaces as a panic here.
pub fn parallel_map<T, R, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        let submitted = pool.execute(move || {
            let r = f(item);
            let _ = tx.send((i, r));
        });
        if let Err(job) = submitted {
            job(); // pool closed: degrade gracefully to inline execution
        }
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut got = 0;
    while let Ok((i, r)) = rx.recv() {
        out[i] = Some(r);
        got += 1;
    }
    assert_eq!(got, n, "worker panicked; {}/{} results received", got, n);
    out.into_iter().map(|r| r.unwrap()).collect()
}

/// Sequential fallback used when determinism across thread counts is
/// required (e.g. golden-file tests of search trajectories).
pub fn serial_map<T, R, F: Fn(T) -> R>(items: Vec<T>, f: F) -> Vec<R> {
    items.into_iter().map(f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let sent = pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
            assert!(sent.is_ok());
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = parallel_map(&pool, (0..64).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = parallel_map(&pool, Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn matches_serial() {
        let pool = ThreadPool::new(5);
        let xs: Vec<u64> = (1..200).collect();
        let p = parallel_map(&pool, xs.clone(), |x| x.pow(2) % 97);
        let s = serial_map(xs, |x| x.pow(2) % 97);
        assert_eq!(p, s);
    }

    #[test]
    fn dead_pool_hands_jobs_back_and_map_degrades_inline() {
        // Kill the only worker, then verify (a) execute returns the job
        // instead of panicking and (b) parallel_map completes inline.
        let pool = ThreadPool::new(1);
        let _ = pool.execute(|| panic!("intentional: kill the worker"));
        // Wait until the pool observably rejects work (the worker's death
        // drops the receiver, closing the channel).
        let handed_back = (0..5_000).any(|_| match pool.execute(|| {}) {
            Ok(()) => {
                thread::sleep(std::time::Duration::from_millis(1));
                false
            }
            Err(job) => {
                job();
                true
            }
        });
        assert!(handed_back, "pool never reported closure");
        let out = parallel_map(&pool, (0..10).collect::<Vec<i64>>(), |x| x + 1);
        assert_eq!(out, (1..=10).collect::<Vec<i64>>());
    }
}
