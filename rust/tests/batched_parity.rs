//! Batched-SoA parity: the batched brood pipeline (the staged engine's
//! default since the SoA rework) must be **bit-identical** to the
//! per-genome staged walk (`EvalContext::with_batched(false)`) and to
//! the from-scratch path (`EvalContext::with_staging(false)`) — for
//! every registry method, at 1 and 4 threads, and on adversarial
//! populations (segment-sharing siblings, duplicates, cache replays).
//!
//! This is the acceptance gate for the SoA rework: grouping offspring by
//! shared mapping-segment id and sweeping the cost model over contiguous
//! slices must be a pure layout change, never a semantic one.

use sparsemap::arch::Platform;
use sparsemap::optimizer::{run_method, ALL_METHODS};
use sparsemap::search::{Backend, EvalContext, Outcome};
use sparsemap::util::rng::Pcg64;
use sparsemap::util::threadpool::ThreadPool;
use sparsemap::workload::Workload;
use std::sync::Arc;

fn workload() -> Workload {
    Workload::spmm("mm", 48, 96, 48, 0.25, 0.2)
}

#[derive(Clone, Copy)]
enum Mode {
    /// The default: staged engine, batched SoA assembly.
    Batched,
    /// Staged engine, per-genome assembly walk (the parity reference).
    PerGenome,
    /// No staging at all: monolithic decode → extract → cost per miss.
    Scratch,
}

fn ctx(budget: usize, threads: usize, mode: Mode) -> EvalContext {
    let c = EvalContext::new(Backend::native(workload(), Platform::mobile()), budget);
    let c = match mode {
        Mode::Batched => c,
        Mode::PerGenome => c.with_batched(false),
        Mode::Scratch => c.with_staging(false),
    };
    if threads > 1 {
        c.with_pool(Some(Arc::new(ThreadPool::new(threads))))
    } else {
        c
    }
}

fn assert_outcomes_identical(a: &Outcome, b: &Outcome, label: &str) {
    assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits(), "{label}: best_edp");
    assert_eq!(a.best_genome, b.best_genome, "{label}: best_genome");
    assert_eq!(a.curve, b.curve, "{label}: best-EDP curve");
    assert_eq!(a.population_mean_curve, b.population_mean_curve, "{label}: mean curve");
    assert_eq!(a.evals, b.evals, "{label}: evals");
    assert_eq!(a.valid_evals, b.valid_evals, "{label}: valid_evals");
    assert_eq!(a.cache_hits, b.cache_hits, "{label}: cache_hits");
    assert_eq!(a.interned, b.interned, "{label}: interned");
}

/// Every registry method, both staged modes, 1 and 4 threads, against
/// one from-scratch reference trajectory per method.
#[test]
fn every_registry_method_bit_identical_across_modes_and_threads() {
    for method in ALL_METHODS {
        let budget = 240;
        let reference = run_method(method, ctx(budget, 1, Mode::Scratch), 42).unwrap();
        for threads in [1usize, 4] {
            for (mode, tag) in [(Mode::Batched, "batched"), (Mode::PerGenome, "per-genome")] {
                let run = run_method(method, ctx(budget, threads, mode), 42).unwrap();
                assert_outcomes_identical(
                    &reference,
                    &run,
                    &format!("{method} {tag} @ {threads} threads"),
                );
            }
        }
    }
}

/// Hand-rolled property test (no proptest crate in the vendored set):
/// randomized populations with segment-sharing siblings, strategy-only
/// siblings, duplicates and a replay batch, compared across all three
/// modes plus a pooled batched context. Eight seeded trials; any failure
/// prints its trial seed for replay.
#[test]
fn randomized_populations_bitwise_parity_across_modes() {
    for trial in 0..8u64 {
        let seed = 100 + trial;
        let mut rng = Pcg64::seeded(seed);
        let mut batched = ctx(50_000, 1, Mode::Batched);
        let mut pergenome = ctx(50_000, 1, Mode::PerGenome);
        let mut scratch = ctx(50_000, 1, Mode::Scratch);
        let mut pooled = ctx(50_000, 4, Mode::Batched);
        let spec = batched.spec.clone();

        let n_parents = 2 + (trial as usize % 5);
        let parents: Vec<Vec<u32>> = (0..n_parents).map(|_| spec.random(&mut rng)).collect();
        let mut pop: Vec<Vec<u32>> = Vec::new();
        for p in &parents {
            pop.push(p.clone());
            for _ in 0..rng.range_u32(0, 7) {
                let mut g = p.clone();
                // Half the siblings share the whole mapping segment
                // (strategy-only mutation: the batched path groups them
                // onto one decoded loop nest); the rest also re-sample
                // format genes, exercising group boundaries.
                let lo = if rng.range_u32(0, 2) == 0 { spec.sg_start } else { spec.format_start };
                for i in lo..spec.len() {
                    g[i] = rng.range_u32(spec.ranges[i].lo, spec.ranges[i].hi);
                }
                pop.push(g);
            }
        }
        // Duplicates inside one batch exercise pending-stage sharing and
        // the result cache.
        let dup = pop[trial as usize % pop.len()].clone();
        pop.push(dup);

        let a = batched.eval_batch(&pop);
        let b = pergenome.eval_batch(&pop);
        let c = scratch.eval_batch(&pop);
        let d = pooled.eval_batch(&pop);
        assert_eq!(a, b, "trial {seed}: batched vs per-genome");
        assert_eq!(a, c, "trial {seed}: batched vs scratch");
        assert_eq!(a, d, "trial {seed}: serial vs pooled batched");
        assert_eq!(batched.telemetry.curve, scratch.telemetry.curve, "trial {seed}: curve");
        assert_eq!(batched.stage_hits(), pergenome.stage_hits(), "trial {seed}: stage hits");

        // Replay the same population: everything comes from the result
        // cache, identically in all modes.
        let a2 = batched.eval_batch(&pop);
        let c2 = scratch.eval_batch(&pop);
        assert_eq!(a2, c2, "trial {seed}: warm replay");
        assert_eq!(a, a2, "trial {seed}: warm replay matches cold results");
    }
}
