//! The search service: a dependency-free HTTP front end over
//! [`crate::api`].
//!
//! `sparsemap serve` turns the library into a long-running daemon that
//! accepts search jobs over plain HTTP/1.1 (std [`std::net::TcpListener`]
//! only — no framework, no TLS, loopback-oriented):
//!
//! | endpoint                  | meaning                                    |
//! |---------------------------|--------------------------------------------|
//! | `GET  /health`            | liveness + load: queue depth, running/suspended job counts, memory-store size |
//! | `GET  /metrics`           | Prometheus text exposition of the [`crate::obs`] registry |
//! | `GET  /methods`           | [`crate::api::methods_json`] — the registry|
//! | `POST /jobs`              | submit a [`crate::api::SearchRequest`] JSON (plus optional `tenant`, `priority`) |
//! | `GET  /jobs`              | list all jobs (summaries)                  |
//! | `GET  /jobs/<id>`         | one job, with the full report when done    |
//! | `GET  /jobs/<id>/events`  | NDJSON progress stream until terminal; every line carries a monotone `seq` for reconnect dedup |
//! | `POST /jobs/<id>/cancel`  | cancel: resumable methods suspend into a checkpoint, the rest hard-stop |
//! | `POST /jobs/<id>/resume`  | re-queue a suspended job from its checkpoint |
//!
//! Jobs wait in a **priority queue** (higher `priority` first, FIFO
//! within a priority) and run on a fixed pool of worker threads; each
//! tenant's total submitted eval budget is capped by a **quota**
//! (`--quota`, 429 past it). Cancelling a job whose method advertises
//! [`crate::optimizer::MethodSpec::resumable`] suspends it through the
//! optimizer checkpoint machinery and persists the checkpoint to
//! `--checkpoint-dir`, so suspended jobs survive a server restart: on
//! startup the directory is rescanned and every recorded job comes back
//! in the `suspended` state, ready for `POST /jobs/<id>/resume`. A
//! resumed run finishes bit-identical to one that was never interrupted
//! (the same guarantee [`crate::api::SearchSession::run_opts`] makes).
//!
//! With `--auth-token <secret>` every endpoint except `GET /health` and
//! `GET /metrics` requires a matching `Authorization: Bearer <secret>`
//! header (401 otherwise) — the actual trust boundary in front of the
//! honor-system `tenant` field. Health probes and Prometheus scrapers
//! stay secret-free; neither endpoint exposes request contents.
//!
//! Every job records into the process-global [`crate::obs`] metrics
//! registry (evals, per-stage latency, per-tenant spend, job lifecycle
//! counters, per-endpoint request latency), which is exactly what
//! `GET /metrics` serves.
//!
//! With `--memory-store <path>` the service opens one shared
//! [`crate::memory::MemoryStore`]: every *completed* job deposits its
//! elite design, and any job whose request carries a `warm_start` block
//! seeds its initial population from the store's nearest prior
//! scenarios (no `store` path needed in the request — the service's
//! store takes precedence). The store is compacted to `--memory-cap`
//! records on every startup.
//!
//! **Fault tolerance.** The daemon is built to survive misbehaving
//! clients, its own bugs, and `kill -9`: connections above `--max-conns`
//! are shed with `503` + `Retry-After` instead of spawning unbounded
//! threads; every socket carries read/write timeouts so a stalled peer
//! cannot pin a thread; a panic inside a search lands that job in
//! `failed` (error message in the job detail) while the service keeps
//! serving; checkpoint and memory writes are atomic, fsynced and retried
//! with jittered backoff; a torn memory-store tail left by a crash is
//! salvaged on the next open (damaged bytes quarantined to a `.corrupt`
//! sidecar). SIGTERM/SIGINT trigger a graceful drain — stop accepting
//! (`/health` reports `"state":"draining"`), suspend running resumable
//! jobs into their checkpoints, flush, exit — so an orchestrator's
//! ordinary stop loses nothing. Chaos tests drive all of this
//! deterministically through [`crate::util::faults`].

mod http;
mod job;
mod queue;
mod server;

pub use job::{Job, JobState};
pub use queue::{JobQueue, QueueEntry, QuotaBook};
pub use server::{serve, start, ServerConfig, ServiceHandle};
