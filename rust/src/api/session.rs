//! [`SearchSession`] — a validated request, ready to run.

use super::report::SearchReport;
use super::request::SearchRequest;
use crate::arch::Platform;
use crate::optimizer;
use crate::search::{Backend, EvalContext, SearchObserver};
use crate::util::threadpool::ThreadPool;
use crate::workload::Workload;
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A validated search arm. Created by [`SearchRequest::build`]; run with
/// [`SearchSession::run`] (or [`SearchSession::run_observed`] to stream
/// progress and stop early). The session owns a cancel token so a run
/// can be aborted from another thread ([`SearchSession::cancel_token`]).
pub struct SearchSession {
    request: SearchRequest,
    workload: Workload,
    platform: Platform,
    stop: Arc<AtomicBool>,
}

impl SearchSession {
    pub(crate) fn new(request: SearchRequest) -> Result<SearchSession> {
        ensure!(request.budget >= 1, "search budget must be at least 1 sample");
        // The registry is the one method-validation path (names, aliases,
        // nearest-match suggestions, and the method_opts schema).
        // Building (and discarding) the optimizer also runs the method's
        // own cross-field checks — e.g. the portfolio rejecting
        // member_opts entries that match none of its members — so every
        // bad request fails here, not mid-run.
        optimizer::resolve(&request.method)?.build(&request.method_opts)?;
        let (workload, platform) = request.resolve()?;
        Ok(SearchSession {
            request,
            workload,
            platform,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn request(&self) -> &SearchRequest {
        &self.request
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// Shared cancel token: store `true` (from any thread) and the run
    /// winds down through the algorithms' normal budget-exhausted path,
    /// still returning a well-formed report with `stopped_early` set.
    pub fn cancel_token(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    #[cfg(feature = "xla")]
    fn backend(&self) -> Backend {
        if self.request.use_pjrt {
            match crate::runtime::Runtime::from_default_dir().and_then(|rt| {
                Backend::pjrt(&rt, self.workload.clone(), self.platform.clone())
            }) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("warning: PJRT backend unavailable ({e}); using native");
                    Backend::native(self.workload.clone(), self.platform.clone())
                }
            }
        } else {
            Backend::native(self.workload.clone(), self.platform.clone())
        }
    }

    #[cfg(not(feature = "xla"))]
    fn backend(&self) -> Backend {
        if self.request.use_pjrt {
            eprintln!("warning: built without the `xla` feature; using the native backend");
        }
        Backend::native(self.workload.clone(), self.platform.clone())
    }

    fn make_context(&self, observer: Option<Box<dyn SearchObserver>>) -> EvalContext {
        let pool = if self.request.threads > 1 {
            Some(Arc::new(ThreadPool::new(self.request.threads)))
        } else {
            None
        };
        EvalContext::new(self.backend(), self.request.budget)
            .with_cache(self.request.cache)
            .with_pool(pool)
            .with_stop_flag(Some(Arc::clone(&self.stop)))
            .with_observer(observer)
    }

    /// Lower the session into a raw [`EvalContext`] — the escape hatch
    /// for drivers that run their own loop over the evaluator (gene
    /// calibration, the Fig. 10 encoding study) rather than a method
    /// from [`crate::optimizer::ALL_METHODS`].
    pub fn into_context(self) -> EvalContext {
        self.make_context(None)
    }

    /// Run the arm to completion (budget exhausted or cancelled).
    pub fn run(self) -> Result<SearchReport> {
        self.run_with(None)
    }

    /// Run with a streaming observer: called after every evaluated batch
    /// with generation, evals, cache hits and best-so-far EDP; returning
    /// [`crate::search::SearchControl::Stop`] ends the run early.
    pub fn run_observed(self, observer: Box<dyn SearchObserver>) -> Result<SearchReport> {
        self.run_with(Some(observer))
    }

    fn run_with(self, observer: Option<Box<dyn SearchObserver>>) -> Result<SearchReport> {
        let ctx = self.make_context(observer);
        let t0 = std::time::Instant::now();
        let outcome = optimizer::run_method_with(
            &self.request.method,
            &self.request.method_opts,
            ctx,
            self.request.seed,
        )?;
        Ok(SearchReport {
            request: self.request,
            outcome,
            wall_s: t0.elapsed().as_secs_f64(),
            stopped_early: self.stop.load(Ordering::SeqCst),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{Progress, SearchControl};

    fn tiny() -> SearchRequest {
        SearchRequest::new().workload_named("mm1").platform_named("mobile").budget(120).seed(3)
    }

    #[test]
    fn build_validates_method_and_budget() {
        assert!(tiny().method("gradient-descent").build().is_err());
        assert!(tiny().budget(0).build().is_err());
        assert!(tiny().build().is_ok());
        // Typos get a nearest-match suggestion from the registry.
        let err = tiny().method("spasemap").build().unwrap_err().to_string();
        assert!(err.contains("did you mean 'sparsemap'"), "{err}");
    }

    #[test]
    fn build_validates_method_opts_and_aliases_run() {
        use crate::util::json::Json;
        // Unknown tunable key fails at build, with a suggestion.
        let bad = tiny().method_opts(Json::parse(r#"{"populaton": 40}"#).unwrap());
        let err = bad.build().unwrap_err().to_string();
        assert!(err.contains("did you mean 'population'"), "{err}");
        // A valid alias + opts combination runs under the canonical name.
        let report = tiny()
            .method("rand")
            .method_opts(Json::parse(r#"{"batch": 32}"#).unwrap())
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.outcome.method, "random");
        assert_eq!(report.outcome.evals, 120);
    }

    #[test]
    fn run_produces_report() {
        let report = tiny().build().unwrap().run().unwrap();
        assert_eq!(report.outcome.workload, "mm1");
        assert_eq!(report.outcome.platform, "mobile");
        assert!(report.outcome.evals <= 120);
        assert!(!report.stopped_early);
        assert!(report.wall_s >= 0.0);
    }

    #[test]
    fn observer_can_stop_early() {
        let report = tiny()
            .budget(5_000)
            .build()
            .unwrap()
            .run_observed(Box::new(|p: &Progress| {
                if p.evals >= 100 {
                    SearchControl::Stop
                } else {
                    SearchControl::Continue
                }
            }))
            .unwrap();
        assert!(report.stopped_early);
        assert!(report.outcome.evals < 5_000, "stopped well before the budget");
    }

    #[test]
    fn pre_cancelled_session_returns_empty_report() {
        let session = tiny().method("random").build().unwrap();
        session.cancel_token().store(true, Ordering::SeqCst);
        let report = session.run().unwrap();
        assert!(report.stopped_early);
        assert_eq!(report.outcome.evals, 0);
    }

    #[test]
    fn into_context_carries_request_knobs() {
        let ctx = tiny().threads(3).build().unwrap().into_context();
        assert_eq!(ctx.budget, 120);
        assert_eq!(ctx.threads(), 3);
    }
}
