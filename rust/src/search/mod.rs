//! Shared search infrastructure: evaluation backends, budget accounting
//! and telemetry (best-so-far curves, valid-point ratios — the raw data
//! behind Fig. 17b and Fig. 18).

pub mod telemetry;

pub use telemetry::{Outcome, Telemetry};

use crate::arch::Platform;
use crate::model::{EvalResult, NativeEvaluator};
use crate::runtime::{BatchEvaluator, Runtime};
use crate::workload::Workload;
use anyhow::Result;

/// Fitness backend: the native Rust model or the PJRT AOT executable.
/// Both implement the same FEATURE_SCHEMA_V1 formula.
pub enum Backend {
    Native(NativeEvaluator),
    Pjrt(Box<BatchEvaluator>),
}

impl Backend {
    pub fn native(workload: Workload, platform: Platform) -> Backend {
        Backend::Native(NativeEvaluator::new(workload, platform))
    }

    pub fn pjrt(rt: &Runtime, workload: Workload, platform: Platform) -> Result<Backend> {
        Ok(Backend::Pjrt(Box::new(BatchEvaluator::new(rt, workload, platform)?)))
    }

    pub fn workload(&self) -> &Workload {
        match self {
            Backend::Native(e) => &e.workload,
            Backend::Pjrt(e) => &e.workload,
        }
    }

    pub fn platform(&self) -> &Platform {
        match self {
            Backend::Native(e) => &e.platform,
            Backend::Pjrt(e) => &e.platform,
        }
    }

    fn eval(&self, genomes: &[Vec<u32>]) -> Vec<EvalResult> {
        match self {
            Backend::Native(e) => genomes.iter().map(|g| e.eval_genome(g)).collect(),
            Backend::Pjrt(e) => e
                .eval_genomes(genomes)
                .expect("PJRT evaluation failed (artifact/runtime error)"),
        }
    }

    fn eval_design(&self, design: &crate::genome::Design) -> EvalResult {
        match self {
            Backend::Native(e) => e.eval_design(design),
            Backend::Pjrt(e) => e
                .eval_designs(std::slice::from_ref(design))
                .expect("PJRT evaluation failed")
                .pop()
                .unwrap(),
        }
    }
}

/// A budgeted evaluation context handed to every search algorithm.
///
/// All algorithms draw from the same sample budget (the paper's 20 000)
/// and report through the same telemetry, which keeps comparisons fair.
pub struct EvalContext {
    backend: Backend,
    pub spec: crate::genome::GenomeSpec,
    pub budget: usize,
    pub telemetry: Telemetry,
}

impl EvalContext {
    pub fn new(backend: Backend, budget: usize) -> EvalContext {
        let spec = crate::genome::GenomeSpec::for_workload(backend.workload());
        EvalContext { backend, spec, budget, telemetry: Telemetry::new() }
    }

    pub fn workload(&self) -> &Workload {
        self.backend.workload()
    }

    pub fn platform(&self) -> &Platform {
        self.backend.platform()
    }

    pub fn used(&self) -> usize {
        self.telemetry.evals
    }

    pub fn remaining(&self) -> usize {
        self.budget.saturating_sub(self.used())
    }

    pub fn exhausted(&self) -> bool {
        self.remaining() == 0
    }

    /// Evaluate a batch, truncated to the remaining budget. Returns one
    /// result per *submitted* genome that fit in the budget.
    pub fn eval_batch(&mut self, genomes: &[Vec<u32>]) -> Vec<EvalResult> {
        let n = genomes.len().min(self.remaining());
        if n == 0 {
            return Vec::new();
        }
        let results = self.backend.eval(&genomes[..n]);
        for (g, r) in genomes[..n].iter().zip(&results) {
            self.telemetry.record(g, r);
        }
        results
    }

    /// Evaluate one genome (budget permitting).
    pub fn eval_one(&mut self, genome: &[u32]) -> Option<EvalResult> {
        self.eval_batch(std::slice::from_ref(&genome.to_vec())).pop()
    }

    /// Evaluate pre-decoded designs from a *foreign* encoding (the
    /// direct-value ablation baseline). `None` designs are dead on
    /// arrival (tiling-constraint violations) but still consume budget —
    /// the evaluator would have rejected them. `record` pairs each design
    /// with the genome to log in telemetry.
    pub fn eval_designs(
        &mut self,
        record: &[Vec<u32>],
        designs: &[Option<crate::genome::Design>],
    ) -> Vec<EvalResult> {
        assert_eq!(record.len(), designs.len());
        let n = designs.len().min(self.remaining());
        let mut out = Vec::with_capacity(n);
        for (g, d) in record[..n].iter().zip(&designs[..n]) {
            let r = match d {
                Some(design) => self.backend.eval_design(design),
                None => EvalResult {
                    energy_pj: 0.0,
                    cycles: 0.0,
                    edp: f64::INFINITY,
                    valid: false,
                },
            };
            self.telemetry.record(g, &r);
            out.push(r);
        }
        out
    }

    /// Finalize into an outcome.
    pub fn outcome(self, method: &str) -> Outcome {
        self.telemetry.into_outcome(
            method,
            &self.backend.workload().id,
            &self.backend.platform().name,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        EvalContext::new(Backend::native(w, Platform::edge()), budget)
    }

    #[test]
    fn budget_enforced() {
        let mut c = ctx(10);
        let mut rng = crate::util::rng::Pcg64::seeded(1);
        let genomes: Vec<_> = (0..20).map(|_| c.spec.random(&mut rng)).collect();
        let r = c.eval_batch(&genomes);
        assert_eq!(r.len(), 10);
        assert!(c.exhausted());
        assert!(c.eval_batch(&genomes).is_empty());
    }

    #[test]
    fn telemetry_tracks_best() {
        let mut c = ctx(100);
        let mut rng = crate::util::rng::Pcg64::seeded(2);
        let genomes: Vec<_> = (0..50).map(|_| c.spec.random(&mut rng)).collect();
        c.eval_batch(&genomes);
        let o = c.outcome("test");
        assert_eq!(o.evals, 50);
        assert!(o.best_edp > 0.0);
        assert!(o.valid_evals <= o.evals);
        // Curve is monotone non-increasing.
        assert!(o.curve.windows(2).all(|w| w[1].1 <= w[0].1));
    }

    #[test]
    fn eval_one_consumes_budget() {
        let mut c = ctx(2);
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        let g = c.spec.random(&mut rng);
        assert!(c.eval_one(&g).is_some());
        assert!(c.eval_one(&g).is_some());
        assert!(c.eval_one(&g).is_none());
    }
}
