//! # SparseMap — evolution-strategy DSE for sparse tensor accelerators
//!
//! A reproduction of *"SparseMap: A Sparse Tensor Accelerator Framework
//! Based on Evolution Strategy"* as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the search framework: genome codec
//!   ([`genome`]), the customized evolution strategy ([`es`]), baseline
//!   optimizers ([`baselines`]), the native analytical cost model
//!   ([`model`]) and experiment drivers ([`report`]).
//! * **L2/L1 (python/compile, build-time only)** — the batched fitness
//!   evaluator as a JAX graph with a Pallas hot-spot kernel, AOT-lowered
//!   to `artifacts/*.hlo.txt`.
//! * **Runtime** (`runtime`, behind the optional `xla` feature) — loads
//!   the AOT artifacts through the PJRT CPU client (`xla` crate) and
//!   evaluates whole populations per call; Python never runs on the
//!   search path. The default build is native-only and fully offline.
//!
//! ## The parallel, staged, memoizing evaluation pipeline
//!
//! Search wall-clock is dominated by fitness evaluation, so the shared
//! [`search::EvalContext`] owns three orthogonal accelerations that every
//! algorithm (SparseMap and all baselines) inherits transparently:
//!
//! * **Parallel batches** — attach a
//!   [`util::threadpool::ThreadPool`] (CLI: `--threads N`) and native
//!   population batches are chunked across workers with an
//!   order-preserving parallel map. The cost model is pure, so search
//!   trajectories are **bit-identical between 1 and N threads**.
//! * **Evaluation cache** — results are memoized by genome, with genomes
//!   hash-consed to dense ids ([`search::engine`]) so a hit costs one
//!   slice hash + one array read and clones nothing. A repeated genome
//!   (ES populations re-produce identical offspring constantly) is
//!   served from the cache without a model call, but **still debits one
//!   evaluation from the sample budget**: the paper's budget counts
//!   submissions, not distinct designs, so cached and uncached arms stay
//!   comparable. Caching never changes a trajectory, only its cost.
//! * **Stage memoization** — a cache miss does not recompute from
//!   scratch: decoded mappings and per-tensor compression stats are
//!   memoized per genome *segment*, so offspring that mutated only part
//!   of a parent's genome reuse the rest and pay only the
//!   allocation-free assembly + cost arithmetic
//!   ([`search::StageEngine`]; bit-for-bit parity with the from-scratch
//!   path is pinned by `rust/tests/engine_parity.rs`).
//!
//! ## Structured sparsity patterns — [`sparsity`]
//!
//! Every workload tensor carries a [`sparsity::DensityModel`] rather
//! than a bare scalar: uniform (the legacy scalar, bit-for-bit
//! compatible), block, banded, power-law-row and measured-histogram
//! patterns. The cost model consumes per-rank slot occupancies,
//! tail-quantile tile provisioning and effectual-MAC fractions from the
//! model, so the *shape* of sparsity — not just its amount — steers the
//! search (`sparsemap patterns` demonstrates the outcome shift; fit a
//! model to a real tensor with `sparsemap inspect-tensor <file>`).
//!
//! ## The optimizer registry — [`optimizer`]
//!
//! Every search method — SparseMap, its ablations, and all baselines —
//! lives behind the [`optimizer::Optimizer`] trait in a static
//! [`optimizer::registry()`]: canonical name, aliases, one-line
//! description and a typed, ranged **tunable schema**. Hyper-parameters
//! travel as a JSON `method_opts` object (API requests, `run-spec`
//! files, CLI `--method-opts`) and validate against that schema; the
//! registry is the single source of truth for method names everywhere
//! (`sparsemap methods` prints it). On top of the trait sits the
//! [`optimizer::portfolio`] meta-optimizer: a successive-halving race of
//! member methods over one shared budget/cache/pool.
//!
//! ## Programmatic use — start at [`api`]
//!
//! [`api`] is the crate's front door: build a [`api::SearchRequest`]
//! (named *or fully custom* workloads and platforms, budget, seed,
//! threads, backend, cache policy), validate it into a
//! [`api::SearchSession`], stream progress through a
//! [`search::SearchObserver`], cancel from another thread, suspend into
//! a resumable [`optimizer::Checkpoint`] ([`api::RunOpts`]), and get a
//! JSON-round-trippable [`api::SearchReport`] back. The CLI
//! (`search`, `run-spec`), the experiment drivers ([`report`]), the
//! long-running search daemon ([`service`], CLI `serve`) and the
//! examples are all thin layers over it.

pub mod api;
pub mod arch;
pub mod baselines;
pub mod es;
pub mod genome;
pub mod mapping;
pub mod memory;
pub mod model;
pub mod obs;
pub mod optimizer;
pub mod report;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod search;
pub mod service;
pub mod sparse;
pub mod sparsity;
pub mod util;
pub mod workload;

/// Common imports for downstream users and the examples.
pub mod prelude {
    pub use crate::api::{methods, run_batch, RunOpts, SearchReport, SearchRequest, SearchSession};
    pub use crate::arch::{Boundary, Platform, StorageLevel};
    pub use crate::genome::{decode, Design, Genome, GenomeSpec};
    pub use crate::mapping::{MapLevel, Mapping};
    pub use crate::memory::MemoryStore;
    pub use crate::model::{EvalResult, NativeEvaluator};
    pub use crate::optimizer::{registry, run_method, MethodSpec, Optimizer, ALL_METHODS};
    pub use crate::search::{Progress, SearchControl, SearchObserver};
    pub use crate::sparse::{RankFormat, SgMechanism, SparseStrategy};
    pub use crate::sparsity::DensityModel;
    pub use crate::util::rng::Pcg64;
    pub use crate::workload::{Workload, WorkloadKind};
}
