//! E2 / Fig. 7 — design-space structure: 1000 random samples of the
//! joint (mapping × sparse strategy) space for an SpMM workload, PCA-
//! projected to (mapping-PC1, strategy-PC1), tagged valid/invalid with
//! EDP. The qualitative claim: invalid points vastly outnumber and
//! surround the valid ones.

use super::{write_csv, ExpConfig};
use crate::arch::Platform;
use crate::model::NativeEvaluator;
use crate::util::pca;
use crate::util::rng::Pcg64;
use crate::workload::table3;

#[derive(Clone, Debug)]
pub struct Fig7Point {
    pub mapping_pc: f64,
    pub strategy_pc: f64,
    pub edp: f64,
    pub valid: bool,
}

pub fn sample(cfg: &ExpConfig, n: usize) -> Vec<Fig7Point> {
    let w = table3::by_id("mm3").expect("mm3"); // the bibd-class SpMM
    let ev = NativeEvaluator::new(w, Platform::cloud());
    let mut rng = Pcg64::seeded(cfg.seed);

    let mut mapping_rows: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut strategy_rows: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut results = Vec::with_capacity(n);
    for _ in 0..n {
        let g = ev.spec.random(&mut rng);
        let r = ev.eval_genome(&g);
        mapping_rows.push(
            g[..ev.spec.format_start].iter().map(|&x| x as f64).collect(),
        );
        strategy_rows.push(
            g[ev.spec.format_start..].iter().map(|&x| x as f64).collect(),
        );
        results.push(r);
    }

    let map_pca = pca::fit(&mapping_rows, 1, 60);
    let str_pca = pca::fit(&strategy_rows, 1, 60);
    mapping_rows
        .iter()
        .zip(&strategy_rows)
        .zip(&results)
        .map(|((m, s), r)| Fig7Point {
            mapping_pc: pca::project(&map_pca, m)[0],
            strategy_pc: pca::project(&str_pca, s)[0],
            edp: if r.valid { r.edp } else { f64::NAN },
            valid: r.valid,
        })
        .collect()
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<String> {
    let points = sample(cfg, 1000);
    let valid = points.iter().filter(|p| p.valid).count();
    let mut csv = String::from("mapping_pc1,strategy_pc1,edp,valid\n");
    for p in &points {
        csv.push_str(&format!(
            "{:.4},{:.4},{},{}\n",
            p.mapping_pc,
            p.strategy_pc,
            if p.valid { format!("{:.4e}", p.edp) } else { String::new() },
            p.valid as u8
        ));
    }
    write_csv(&cfg.out_dir, "fig7.csv", &csv)?;
    Ok(format!(
        "Fig. 7 — design-space scatter (mm3 @ cloud, 1000 samples)\n\
         valid: {} / {}  ({:.1}%) — invalid points dominate the space\n\
         CSV: fig7.csv (mapping_pc1, strategy_pc1, edp, valid)\n",
        valid,
        points.len(),
        100.0 * valid as f64 / points.len() as f64
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invalid_points_dominate() {
        let cfg = ExpConfig { seed: 3, ..Default::default() };
        let pts = sample(&cfg, 400);
        let valid = pts.iter().filter(|p| p.valid).count();
        assert!(valid > 0, "no valid points at all");
        assert!(
            (valid as f64) < 0.5 * pts.len() as f64,
            "valid points are not a minority: {valid}/{}",
            pts.len()
        );
    }

    #[test]
    fn projections_have_spread() {
        let cfg = ExpConfig { seed: 4, ..Default::default() };
        let pts = sample(&cfg, 200);
        let var = |xs: Vec<f64>| {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
        };
        assert!(var(pts.iter().map(|p| p.mapping_pc).collect()) > 1e-6);
        assert!(var(pts.iter().map(|p| p.strategy_pc).collect()) > 1e-6);
    }
}
