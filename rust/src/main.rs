//! SparseMap CLI — the L3 coordinator entrypoint.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md E1–E9)
//! plus utility commands for single searches and diagnostics. Everything
//! search-shaped goes through [`sparsemap::api`] — the CLI is a thin
//! argument-parsing layer over `SearchRequest`/`SearchSession`. Run with
//! no arguments for usage.

use sparsemap::api::{RunOpts, SearchRequest};
use sparsemap::arch::Platform;
use sparsemap::es::sensitivity::calibrate;
use sparsemap::es::CalibConfig;
use sparsemap::genome::{decode, describe};
use sparsemap::report::{fig10, fig17, fig18, fig2, fig7, patterns, table4, ExpConfig};
use sparsemap::sparsity::inspect;
use sparsemap::util::cli::Args;
use sparsemap::util::json::Json;
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::table3;
use std::path::PathBuf;

const USAGE: &str = "\
sparsemap — evolution-strategy DSE for sparse tensor accelerators

USAGE: sparsemap <COMMAND> [OPTIONS]

Experiment commands (one per paper table/figure):
  fig2                 E1: mapping x sparse-strategy interplay sweep
  fig7                 E2: design-space PCA scatter (1000 samples)
  fig10                E3: Cantor vs random permutation encoding
  fig17a               E4: SparseMap vs PSO/MCTS/TBPSA/PPO/DQN (VGG16, cloud)
  fig17b               E5: valid-point ratio per platform
  fig18                E7: ablation convergence (es-direct / es-pfce / full)
  table4               E6/E9: full 28x3 EDP matrix (--summary for ratios only)
  patterns             sparsity-pattern sweep: best design/EDP under
                         uniform vs block vs banded operand sparsity at
                         equal mean density

Utility commands:
  search               run one search arm
                         --workload mm3 --platform cloud --method sparsemap
                         --budget 20000 --seed 42 [--pjrt] [--show-design]
                         [--json] [--method-opts '{"population": 200}']
                         [--memory FILE] records the winning design in a
                         design-memory store; add [--warm-start] (with
                         [--warm-start-frac F] [--warm-start-k K]) to seed
                         the initial population from the store's nearest
                         prior scenarios; [--trace FILE] streams a
                         sparsemap.trace.v1 NDJSON trace of the run
  run-spec FILE        run a search request from a JSON spec file: custom
                         workloads (any einsum contraction) and platforms
                         (any PE-array geometry) welcome; CLI options
                         override spec fields; [--json] prints the full
                         report to stdout, [--show-design] renders the
                         winner
  methods              list every search method in the optimizer registry:
                         name, aliases, description, whether it supports
                         checkpoint/resume, and the tunables accepted in
                         method_opts (with defaults); [--json] emits the
                         machine-readable listing. --method accepts
                         aliases; `portfolio` races members over one
                         shared budget
  serve                run the HTTP search service: submit jobs with
                         POST /jobs, stream NDJSON progress, cancel into
                         a checkpoint and resume later (checkpoints
                         survive restarts with --checkpoint-dir)
                         --addr 127.0.0.1:7878 [--quota EVALS]
                         [--checkpoint-dir DIR] [--threads N-workers]
                         [--auth-token SECRET] requires Authorization:
                         Bearer on every endpoint but /health;
                         [--memory-store FILE] shares one design memory
                         across jobs (completed jobs deposit elites,
                         warm_start requests seed from it), compacted to
                         [--memory-cap N] records at startup;
                         [--max-conns N] sheds connections above N with
                         503 + Retry-After (default 64); SIGTERM/SIGINT
                         drain gracefully (suspend running resumable
                         jobs to checkpoints, flush, exit)
  memory ACTION        inspect or maintain a design-memory store
                         (--store FILE): `stats` prints per-scenario
                         record counts and a nearest-neighbour distance
                         histogram over the stored embeddings, `compact
                         --cap N` evicts worst-cost records down to the
                         cap, `export` dumps every record as JSON
  trace summarize FILE render an NDJSON trace written by --trace back
                         into a per-stage latency table and a
                         generation-by-generation convergence curve
  calibrate            run high-sensitivity gene calibration and print S(v)
                         --workload mm3 --platform cloud
  inspect-tensor FILE  parse a sparse tensor file (COO/MatrixMarket or
                         SMTX), fit a density model and print the
                         paste-ready "density" spec + row histogram
  workloads            list the Table III workload suite
  platforms            list the Table II platforms
  demo                 run the AOT gated-SpMM artifact through PJRT
                         (needs a build with --features xla)

Common options:
  --budget N           samples per search arm (default 20000)
  --seed N             RNG seed (default 42)
  --out DIR            CSV/report output directory (default results/)
  --threads N          worker threads: population evaluation fans out
                       across N workers (results are bit-identical for
                       any N); matrix experiments also run N arms at once
  --pjrt               evaluate through the AOT PJRT artifact
  --workloads a,b,c    restrict table4 to a workload subset
  --fault-plan SPEC    arm deterministic fault injection (chaos testing):
                       e.g. 'store-append:torn:25@1', 'eval:panic@3',
                       'seed=7;checkpoint-write:error'; also readable
                       from the SPARSEMAP_FAULTS environment variable

Unknown options are rejected (with a nearest-match suggestion), so typos
fail loudly instead of silently running defaults.

Repeat evaluations are served from a per-arm cache: they still debit the
sample budget (submissions are what the paper counts) but skip the model
call; `search` reports both submissions and the model evals/s actually
paid for.
";

/// Per-subcommand argument whitelists (on top of the common set).
fn check_args(args: &Args) -> anyhow::Result<()> {
    const COMMON_OPTS: &[&str] = &["budget", "seed", "out", "threads", "fault-plan"];
    const COMMON_FLAGS: &[&str] = &["pjrt"];
    const SEARCH_OPTS: &[&str] = &[
        "workload",
        "platform",
        "method",
        "method-opts",
        "memory",
        "warm-start-frac",
        "warm-start-k",
        "trace",
    ];
    const SEARCH_FLAGS: &[&str] = &["show-design", "json", "warm-start"];
    let (opts, flags): (&[&str], &[&str]) = match args.subcommand.as_str() {
        "search" => (SEARCH_OPTS, SEARCH_FLAGS),
        "run-spec" => (SEARCH_OPTS, SEARCH_FLAGS),
        "calibrate" => (&["workload", "platform"], &[]),
        "methods" => (&[], &["json"]),
        "serve" => (
            &[
                "addr",
                "quota",
                "checkpoint-dir",
                "auth-token",
                "memory-store",
                "memory-cap",
                "max-conns",
            ],
            &[],
        ),
        "memory" => (&["store", "cap"], &[]),
        "trace" => (&[], &[]),
        "table4" => (&["workloads"], &["summary"]),
        _ => (&[], &[]),
    };
    let known_opts: Vec<&str> = COMMON_OPTS.iter().chain(opts).copied().collect();
    let known_flags: Vec<&str> = COMMON_FLAGS.iter().chain(flags).copied().collect();
    args.reject_unknown(&known_opts, &known_flags)
}

fn exp_config(args: &Args) -> anyhow::Result<ExpConfig> {
    anyhow::ensure!(
        args.opt_u64("budget", 20_000)? >= 1,
        "--budget must be at least 1 sample"
    );
    let mut cfg = ExpConfig {
        budget: args.opt_u64("budget", 20_000)? as usize,
        seed: args.opt_u64("seed", 42)?,
        out_dir: PathBuf::from(args.opt_or("out", "results")),
        use_pjrt: args.flag("pjrt"),
        ..Default::default()
    };
    if let Some(t) = args.opt("threads") {
        cfg.threads = t.parse().map_err(|_| anyhow::anyhow!("--threads expects a number"))?;
    }
    Ok(cfg)
}

/// Overlay CLI options onto a request (from defaults or a spec file).
fn apply_overrides(mut req: SearchRequest, args: &Args) -> anyhow::Result<SearchRequest> {
    if let Some(w) = args.opt("workload") {
        req = req.workload_named(w);
    }
    if let Some(p) = args.opt("platform") {
        req = req.platform_named(p);
    }
    if let Some(m) = args.opt("method") {
        req = req.method(m);
    }
    if let Some(mo) = args.opt("method-opts") {
        let opts = Json::parse(mo)
            .map_err(|e| anyhow::anyhow!("--method-opts must be inline JSON: {e}"))?;
        req = req.method_opts(opts);
    }
    if args.opt("budget").is_some() {
        req.budget = args.opt_u64("budget", 0)? as usize;
    }
    if args.opt("seed").is_some() {
        req.seed = args.opt_u64("seed", 0)?;
    }
    if let Some(t) = args.opt("threads") {
        req.threads = t.parse().map_err(|_| anyhow::anyhow!("--threads expects a number"))?;
    }
    if args.flag("pjrt") {
        req = req.pjrt(true);
    }
    // Warm-start: `--warm-start` (or either tuning knob) opts in, layered
    // over any warm_start block a spec file already carries; `--memory`
    // supplies the store path.
    let tuned = args.opt("warm-start-frac").is_some() || args.opt("warm-start-k").is_some();
    if args.flag("warm-start") || tuned {
        let mut ws = req.warm_start.take().unwrap_or_default();
        if let Some(f) = args.opt("warm-start-frac") {
            ws.fraction =
                f.parse().map_err(|_| anyhow::anyhow!("--warm-start-frac expects a number"))?;
        }
        if let Some(k) = args.opt("warm-start-k") {
            ws.k = k.parse().map_err(|_| anyhow::anyhow!("--warm-start-k expects a number"))?;
        }
        req.warm_start = Some(ws);
    }
    if let Some(path) = args.opt("memory") {
        if let Some(ws) = &mut req.warm_start {
            ws.store = Some(path.to_string());
        }
    }
    Ok(req)
}

/// Run a built request, print the summary (or the full JSON report with
/// `--json`), write the report next to the CSVs, and optionally render
/// the winning design.
fn run_and_report(req: SearchRequest, args: &Args) -> anyhow::Result<()> {
    let out_dir = PathBuf::from(args.opt_or("out", "results"));
    let session = req.build()?;
    let (workload, platform) = (session.workload().clone(), session.platform().clone());
    let trace = args.opt("trace").map(PathBuf::from);
    let report =
        session.run_opts(RunOpts { trace: trace.clone(), ..Default::default() })?;
    let outcome = &report.outcome;

    if args.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        println!(
            "{} on {} @ {}: best EDP {:.4e}  ({} evals, {} cache hits, {} stage hits over \
             {} distinct genomes, {:.1}% valid, {:.2}s, {:.0} model evals/s, {} threads)",
            outcome.method,
            outcome.workload,
            outcome.platform,
            outcome.best_edp,
            outcome.evals,
            outcome.cache_hits,
            outcome.stage_hits,
            outcome.interned,
            100.0 * outcome.valid_ratio(),
            report.wall_s,
            report.model_evals_per_s(),
            report.request.threads.max(1),
        );
        // The portfolio meta-method carries a per-member breakdown.
        for m in report.members() {
            println!(
                "  member {:12} {:6} evals over {} {}, own best {}{}",
                m.method,
                m.evals,
                m.rounds,
                if m.pulls > 0 { "pull(s)" } else { "round(s)" },
                if m.best_edp.is_finite() { format!("{:.4e}", m.best_edp) } else { "-".into() },
                match m.eliminated_round {
                    Some(r) => format!("  (eliminated after round {r})"),
                    None => String::new(),
                },
            );
        }
    }
    if args.flag("show-design") {
        if let Some(g) = &outcome.best_genome {
            let spec = sparsemap::genome::GenomeSpec::for_workload(&workload);
            if g.len() == spec.len() {
                let design = decode(&spec, &workload, g);
                println!("--- best design ---\n{}", describe(&design, &workload));
            } else {
                println!("(best genome uses a foreign encoding; not rendered)");
            }
        }
    }
    std::fs::create_dir_all(&out_dir)?;
    let path = out_dir.join(format!(
        "search_{}_{}_{}.json",
        outcome.method, workload.id, platform.name
    ));
    std::fs::write(&path, report.to_json().pretty())?;
    if !args.flag("json") {
        println!("report written to {}", path.display());
        if let Some(t) = &trace {
            println!(
                "trace written to {} (render with `sparsemap trace summarize`)",
                t.display()
            );
        }
    }
    // `--memory` records the winning design so later runs on similar
    // scenarios can warm-start from it.
    if let Some(store_path) = args.opt("memory") {
        let mut store = sparsemap::memory::MemoryStore::open(store_path)?;
        let recorded =
            store.remember(&workload, &platform, &outcome.method, outcome, report.request.seed)?;
        if !args.flag("json") {
            if recorded {
                println!(
                    "best design recorded in {} ({} record(s))",
                    store.path().display(),
                    store.len()
                );
            } else {
                println!("no valid design to record in the memory store");
            }
        }
    }
    Ok(())
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    // SearchRequest::default() already encodes the CLI defaults
    // (mm3/cloud/sparsemap/20000/42); only the thread default differs —
    // the CLI uses all cores like the experiment drivers do.
    let req = SearchRequest::new().threads(ExpConfig::default().threads);
    run_and_report(apply_overrides(req, args)?, args)
}

fn cmd_run_spec(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: sparsemap run-spec <file.json> [overrides]"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read spec file '{path}': {e}"))?;
    let spec = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let mut req = SearchRequest::from_json(&spec)?;
    if spec.get("threads").is_none() && args.opt("threads").is_none() {
        // Match `search`: default to all cores unless the spec or the
        // CLI pins a thread count.
        req.threads = ExpConfig::default().threads;
    }
    let req = apply_overrides(req, args)?;
    run_and_report(req, args)
}

fn cmd_inspect_tensor(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: sparsemap inspect-tensor <file>"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read tensor file '{path}': {e}"))?;
    let report = inspect::inspect(&text).map_err(|e| e.context(format!("'{path}'")))?;
    print!("{report}");
    Ok(())
}

fn cmd_methods(args: &Args) {
    use sparsemap::optimizer::TunableKind;
    if args.flag("json") {
        println!("{}", sparsemap::api::methods_json().pretty());
        return;
    }
    println!("search methods (pass to --method by name or alias; tune via method_opts):\n");
    for m in sparsemap::api::methods() {
        let aliases = if m.aliases.is_empty() {
            String::new()
        } else {
            format!("  (aliases: {})", m.aliases.join(", "))
        };
        let resumable = if m.resumable { "  [resumable]" } else { "" };
        println!("{}{}{}", m.name, aliases, resumable);
        println!("    {}", m.summary);
        if m.tunables.is_empty() {
            println!("    tunables: none");
        } else {
            for t in m.tunables {
                let range = match t.kind {
                    TunableKind::Int { min, max } => format!("int in [{min}, {max}]"),
                    TunableKind::Float { min, max } => format!("float in [{min}, {max}]"),
                    TunableKind::Choice { options } => format!("one of {options:?}"),
                    TunableKind::MethodList => "array of method names".to_string(),
                    TunableKind::OptsByMethod => "object: method -> its opts".to_string(),
                };
                println!("    {:14} {} (default {}) — {}", t.key, range, t.default, t.help);
            }
        }
        println!();
    }
    println!("example: sparsemap search --method pso --method-opts '{{\"swarm\": 24}}'");
    println!("[resumable] methods suspend into a checkpoint and resume bit-identically");
}

/// `sparsemap serve` — the long-running HTTP search service. `--threads`
/// here means concurrent search jobs (each job's own thread count comes
/// from its request); default is one job at a time.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let workers = match args.opt("threads") {
        Some(t) => t.parse().map_err(|_| anyhow::anyhow!("--threads expects a number"))?,
        None => 1,
    };
    let memory_cap = args.opt_u64("memory-cap", sparsemap::memory::DEFAULT_CAP as u64)? as usize;
    anyhow::ensure!(memory_cap >= 1, "--memory-cap must be at least 1");
    let defaults = sparsemap::service::ServerConfig::default();
    let max_conns = args.opt_u64("max-conns", defaults.max_conns as u64)? as usize;
    anyhow::ensure!(max_conns >= 1, "--max-conns must be at least 1");
    let cfg = sparsemap::service::ServerConfig {
        addr: args.opt_or("addr", "127.0.0.1:7878"),
        workers,
        quota: args.opt_u64("quota", 0)? as usize,
        checkpoint_dir: args.opt("checkpoint-dir").map(PathBuf::from),
        auth_token: args.opt("auth-token").map(str::to_string),
        memory_store: args.opt("memory-store").map(PathBuf::from),
        memory_cap,
        max_conns,
        ..defaults
    };
    sparsemap::service::serve(cfg)
}

/// `sparsemap memory <stats|compact|export> --store FILE [--cap N]` —
/// inspect or bound a design-memory store outside any search.
fn cmd_memory(args: &Args) -> anyhow::Result<()> {
    let usage = "usage: sparsemap memory <stats|compact|export> --store <file> [--cap N]";
    let action = args.positional.first().ok_or_else(|| anyhow::anyhow!(usage))?.as_str();
    let store_path = args.opt("store").ok_or_else(|| anyhow::anyhow!(usage))?;
    let mut store = sparsemap::memory::MemoryStore::open(store_path)?;
    match action {
        "stats" => println!("{}", store.stats_json().pretty()),
        "export" => println!("{}", store.export_json().pretty()),
        "compact" => {
            let cap = args.opt_u64("cap", sparsemap::memory::DEFAULT_CAP as u64)? as usize;
            anyhow::ensure!(cap >= 1, "--cap must be at least 1");
            let evicted = store.compact(cap)?;
            println!("evicted {evicted} record(s); {} remain", store.len());
        }
        other => anyhow::bail!("unknown memory action '{other}'\n{usage}"),
    }
    Ok(())
}

/// `sparsemap trace summarize <file.ndjson>` — render a trace written by
/// `--trace` back into per-stage latency and convergence tables.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let usage = "usage: sparsemap trace summarize <file.ndjson>";
    let action = args.positional.first().ok_or_else(|| anyhow::anyhow!(usage))?.as_str();
    anyhow::ensure!(action == "summarize", "unknown trace action '{action}'\n{usage}");
    let path = args.positional.get(1).ok_or_else(|| anyhow::anyhow!(usage))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read trace file '{path}': {e}"))?;
    let summary =
        sparsemap::obs::summarize(&text).map_err(|e| anyhow::anyhow!("'{path}': {e}"))?;
    print!("{summary}");
    Ok(())
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let session = SearchRequest::new()
        .workload_named(&args.opt_or("workload", "mm3"))
        .platform_named(&args.opt_or("platform", "cloud"))
        .budget(cfg.budget)
        .seed(cfg.seed)
        .threads(cfg.threads)
        .pjrt(cfg.use_pjrt)
        .build()?;
    let mut ctx = session.into_context();
    let mut rng = Pcg64::seeded(cfg.seed);
    let sens = calibrate(&mut ctx, CalibConfig::default(), &mut rng);
    println!(
        "gene sensitivities (E8; {} evals = {:.1}% of budget):",
        sens.evals_spent,
        100.0 * sens.evals_spent as f64 / cfg.budget as f64
    );
    for (i, s) in sens.scores.iter().enumerate() {
        let class = if sens.high.contains(&i) { "HIGH" } else { "low " };
        println!("  gene {i:3} [{class}]  S = {s:.4e}  ({:?})", ctx.spec.kinds[i]);
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_demo() -> anyhow::Result<()> {
    anyhow::bail!(
        "the demo executes AOT artifacts through PJRT; rebuild with `--features xla` \
         (and a real xla crate in rust/vendor/xla)"
    )
}

#[cfg(feature = "xla")]
fn cmd_demo() -> anyhow::Result<()> {
    let rt = sparsemap::runtime::Runtime::from_default_dir()?;
    let demo = sparsemap::runtime::SpmmDemo::new(&rt)?;
    let (m, k, n) = (demo.m, demo.k, demo.n);
    let mut rng = Pcg64::seeded(1);
    let p: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let q: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let pm: Vec<f32> = (0..m * k).map(|_| if rng.chance(0.5) { 1.0 } else { 0.0 }).collect();
    let qm: Vec<f32> = (0..k * n).map(|_| if rng.chance(0.25) { 1.0 } else { 0.0 }).collect();
    let (z, eff) = demo.run(&p, &q, &pm, &qm)?;
    println!(
        "gated SpMM {m}x{k} * {k}x{n} through PJRT: effectual MACs {eff} of {} ({:.1}%)",
        m * k * n,
        100.0 * eff / (m * k * n) as f64,
    );
    println!("z[0..4] = {:?}", &z[..4]);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    if args.flag("help") || args.opt("help").is_some() {
        println!("{USAGE}");
        return Ok(());
    }
    check_args(&args)?;
    // Chaos testing: arm the process-global fault plan before anything
    // touches disk or sockets. CLI flag wins over the environment.
    sparsemap::util::faults::init_from_env()?;
    if let Some(spec) = args.opt("fault-plan") {
        let plan = sparsemap::util::faults::FaultPlan::parse(spec)?;
        eprintln!("fault plan armed from --fault-plan: {}", plan.describe());
        sparsemap::util::faults::arm(plan);
    }
    let cfg = exp_config(&args)?;

    match args.subcommand.as_str() {
        "fig2" => println!("{}", fig2::run(&cfg)?),
        "fig7" => println!("{}", fig7::run(&cfg)?),
        "fig10" => println!("{}", fig10::run(&cfg)?),
        "fig17a" => println!("{}", fig17::run_a(&cfg)?),
        "fig17b" => println!("{}", fig17::run_b(&cfg)?),
        "fig18" => println!("{}", fig18::run(&cfg)?),
        "table4" => {
            let subset = args
                .opt("workloads")
                .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
            println!("{}", table4::run(&cfg, subset, args.flag("summary"))?);
        }
        "patterns" => println!("{}", patterns::run(&cfg)?),
        "search" => cmd_search(&args)?,
        "run-spec" => cmd_run_spec(&args)?,
        "methods" => cmd_methods(&args),
        "serve" => cmd_serve(&args)?,
        "memory" => cmd_memory(&args)?,
        "trace" => cmd_trace(&args)?,
        "calibrate" => cmd_calibrate(&args)?,
        "inspect-tensor" => cmd_inspect_tensor(&args)?,
        "demo" => cmd_demo()?,
        "workloads" => {
            for w in table3::all() {
                let dims: Vec<String> =
                    w.dims.iter().map(|d| format!("{}={}", d.name, d.size)).collect();
                println!(
                    "{:8} {:7} {}  dP={:.3} dQ={:.3}",
                    w.id,
                    w.kind.as_str(),
                    dims.join(" "),
                    w.tensors[0].density.avg(),
                    w.tensors[1].density.avg()
                );
            }
        }
        "platforms" => {
            for p in Platform::all() {
                println!(
                    "{:7} {}x{} PEs, {} MACs/PE, PE buf {} KB, GLB {} KB, DRAM {:.3} GB/s",
                    p.name,
                    p.pe_rows,
                    p.pe_cols,
                    p.macs_per_pe,
                    p.pe_buf_bytes >> 10,
                    p.glb_bytes >> 10,
                    p.dram_bw_bytes_per_s / 1e9
                );
            }
        }
        "" | "help" => println!("{USAGE}"),
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
