//! The `portfolio` meta-optimizer: a bandit race of member methods over
//! one **shared** budget, evaluation cache and worker pool — the first
//! method only expressible because every search arm now runs behind the
//! [`Optimizer`] trait against a borrowed [`EvalContext`].
//!
//! ## How the race works
//!
//! The portfolio never evaluates a genome itself. It repeatedly grants
//! one member a slice of the remaining shared budget and runs it *to
//! that fence* ([`EvalContext::set_fence`]): the member sees an ordinary
//! budget-exhausted context and winds down through its normal exit path.
//! Two allocation policies pick who runs next (`alloc` tunable):
//!
//! * **`ucb` (default)** — UCB1 bandit pulls. The budget is split across
//!   `pulls` slices; each pull goes to the member maximizing
//!   `mean_reward + ucb_c * sqrt(ln(total_pulls) / member_pulls)`
//!   (unpulled members first, in list order; ties break to the first
//!   index). A pull's reward is 1.0 if its slice improved the *global*
//!   best EDP, 0.5 if it improved only the member's own best, else 0.0.
//!   Nobody is eliminated: a member that stops paying simply stops
//!   getting pulls, which is the right behaviour now that members
//!   pause/continue for free.
//! * **`halving`** — the original fixed successive-halving schedule:
//!   `rounds` rounds of equal shares, the worst `1 - 1/eta` of survivors
//!   eliminated after every round but the last, rounding leftovers to
//!   the best survivor.
//!
//! Each member is built **once**, at its first slice, and the same
//! optimizer instance runs every later slice. Since the [`Optimizer`]
//! overhaul made the search arms suspendable state machines, a member
//! whose slice fence runs out simply pauses at its next safe point and
//! *continues* from there when a later pull grants it more budget — no
//! budget is re-spent replaying earlier slices, and the ES family keeps
//! one coherent population/annealing schedule across pulls instead of
//! restarting. (Methods without live state, e.g. mcts or the RL arms,
//! still effectively restart; their replayed prefix is served by the
//! shared evaluation cache but does debit the budget, since the paper
//! counts submissions.) The shared telemetry accumulates in the one
//! context, so the portfolio's [`Outcome`] carries the global best
//! across all members, and [`Outcome::members`] breaks the spend down
//! per member — their `evals` sum to the outcome's `evals` exactly,
//! down to budget 1.
//!
//! The race itself is suspendable too: a raised suspend flag pauses the
//! in-flight member mid-slice, and [`Optimizer::suspend`] captures the
//! pull/member/fence cursor (plus the slice-start reward references, so
//! bandit bookkeeping resumes bit-identically) and every live member's
//! own state; a restored portfolio picks the race up exactly where it
//! stopped.

use super::{opt_f64, opt_usize, resolve, MethodSpec, Optimizer};
use crate::search::{EvalContext, MemberStats, Outcome};
use crate::util::json::{f64_bits, f64_from_bits, Json};
use anyhow::{anyhow, bail, ensure, Result};

/// Default member set: the flagship ES, its encoding-only ablation, and
/// the two strongest non-ES baselines at small budgets.
pub const DEFAULT_MEMBERS: &[&str] = &["sparsemap", "es-pfce", "pso", "random"];

/// Budget-allocation policy (the `alloc` tunable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Alloc {
    /// UCB1 bandit pulls (the default).
    Ucb,
    /// Fixed successive halving (the pre-bandit schedule).
    Halving,
}

struct Member {
    spec: &'static MethodSpec,
    opts: Json,
    /// Built lazily at the member's first slice and kept across rounds,
    /// so later slices continue the same search instead of replaying it.
    /// Dropped on elimination (losers never run again).
    opt: Option<Box<dyn Optimizer>>,
    evals: usize,
    best_edp: f64,
    rounds: usize,
    /// Completed bandit pulls (equals `rounds` in ucb mode; stays 0
    /// under halving).
    pulls: usize,
    /// Accumulated bandit reward across completed pulls.
    reward: f64,
    eliminated_round: Option<usize>,
}

/// Where a suspended race stopped. Halving: which round, which survivor
/// within that round's alive order, the share fixed at round start, and
/// — when a member was paused mid-slice — its absolute fence. Ucb:
/// `round` is the pull index, `member_pos` the in-flight member (or the
/// `members.len()` sentinel for a between-pulls boundary), `share`
/// smuggles the stall counter, and `ucb_ref` holds the slice-start
/// (global best, member best) pair the pull's reward is judged against.
struct Cursor {
    round: usize,
    member_pos: usize,
    share: usize,
    fence: Option<usize>,
    in_leftover: bool,
    ucb_ref: Option<(f64, f64)>,
}

/// The meta-optimizer. Construct through the registry:
/// `resolve("portfolio")?.build(&opts)`.
pub struct Portfolio {
    members: Vec<Member>,
    alloc: Alloc,
    ucb_c: f64,
    pulls: usize,
    rounds: usize,
    eta: usize,
    cursor: Option<Cursor>,
}

/// Registry builder (opts pre-validated against the portfolio tunables).
pub(crate) fn build(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let names: Vec<String> = match opts.get("members") {
        Some(Json::Arr(a)) => {
            a.iter().map(|m| m.as_str().unwrap_or_default().to_string()).collect()
        }
        _ => DEFAULT_MEMBERS.iter().map(|s| s.to_string()).collect(),
    };
    let mut members = Vec::with_capacity(names.len());
    for name in &names {
        let spec = resolve(name)?;
        if members.iter().any(|m: &Member| std::ptr::eq(m.spec, spec)) {
            bail!("portfolio member '{}' listed twice", spec.name);
        }
        members.push(Member {
            spec,
            opts: Json::Obj(Default::default()),
            opt: None,
            evals: 0,
            best_edp: f64::INFINITY,
            rounds: 0,
            pulls: 0,
            reward: 0.0,
            eliminated_round: None,
        });
    }
    // `member_opts` keys resolve through the registry like any method
    // name (aliases welcome), and each must name an actual member —
    // silently dropping a user's tuning would be the worst failure mode.
    if let Some(map) = opts.get("member_opts").and_then(Json::as_obj) {
        let mut assigned = vec![false; members.len()];
        for (key, val) in map {
            let kspec = resolve(key)?;
            let Some(i) = members.iter().position(|m| std::ptr::eq(m.spec, kspec)) else {
                bail!(
                    "member_opts entry '{key}' does not match any portfolio member \
                     (members: {names:?})"
                );
            };
            if assigned[i] {
                bail!("member_opts sets '{}' twice (via different spellings)", kspec.name);
            }
            assigned[i] = true;
            members[i].opts = val.clone();
        }
    }
    let alloc = match opts.get("alloc").and_then(Json::as_str) {
        Some("halving") => Alloc::Halving,
        _ => Alloc::Ucb,
    };
    Ok(Box::new(Portfolio {
        members,
        alloc,
        ucb_c: opt_f64(opts, "ucb_c", 1.4),
        pulls: opt_usize(opts, "pulls", 16).max(1),
        rounds: opt_usize(opts, "rounds", 3).max(1),
        eta: opt_usize(opts, "eta", 2).max(2),
        cursor: None,
    }))
}

impl Portfolio {
    /// Run `member` until `fence` (an absolute submission count), folding
    /// the slice's spend and per-slice best into its stats. `round` is
    /// the portfolio-level round (halving) or pull (ucb) index — the
    /// number recorded in `eliminated_round` on a build failure. Returns
    /// `false` when the member was paused mid-slice by a suspend request
    /// (its stats are still folded; `rounds` is only counted once the
    /// slice completes).
    fn run_slice(
        member: &mut Member,
        ctx: &mut EvalContext,
        fence: Option<usize>,
        seed: u64,
        round: usize,
    ) -> bool {
        let before = ctx.used();
        ctx.begin_slice();
        ctx.set_fence(fence);
        if member.opt.is_none() {
            // Validated at build time, so this only fails if a member's
            // semantic invariants break — eliminate it (loudly) rather
            // than poison the whole race.
            match member.spec.build(&member.opts) {
                Ok(opt) => member.opt = Some(opt),
                Err(e) => {
                    eprintln!(
                        "warning: portfolio member '{}' failed to build: {e}",
                        member.spec.name
                    );
                    member.eliminated_round = Some(round);
                }
            }
        }
        if let Some(opt) = member.opt.as_mut() {
            opt.run(ctx, seed);
        }
        ctx.set_fence(None);
        member.evals += ctx.used() - before;
        member.best_edp = member.best_edp.min(ctx.slice_best());
        let completed = !ctx.suspend_requested();
        if completed {
            member.rounds += 1;
        }
        completed
    }

    fn alive(&self) -> Vec<usize> {
        (0..self.members.len())
            .filter(|&i| self.members[i].eliminated_round.is_none())
            .collect()
    }

    /// UCB1 arm selection: unpulled members first (list order), then the
    /// highest `mean_reward + c * sqrt(ln(t) / pulls)` with strict-`>`
    /// comparison, so ties break to the first index — deterministic.
    fn pick_ucb(&self, alive: &[usize]) -> usize {
        if let Some(&i) = alive.iter().find(|&&i| self.members[i].pulls == 0) {
            return i;
        }
        let total: usize = alive.iter().map(|&i| self.members[i].pulls).sum();
        let ln_t = (total as f64).ln();
        let mut best = alive[0];
        let mut best_score = f64::NEG_INFINITY;
        for &i in alive {
            let m = &self.members[i];
            let n = m.pulls as f64;
            let score = m.reward / n + self.ucb_c * (ln_t / n).sqrt();
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }

    /// The bandit loop: split the remaining budget over the remaining
    /// pulls (`remaining.div_ceil(pulls_left)` per slice, so the last
    /// pull drains whatever is left) and hand each slice to the UCB1
    /// pick. Runs until the shared budget is exhausted; a stall guard
    /// breaks after `members.len() + 1` consecutive zero-progress pulls
    /// (every member wound down without spending), so a portfolio of
    /// early-terminating members cannot livelock.
    fn run_ucb(&mut self, ctx: &mut EvalContext, seed: u64) {
        let sentinel = self.members.len();
        let (mut pull, mut stall, mut pending) = match self.cursor.take() {
            Some(c) => {
                let pending = match (c.member_pos < sentinel, c.fence, c.ucb_ref) {
                    (true, Some(f), Some(refs)) => Some((c.member_pos, f, refs)),
                    _ => None,
                };
                (c.round, c.share, pending)
            }
            None => (0, 0, None),
        };
        loop {
            if ctx.exhausted() {
                break;
            }
            let alive = self.alive();
            if alive.is_empty() {
                break;
            }
            if ctx.suspend_requested() {
                self.cursor = Some(match pending.take() {
                    Some((i, fence, refs)) => Cursor {
                        round: pull,
                        member_pos: i,
                        share: stall,
                        fence: Some(fence),
                        in_leftover: false,
                        ucb_ref: Some(refs),
                    },
                    None => Cursor {
                        round: pull,
                        member_pos: sentinel,
                        share: stall,
                        fence: None,
                        in_leftover: false,
                        ucb_ref: None,
                    },
                });
                return;
            }
            let (i, fence, (global_before, own_before)) = match pending.take() {
                // A pull interrupted mid-flight keeps its original fence
                // and reward references, so the resumed slice finishes
                // exactly the allocation it was granted and its reward
                // is judged against the same baseline.
                Some(p) => p,
                None => {
                    let i = self.pick_ucb(&alive);
                    let pulls_left = self.pulls.saturating_sub(pull).max(1);
                    let share = ctx.remaining().div_ceil(pulls_left).max(1);
                    (
                        i,
                        ctx.used() + share,
                        (ctx.telemetry.best_edp, self.members[i].best_edp),
                    )
                }
            };
            let before = ctx.used();
            if !Self::run_slice(&mut self.members[i], ctx, Some(fence), seed, pull) {
                self.cursor = Some(Cursor {
                    round: pull,
                    member_pos: i,
                    share: stall,
                    fence: Some(fence),
                    in_leftover: false,
                    ucb_ref: Some((global_before, own_before)),
                });
                return;
            }
            let m = &mut self.members[i];
            m.pulls += 1;
            m.reward += if ctx.telemetry.best_edp < global_before {
                1.0
            } else if m.best_edp < own_before {
                0.5
            } else {
                0.0
            };
            if ctx.used() > before {
                stall = 0;
            } else {
                stall += 1;
                if stall > self.members.len() {
                    break;
                }
            }
            pull += 1;
        }
    }

    /// The original fixed successive-halving schedule (`alloc:
    /// "halving"`).
    fn run_halving(&mut self, ctx: &mut EvalContext, seed: u64) {
        let (mut round, mut pos, mut share, mut pending_fence, resumed_leftover) =
            match self.cursor.take() {
                Some(c) => (c.round, c.member_pos, c.share, c.fence, c.in_leftover),
                None => (0, 0, 0, None, false),
            };
        if !resumed_leftover {
            while round < self.rounds {
                let alive = self.alive();
                if alive.is_empty() || ctx.exhausted() {
                    break;
                }
                if pos == 0 && pending_fence.is_none() {
                    // This round's pot: an equal share of what's left for
                    // each remaining round, split evenly across survivors.
                    // Fixed at round start (and restored verbatim when
                    // resuming mid-round, where `remaining()` has moved).
                    let pot = ctx.remaining() / (self.rounds - round);
                    share = (pot / alive.len()).max(1);
                }
                let mut suspended = false;
                while pos < alive.len() {
                    if ctx.exhausted() {
                        break;
                    }
                    if ctx.suspend_requested() {
                        suspended = true;
                        break;
                    }
                    let fence = match pending_fence.take() {
                        // A slice interrupted mid-flight keeps its
                        // original fence so the member finishes exactly
                        // the allocation it was granted.
                        Some(f) => f,
                        None => ctx.used() + share.min(ctx.remaining()),
                    };
                    // Same member seed every round; the persistent
                    // optimizer instance continues from where the last
                    // fence paused it (module docs).
                    if !Self::run_slice(&mut self.members[alive[pos]], ctx, Some(fence), seed, round)
                    {
                        pending_fence = Some(fence);
                        suspended = true;
                        break;
                    }
                    pos += 1;
                }
                if suspended {
                    self.cursor = Some(Cursor {
                        round,
                        member_pos: pos,
                        share,
                        fence: pending_fence,
                        in_leftover: false,
                        ucb_ref: None,
                    });
                    return;
                }
                // Successive halving after every round but the last: rank
                // survivors by their own best and keep ceil(alive/eta),
                // stable on ties (registry order).
                if round + 1 < self.rounds {
                    let mut ranked = self.alive();
                    ranked.sort_by(|&a, &b| {
                        self.members[a].best_edp.total_cmp(&self.members[b].best_edp)
                    });
                    let keep = ranked.len().div_ceil(self.eta).max(1);
                    for &i in &ranked[keep..] {
                        self.members[i].eliminated_round = Some(round);
                        self.members[i].opt = None;
                    }
                }
                round += 1;
                pos = 0;
            }
        }
        // Rounding leftovers go to the best survivor, unfenced. The best
        // pick is recomputed on resume from the persisted per-member
        // stats, so it lands on the same survivor.
        if !ctx.exhausted() {
            let leftover_cursor = Cursor {
                round: self.rounds,
                member_pos: 0,
                share: 0,
                fence: None,
                in_leftover: true,
                ucb_ref: None,
            };
            if ctx.suspend_requested() {
                self.cursor = Some(leftover_cursor);
                return;
            }
            let best = self
                .alive()
                .into_iter()
                .min_by(|&a, &b| self.members[a].best_edp.total_cmp(&self.members[b].best_edp));
            if let Some(i) = best {
                let last_round = self.rounds.saturating_sub(1);
                if !Self::run_slice(&mut self.members[i], ctx, None, seed, last_round) {
                    self.cursor = Some(leftover_cursor);
                }
            }
        }
    }
}

impl Optimizer for Portfolio {
    fn label(&self) -> &str {
        "portfolio"
    }

    fn run(&mut self, ctx: &mut EvalContext, seed: u64) {
        match self.alloc {
            Alloc::Ucb => self.run_ucb(ctx, seed),
            Alloc::Halving => self.run_halving(ctx, seed),
        }
    }

    fn annotate(&self, outcome: &mut Outcome) {
        outcome.members = self
            .members
            .iter()
            .map(|m| MemberStats {
                method: m.spec.name.to_string(),
                evals: m.evals,
                best_edp: m.best_edp,
                rounds: m.rounds,
                pulls: m.pulls,
                eliminated_round: m.eliminated_round,
            })
            .collect();
    }

    fn suspend(&self) -> Option<Json> {
        let mut members = Vec::with_capacity(self.members.len());
        for m in &self.members {
            let opt_state = match (&m.opt, m.eliminated_round) {
                // A live member with built state must checkpoint it; if
                // its method cannot, the whole race cannot be suspended
                // faithfully (resuming would silently restart it).
                (Some(opt), None) => opt.suspend()?,
                _ => Json::Null,
            };
            members.push(Json::obj(vec![
                ("name", Json::str(m.spec.name)),
                ("evals", Json::num(m.evals as f64)),
                ("best_edp", f64_bits(m.best_edp)),
                ("rounds", Json::num(m.rounds as f64)),
                ("pulls", Json::num(m.pulls as f64)),
                ("reward", f64_bits(m.reward)),
                (
                    "eliminated_round",
                    match m.eliminated_round {
                        Some(r) => Json::num(r as f64),
                        None => Json::Null,
                    },
                ),
                ("opt", opt_state),
            ]));
        }
        Some(Json::obj(vec![(
            "portfolio",
            Json::obj(vec![
                (
                    "cursor",
                    match &self.cursor {
                        Some(c) => cursor_to_json(c),
                        None => Json::Null,
                    },
                ),
                ("members", Json::Arr(members)),
            ]),
        )]))
    }

    fn resume(&mut self, state: &Json) -> Result<()> {
        let p = state
            .get("portfolio")
            .ok_or_else(|| anyhow!("portfolio state is missing 'portfolio'"))?;
        self.cursor = match p.get("cursor") {
            None | Some(Json::Null) => None,
            Some(c) => Some(cursor_from_json(c)?),
        };
        let members = p
            .get("members")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("portfolio state is missing 'members'"))?;
        ensure!(
            members.len() == self.members.len(),
            "portfolio member count mismatch: state has {}, configured {}",
            members.len(),
            self.members.len()
        );
        for (m, mj) in self.members.iter_mut().zip(members) {
            let name = mj
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("portfolio member state is missing 'name'"))?;
            ensure!(
                name == m.spec.name,
                "portfolio member mismatch: state has '{name}', configured '{}'",
                m.spec.name
            );
            m.evals = usize_field(mj, "evals")?;
            m.rounds = usize_field(mj, "rounds")?;
            // Absent in pre-bandit checkpoints: default to zero rather
            // than reject them.
            m.pulls = mj.get("pulls").and_then(Json::as_u64).unwrap_or(0) as usize;
            m.reward = mj.get("reward").and_then(f64_from_bits).unwrap_or(0.0);
            m.best_edp = mj
                .get("best_edp")
                .and_then(f64_from_bits)
                .ok_or_else(|| anyhow!("portfolio member '{name}' has a bad 'best_edp'"))?;
            m.eliminated_round = match mj.get("eliminated_round") {
                None | Some(Json::Null) => None,
                Some(v) => Some(
                    v.as_u64()
                        .ok_or_else(|| anyhow!("bad 'eliminated_round' for member '{name}'"))?
                        as usize,
                ),
            };
            m.opt = match mj.get("opt") {
                None | Some(Json::Null) => None,
                Some(s) => {
                    let mut opt = m.spec.build(&m.opts)?;
                    opt.resume(s)?;
                    Some(opt)
                }
            };
        }
        Ok(())
    }
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_u64)
        .map(|v| v as usize)
        .ok_or_else(|| anyhow!("portfolio state is missing '{key}'"))
}

fn cursor_to_json(c: &Cursor) -> Json {
    Json::obj(vec![
        ("round", Json::num(c.round as f64)),
        ("member_pos", Json::num(c.member_pos as f64)),
        ("share", Json::num(c.share as f64)),
        (
            "fence",
            match c.fence {
                Some(f) => Json::num(f as f64),
                None => Json::Null,
            },
        ),
        ("in_leftover", Json::Bool(c.in_leftover)),
        (
            "ucb_ref",
            match c.ucb_ref {
                Some((g, o)) => Json::Arr(vec![f64_bits(g), f64_bits(o)]),
                None => Json::Null,
            },
        ),
    ])
}

fn cursor_from_json(j: &Json) -> Result<Cursor> {
    Ok(Cursor {
        round: usize_field(j, "round")?,
        member_pos: usize_field(j, "member_pos")?,
        share: usize_field(j, "share")?,
        fence: match j.get("fence") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_u64().ok_or_else(|| anyhow!("portfolio cursor has a bad 'fence'"))? as usize,
            ),
        },
        in_leftover: j
            .get("in_leftover")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow!("portfolio cursor is missing 'in_leftover'"))?,
        ucb_ref: match j.get("ucb_ref").and_then(Json::as_arr) {
            Some(pair) if pair.len() == 2 => Some((
                f64_from_bits(&pair[0])
                    .ok_or_else(|| anyhow!("portfolio cursor has a bad 'ucb_ref'"))?,
                f64_from_bits(&pair[1])
                    .ok_or_else(|| anyhow!("portfolio cursor has a bad 'ucb_ref'"))?,
            )),
            _ => None,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::super::{run_method, run_method_with, ALL_METHODS};
    use crate::arch::Platform;
    use crate::search::{Backend, EvalContext};
    use crate::util::json::Json;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.4, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn halving_spends_exactly_its_budget_across_members() {
        let opts = Json::parse(r#"{"alloc": "halving"}"#).unwrap();
        let o = run_method_with("portfolio", &opts, ctx(900), 11).unwrap();
        assert_eq!(o.method, "portfolio");
        assert!(o.evals <= 900, "overspent: {}", o.evals);
        assert_eq!(o.members.len(), super::DEFAULT_MEMBERS.len());
        let member_sum: usize = o.members.iter().map(|m| m.evals).sum();
        assert_eq!(member_sum, o.evals, "member evals must sum to the outcome's");
        // The global best is at least as good as every member's own best.
        for m in &o.members {
            assert!(o.best_edp <= m.best_edp, "{} beat the portfolio best", m.method);
        }
        // With rounds=3 over 4 members someone must have been eliminated.
        assert!(o.members.iter().any(|m| m.eliminated_round.is_some()));
        assert!(o.members.iter().any(|m| m.eliminated_round.is_none()));
    }

    #[test]
    fn ucb_default_allocates_whole_budget_without_elimination() {
        let o = run_method("portfolio", ctx(900), 11).unwrap();
        assert_eq!(o.method, "portfolio");
        assert!(o.evals <= 900, "overspent: {}", o.evals);
        let member_sum: usize = o.members.iter().map(|m| m.evals).sum();
        assert_eq!(member_sum, o.evals, "member evals must sum to the outcome's");
        // The bandit never eliminates; every member got its warm-up pull.
        assert!(o.members.iter().all(|m| m.eliminated_round.is_none()));
        assert!(o.members.iter().all(|m| m.pulls >= 1), "{:?}", o.members);
        let total_pulls: usize = o.members.iter().map(|m| m.pulls).sum();
        assert!(total_pulls >= super::DEFAULT_MEMBERS.len(), "{total_pulls}");
        for m in &o.members {
            assert!(o.best_edp <= m.best_edp, "{} beat the portfolio best", m.method);
        }
    }

    #[test]
    fn ucb_tunables_reach_the_bandit() {
        // One pull: the whole budget goes to the first warm-up member;
        // the others never run.
        let opts = Json::parse(r#"{"pulls": 1}"#).unwrap();
        let o = run_method_with("portfolio", &opts, ctx(200), 7).unwrap();
        assert_eq!(o.members.iter().map(|m| m.evals).sum::<usize>(), o.evals);
        let ran: Vec<&str> =
            o.members.iter().filter(|m| m.pulls > 0).map(|m| m.method.as_str()).collect();
        assert_eq!(ran, vec!["sparsemap"], "single pull goes to the first member");
        // Bad alloc strings are rejected by schema validation.
        let bad = Json::parse(r#"{"alloc": "thompson"}"#).unwrap();
        let err = run_method_with("portfolio", &bad, ctx(40), 1).unwrap_err().to_string();
        assert!(err.contains("must be one of"), "{err}");
    }

    #[test]
    fn portfolio_is_deterministic_per_seed() {
        let a = run_method("portfolio", ctx(600), 4).unwrap();
        let b = run_method("portfolio", ctx(600), 4).unwrap();
        assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits());
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.members, b.members);
    }

    #[test]
    fn custom_members_and_member_opts() {
        let opts = Json::parse(
            r#"{"members": ["pso", "random"], "rounds": 2,
                "member_opts": {"pso": {"swarm": 12}}}"#,
        )
        .unwrap();
        let o = run_method_with("portfolio", &opts, ctx(400), 3).unwrap();
        assert_eq!(o.members.len(), 2);
        assert_eq!(o.members[0].method, "pso");
        assert_eq!(o.members[1].method, "random");
        assert_eq!(o.members.iter().map(|m| m.evals).sum::<usize>(), o.evals);
    }

    #[test]
    fn member_opts_resolve_aliases_and_reject_non_members() {
        // Opts keyed by an alias must reach the member named canonically
        // in `members`: if the alias failed to resolve onto the member,
        // build would reject it as a non-member entry and this unwrap
        // would fail.
        let aliased = Json::parse(
            r#"{"members": ["random"], "rounds": 1,
                "member_opts": {"rand": {"batch": 1}}}"#,
        )
        .unwrap();
        let o = run_method_with("portfolio", &aliased, ctx(40), 5).unwrap();
        assert_eq!(o.members[0].method, "random");
        assert_eq!(o.evals, 40);

        // Opts for a method that is not a member must fail loudly, not
        // be silently dropped.
        let stray = Json::parse(
            r#"{"members": ["pso"], "member_opts": {"random": {"batch": 8}}}"#,
        )
        .unwrap();
        let err = run_method_with("portfolio", &stray, ctx(40), 5).unwrap_err().to_string();
        assert!(err.contains("does not match any portfolio member"), "{err}");

        // Two spellings of the same member cannot both carry opts.
        let twice = Json::parse(
            r#"{"members": ["random"],
                "member_opts": {"random": {"batch": 8}, "rand": {"batch": 9}}}"#,
        )
        .unwrap();
        assert!(run_method_with("portfolio", &twice, ctx(40), 5).is_err());
    }

    #[test]
    fn nested_portfolio_and_duplicates_rejected() {
        let nested = Json::parse(r#"{"members": ["portfolio"]}"#).unwrap();
        assert!(run_method_with("portfolio", &nested, ctx(50), 1).is_err());
        // An alias duplicating a canonical member is caught too.
        let dup = Json::parse(r#"{"members": ["pso", "pso"]}"#).unwrap();
        assert!(run_method_with("portfolio", &dup, ctx(50), 1).is_err());
        let alias_dup = Json::parse(r#"{"members": ["random", "rand"]}"#).unwrap();
        assert!(run_method_with("portfolio", &alias_dup, ctx(50), 1).is_err());
    }

    #[test]
    fn tiny_budget_degrades_gracefully() {
        // Far fewer samples than members x pulls/rounds: must terminate,
        // never overspend, and still account every eval to a member —
        // under both allocation policies.
        for alloc in ["ucb", "halving"] {
            let opts = Json::parse(&format!(r#"{{"alloc": "{alloc}"}}"#)).unwrap();
            for budget in [1usize, 3, 7, 11] {
                let o = run_method_with("portfolio", &opts, ctx(budget), 2).unwrap();
                assert!(
                    o.evals <= budget,
                    "{alloc} budget {budget} overspent: {}",
                    o.evals
                );
                assert_eq!(
                    o.members.iter().map(|m| m.evals).sum::<usize>(),
                    o.evals,
                    "{alloc} budget {budget}: member evals must sum exactly"
                );
            }
        }
    }

    #[test]
    fn portfolio_listed_in_registry() {
        assert!(ALL_METHODS.contains(&"portfolio"));
    }

    #[test]
    fn suspended_portfolio_resumes_to_identical_outcome() {
        use super::super::resolve;
        use crate::search::{Progress, SearchControl};
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        // Both allocation policies must survive a mid-slice suspension
        // bit-identically (the bandit additionally round-trips its
        // pull/reward bookkeeping).
        for alloc in ["ucb", "halving"] {
            let opts = Json::parse(&format!(r#"{{"alloc": "{alloc}"}}"#)).unwrap();
            let spec = resolve("portfolio").unwrap();

            let a = {
                let mut c = ctx(900);
                let mut opt = spec.build(&opts).unwrap();
                opt.run(&mut c, 11);
                let mut o = c.outcome("portfolio");
                opt.annotate(&mut o);
                o
            };

            // Same race, but an observer raises the suspend flag halfway
            // through; the in-flight member pauses mid-slice.
            let flag = Arc::new(AtomicBool::new(false));
            let obs_flag = flag.clone();
            let mut c = ctx(900).with_observer(Some(Box::new(move |p: &Progress| {
                if p.evals >= 450 {
                    obs_flag.store(true, Ordering::SeqCst);
                }
                SearchControl::Continue
            })));
            c.set_suspend_flag(Some(flag.clone()));
            let mut opt = spec.build(&opts).unwrap();
            opt.run(&mut c, 11);
            assert!(c.used() < 900, "{alloc}: race should have paused before the budget");

            // Round-trip the race state (cursor + every live member's own
            // checkpoint) through actual JSON text, restore into a fresh
            // portfolio, and finish the run.
            let state = Json::parse(&opt.suspend().unwrap().dumps()).unwrap();
            let mut resumed = spec.build(&opts).unwrap();
            resumed.resume(&state).unwrap();

            flag.store(false, Ordering::SeqCst);
            c.set_observer(None);
            resumed.run(&mut c, 11);
            let mut b = c.outcome("portfolio");
            resumed.annotate(&mut b);

            assert_eq!(a.evals, b.evals, "{alloc}");
            assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits(), "{alloc}");
            assert_eq!(a.curve, b.curve, "{alloc}");
            assert_eq!(
                a.members, b.members,
                "{alloc}: per-member accounting must survive suspension"
            );
            let member_sum: usize = b.members.iter().map(|m| m.evals).sum();
            assert_eq!(member_sum, b.evals, "{alloc}: member evals must still sum");
        }
    }

    #[test]
    fn suspend_with_stateless_member_mid_race_is_refused() {
        use super::super::resolve;

        // `mcts` has no checkpointable state; once it has run a slice the
        // race cannot be suspended faithfully, so suspend() must refuse
        // rather than silently restart the member on resume.
        let opts =
            Json::parse(r#"{"members": ["mcts", "random"], "rounds": 1}"#).unwrap();
        let spec = resolve("portfolio").unwrap();
        let mut opt = spec.build(&opts).unwrap();
        assert!(opt.suspend().is_some(), "fresh portfolio has nothing mid-state");
        let mut c = ctx(60);
        opt.run(&mut c, 9);
        assert!(opt.suspend().is_none(), "live stateless member must block suspend");
    }
}
