//! End-to-end observability acceptance: tracing must not perturb search
//! trajectories, traces must be deterministic once wall-clock fields are
//! stripped, a metrics scope must account for exactly the run it was
//! attached to, and plain library runs must leave the process-global
//! registry untouched.

use sparsemap::api::{RunOpts, SearchRequest};
use sparsemap::obs::{self, read_trace, Metrics, TRACE_SCHEMA};
use sparsemap::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn trace_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("sparsemap_obs_accept");
    let _ = std::fs::create_dir_all(&dir);
    dir.join(format!("{}_{}.ndjson", name, std::process::id()))
}

fn arm(seed: u64) -> SearchRequest {
    SearchRequest::new()
        .workload_named("mm1")
        .platform_named("mobile")
        .method("random")
        .budget(300)
        .seed(seed)
}

/// Trace lines with every wall-clock field stripped (`ms` on all
/// records, `wall_s` on `finish`) and `stages` records reduced to their
/// per-stage sample counts (the latency values are wall time).
fn normalized_lines(path: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(path).unwrap();
    read_trace(&text)
        .unwrap()
        .into_iter()
        .map(|mut rec| {
            if let Json::Obj(o) = &mut rec {
                o.remove("ms");
                o.remove("wall_s");
                if let Some(Json::Obj(stages)) = o.get_mut("stages") {
                    for snap in stages.values_mut() {
                        let count = snap.get("count").cloned().unwrap_or(Json::Null);
                        *snap = count;
                    }
                }
            }
            rec.dumps()
        })
        .collect()
}

#[test]
fn tracing_is_trajectory_neutral_and_deterministic_modulo_timing() {
    let plain = arm(21).build().unwrap().run().unwrap();

    let run_traced = |path: &Path| {
        let _ = std::fs::remove_file(path);
        arm(21)
            .build()
            .unwrap()
            .run_opts(RunOpts { trace: Some(path.to_path_buf()), ..Default::default() })
            .unwrap()
    };
    let p1 = trace_path("det_a");
    let p2 = trace_path("det_b");
    let a = run_traced(&p1);
    let b = run_traced(&p2);

    // Tracing is a pure observer: the report is bit-identical to an
    // untraced run of the same request.
    for traced in [&a, &b] {
        assert_eq!(traced.outcome.best_edp.to_bits(), plain.outcome.best_edp.to_bits());
        assert_eq!(traced.outcome.curve, plain.outcome.curve);
        assert_eq!(traced.outcome.evals, plain.outcome.evals);
    }

    // And the trace itself is deterministic once wall-clock fields are
    // stripped: two runs of the same seeded request agree line for line.
    let la = normalized_lines(&p1);
    let lb = normalized_lines(&p2);
    assert!(la.len() > 3, "start + generations + stages + finish: {la:?}");
    assert_eq!(la, lb);

    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);
}

#[test]
fn metrics_scope_accounts_for_exactly_its_run() {
    let m = Arc::new(Metrics::new());
    let report = arm(5)
        .build()
        .unwrap()
        .run_opts(RunOpts { metrics: Some(Arc::clone(&m)), ..Default::default() })
        .unwrap();

    // The scope's counters mirror the report's outcome exactly.
    assert_eq!(m.evals.get(), report.outcome.evals as u64);
    assert_eq!(m.valid_evals.get(), report.outcome.valid_evals as u64);
    assert_eq!(m.eval_cache_hits.get(), report.outcome.cache_hits as u64);
    assert_eq!(m.batches.get(), report.outcome.batches as u64);
    assert!(m.batches.get() > 0, "a 300-eval run evaluates batches");
    assert!(m.stage_ns[0].snapshot().count > 0, "decode latency was sampled");
    assert_eq!(m.best_edp.get(), report.outcome.best_edp);

    // The same numbers round-trip through the Prometheus renderer.
    let text = m.render_prometheus();
    assert!(text.contains(&format!("sparsemap_evals_total {}", report.outcome.evals)), "{text}");
    assert!(text.contains("sparsemap_stage_seconds_bucket{stage=\"decode\""), "{text}");
}

#[test]
fn plain_library_runs_leave_the_global_registry_untouched() {
    // Library calls are unobserved unless a scope is attached: no test
    // in this binary touches `obs::global()`, including the traced and
    // scoped runs above (tracing gets a *private* scope).
    arm(9).build().unwrap().run().unwrap();
    let g = obs::global();
    assert_eq!(g.evals.get(), 0);
    assert_eq!(g.batches.get(), 0);
    assert_eq!(g.stage_ns[0].snapshot().count, 0);
}

#[test]
fn trace_records_carry_schema_and_outcome() {
    let path = trace_path("schema");
    let _ = std::fs::remove_file(&path);
    let report = arm(13)
        .build()
        .unwrap()
        .run_opts(RunOpts { trace: Some(path.clone()), ..Default::default() })
        .unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let records = read_trace(&text).unwrap();
    assert!(records.iter().all(|r| r.get("v").and_then(Json::as_str) == Some(TRACE_SCHEMA)));
    let finish = records.last().unwrap();
    assert_eq!(finish.get("ev").and_then(Json::as_str), Some("finish"));
    assert_eq!(
        finish.get("evals").and_then(Json::as_u64),
        Some(report.outcome.evals as u64)
    );
    let summary = obs::summarize(&text).unwrap();
    assert!(summary.contains("mm1@mobile"), "{summary}");
    assert!(summary.contains("stage latency"), "{summary}");
    assert!(summary.contains("convergence"), "{summary}");
    let _ = std::fs::remove_file(&path);
}
