//! Particle Swarm Optimization baseline (§III.C).
//!
//! Standard global-best PSO over a continuous relaxation of the *raw*
//! (direct-encoded) design space — see [`super::space`] for why the
//! classical baselines do not get SparseMap's prime-factor encoding.
//! Positions live in `[lo, hi]` per gene and decode by rounding;
//! constants follow Clerc's constriction values.

use super::space::DirectSpace;
use crate::search::{EvalContext, Outcome};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct PsoConfig {
    pub swarm: usize,
    pub inertia: f64,
    pub c1: f64,
    pub c2: f64,
}

impl Default for PsoConfig {
    fn default() -> Self {
        PsoConfig { swarm: 40, inertia: 0.729, c1: 1.494, c2: 1.494 }
    }
}

fn decode(pos: &[f64], space: &DirectSpace) -> Vec<u32> {
    (0..space.len()).map(|i| space.snap(i, pos[i])).collect()
}

/// Config-parameterized core against a borrowed context (the registry /
/// portfolio entry point; telemetry accumulates in `ctx`).
pub fn pso_with(ctx: &mut EvalContext, cfg: &PsoConfig, seed: u64) {
    // The registry schema enforces swarm >= 1; floor it here too so a
    // direct caller can't hit the empty-swarm indexing below.
    let cfg = PsoConfig { swarm: cfg.swarm.max(1), ..*cfg };
    let space = DirectSpace::new(ctx, seed);
    let mut rng = Pcg64::seeded(seed);
    let n = space.len();
    let lo: Vec<f64> = (0..n).map(|i| space.bounds(i).0 as f64).collect();
    let hi: Vec<f64> = (0..n).map(|i| space.bounds(i).1 as f64).collect();

    // Positions start at feasible-looking points (small-divisor-biased
    // samples): per-level tile factors multiply up to the dimension, so a
    // uniform start overshoots and the whole swarm would begin dead.
    let mut pos: Vec<Vec<f64>> = (0..cfg.swarm)
        .map(|_| (0..n).map(|i| space.sample_action(i, &mut rng) as f64).collect())
        .collect();
    let mut vel: Vec<Vec<f64>> = (0..cfg.swarm)
        .map(|_| (0..n).map(|i| (hi[i] - lo[i]) * (rng.f64() - 0.5) * 0.05).collect())
        .collect();
    let mut pbest = pos.clone();
    let mut pbest_cost = vec![f64::INFINITY; cfg.swarm];
    let mut gbest = pos[0].clone();
    let mut gbest_cost = f64::INFINITY;

    while !ctx.exhausted() {
        let genomes: Vec<Vec<u32>> = pos.iter().map(|p| decode(p, &space)).collect();
        let results = space.eval(ctx, &genomes);
        for (i, r) in results.iter().enumerate() {
            let cost = if r.valid { r.edp } else { f64::INFINITY };
            if cost < pbest_cost[i] {
                pbest_cost[i] = cost;
                pbest[i] = pos[i].clone();
            }
            if cost < gbest_cost {
                gbest_cost = cost;
                gbest = pos[i].clone();
            }
        }
        if results.len() < cfg.swarm {
            break;
        }
        for i in 0..cfg.swarm {
            for d in 0..n {
                let r1 = rng.f64();
                let r2 = rng.f64();
                vel[i][d] = cfg.inertia * vel[i][d]
                    + cfg.c1 * r1 * (pbest[i][d] - pos[i][d])
                    + cfg.c2 * r2 * (gbest[d] - pos[i][d]);
                let vmax = (hi[d] - lo[d]) * 0.5;
                vel[i][d] = vel[i][d].clamp(-vmax, vmax);
                pos[i][d] = (pos[i][d] + vel[i][d]).clamp(lo[d], hi[d]);
            }
        }
    }
}

pub fn pso(mut ctx: EvalContext, seed: u64) -> Outcome {
    pso_with(&mut ctx, &PsoConfig::default(), seed);
    ctx.outcome("pso")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.3, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn pso_runs_within_budget() {
        let o = pso(ctx(1_000), 5);
        assert!(o.evals <= 1_000);
        assert_eq!(o.method, "pso");
    }

    #[test]
    fn decode_clamps_to_bounds() {
        let c = ctx(10);
        let space = DirectSpace::new(&c, 1);
        let below = vec![-10.0; space.len()];
        let above = vec![1e9; space.len()];
        for g in [decode(&below, &space), decode(&above, &space)] {
            for (i, &v) in g.iter().enumerate() {
                let (lo, hi) = space.bounds(i);
                assert!(v >= lo && v <= hi, "gene {i} value {v} not in [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn pso_struggles_with_raw_space_validity() {
        // The paper's point: classical optimizers waste most of the
        // budget on invalid (tiling-violating) points.
        let o = pso(ctx(2_000), 6);
        assert!(o.valid_ratio() < 0.6, "valid ratio {}", o.valid_ratio());
    }
}
