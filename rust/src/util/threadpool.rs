//! A small fixed-size thread pool with a parallel-map primitive.
//!
//! No `tokio`/`rayon` in the offline vendor set; search drivers only need
//! fork–join over independent work items (e.g. one search arm per seed, or
//! chunked population evaluation), which this covers with `std::thread` +
//! channels.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `n` threads (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("sparsemap-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender) }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Apply `f` to every item of `items` in parallel on `pool`, preserving
/// order. `f` must be cloneable across threads (wrap captured state in
/// `Arc`). Results are collected via a channel; panics in workers surface
/// as a panic here (missing results).
pub fn parallel_map<T, R, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let r = f(item);
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut got = 0;
    while let Ok((i, r)) = rx.recv() {
        out[i] = Some(r);
        got += 1;
    }
    assert_eq!(got, n, "worker panicked; {}/{} results received", got, n);
    out.into_iter().map(|r| r.unwrap()).collect()
}

/// Sequential fallback used when determinism across thread counts is
/// required (e.g. golden-file tests of search trajectories).
pub fn serial_map<T, R, F: Fn(T) -> R>(items: Vec<T>, f: F) -> Vec<R> {
    items.into_iter().map(f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = parallel_map(&pool, (0..64).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = parallel_map(&pool, Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn matches_serial() {
        let pool = ThreadPool::new(5);
        let xs: Vec<u64> = (1..200).collect();
        let p = parallel_map(&pool, xs.clone(), |x| x.pow(2) % 97);
        let s = serial_map(xs, |x| x.pow(2) % 97);
        assert_eq!(p, s);
    }
}
