//! Skipping/Gating mechanisms (sparse acceleration features, Fig. 6).
//!
//! At each of the three sites — GLB (L2), PE buffer (L3) and the compute
//! unit (C) — the accelerator may apply one of seven S/G choices encoded
//! by a single gene (0..6, the table under Fig. 13):
//!
//! | gene | mechanism        | meaning                                      |
//! |------|------------------|----------------------------------------------|
//! | 0    | None             | process everything                           |
//! | 1    | Gate P←Q         | idle P-side work when the Q operand is zero  |
//! | 2    | Gate Q←P         | idle Q-side work when the P operand is zero  |
//! | 3    | Gate P↔Q         | idle both when either is zero                |
//! | 4    | Skip P←Q         | jump over P work for zero Q operands         |
//! | 5    | Skip Q←P         | jump over Q work for zero P operands         |
//! | 6    | Skip/Gate P↔Q    | double-sided intersection                    |
//!
//! Gating saves energy only; skipping saves energy *and* cycles (it needs
//! the driving operand's metadata to find the next effectual element).

/// Decoded S/G mechanism at one site.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SgMechanism {
    None,
    GatePfromQ,
    GateQfromP,
    GateBoth,
    SkipPfromQ,
    SkipQfromP,
    SkipBoth,
}

pub const NUM_SG_CHOICES: u32 = 7;

impl SgMechanism {
    pub fn from_gene(g: u32) -> SgMechanism {
        match g % NUM_SG_CHOICES {
            0 => SgMechanism::None,
            1 => SgMechanism::GatePfromQ,
            2 => SgMechanism::GateQfromP,
            3 => SgMechanism::GateBoth,
            4 => SgMechanism::SkipPfromQ,
            5 => SgMechanism::SkipQfromP,
            _ => SgMechanism::SkipBoth,
        }
    }

    pub fn gene(self) -> u32 {
        match self {
            SgMechanism::None => 0,
            SgMechanism::GatePfromQ => 1,
            SgMechanism::GateQfromP => 2,
            SgMechanism::GateBoth => 3,
            SgMechanism::SkipPfromQ => 4,
            SgMechanism::SkipQfromP => 5,
            SgMechanism::SkipBoth => 6,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SgMechanism::None => "None",
            SgMechanism::GatePfromQ => "Gate P<-Q",
            SgMechanism::GateQfromP => "Gate Q<-P",
            SgMechanism::GateBoth => "Gate P<->Q",
            SgMechanism::SkipPfromQ => "Skip P<-Q",
            SgMechanism::SkipQfromP => "Skip Q<-P",
            SgMechanism::SkipBoth => "Skip/Gate P<->Q",
        }
    }

    pub fn is_skip(self) -> bool {
        matches!(self, SgMechanism::SkipPfromQ | SgMechanism::SkipQfromP | SgMechanism::SkipBoth)
    }

    pub fn is_gate(self) -> bool {
        matches!(self, SgMechanism::GatePfromQ | SgMechanism::GateQfromP | SgMechanism::GateBoth)
    }

    pub fn double_sided(self) -> bool {
        matches!(self, SgMechanism::GateBoth | SgMechanism::SkipBoth)
    }

    /// Which operand's metadata *drives* the decision (must be available
    /// in compressed form for skipping): returns (needs_P, needs_Q).
    pub fn drivers(self) -> (bool, bool) {
        match self {
            SgMechanism::None => (false, false),
            SgMechanism::GatePfromQ | SgMechanism::SkipPfromQ => (false, true),
            SgMechanism::GateQfromP | SgMechanism::SkipQfromP => (true, false),
            SgMechanism::GateBoth | SgMechanism::SkipBoth => (true, true),
        }
    }
}

/// Fractions of work that remain effectual after applying a mechanism,
/// given operand densities `dp`, `dq`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SgEffect {
    /// Fraction of P-side traffic/work still *energized*.
    pub p_energy: f64,
    /// Fraction of Q-side traffic/work still energized.
    pub q_energy: f64,
    /// Fraction of cycles still spent (1.0 for gating — it cannot shorten
    /// the schedule).
    pub cycles: f64,
}

/// Effect of a mechanism at a transfer/compute site.
pub fn effect(m: SgMechanism, dp: f64, dq: f64) -> SgEffect {
    let both = dp * dq; // fraction of positions where both are nonzero
    match m {
        SgMechanism::None => SgEffect { p_energy: 1.0, q_energy: 1.0, cycles: 1.0 },
        SgMechanism::GatePfromQ => SgEffect { p_energy: dq, q_energy: 1.0, cycles: 1.0 },
        SgMechanism::GateQfromP => SgEffect { p_energy: 1.0, q_energy: dp, cycles: 1.0 },
        SgMechanism::GateBoth => SgEffect { p_energy: both, q_energy: both, cycles: 1.0 },
        SgMechanism::SkipPfromQ => SgEffect { p_energy: dq, q_energy: 1.0, cycles: dq },
        SgMechanism::SkipQfromP => SgEffect { p_energy: 1.0, q_energy: dp, cycles: dp },
        SgMechanism::SkipBoth => SgEffect { p_energy: both, q_energy: both, cycles: both },
    }
}

/// Relative hardware overhead (control energy per effectual word) of the
/// mechanism — double-sided intersection needs look-ahead comparators
/// (ExTensor-style), single-sided needs a simple metadata scanner, gating
/// a mere enable signal.
pub fn control_overhead(m: SgMechanism) -> f64 {
    match m {
        SgMechanism::None => 0.0,
        SgMechanism::GatePfromQ | SgMechanism::GateQfromP => 0.02,
        SgMechanism::GateBoth => 0.04,
        SgMechanism::SkipPfromQ | SgMechanism::SkipQfromP => 0.10,
        SgMechanism::SkipBoth => 0.25,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gene_roundtrip() {
        for g in 0..NUM_SG_CHOICES {
            assert_eq!(SgMechanism::from_gene(g).gene(), g);
        }
    }

    #[test]
    fn gating_never_saves_cycles() {
        for m in [SgMechanism::GatePfromQ, SgMechanism::GateQfromP, SgMechanism::GateBoth] {
            assert_eq!(effect(m, 0.3, 0.4).cycles, 1.0);
            assert!(m.is_gate() && !m.is_skip());
        }
    }

    #[test]
    fn skipping_saves_cycles_proportional_to_driver() {
        let e = effect(SgMechanism::SkipPfromQ, 0.9, 0.2);
        assert_eq!(e.cycles, 0.2); // driven by Q's density
        assert_eq!(e.p_energy, 0.2);
        assert_eq!(e.q_energy, 1.0);
    }

    #[test]
    fn double_sided_is_strongest() {
        let dp = 0.3;
        let dq = 0.4;
        let both = effect(SgMechanism::SkipBoth, dp, dq);
        let one = effect(SgMechanism::SkipPfromQ, dp, dq);
        assert!(both.cycles < one.cycles);
        assert!(both.p_energy <= one.p_energy);
        let (skip_both, skip_one) = (SgMechanism::SkipBoth, SgMechanism::SkipPfromQ);
        assert!(control_overhead(skip_both) > control_overhead(skip_one));
    }

    #[test]
    fn drivers_match_semantics() {
        assert_eq!(SgMechanism::SkipPfromQ.drivers(), (false, true));
        assert_eq!(SgMechanism::GateQfromP.drivers(), (true, false));
        assert_eq!(SgMechanism::SkipBoth.drivers(), (true, true));
        assert_eq!(SgMechanism::None.drivers(), (false, false));
    }

    #[test]
    fn dense_operands_neutralize() {
        for g in 0..NUM_SG_CHOICES {
            let e = effect(SgMechanism::from_gene(g), 1.0, 1.0);
            assert_eq!(e.p_energy, 1.0);
            assert_eq!(e.q_energy, 1.0);
            assert_eq!(e.cycles, 1.0);
        }
    }
}
