//! The paper's full workload suite (Table III): 15 SpMM + 13 SpConv.
//!
//! Densities are verbatim from the table. Sizes printed in the paper with
//! a "K" suffix are resolved to concrete power-of-two-friendly values
//! (92K → 92160, 7.7K → 7680, ...), documented per row; the DSE behaviour
//! depends only on extents/densities, not on the authors' exact rounding.

use super::spconv::{lower_conv, ConvShape};
use super::Workload;

/// All Table III workloads, mm1..mm15 then conv1..conv13.
pub fn all() -> Vec<Workload> {
    let mut v = spmm_suite();
    v.extend(spconv_suite());
    v
}

/// Look up a Table III workload by id (e.g. "mm3", "conv4").
pub fn by_id(id: &str) -> Option<Workload> {
    all().into_iter().find(|w| w.id == id)
}

/// The 15 SpMM rows (DeepBench + sparseGPT-derived).
pub fn spmm_suite() -> Vec<Workload> {
    // (id, M, K(shared), N, dP, dQ). Operand1 is M×K, operand2 K×N; the
    // table lists each operand's own shape — the shared middle extent is
    // the contraction K.
    let rows: &[(&str, u64, u64, u64, f64, f64)] = &[
        ("mm1", 124, 124, 124, 0.785, 0.785),
        ("mm2", 171, 92_160, 171, 0.209, 0.209),
        ("mm3", 730, 730, 730, 0.118, 0.118), // DeepBench "bibd" class
        ("mm4", 7_680, 2_560, 7_680, 0.050, 0.050),
        ("mm5", 9_216, 9_216, 9_216, 0.041, 0.041),
        ("mm6", 2_560, 2_560, 2_560, 0.011, 0.011),
        ("mm7", 1_632, 4_608, 1_632, 0.003, 0.003),
        ("mm8", 2_048, 12_288, 128, 1.0, 0.50), // sparseGPT MHA/MLP rows
        ("mm9", 2_048, 12_288, 49_152, 1.0, 0.50),
        ("mm10", 2_048, 49_152, 12_288, 1.0, 0.50),
        ("mm11", 128, 1_024, 128, 0.006, 0.006),
        ("mm12", 768, 64, 768, 0.059, 0.059),
        ("mm13", 12_288, 24_576, 12_288, 0.010, 0.010),
        ("mm14", 256, 512, 2_048, 0.328, 0.718),
        ("mm15", 1_024, 16_384, 16_384, 0.600, 0.780),
    ];
    rows.iter()
        .map(|&(id, m, k, n, dp, dq)| Workload::spmm(id, m, k, n, dp, dq))
        .collect()
}

/// The 13 SpConv rows (VGG16-style pruned layers; operand1 = activations
/// C×H×W, operand2 = weights Kout×C×R×S, densities verbatim).
pub fn spconv_suite() -> Vec<Workload> {
    let rows: &[(&str, u64, u64, u64, u64, u64, u64, f64, f64)] = &[
        // id,           C,   H,  W, Kout,  R, S, d_act, d_wgt
        ("conv1", 3, 32, 32, 64, 3, 3, 1.0, 0.546),
        ("conv2", 64, 32, 32, 256, 1, 1, 0.450, 0.252),
        ("conv3", 128, 16, 16, 512, 1, 1, 0.396, 0.366),
        ("conv4", 128, 16, 16, 128, 3, 3, 0.477, 0.647),
        ("conv5", 1_024, 8, 8, 256, 1, 1, 0.402, 0.501),
        ("conv6", 256, 8, 8, 256, 3, 3, 0.430, 0.617),
        ("conv7", 512, 4, 4, 2_048, 1, 1, 0.590, 0.118),
        ("conv8", 128, 64, 64, 512, 4, 4, 0.400, 0.300),
        ("conv9", 128, 64, 64, 64, 1, 1, 1.0, 0.200),
        ("conv10", 256, 64, 64, 512, 1, 1, 0.400, 0.250),
        ("conv11", 4, 32, 32, 64, 3, 3, 0.340, 0.146),
        ("conv12", 1_024, 4, 4, 64, 1, 1, 0.790, 0.118),
        ("conv13", 256, 16, 16, 128, 1, 1, 0.902, 0.051),
    ];
    rows.iter()
        .map(|&(id, c, h, w, kout, r, s, da, dw)| {
            lower_conv(id, ConvShape { c, h, w, kout, r, s }, da, dw)
        })
        .collect()
}

/// Convenience: the VGG16 conv layers used by Fig. 17.
pub fn vgg16_convs() -> Vec<Workload> {
    spconv_suite()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadKind, TENSOR_P, TENSOR_Q};

    #[test]
    fn suite_sizes() {
        assert_eq!(spmm_suite().len(), 15);
        assert_eq!(spconv_suite().len(), 13);
        assert_eq!(all().len(), 28);
    }

    #[test]
    fn unique_ids() {
        let mut ids: Vec<String> = all().iter().map(|w| w.id.clone()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 28);
    }

    #[test]
    fn lookup() {
        let w = by_id("mm3").unwrap();
        assert_eq!(w.dims[0].size, 730);
        assert!((w.tensors[TENSOR_P].density.avg() - 0.118).abs() < 1e-12);
        assert!(by_id("nope").is_none());
    }

    #[test]
    fn densities_in_range() {
        for w in all() {
            for t in &w.tensors {
                let d = t.density.avg();
                assert!(d > 0.0 && d <= 1.0, "{}: {}", w.id, d);
                assert!(t.density.validate().is_ok(), "{}", w.id);
            }
        }
    }

    #[test]
    fn conv_rows_are_gemms() {
        let w = by_id("conv4").unwrap();
        assert_eq!(w.kind, WorkloadKind::SpConv);
        // conv4: 128 out-ch, K = 128*3*3, N = 16*16.
        assert_eq!(w.dims[0].size, 128);
        assert_eq!(w.dims[1].size, 128 * 9);
        assert_eq!(w.dims[2].size, 256);
        assert!((w.tensors[TENSOR_P].density.avg() - 0.647).abs() < 1e-12);
        assert!((w.tensors[TENSOR_Q].density.avg() - 0.477).abs() < 1e-12);
    }

    #[test]
    fn mm8_dense_operand() {
        let w = by_id("mm8").unwrap();
        assert_eq!(w.tensors[TENSOR_P].density.avg(), 1.0);
        assert_eq!(w.tensors[TENSOR_Q].density.avg(), 0.5);
    }

    #[test]
    fn all_dims_factorizable() {
        for w in all() {
            for d in &w.dims {
                assert!(!d.factors.is_empty(), "{}: dim {} has no factors", w.id, d.name);
                assert_eq!(d.factors.iter().product::<u64>(), d.padded);
            }
        }
    }
}
