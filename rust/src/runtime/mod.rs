//! PJRT runtime: load and execute the AOT artifacts from the search hot
//! path. Python never runs here — `make artifacts` produced HLO text at
//! build time; this module compiles it once per process and executes it
//! per population batch.

pub mod client;
pub mod evaluator;

pub use client::{artifacts_dir, ArtifactMeta, Runtime};
pub use evaluator::{BatchEvaluator, SpmmDemo};
