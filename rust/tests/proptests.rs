//! Property-based tests (hand-rolled: seeded random generation + invariant
//! checks, proptest-style) over the coordinator's core invariants.

use sparsemap::arch::{Boundary, Platform};
use sparsemap::genome::{decode, ops, tensor_ranks, GenomeSpec};
use sparsemap::mapping::{loopnest, permutation, MapLevel};
use sparsemap::memory::{
    decode_file, dist2, header_bytes, salvage_file, AnnIndex, MemRecord, EMBED_DIM,
};
use sparsemap::model::{evaluate_features, extract, platform_vector, NativeEvaluator};
use sparsemap::sparse::{stack_storage, stack_storage_model, RankFormat};
use sparsemap::sparsity::DensityModel;
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::{table3, Workload, WorkloadKind, TENSOR_P, TENSOR_Q, TENSOR_Z};

fn random_workload(rng: &mut Pcg64) -> Workload {
    let dims: Vec<u64> = (0..3).map(|_| 1 << rng.range_u32(2, 9)).collect();
    let dp = 0.01 + rng.f64() * 0.99;
    let dq = 0.01 + rng.f64() * 0.99;
    Workload::spmm("prop", dims[0], dims[1], dims[2], dp, dq)
}

/// Invariant: decoding any in-range genome yields a mapping that tiles
/// every dimension exactly (the PFCE guarantee) with aligned format
/// stacks, for arbitrary workloads.
#[test]
fn prop_decode_total_and_constraint_preserving() {
    let mut rng = Pcg64::seeded(101);
    for _ in 0..40 {
        let w = random_workload(&mut rng);
        let spec = GenomeSpec::for_workload(&w);
        for _ in 0..50 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            assert!(d.mapping.respects(&w));
            for t in 0..3 {
                assert_eq!(
                    d.strategy.formats[t].len(),
                    tensor_ranks(&d.mapping, &w, t).len()
                );
            }
        }
    }
}

/// Invariant: genetic operators never leave the genome's valid ranges.
#[test]
fn prop_operators_preserve_ranges() {
    let mut rng = Pcg64::seeded(102);
    for _ in 0..20 {
        let w = random_workload(&mut rng);
        let spec = GenomeSpec::for_workload(&w);
        let mut a = spec.random(&mut rng);
        let b = spec.random(&mut rng);
        for _ in 0..30 {
            let (c1, c2) = ops::onepoint_crossover(&a, &b, &mut rng);
            assert!(spec.in_range(&c1) && spec.in_range(&c2));
            ops::point_mutation(&spec, &mut a, 0.3, &mut rng);
            assert!(spec.in_range(&a));
            let i = rng.index(spec.len());
            ops::nudge_gene(&spec, &mut a, i, &mut rng);
            assert!(spec.in_range(&a));
        }
    }
}

/// Invariant: Cantor encoding is a bijection on every rank d ∈ {2..5} and
/// adjacent codes are closer (Kendall tau) on average than random pairs.
#[test]
fn prop_cantor_bijection_and_locality() {
    for d in 2..=5usize {
        let total = permutation::factorial(d);
        let mut seen = std::collections::HashSet::new();
        for code in 1..=total {
            let p = permutation::decode(code, d);
            assert_eq!(permutation::encode(&p), code);
            seen.insert(p);
        }
        assert_eq!(seen.len() as u64, total);
    }
    // Locality: mean tau between adjacent codes < mean tau between random
    // code pairs (d = 4).
    let d = 4;
    let total = permutation::factorial(d);
    let adj: f64 = (1..total)
        .map(|c| {
            permutation::kendall_tau(
                &permutation::decode(c, d),
                &permutation::decode(c + 1, d),
            ) as f64
        })
        .sum::<f64>()
        / (total - 1) as f64;
    let mut rng = Pcg64::seeded(103);
    let rand: f64 = (0..200)
        .map(|_| {
            let a = 1 + rng.below(total);
            let b = 1 + rng.below(total);
            permutation::kendall_tau(
                &permutation::decode(a, d),
                &permutation::decode(b, d),
            ) as f64
        })
        .sum::<f64>()
        / 200.0;
    assert!(adj < rand, "adjacent tau {adj} >= random tau {rand}");
}

/// Invariant: traffic accounting is conservative — every tensor's DRAM
/// traffic is at least its tile size × 1 and at most the full dense
/// iteration-space traffic.
#[test]
fn prop_traffic_bounds() {
    let mut rng = Pcg64::seeded(104);
    for _ in 0..25 {
        let w = random_workload(&mut rng);
        let spec = GenomeSpec::for_workload(&w);
        for _ in 0..40 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            for t in [TENSOR_P, TENSOR_Q] {
                let tile = loopnest::tile_elems(&d.mapping, &w, t, Boundary::DramGlb);
                let mult = loopnest::input_multiplicity(&d.mapping, &w, t, Boundary::DramGlb);
                let traffic = tile * mult;
                assert!(traffic + 1e-9 >= w.tensor_elems(t), "tensor read less than once");
                assert!(
                    traffic <= w.total_ops() + 1e-9,
                    "traffic {traffic} exceeds dense op count {}",
                    w.total_ops()
                );
            }
            let ztraf = loopnest::output_traffic_elems(&d.mapping, &w, Boundary::DramGlb);
            assert!(ztraf + 1e-9 >= w.tensor_elems(TENSOR_Z));
        }
    }
}

/// Invariant: denser workloads never get *cheaper* total energy under the
/// same design (monotonicity of the sparsity model).
#[test]
fn prop_energy_monotone_in_density() {
    let mut rng = Pcg64::seeded(105);
    for _ in 0..20 {
        let m = 1u64 << rng.range_u32(3, 7);
        let spec_w = Workload::spmm("a", m, m, m, 0.2, 0.2);
        let spec = GenomeSpec::for_workload(&spec_w);
        let g = spec.random(&mut rng);
        let mut last = 0.0;
        for d in [0.05, 0.2, 0.5, 0.9] {
            let w = Workload::spmm("a", m, m, m, d, d);
            let ev = NativeEvaluator::new(w, Platform::mobile());
            let design = decode(&ev.spec, &ev.workload, &g);
            let cb = ev.breakdown(&design);
            assert!(
                cb.energy_pj >= last * 0.999,
                "energy decreased with density: {} -> {}",
                last,
                cb.energy_pj
            );
            last = cb.energy_pj;
        }
    }
}

/// Invariant: the feature-vector formula equals the native breakdown —
/// `evaluate_features` is deterministic and pure.
#[test]
fn prop_evaluate_features_pure() {
    let mut rng = Pcg64::seeded(106);
    let w = table3::by_id("mm3").unwrap();
    let plat = Platform::cloud();
    let spec = GenomeSpec::for_workload(&w);
    let pv = platform_vector(&plat);
    for _ in 0..100 {
        let g = spec.random(&mut rng);
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &plat);
        let a = evaluate_features(&f, &pv);
        let b = evaluate_features(&f, &pv);
        assert_eq!(a, b);
    }
}

/// Invariant: spatial fanout at a level equals the product of per-tensor
/// distinct × multicast decomposition for each tensor.
#[test]
fn prop_spatial_decomposition() {
    let mut rng = Pcg64::seeded(107);
    for _ in 0..25 {
        let w = random_workload(&mut rng);
        let spec = GenomeSpec::for_workload(&w);
        let g = spec.random(&mut rng);
        let d = decode(&spec, &w, &g);
        for level in [MapLevel::L2S, MapLevel::L3S] {
            let fanout = d.mapping.fanout(level);
            for t in 0..3 {
                let distinct = loopnest::spatial_distinct(&d.mapping, &w, t, level);
                assert!(fanout % distinct == 0, "distinct must divide fanout");
            }
        }
    }
}

/// One random instance of every density-model variant at a shared mean
/// density (where the variant permits pinning it).
fn random_density_models(rng: &mut Pcg64) -> Vec<DensityModel> {
    let d = 0.01 + rng.f64() * 0.98;
    let mut buckets: Vec<f64> = (0..1 + rng.index(31)).map(|_| rng.f64()).collect();
    buckets.push(d); // at least one strictly positive bucket
    vec![
        DensityModel::uniform(d),
        DensityModel::block(1 + rng.below(128), d),
        DensityModel::banded(1 + rng.below(64), 64 + rng.below(1024)),
        DensityModel::row_skewed(rng.f64() * 0.9, d),
        DensityModel::measured(buckets),
    ]
}

/// Invariant: every density model's occupancy statistics are proper
/// probabilities/densities — `avg`, `slot_prob` and `occupancy_quantile`
/// in [0, 1], quantiles non-decreasing in `q`, `sizing_ratio` a finite
/// multiplier >= 1.
#[test]
fn prop_density_model_occupancies_in_unit_interval() {
    let mut rng = Pcg64::seeded(109);
    for _ in 0..60 {
        for m in random_density_models(&mut rng) {
            assert!(m.validate().is_ok(), "{}", m.describe());
            let avg = m.avg();
            assert!((0.0..=1.0).contains(&avg) && avg > 0.0, "{}", m.describe());
            let mut tile = 1.0f64;
            while tile <= 10e6 {
                let p = m.slot_prob(tile);
                assert!((0.0..=1.0).contains(&p), "{}: slot_prob {p}", m.describe());
                let mut last_q = 0.0f64;
                for q in [0.0, 0.25, 0.5, 0.75, 0.95, 1.0] {
                    let v = m.occupancy_quantile(tile, q);
                    assert!((0.0..=1.0).contains(&v), "{}: quantile {v}", m.describe());
                    assert!(v + 1e-12 >= last_q, "{}: quantile not monotone", m.describe());
                    last_q = v;
                }
                let r = m.sizing_ratio(tile);
                assert!(r.is_finite() && r >= 1.0, "{}: ratio {r}", m.describe());
                tile *= 7.0;
            }
        }
    }
}

/// Invariant: expected tile nonzeros and per-slot occupancy are monotone
/// in the tile/slot size for every model.
#[test]
fn prop_density_model_monotone_in_tile_size() {
    let mut rng = Pcg64::seeded(111);
    for _ in 0..60 {
        for m in random_density_models(&mut rng) {
            let mut last_nnz = 0.0f64;
            let mut last_p = 0.0f64;
            let mut tile = 1.0f64;
            while tile <= 10e6 {
                let nnz = m.tile_nonzeros(tile);
                let p = m.slot_prob(tile);
                assert!(nnz + 1e-12 >= last_nnz, "{}: nonzeros shrank", m.describe());
                assert!(nnz <= tile + 1e-9, "{}: more nonzeros than slots", m.describe());
                assert!(p + 1e-12 >= last_p, "{}: slot_prob shrank", m.describe());
                last_nnz = nnz;
                last_p = p;
                tile *= 3.0;
            }
        }
    }
}

/// Invariant: `Uniform(d)` reproduces the legacy scalar-density path
/// exactly — same storage-model bits, a sizing ratio of exactly 1, and
/// workloads built through the scalar and model constructors are
/// identical values.
#[test]
fn prop_uniform_reproduces_legacy_scalar_path() {
    let mut rng = Pcg64::seeded(112);
    const FMTS: [RankFormat; 5] = [
        RankFormat::Uncompressed,
        RankFormat::Bitmask,
        RankFormat::Rle,
        RankFormat::CoordinatePayload,
        RankFormat::UncompressedOffsetPair,
    ];
    for _ in 0..300 {
        let d = 0.001 + rng.f64() * 0.999;
        let extents: Vec<u64> = (0..1 + rng.index(3)).map(|_| 1 + rng.below(256)).collect();
        let formats: Vec<RankFormat> =
            extents.iter().map(|_| FMTS[rng.index(FMTS.len())]).collect();
        let legacy = stack_storage(&extents, &formats, d);
        let model = stack_storage_model(&extents, &formats, &DensityModel::uniform(d));
        assert_eq!(legacy.0.to_bits(), model.0.to_bits());
        assert_eq!(legacy.1.to_bits(), model.1.to_bits());
        let m = DensityModel::uniform(d);
        assert_eq!(m.avg().to_bits(), d.to_bits());
        assert_eq!(m.sizing_ratio(1.0 + rng.f64() * 1e6), 1.0);
    }
    // Workload-level parity: the scalar constructor is exactly the
    // Uniform model path.
    let dims = vec![("M".to_string(), 48), ("K".to_string(), 96), ("N".to_string(), 32)];
    let scalar = Workload::custom(
        "u",
        WorkloadKind::SpMM,
        dims.clone(),
        vec![
            ("P".to_string(), vec![0, 1], 0.3),
            ("Q".to_string(), vec![1, 2], 0.7),
            ("Z".to_string(), vec![0, 2], 0.0),
        ],
        vec![1],
    )
    .unwrap();
    let modeled = Workload::custom_models(
        "u",
        WorkloadKind::SpMM,
        dims,
        vec![
            ("P".to_string(), vec![0, 1], Some(DensityModel::uniform(0.3))),
            ("Q".to_string(), vec![1, 2], Some(DensityModel::uniform(0.7))),
            ("Z".to_string(), vec![0, 2], None),
        ],
        vec![1],
    )
    .unwrap();
    assert_eq!(scalar, modeled);
    let ev = NativeEvaluator::new(scalar.clone(), Platform::mobile());
    let em = NativeEvaluator::new(modeled, Platform::mobile());
    let mut rng = Pcg64::seeded(113);
    for _ in 0..50 {
        let g = ev.spec.random(&mut rng);
        assert_eq!(
            ev.eval_genome(&g).edp.to_bits(),
            em.eval_genome(&g).edp.to_bits()
        );
    }
}

fn random_mem_record(rng: &mut Pcg64) -> MemRecord {
    let mut embed = [0.0f64; EMBED_DIM];
    for v in embed.iter_mut() {
        *v = rng.normal();
    }
    MemRecord {
        tag: format!("w{}@p{}#m{}", rng.below(50), rng.below(8), rng.below(4)),
        best_edp: if rng.chance(0.05) { f64::INFINITY } else { rng.f64() * 1e12 },
        evals: rng.below(1 << 20) as u32,
        valid_evals: rng.below(1 << 20) as u32,
        seed: rng.next_u64(),
        embed,
        genome: (0..1 + rng.index(48)).map(|_| rng.range_u32(0, 5000)).collect(),
    }
}

fn random_embed(rng: &mut Pcg64) -> [f64; EMBED_DIM] {
    let mut e = [0.0f64; EMBED_DIM];
    for v in e.iter_mut() {
        *v = rng.normal();
    }
    e
}

/// Exact top-k by squared distance, the reference the index must match:
/// rank by `(dist2, id)` exactly as `AnnIndex::query` documents.
fn exact_top_k(corpus: &[[f64; EMBED_DIM]], q: &[f64; EMBED_DIM], k: usize) -> Vec<u32> {
    let mut ranked: Vec<(f64, u32)> =
        corpus.iter().enumerate().map(|(i, e)| (dist2(e, q), i as u32)).collect();
    ranked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    ranked.truncate(k);
    ranked.into_iter().map(|(_, id)| id).collect()
}

/// Invariant: any memory record round-trips through the on-disk encoding
/// bit-exactly, alone and inside a multi-record file.
#[test]
fn prop_memory_record_round_trips_bit_exactly() {
    let mut rng = Pcg64::seeded(201);
    let mut file = header_bytes().to_vec();
    let mut recs = Vec::new();
    for _ in 0..200 {
        let rec = random_mem_record(&mut rng);
        let bytes = rec.encode();
        let (back, used) = MemRecord::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, rec);
        assert_eq!(back.best_edp.to_bits(), rec.best_edp.to_bits());
        for (a, b) in back.embed.iter().zip(&rec.embed) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        if recs.len() < 40 {
            file.extend_from_slice(&bytes);
            recs.push(rec);
        }
    }
    assert_eq!(decode_file(&file).unwrap(), recs);
}

/// Invariant: a truncated or corrupted store never silently yields
/// different data — every cut mid-record rejects, and every single-byte
/// flip either rejects or decodes to the identical records.
#[test]
fn prop_memory_store_rejects_truncation_and_corruption() {
    let mut rng = Pcg64::seeded(202);
    for _ in 0..8 {
        let recs: Vec<MemRecord> =
            (0..1 + rng.index(4)).map(|_| random_mem_record(&mut rng)).collect();
        let mut file = header_bytes().to_vec();
        for r in &recs {
            file.extend_from_slice(&r.encode());
        }
        assert_eq!(decode_file(&file).unwrap(), recs);
        // Cuts at exact record boundaries legitimately parse as a
        // shorter file; every other proper prefix must reject.
        let mut boundaries = vec![16usize];
        for r in &recs {
            boundaries.push(boundaries.last().unwrap() + r.encode().len());
        }
        for _ in 0..30 {
            let cut = 17 + rng.index(file.len() - 17);
            if boundaries.contains(&cut) {
                continue;
            }
            assert!(decode_file(&file[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        // 60 random byte flips.
        for _ in 0..60 {
            let i = rng.index(file.len());
            let bit = 1u8 << rng.index(8);
            let mut evil = file.clone();
            evil[i] ^= bit;
            if let Ok(back) = decode_file(&evil) {
                assert_eq!(back, recs, "flip of bit {bit:#x} at byte {i} changed the data");
            }
        }
    }
}

/// Invariant: salvage never yields a partial record. For *every* cut
/// point of a multi-record file, `salvage_file` recovers exactly the
/// wholly-contained records, reports `valid_len` at the last record
/// boundary at or before the cut, and flags damage iff the cut is not a
/// boundary — so crash recovery can only lose the record being written,
/// never corrupt an earlier one.
#[test]
fn prop_salvage_recovers_exactly_the_whole_record_prefix() {
    let mut rng = Pcg64::seeded(204);
    for _ in 0..4 {
        let recs: Vec<MemRecord> = (0..5).map(|_| random_mem_record(&mut rng)).collect();
        let mut file = header_bytes().to_vec();
        let mut boundaries = vec![file.len()];
        for r in &recs {
            file.extend_from_slice(&r.encode());
            boundaries.push(file.len());
        }
        // Any cut inside the header is unrecoverable by design.
        for cut in 0..boundaries[0] {
            assert!(salvage_file(&file[..cut]).is_err(), "header cut {cut} salvaged");
        }
        for cut in boundaries[0]..=file.len() {
            let s = salvage_file(&file[..cut]).unwrap();
            let n_whole = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
            assert_eq!(s.records, recs[..n_whole], "cut at {cut}");
            assert_eq!(s.valid_len, boundaries[n_whole], "cut at {cut}");
            assert_eq!(
                s.damage.is_some(),
                !boundaries.contains(&cut),
                "cut at {cut}: damage flag must mark exactly the non-boundary cuts"
            );
        }
    }
}

/// Invariant: the ANN index returns exactly the brute-force top-k (same
/// ids, same order) for arbitrary corpora and queries at pinned seeds.
#[test]
fn prop_ann_top_k_matches_brute_force() {
    let mut rng = Pcg64::seeded(203);
    for _ in 0..10 {
        let n = 1 + rng.index(512);
        let corpus: Vec<[f64; EMBED_DIM]> = (0..n).map(|_| random_embed(&mut rng)).collect();
        let index = AnnIndex::build(&corpus);
        for _ in 0..10 {
            let q = random_embed(&mut rng);
            let k = 1 + rng.index(12);
            assert_eq!(index.query(&q, k), exact_top_k(&corpus, &q, k), "n={n} k={k}");
        }
    }
}

/// Invariant: inserting records one at a time is indistinguishable from
/// building the index over the full corpus — including past the
/// brute-force cutoff where the LSH buckets take over — and queries are
/// deterministic across identically-built instances.
#[test]
fn prop_ann_incremental_insert_consistent_with_batch_build() {
    let mut rng = Pcg64::seeded(204);
    for round in 0..4 {
        // Cover both sides of the exact-scan cutoff (512).
        let n = if round % 2 == 0 { 40 + rng.index(200) } else { 530 + rng.index(200) };
        let corpus: Vec<[f64; EMBED_DIM]> = (0..n).map(|_| random_embed(&mut rng)).collect();
        let batch = AnnIndex::build(&corpus);
        let mut incremental = AnnIndex::new();
        for (i, e) in corpus.iter().enumerate() {
            assert_eq!(incremental.insert(*e), i as u32);
        }
        assert_eq!(incremental.len(), batch.len());
        for _ in 0..10 {
            let q = random_embed(&mut rng);
            let k = 1 + rng.index(10);
            let got = incremental.query(&q, k);
            assert_eq!(got, batch.query(&q, k), "n={n} k={k}");
            assert_eq!(got, AnnIndex::build(&corpus).query(&q, k), "rebuild differs");
            // Results come back nearest-first.
            let d: Vec<f64> = got.iter().map(|&i| dist2(&corpus[i as usize], &q)).collect();
            assert!(d.windows(2).all(|w| w[0] <= w[1]), "not sorted by distance");
        }
    }
}

/// Invariant: EvalContext budget accounting is exact under arbitrary
/// interleavings of batch sizes.
#[test]
fn prop_budget_accounting_exact() {
    let mut rng = Pcg64::seeded(108);
    for _ in 0..10 {
        let w = random_workload(&mut rng);
        let budget = 50 + rng.index(300);
        let mut ctx = sparsemap::search::EvalContext::new(
            sparsemap::search::Backend::native(w, Platform::edge()),
            budget,
        );
        let spec = ctx.spec.clone();
        let mut submitted = 0;
        while !ctx.exhausted() {
            let n = 1 + rng.index(40);
            let genomes: Vec<Vec<u32>> = (0..n).map(|_| spec.random(&mut rng)).collect();
            let got = ctx.eval_batch(&genomes).len();
            submitted += got;
            assert_eq!(ctx.used(), submitted);
            assert!(got == n || ctx.exhausted());
        }
        assert_eq!(ctx.used(), budget);
    }
}
