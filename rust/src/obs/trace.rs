//! `sparsemap.trace.v1` — streaming NDJSON search traces.
//!
//! A trace is one JSON record per line, every record carrying the
//! schema tag (`"v": "sparsemap.trace.v1"`), an event kind (`"ev"`) and
//! a wall-clock offset in milliseconds (`"ms"`). Event kinds:
//!
//! * `start` — run header: workload, platform, method, budget, seed.
//! * `generation` — one per evaluated batch, mirrored off the
//!   [`SearchObserver`] stream: evals, valid evals, cache/stage hits,
//!   interned count, best EDP. **Deterministic modulo the `ms` field** —
//!   two runs of the same seeded request produce identical generation
//!   records.
//! * `stages` — a snapshot of the per-phase latency histograms from the
//!   run's [`Metrics`] scope (decode/mapping/format/assemble).
//! * `marker` — checkpoint/resume lifecycle markers.
//! * `finish` — final outcome summary.
//!
//! [`TraceWriter`] streams records through a buffered file;
//! [`TraceObserver`] tees an [`EvalContext`](crate::search::EvalContext)
//! observer slot into it, so tracing composes with any caller-supplied
//! observer. `summarize` renders a written trace back into a per-stage
//! latency table and a generation convergence curve
//! (`sparsemap trace summarize <file>`).

use super::metrics::{Metrics, STAGE_NAMES};
use crate::search::{Progress, SearchControl, SearchObserver};
use crate::util::json::Json;
use crate::util::table::{sci, Table};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Schema tag carried by every trace record.
pub const TRACE_SCHEMA: &str = "sparsemap.trace.v1";

/// Streaming NDJSON trace writer. Each emit is one line, flushed with
/// the underlying `BufWriter`'s policy; [`TraceWriter::finish`] flushes
/// explicitly. IO errors after creation are deliberately swallowed by
/// the callers (a failing trace must never abort a search).
pub struct TraceWriter {
    w: BufWriter<File>,
    t0: Instant,
}

impl TraceWriter {
    pub fn create(path: &Path) -> std::io::Result<TraceWriter> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        Ok(TraceWriter { w: BufWriter::new(File::create(path)?), t0: Instant::now() })
    }

    /// Emit one record: `{"v", "ev", "ms", ...fields}`.
    pub fn event(&mut self, ev: &str, fields: Vec<(&str, Json)>) -> std::io::Result<()> {
        let ms = self.t0.elapsed().as_millis() as f64;
        let mut pairs =
            vec![("v", Json::str(TRACE_SCHEMA)), ("ev", Json::str(ev)), ("ms", Json::num(ms))];
        pairs.extend(fields);
        writeln!(self.w, "{}", Json::obj(pairs).dumps())
    }

    /// Run header.
    pub fn start(
        &mut self,
        workload: &str,
        platform: &str,
        method: &str,
        budget: usize,
        seed: u64,
    ) -> std::io::Result<()> {
        self.event(
            "start",
            vec![
                ("workload", Json::str(workload)),
                ("platform", Json::str(platform)),
                ("method", Json::str(method)),
                ("budget", Json::num(budget as f64)),
                ("seed", Json::num(seed as f64)),
            ],
        )
    }

    /// One generation summary off the observer stream.
    pub fn generation(&mut self, p: &Progress) -> std::io::Result<()> {
        let best = if p.best_edp.is_finite() { Json::num(p.best_edp) } else { Json::Null };
        self.event(
            "generation",
            vec![
                ("batch", Json::num(p.batches as f64)),
                ("evals", Json::num(p.evals as f64)),
                ("valid_evals", Json::num(p.valid_evals as f64)),
                ("cache_hits", Json::num(p.cache_hits as f64)),
                ("interned", Json::num(p.interned as f64)),
                ("stage_hits", Json::num(p.stage_hits as f64)),
                ("budget", Json::num(p.budget as f64)),
                ("best_edp", best),
            ],
        )
    }

    /// Snapshot the per-stage latency histograms of this run's metrics
    /// scope (sample units are nanoseconds; serialized in seconds),
    /// plus the batched-pipeline histograms: `brood_size` (dimensionless
    /// submissions per batch) and `soa_slice` (SoA cost-model sweep wall
    /// time, seconds). One `stages` record carries all of them.
    pub fn stages(&mut self, m: &Metrics) -> std::io::Result<()> {
        let mut stages: Vec<(&str, Json)> = STAGE_NAMES
            .iter()
            .zip(&m.stage_ns)
            .map(|(name, h)| (*name, h.snapshot().to_json(1e-9)))
            .collect();
        stages.push(("brood_size", m.brood_size.snapshot().to_json(1.0)));
        stages.push(("soa_slice", m.soa_slice_ns.snapshot().to_json(1e-9)));
        self.event("stages", vec![("stages", Json::obj(stages))])
    }

    /// Checkpoint/resume lifecycle marker.
    pub fn marker(&mut self, kind: &str, fields: Vec<(&str, Json)>) -> std::io::Result<()> {
        let mut all = vec![("kind", Json::str(kind))];
        all.extend(fields);
        self.event("marker", all)
    }

    /// Final outcome summary; flushes the stream.
    pub fn finish(
        &mut self,
        best_edp: f64,
        evals: usize,
        wall_s: f64,
        stopped_early: bool,
    ) -> std::io::Result<()> {
        let best = if best_edp.is_finite() { Json::num(best_edp) } else { Json::Null };
        self.event(
            "finish",
            vec![
                ("best_edp", best),
                ("evals", Json::num(evals as f64)),
                ("wall_s", Json::num(wall_s)),
                ("stopped_early", Json::Bool(stopped_early)),
            ],
        )?;
        self.w.flush()
    }
}

/// Observer tee: writes a `generation` record per batch, then delegates
/// to the wrapped observer (if any) for flow control. Attached by
/// [`SearchSession::run_opts`](crate::api::SearchSession) when
/// [`RunOpts::trace`](crate::api::RunOpts) is set.
pub struct TraceObserver {
    writer: Arc<Mutex<TraceWriter>>,
    inner: Option<Box<dyn SearchObserver>>,
}

impl TraceObserver {
    pub fn new(
        writer: Arc<Mutex<TraceWriter>>,
        inner: Option<Box<dyn SearchObserver>>,
    ) -> TraceObserver {
        TraceObserver { writer, inner }
    }
}

impl SearchObserver for TraceObserver {
    fn on_batch(&mut self, progress: &Progress) -> SearchControl {
        if let Ok(mut w) = self.writer.lock() {
            let _ = w.generation(progress);
        }
        match self.inner.as_mut() {
            Some(obs) => obs.on_batch(progress),
            None => SearchControl::Continue,
        }
    }
}

/// Parse NDJSON trace text into records, validating the schema tag on
/// every line. Blank lines are tolerated (trailing newline).
pub fn read_trace(text: &str) -> Result<Vec<Json>, String> {
    let mut records = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rec = Json::parse(line).map_err(|e| format!("trace line {}: {e}", i + 1))?;
        match rec.get("v").and_then(Json::as_str) {
            Some(TRACE_SCHEMA) => {}
            Some(other) => {
                return Err(format!(
                    "trace line {}: unsupported schema '{other}' (expected {TRACE_SCHEMA})",
                    i + 1
                ))
            }
            None => return Err(format!("trace line {}: missing schema tag 'v'", i + 1)),
        }
        if rec.get("ev").and_then(Json::as_str).is_none() {
            return Err(format!("trace line {}: missing event kind 'ev'", i + 1));
        }
        records.push(rec);
    }
    if records.is_empty() {
        return Err("trace is empty".to_string());
    }
    Ok(records)
}

/// Generation rows rendered by the convergence table before
/// downsampling kicks in.
const MAX_CURVE_ROWS: usize = 20;

/// Render a trace into the human summary behind
/// `sparsemap trace summarize`: run header, per-stage latency table,
/// downsampled generation convergence curve, markers and final outcome.
pub fn summarize(text: &str) -> Result<String, String> {
    let records = read_trace(text)?;
    let mut out = String::new();

    let ev = |r: &Json| r.get("ev").and_then(Json::as_str).unwrap_or("").to_string();
    if let Some(s) = records.iter().find(|r| ev(r) == "start") {
        let f = |k: &str| s.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
        let n = |k: &str| s.get(k).and_then(Json::as_u64).unwrap_or(0);
        out.push_str(&format!(
            "trace: {}@{} method={} budget={} seed={}\n",
            f("workload"),
            f("platform"),
            f("method"),
            n("budget"),
            n("seed")
        ));
    }

    // Per-stage latency: the LAST stages record is the cumulative one.
    if let Some(s) = records.iter().rev().find(|r| ev(r) == "stages") {
        let mut t = Table::new(&["stage", "batches", "mean", "p50", "p95", "max", "total"]);
        if let Some(stages) = s.get("stages").and_then(Json::as_obj) {
            for name in STAGE_NAMES {
                let Some(h) = stages.get(name) else { continue };
                let g = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                t.row(vec![
                    name.to_string(),
                    format!("{}", g("count") as u64),
                    format!("{}s", sci(g("mean"))),
                    format!("{}s", sci(g("p50"))),
                    format!("{}s", sci(g("p95"))),
                    format!("{}s", sci(g("max"))),
                    format!("{}s", sci(g("sum"))),
                ]);
            }
        }
        if !t.is_empty() {
            out.push_str("\nstage latency (per batch):\n");
            out.push_str(&t.render());
        }
        // Batched-pipeline extras ride in the same stages record.
        if let Some(stages) = s.get("stages").and_then(Json::as_obj) {
            if let Some(b) = stages.get("brood_size") {
                let g = |k: &str| b.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                if g("count") > 0.0 {
                    out.push_str(&format!(
                        "brood size: mean {:.1} p50 {} p95 {} max {} ({} batches)\n",
                        g("mean"),
                        g("p50") as u64,
                        g("p95") as u64,
                        g("max") as u64,
                        g("count") as u64
                    ));
                }
            }
            if let Some(h) = stages.get("soa_slice") {
                let g = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                if g("count") > 0.0 {
                    out.push_str(&format!(
                        "soa slice: mean {}s p95 {}s total {}s ({} batches)\n",
                        sci(g("mean")),
                        sci(g("p95")),
                        sci(g("sum")),
                        g("count") as u64
                    ));
                }
            }
        }
    }

    let gens: Vec<&Json> = records.iter().filter(|r| ev(r) == "generation").collect();
    if !gens.is_empty() {
        let stride = gens.len().div_ceil(MAX_CURVE_ROWS).max(1);
        let mut t = Table::new(&["gen", "evals", "best EDP", "cache hits", "stage hits"]);
        for (i, g) in gens.iter().enumerate() {
            if i % stride != 0 && i + 1 != gens.len() {
                continue;
            }
            let n = |k: &str| g.get(k).and_then(Json::as_u64).unwrap_or(0);
            let best = g
                .get("best_edp")
                .and_then(Json::as_f64)
                .map_or("-".to_string(), sci);
            t.row(vec![
                format!("{}", n("batch")),
                format!("{}", n("evals")),
                best,
                format!("{}", n("cache_hits")),
                format!("{}", n("stage_hits")),
            ]);
        }
        out.push_str(&format!("\nconvergence ({} generations):\n", gens.len()));
        out.push_str(&t.render());
    }

    let markers: Vec<String> = records
        .iter()
        .filter(|r| ev(r) == "marker")
        .map(|r| r.get("kind").and_then(Json::as_str).unwrap_or("?").to_string())
        .collect();
    if !markers.is_empty() {
        out.push_str(&format!("\nmarkers: {}\n", markers.join(", ")));
    }

    match records.iter().rev().find(|r| ev(r) == "finish") {
        Some(f) => {
            let best = f
                .get("best_edp")
                .and_then(Json::as_f64)
                .map_or("-".to_string(), sci);
            out.push_str(&format!(
                "\nfinished: best_edp={} evals={} wall={:.3}s{}\n",
                best,
                f.get("evals").and_then(Json::as_u64).unwrap_or(0),
                f.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0),
                if f.get("stopped_early").and_then(Json::as_bool) == Some(true) {
                    " (stopped early)"
                } else {
                    ""
                },
            ));
        }
        None => out.push_str("\n(no finish record — truncated trace?)\n"),
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::STAGE_MAPPING;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sparsemap-trace-{}-{tag}.ndjson", std::process::id()))
    }

    fn progress(batch: usize, evals: usize, best: f64) -> Progress {
        Progress {
            batches: batch,
            evals,
            valid_evals: evals - 1,
            cache_hits: 2,
            interned: evals,
            stage_hits: 4,
            best_edp: best,
            budget: 100,
        }
    }

    #[test]
    fn write_read_summarize_round_trip() {
        let path = tmp_path("roundtrip");
        let m = Metrics::new();
        m.stage_ns[STAGE_MAPPING].record(10_000);
        m.brood_size.record(48);
        m.soa_slice_ns.record(2_000);
        {
            let mut w = TraceWriter::create(&path).unwrap();
            w.start("mm1", "mobile", "es-std", 100, 7).unwrap();
            w.generation(&progress(1, 10, f64::INFINITY)).unwrap();
            w.generation(&progress(2, 20, 3.5)).unwrap();
            w.marker("checkpoint", vec![("evals", Json::num(20.0))]).unwrap();
            w.stages(&m).unwrap();
            w.finish(3.5, 20, 0.01, false).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let records = read_trace(&text).unwrap();
        assert_eq!(records.len(), 6);
        assert!(records.iter().all(|r| r.get("v").and_then(Json::as_str) == Some(TRACE_SCHEMA)));
        // Infinite best EDP serializes as null (JSON has no Inf).
        let g1 = &records[1];
        assert_eq!(g1.get("ev").and_then(Json::as_str), Some("generation"));
        assert_eq!(g1.get("best_edp"), Some(&Json::Null));
        assert_eq!(records[2].get("best_edp").and_then(Json::as_f64), Some(3.5));

        let summary = summarize(&text).unwrap();
        assert!(summary.contains("mm1@mobile"), "{summary}");
        assert!(summary.contains("mapping"), "{summary}");
        assert!(summary.contains("brood size: mean 48.0"), "{summary}");
        assert!(summary.contains("soa slice: mean"), "{summary}");
        assert!(summary.contains("convergence (2 generations)"), "{summary}");
        assert!(summary.contains("markers: checkpoint"), "{summary}");
        assert!(summary.contains("finished: best_edp="), "{summary}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn observer_tee_writes_and_delegates() {
        let path = tmp_path("tee");
        let w = Arc::new(Mutex::new(TraceWriter::create(&path).unwrap()));
        let mut obs = TraceObserver::new(
            Arc::clone(&w),
            Some(Box::new(|p: &Progress| {
                if p.evals >= 20 { SearchControl::Stop } else { SearchControl::Continue }
            })),
        );
        assert_eq!(obs.on_batch(&progress(1, 10, 5.0)), SearchControl::Continue);
        assert_eq!(obs.on_batch(&progress(2, 20, 4.0)), SearchControl::Stop);
        w.lock().unwrap().finish(4.0, 20, 0.0, true).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let records = read_trace(&text).unwrap();
        let gens = records
            .iter()
            .filter(|r| r.get("ev").and_then(Json::as_str) == Some("generation"))
            .count();
        assert_eq!(gens, 2);
        // No inner observer: tracing alone never stops a run.
        let mut bare = TraceObserver::new(Arc::clone(&w), None);
        assert_eq!(bare.on_batch(&progress(3, 30, 4.0)), SearchControl::Continue);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn read_trace_rejects_bad_input() {
        assert!(read_trace("").is_err(), "empty trace");
        assert!(read_trace("{\"ev\": \"start\"}\n").unwrap_err().contains("missing schema"));
        assert!(read_trace("{\"v\": \"other.v9\", \"ev\": \"x\"}\n")
            .unwrap_err()
            .contains("unsupported schema"));
        let ok = format!("{{\"v\": \"{TRACE_SCHEMA}\", \"ev\": \"start\"}}\n");
        assert_eq!(read_trace(&ok).unwrap().len(), 1);
        let noev = format!("{{\"v\": \"{TRACE_SCHEMA}\"}}\n");
        assert!(read_trace(&noev).unwrap_err().contains("missing event kind"));
    }
}
