//! Golden trajectory pins for the optimizer registry.
//!
//! Two guarantees, two mechanisms:
//!
//! 1. **Migration changed nothing** — for every method that predates the
//!    registry, the registry dispatch with default (empty) `method_opts`
//!    must reproduce the legacy free-function wiring bit-for-bit
//!    (`registry_defaults_reproduce_legacy_wrappers`). This is the exact
//!    shape of the old `baselines::run_method` string match.
//! 2. **Trajectories stay pinned across future PRs** — every registry
//!    method's outcome at a fixed scenario/seed/budget is compared
//!    against the committed snapshot `tests/golden/trajectories.json`
//!    (best-EDP bits, eval counts, full convergence curve). Regenerate
//!    after an *intentional* trajectory change with:
//!
//!    ```bash
//!    cd rust && GOLDEN_UPDATE=1 cargo test --release --test golden_trajectories
//!    ```
//!
//!    A snapshot with `"placeholder": true` (no toolchain in the
//!    authoring container) skips the comparison but still exercises
//!    every method and prints the computed snapshot path.

use sparsemap::arch::Platform;
use sparsemap::optimizer::{run_method, ALL_METHODS};
use sparsemap::search::{Backend, EvalContext, Outcome};
use sparsemap::util::json::Json;
use sparsemap::workload::table3;

const GOLDEN_BUDGET: usize = 300;
const GOLDEN_SEED: u64 = 42;
const GOLDEN_WORKLOAD: &str = "mm1";
const GOLDEN_PLATFORM: &str = "mobile";

fn golden_ctx(budget: usize) -> EvalContext {
    let w = table3::by_id(GOLDEN_WORKLOAD).unwrap();
    EvalContext::new(Backend::native(w, Platform::by_name(GOLDEN_PLATFORM).unwrap()), budget)
}

fn outcome_snapshot(o: &Outcome) -> Json {
    Json::obj(vec![
        ("evals", Json::num(o.evals as f64)),
        ("valid_evals", Json::num(o.valid_evals as f64)),
        ("cache_hits", Json::num(o.cache_hits as f64)),
        (
            "best_edp",
            if o.best_edp.is_finite() { Json::num(o.best_edp) } else { Json::Null },
        ),
        // Bit pattern, immune to any float-formatting drift.
        ("best_edp_bits", Json::str(&format!("{:016x}", o.best_edp.to_bits()))),
        (
            "curve",
            Json::Arr(
                o.curve
                    .iter()
                    .map(|&(e, v)| {
                        Json::Arr(vec![
                            Json::num(e as f64),
                            Json::str(&format!("{:016x}", v.to_bits())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn compute_snapshot() -> Json {
    let mut methods: Vec<(String, Json)> = Vec::new();
    for m in ALL_METHODS {
        let o = run_method(m, golden_ctx(GOLDEN_BUDGET), GOLDEN_SEED).unwrap();
        assert_eq!(&o.method, m, "outcome label must be the canonical name");
        assert!(o.evals <= GOLDEN_BUDGET, "{m} overspent");
        methods.push((m.to_string(), outcome_snapshot(&o)));
    }
    Json::obj(vec![
        ("schema", Json::str("sparsemap.golden.v1")),
        ("workload", Json::str(GOLDEN_WORKLOAD)),
        ("platform", Json::str(GOLDEN_PLATFORM)),
        ("budget", Json::num(GOLDEN_BUDGET as f64)),
        ("seed", Json::num(GOLDEN_SEED as f64)),
        (
            "methods",
            Json::Obj(methods.into_iter().collect()),
        ),
    ])
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/trajectories.json")
}

#[test]
fn trajectories_match_golden_snapshot() {
    let path = golden_path();
    let computed = compute_snapshot();
    if std::env::var_os("GOLDEN_UPDATE").is_some() {
        std::fs::write(&path, computed.pretty()).unwrap();
        eprintln!("golden snapshot regenerated at {}", path.display());
        return;
    }
    let committed = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .expect("tests/golden/trajectories.json must parse");
    if committed.get("placeholder").and_then(Json::as_bool) == Some(true) {
        // No measured snapshot committed yet (the authoring container had
        // no toolchain). Leave the computed one where a maintainer can
        // pick it up, and rely on the legacy-wrapper parity pin below.
        let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("target/golden_trajectories.computed.json");
        let _ = std::fs::write(&out, computed.pretty());
        eprintln!(
            "golden snapshot is a placeholder; computed snapshot written to {} — commit it \
             via GOLDEN_UPDATE=1 (see module docs)",
            out.display()
        );
        return;
    }
    for key in ["workload", "platform", "budget", "seed"] {
        assert_eq!(committed.get(key), computed.get(key), "golden scenario field '{key}'");
    }
    let committed_methods = committed.get("methods").and_then(Json::as_obj).unwrap();
    for m in ALL_METHODS {
        let got = computed.get("methods").and_then(|j| j.get(m)).unwrap();
        match committed_methods.get(*m) {
            // A method added after the snapshot was cut: tolerated so the
            // snapshot machinery never blocks adding methods; regenerate
            // to pin it.
            None => eprintln!("note: method '{m}' has no golden entry yet (GOLDEN_UPDATE=1)"),
            Some(want) => assert_eq!(want, got, "trajectory drift for '{m}'"),
        }
    }
}

/// The migration pin: default-config registry dispatch is bit-for-bit
/// the legacy free-function wiring (the old `baselines::run_method`
/// match arms, reproduced here verbatim).
#[test]
fn registry_defaults_reproduce_legacy_wrappers() {
    use sparsemap::baselines as b;
    use sparsemap::es::{run_sparsemap, EsConfig, EsVariant};
    let budget = 200;
    let seed = 7;
    let legacy: Vec<(&str, fn(EvalContext, u64) -> Outcome)> = vec![
        ("sparsemap", |ctx, s| run_sparsemap(ctx, EsConfig::default(), s)),
        ("es-pfce", |ctx, s| {
            run_sparsemap(ctx, EsConfig { variant: EsVariant::Pfce, ..EsConfig::default() }, s)
        }),
        ("es-direct", b::es_direct),
        ("random", b::pure_random),
        ("sparseloop", b::sparseloop_mapper),
        ("sage-like", b::sage_like),
        ("pso", b::pso),
        ("mcts", b::mcts),
        ("tbpsa", b::tbpsa),
        ("ppo", b::ppo),
        ("dqn", b::dqn),
    ];
    for (name, f) in legacy {
        let old = f(golden_ctx(budget), seed);
        let new = run_method(name, golden_ctx(budget), seed).unwrap();
        assert_eq!(old.method, new.method, "{name}: label");
        assert_eq!(old.best_edp.to_bits(), new.best_edp.to_bits(), "{name}: best_edp");
        assert_eq!(old.best_genome, new.best_genome, "{name}: best_genome");
        assert_eq!(old.curve, new.curve, "{name}: curve");
        assert_eq!(old.evals, new.evals, "{name}: evals");
        assert_eq!(old.valid_evals, new.valid_evals, "{name}: valid_evals");
        assert_eq!(old.cache_hits, new.cache_hits, "{name}: cache_hits");
    }
}

/// Determinism across the whole registry (the snapshot is only
/// meaningful if repeated runs agree).
#[test]
fn registry_methods_deterministic_at_golden_seed() {
    for m in ALL_METHODS {
        let a = run_method(m, golden_ctx(120), GOLDEN_SEED).unwrap();
        let b = run_method(m, golden_ctx(120), GOLDEN_SEED).unwrap();
        assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits(), "{m}");
        assert_eq!(a.curve, b.curve, "{m}");
        assert_eq!(a.valid_evals, b.valid_evals, "{m}");
    }
}
