//! Minimal JSON reader/writer.
//!
//! The vendor set has no `serde`, so experiment configs, telemetry dumps
//! and the `artifacts/meta.json` contract between the Rust runtime and the
//! Python AOT pipeline use this small self-contained implementation.
//! It supports the full JSON grammar except `\u` surrogate pairs beyond
//! the BMP (sufficient for our ASCII-only artifacts).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in sorted order (BTreeMap) so that
/// serialization is deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- builders --------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::str(s)).collect())
    }

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Bit-exact f64 encoding for checkpoints: the IEEE-754 bit pattern as a
/// 16-hex-digit string. `Json::Num` cannot represent INFINITY/NaN (best-EDP
/// fields start at `f64::INFINITY`) and a decimal round-trip through the
/// writer is not guaranteed bit-identical, so checkpoint floats travel as
/// bits and decode with [`f64_from_bits`].
pub fn f64_bits(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

/// Decode a float written by [`f64_bits`]. `None` for anything that is not
/// a 16-hex-digit string.
pub fn f64_from_bits(j: &Json) -> Option<f64> {
    let s = j.as_str()?;
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    if start + len > self.src.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.src[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.dumps()).unwrap();
            assert_eq!(v, v2, "src={src}");
        }
    }

    #[test]
    fn nested_structure() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
        let rt = Json::parse(&v.dumps()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "tru", "{\"a\"}", "1 2", "{'a':1}"] {
            assert!(Json::parse(src).is_err(), "src={src}");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
        assert_eq!(Json::parse(&v.dumps()).unwrap(), v);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("name", Json::str("t")),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn f64_bits_round_trip() {
        for x in [0.0, -0.0, 1.5, f64::INFINITY, f64::NEG_INFINITY, 1e-308, 3.7e42] {
            let j = f64_bits(x);
            let back = f64_from_bits(&j).unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "x={x}");
        }
        // NaN round-trips by bit pattern even though NaN != NaN.
        let j = f64_bits(f64::NAN);
        assert_eq!(f64_from_bits(&j).unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(f64_from_bits(&Json::str("zz")), None);
        assert_eq!(f64_from_bits(&Json::num(1.0)), None);
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }
}
