//! Benchmark harness (in-tree; the offline vendor set has no criterion).
//!
//! One benchmark per paper table/figure plus microbenchmarks of the two
//! evaluator hot paths. Each benchmark reports median wall time over
//! repeated runs; experiment benches run scaled-down budgets (the full
//! 20k-budget runs are recorded in EXPERIMENTS.md).
//!
//! Run: `cargo bench` (optionally `cargo bench -- <filter> [--quick]`).
//!
//! `--json <file>` additionally writes a machine-readable snapshot
//! (`sparsemap.bench.v1`: name, runs, median/min seconds, items/sec per
//! benchmark) — the format CI archives and `BENCH_*.json` snapshots at
//! the repo root use to track the perf trajectory across PRs. See
//! README "Performance".

use sparsemap::arch::Platform;
use sparsemap::optimizer::run_method;
use sparsemap::model::NativeEvaluator;
use sparsemap::report::{fig10, fig17, fig18, fig2, fig7, patterns, table4, ExpConfig};
use sparsemap::search::{Backend, EvalContext};
use sparsemap::util::json::Json;
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::table3;
use std::time::Instant;

struct Bench {
    name: &'static str,
    runs: usize,
    f: Box<dyn Fn()>,
    /// Work items per run for throughput reporting (0 = none).
    items: usize,
}

fn time_one(f: &dyn Fn()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path: Option<String> = match args.iter().position(|a| a == "--json") {
        Some(i) => match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => Some(v.clone()),
            // A missing or flag-shaped value would otherwise silently
            // skip the snapshot (or write a file named like a flag) —
            // fail loudly instead so CI consumers notice.
            _ => {
                eprintln!("error: --json requires an output file path");
                std::process::exit(2);
            }
        },
        None => None,
    };
    let filter: Vec<&String> = {
        // Drop flags and --json's value from the name filters.
        let json_value_idx = args.iter().position(|a| a == "--json").map(|i| i + 1);
        args.iter()
            .enumerate()
            .filter(|(i, a)| !a.starts_with("--") && Some(*i) != json_value_idx)
            .map(|(_, a)| a)
            .collect()
    };

    let tmp = std::env::temp_dir().join("sm_bench");
    let cfg = |budget: usize| ExpConfig {
        budget,
        seed: 42,
        out_dir: tmp.clone(),
        threads: 8,
        ..Default::default()
    };

    let mut benches: Vec<Bench> = Vec::new();

    // --- microbenchmarks: the two evaluator hot paths ---------------------
    benches.push(Bench {
        name: "native_eval_throughput_mm3_cloud",
        runs: 5,
        items: 20_000,
        f: Box::new(|| {
            let ev = NativeEvaluator::new(table3::by_id("mm3").unwrap(), Platform::cloud());
            let mut rng = Pcg64::seeded(1);
            let mut acc = 0.0f64;
            for _ in 0..20_000 {
                let g = ev.spec.random(&mut rng);
                acc += ev.eval_genome(&g).energy_pj;
            }
            std::hint::black_box(acc);
        }),
    });
    // Parallel population evaluation: the acceptance bar is >= 2x at 4
    // threads vs 1 thread (cache off so every genome hits the model).
    // Genomes and pools are built once, outside the timed closure, so the
    // measurement is the eval_batch call alone.
    let pop_genomes: std::rc::Rc<Vec<Vec<u32>>> = {
        let spec = sparsemap::genome::GenomeSpec::for_workload(&table3::by_id("mm3").unwrap());
        let mut rng = Pcg64::seeded(7);
        std::rc::Rc::new((0..20_000).map(|_| spec.random(&mut rng)).collect())
    };
    for threads in [1usize, 2, 4, 8] {
        let pool = if threads > 1 {
            Some(std::sync::Arc::new(sparsemap::util::threadpool::ThreadPool::new(threads)))
        } else {
            None
        };
        let genomes = pop_genomes.clone();
        benches.push(Bench {
            name: Box::leak(format!("population_eval_20k_mm3_{threads}t").into_boxed_str()),
            runs: 3,
            items: 20_000,
            f: Box::new(move || {
                let mut ctx = EvalContext::new(
                    Backend::native(table3::by_id("mm3").unwrap(), Platform::cloud()),
                    20_000,
                )
                .with_pool(pool.clone())
                .with_cache(false);
                std::hint::black_box(ctx.eval_batch(&genomes));
            }),
        });
    }
    // Cache effectiveness: 40 "generations" re-submitting the same 500
    // genomes — 19.5k of the 20k submissions are served from the cache.
    let cache_genomes = pop_genomes.clone();
    benches.push(Bench {
        name: "cached_reeval_20k_duplicated_population",
        runs: 3,
        items: 20_000,
        f: Box::new(move || {
            let mut ctx = EvalContext::new(
                Backend::native(table3::by_id("mm3").unwrap(), Platform::cloud()),
                20_000,
            );
            let base = &cache_genomes[..500];
            for _ in 0..40 {
                std::hint::black_box(ctx.eval_batch(base));
            }
        }),
    });
    // Staged-engine effectiveness: a 10k-offspring population over 100
    // parents where only the S/G genes mutate — the common ES shape. The
    // `staged_*` arm reuses memoized mapping/format stages through the
    // batched SoA assembly (the engine default); `pergenome_*` is the
    // same staged engine forced onto the per-genome assembly walk
    // (`with_batched(false)`), isolating what the SoA re-layout buys;
    // the `scratch_*` arm is the same population through the
    // from-scratch decode→extract loop (`with_staging(false)`, cache off
    // for all three so every genome is recomputed). staged/scratch is
    // the engine's headline speedup (the `#[ignore]`d test in
    // engine_parity.rs asserts >= 2x on the 100-genome version);
    // staged/pergenome is the batching speedup on top.
    let offspring_pop: std::rc::Rc<Vec<Vec<u32>>> = {
        let w = table3::by_id("mm3").unwrap();
        let spec = sparsemap::genome::GenomeSpec::for_workload(&w);
        let mut rng = Pcg64::seeded(11);
        let parents: Vec<Vec<u32>> = (0..100).map(|_| spec.random(&mut rng)).collect();
        std::rc::Rc::new(
            (0..10_000)
                .map(|i| {
                    let mut g = parents[i % parents.len()].clone();
                    for j in spec.sg_start..spec.len() {
                        g[j] = rng.range_u32(spec.ranges[j].lo, spec.ranges[j].hi);
                    }
                    g
                })
                .collect(),
        )
    };
    for (name, staging, batched) in [
        ("staged_offspring_eval_10k_mm3", true, true),
        ("pergenome_offspring_eval_10k_mm3", true, false),
        ("scratch_offspring_eval_10k_mm3", false, true),
    ] {
        let genomes = offspring_pop.clone();
        benches.push(Bench {
            name,
            runs: 3,
            items: 10_000,
            f: Box::new(move || {
                let mut ctx = EvalContext::new(
                    Backend::native(table3::by_id("mm3").unwrap(), Platform::cloud()),
                    20_000,
                )
                .with_cache(false)
                .with_staging(staging)
                .with_batched(batched);
                std::hint::black_box(ctx.eval_batch(&genomes));
            }),
        });
    }
    // Per-tile occupancy queries on the density models: these run inside
    // every fitness call (per-rank slot probabilities + per-tensor
    // sizing ratios), so they must stay in the tens-of-ns range.
    benches.push(Bench {
        name: "density_model_occupancy_1m_queries",
        runs: 3,
        items: 1_000_000,
        f: Box::new(|| {
            use sparsemap::sparsity::DensityModel;
            let models = [
                DensityModel::uniform(0.1),
                DensityModel::block(64, 0.1),
                DensityModel::banded(102, 1024),
                DensityModel::row_skewed(0.6, 0.1),
                DensityModel::measured((0..32).map(|i| (i as f64 + 0.5) / 64.0).collect()),
            ];
            let tiles = [16.0, 256.0, 4096.0, 65_536.0];
            let mut acc = 0.0f64;
            for i in 0..1_000_000usize {
                let m = &models[i % models.len()];
                let t = tiles[(i / models.len()) % tiles.len()];
                acc += m.slot_prob(t) + m.sizing_ratio(t);
            }
            std::hint::black_box(acc);
        }),
    });
    // Compile the artifact once; the bench measures steady-state
    // batched evaluation (what a search actually pays per generation).
    #[cfg(feature = "xla")]
    {
        let pjrt_ev = std::rc::Rc::new(
            sparsemap::runtime::Runtime::from_default_dir()
                .and_then(|rt| {
                    sparsemap::runtime::BatchEvaluator::new(
                        &rt,
                        table3::by_id("mm3").unwrap(),
                        Platform::cloud(),
                    )
                })
                .expect("run `make artifacts` first"),
        );
        let pjrt_genomes: std::rc::Rc<Vec<Vec<u32>>> = {
            let mut rng = Pcg64::seeded(1);
            std::rc::Rc::new((0..8 * 256).map(|_| pjrt_ev.spec.random(&mut rng)).collect())
        };
        let ev = pjrt_ev.clone();
        let genomes = pjrt_genomes.clone();
        benches.push(Bench {
            name: "pjrt_eval_throughput_mm3_cloud",
            runs: 3,
            items: 8 * 256,
            f: Box::new(move || {
                std::hint::black_box(ev.eval_genomes(&genomes).unwrap());
            }),
        });
    }
    benches.push(Bench {
        name: "sparsemap_search_5k_mm3_cloud",
        runs: 3,
        items: 5_000,
        f: Box::new(|| {
            let ctx = EvalContext::new(
                Backend::native(table3::by_id("mm3").unwrap(), Platform::cloud()),
                5_000,
            );
            std::hint::black_box(run_method("sparsemap", ctx, 42).unwrap());
        }),
    });
    benches.push(Bench {
        name: "portfolio_race_5k_mm3_cloud",
        runs: 3,
        items: 5_000,
        f: Box::new(|| {
            let ctx = EvalContext::new(
                Backend::native(table3::by_id("mm3").unwrap(), Platform::cloud()),
                5_000,
            );
            std::hint::black_box(run_method("portfolio", ctx, 42).unwrap());
        }),
    });
    benches.push(Bench {
        // Registry lookup + opts validation + builder — the dispatch
        // overhead the trait layer added to every arm (should be
        // microseconds against searches that take seconds).
        name: "registry_build_all_methods",
        runs: 5,
        items: sparsemap::optimizer::ALL_METHODS.len(),
        f: Box::new(|| {
            let empty = sparsemap::util::json::Json::Obj(Default::default());
            for m in sparsemap::optimizer::registry() {
                std::hint::black_box(m.build(&empty).unwrap());
            }
        }),
    });

    // --- one bench per table/figure ---------------------------------------
    let c2 = cfg(0);
    benches.push(Bench {
        name: "fig2_interplay_sweep",
        runs: 3,
        items: 0,
        f: Box::new(move || {
            std::hint::black_box(fig2::run(&c2).unwrap());
        }),
    });
    let c7 = cfg(0);
    benches.push(Bench {
        name: "fig7_design_space_scatter_1000",
        runs: 3,
        items: 1000,
        f: Box::new(move || {
            std::hint::black_box(fig7::run(&c7).unwrap());
        }),
    });
    let c10 = cfg(2_000);
    benches.push(Bench {
        name: "fig10_encoding_arms_2k",
        runs: 2,
        items: 4_000,
        f: Box::new(move || {
            std::hint::black_box(fig10::run_arms(&c10));
        }),
    });
    let c17 = cfg(800);
    benches.push(Bench {
        name: "fig17a_method_matrix_conv11_800",
        runs: 2,
        items: 800 * fig17::FIG17_METHODS.len(),
        f: Box::new(move || {
            std::hint::black_box(fig17::run_matrix(&c17, &Platform::cloud(), &["conv11"]));
        }),
    });
    let c17b = cfg(500);
    benches.push(Bench {
        name: "fig17b_valid_ratio_matrix_500",
        runs: 1,
        items: 0,
        f: Box::new(move || {
            std::hint::black_box(fig17::run_b(&c17b).unwrap());
        }),
    });
    let c18 = cfg(1_500);
    benches.push(Bench {
        name: "fig18_ablation_arms_1500",
        runs: 2,
        items: 0,
        f: Box::new(move || {
            std::hint::black_box(fig18::run_arms(&c18));
        }),
    });
    let cpat = cfg(600);
    benches.push(Bench {
        name: "patterns_sweep_3_arms_600",
        runs: 1,
        items: 3 * 600,
        f: Box::new(move || {
            std::hint::black_box(patterns::run_arms(&cpat));
        }),
    });
    let c4 = cfg(1_000);
    benches.push(Bench {
        name: "table4_subset_matrix_1000",
        runs: 1,
        items: 0,
        f: Box::new(move || {
            let wls = vec!["mm1".to_string(), "mm3".to_string(), "conv11".to_string()];
            std::hint::black_box(table4::run_matrix(&c4, &wls));
        }),
    });

    println!("{:<40} {:>10} {:>12} {:>14}", "benchmark", "runs", "median", "throughput");
    let mut rows: Vec<Json> = Vec::new();
    for b in &benches {
        if !filter.is_empty() && !filter.iter().any(|f| b.name.contains(f.as_str())) {
            continue;
        }
        let runs = if quick { 1 } else { b.runs };
        let mut times: Vec<f64> = (0..runs).map(|_| time_one(&b.f)).collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let min = times[0];
        let thr = if b.items > 0 {
            format!("{:>10.0}/s", b.items as f64 / median)
        } else {
            "-".to_string()
        };
        println!("{:<40} {:>10} {:>10.3}s {:>14}", b.name, runs, median, thr);
        rows.push(Json::obj(vec![
            ("name", Json::str(b.name)),
            ("runs", Json::num(runs as f64)),
            ("median_s", Json::num(median)),
            ("min_s", Json::num(min)),
            ("items", Json::num(b.items as f64)),
            (
                "items_per_s",
                if b.items > 0 { Json::num(b.items as f64 / median) } else { Json::Null },
            ),
        ]));
    }
    if let Some(path) = json_path {
        let doc = Json::obj(vec![
            ("schema", Json::str("sparsemap.bench.v1")),
            ("quick", Json::Bool(quick)),
            ("benches", Json::Arr(rows)),
        ]);
        if let Err(e) = std::fs::write(&path, doc.pretty()) {
            eprintln!("error: could not write bench JSON to {path}: {e}");
            std::process::exit(1);
        }
        println!("bench JSON written to {path}");
    }
}
