//! Deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] arms a set of *named fault points* — well-known
//! crash-prone seams in the codebase (see the `points` constants) — with
//! injected failures: I/O errors, torn writes (only the first `k` bytes
//! land before the "crash"), delays, and panics. Production code checks
//! its fault point via [`check`]/[`write_all_at`]/[`fail_io`]; the checks
//! are compiled in always, but when nothing is armed they cost a single
//! relaxed atomic load, so the zero-allocation eval hot path (pinned by
//! `tests/alloc_steady_state.rs`) is untouched.
//!
//! ## Plan grammar
//!
//! A plan is a `;`-separated list of entries:
//!
//! ```text
//! plan  := entry (';' entry)*
//! entry := 'seed=' N                    — seeds derived values (torn cut points)
//!        | point ':' kind
//! kind  := 'error'            ['@' N]   — injected io::Error
//!        | 'torn' [':' K]     ['@' N]   — write first K bytes then fail
//!        | 'delay' ':' MS     ['@' N]   — sleep MS milliseconds, then proceed
//!        | 'panic'            ['@' N]   — panic at the fault point
//! ```
//!
//! `@N` fires the arm on the N-th *hit* of its point (1-based, default 1);
//! each arm fires exactly once. `torn` without an explicit `K` derives a
//! cut point deterministically from the plan seed. Examples:
//!
//! ```text
//! store-append:torn:25@1            tear the first store append after 25 bytes
//! checkpoint-write:error@1          fail the first checkpoint write
//! eval:panic@3                      panic in the third eval batch
//! socket-read:delay:200             stall the first socket read 200 ms
//! seed=7;store-append:torn@1        seeded pseudo-random cut point
//! ```
//!
//! Plans activate process-globally via the `SPARSEMAP_FAULTS` environment
//! variable ([`init_from_env`], called from `main`) or `--fault-plan` on
//! the CLI, and per-run via `api::RunOpts::faults` (tests). Torn writes
//! simulate a crash mid-`write_all`: the injected error message carries a
//! `simulated crash` marker so recovery code that could not possibly run
//! after a real crash (in-process truncate-back, retry loops) can decline
//! to mask the injection — see [`simulates_crash`].

use crate::util::rng::Pcg64;
use anyhow::{bail, Result};
use std::io::{self, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Named fault points. Production seams check exactly one of these.
pub mod points {
    /// `MemoryStore::append` record write (torn tail on disk).
    pub const STORE_APPEND: &str = "store-append";
    /// Any [`crate::util::fsio::atomic_write`] — service job checkpoints
    /// and `memory compact` rewrites both funnel through it.
    pub const CHECKPOINT_WRITE: &str = "checkpoint-write";
    /// Service connection handler, before reading the request.
    pub const SOCKET_READ: &str = "socket-read";
    /// Service connection handler, before writing the response.
    pub const SOCKET_WRITE: &str = "socket-write";
    /// `EvalContext::eval_batch` entry (panic/delay only — the hot path
    /// has no error return).
    pub const EVAL: &str = "eval";
}

/// All valid point names (for parse-time validation and docs).
pub const ALL_POINTS: [&str; 5] = [
    points::STORE_APPEND,
    points::CHECKPOINT_WRITE,
    points::SOCKET_READ,
    points::SOCKET_WRITE,
    points::EVAL,
];

/// What an armed fault does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Return an injected `io::Error`.
    Error,
    /// Write only the first `k` bytes, then fail with a simulated-crash
    /// error (the torn prefix stays on disk, as after `kill -9`).
    Torn(usize),
    /// Sleep for the given milliseconds, then proceed normally.
    Delay(u64),
    /// Panic at the fault point.
    Panic,
}

/// The action a caller must take when its fault point fires. Delays are
/// handled inside [`FaultPlan::check`] (the sleep happens there) and are
/// never surfaced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    Error,
    Torn(usize),
    Panic,
}

struct FaultArm {
    point: String,
    kind: FaultKind,
    /// 1-based hit ordinal at which this arm fires (each arm once).
    at: u64,
    hits: AtomicU64,
}

/// A parsed, seeded set of fault arms. Hit counting is interior-mutable
/// so a plan can be shared (`Arc`) across threads.
pub struct FaultPlan {
    seed: u64,
    arms: Vec<FaultArm>,
}

impl FaultPlan {
    /// Parse the plan grammar (module docs). Unknown points, malformed
    /// kinds and zero ordinals are errors — a typo must not silently arm
    /// nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed = 0u64;
        let mut raw: Vec<(String, String)> = Vec::new();
        for entry in spec.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            if let Some(v) = entry.strip_prefix("seed=") {
                seed = v.trim().parse().map_err(|_| {
                    anyhow::anyhow!("fault plan: bad seed {v:?} (expected an unsigned integer)")
                })?;
                continue;
            }
            let Some((point, kind)) = entry.split_once(':') else {
                bail!("fault plan entry {entry:?}: expected 'point:kind' (or 'seed=N')");
            };
            let point = point.trim();
            if !ALL_POINTS.contains(&point) {
                bail!(
                    "fault plan: unknown point {point:?} (valid: {})",
                    ALL_POINTS.join(", ")
                );
            }
            raw.push((point.to_string(), kind.trim().to_string()));
        }
        // Derived values (torn cut points without an explicit K) come
        // from the plan seed, so a pinned seed pins the whole plan.
        let mut rng = Pcg64::seeded(seed ^ 0xfa17_fa17_fa17_fa17);
        let mut arms = Vec::with_capacity(raw.len());
        for (point, kindspec) in raw {
            let (kindspec, at) = match kindspec.rsplit_once('@') {
                Some((k, n)) => {
                    let at: u64 = n.trim().parse().map_err(|_| {
                        anyhow::anyhow!("fault plan: bad hit ordinal {n:?} in {point}:{kindspec}")
                    })?;
                    if at == 0 {
                        bail!("fault plan: hit ordinals are 1-based ({point}:{kindspec})");
                    }
                    (k.trim().to_string(), at)
                }
                None => (kindspec, 1),
            };
            let (name, arg) = match kindspec.split_once(':') {
                Some((n, a)) => (n.trim(), Some(a.trim())),
                None => (kindspec.as_str(), None),
            };
            let parse_arg = |what: &str| -> Result<u64> {
                match arg {
                    Some(a) => a.parse().map_err(|_| {
                        anyhow::anyhow!("fault plan: bad {what} {a:?} for point {point}")
                    }),
                    None => bail!("fault plan: kind {name:?} at {point} requires :{what}"),
                }
            };
            let kind = match name {
                "error" => FaultKind::Error,
                "panic" => FaultKind::Panic,
                "delay" => FaultKind::Delay(parse_arg("millis")?),
                "torn" => FaultKind::Torn(match arg {
                    Some(_) => parse_arg("cut offset")? as usize,
                    None => 1 + rng.below(255) as usize,
                }),
                other => bail!(
                    "fault plan: unknown kind {other:?} (valid: error, torn, delay, panic)"
                ),
            };
            arms.push(FaultArm { point, kind, at, hits: AtomicU64::new(0) });
        }
        Ok(FaultPlan { seed, arms })
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn is_empty(&self) -> bool {
        self.arms.is_empty()
    }

    /// One-line description for startup logging.
    pub fn describe(&self) -> String {
        let arms: Vec<String> = self
            .arms
            .iter()
            .map(|a| format!("{}:{:?}@{}", a.point, a.kind, a.at))
            .collect();
        format!("seed={} [{}]", self.seed, arms.join(", "))
    }

    /// Register one hit of `point` against this plan. Returns the action
    /// to take if an arm fired; delays sleep here and return `None`.
    pub fn check(&self, point: &str) -> Option<FaultAction> {
        let mut fired = None;
        for arm in &self.arms {
            if arm.point != point {
                continue;
            }
            let hit = arm.hits.fetch_add(1, Ordering::Relaxed) + 1;
            if hit != arm.at {
                continue;
            }
            crate::obs::global().faults_injected.inc();
            match arm.kind {
                FaultKind::Delay(ms) => std::thread::sleep(Duration::from_millis(ms)),
                FaultKind::Error => fired = Some(FaultAction::Error),
                FaultKind::Torn(k) => fired = Some(FaultAction::Torn(k)),
                FaultKind::Panic => fired = Some(FaultAction::Panic),
            }
        }
        fired
    }
}

// Process-global armed plan. `ARMED` is the fast-path gate: disarmed,
// every fault-point check is this one relaxed load and nothing else.
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

/// Arm `plan` process-globally (replacing any previous plan).
pub fn arm(plan: FaultPlan) {
    let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    *slot = Some(Arc::new(plan));
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm: all fault points return to their single-load fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
    let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    *slot = None;
}

/// The currently armed global plan, if any.
pub fn armed_plan() -> Option<Arc<FaultPlan>> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    PLAN.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Arm from `SPARSEMAP_FAULTS` if set and non-empty (called once from
/// `main`). A malformed plan is a startup error, never a silent no-op.
pub fn init_from_env() -> Result<()> {
    if let Ok(spec) = std::env::var("SPARSEMAP_FAULTS") {
        if !spec.trim().is_empty() {
            let plan = FaultPlan::parse(&spec)?;
            eprintln!("fault plan armed from SPARSEMAP_FAULTS: {}", plan.describe());
            arm(plan);
        }
    }
    Ok(())
}

/// Register a hit of `point` against the global plan. Disarmed cost: one
/// relaxed atomic load.
pub fn hit(point: &str) -> Option<FaultAction> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    armed_plan().and_then(|p| p.check(point))
}

/// Register a hit against a caller-held plan if one is attached, else the
/// global plan. This is the hot-path entry: with no local plan and
/// nothing armed it is a `None` branch plus one relaxed load.
pub fn check(local: Option<&Arc<FaultPlan>>, point: &str) -> Option<FaultAction> {
    match local {
        Some(plan) => plan.check(point),
        None => hit(point),
    }
}

fn injected_error(point: &str) -> io::Error {
    io::Error::new(io::ErrorKind::Other, format!("injected fault at point '{point}'"))
}

fn torn_error(point: &str, k: usize) -> io::Error {
    io::Error::new(
        io::ErrorKind::Other,
        format!("injected torn write at point '{point}' ({k} bytes landed; simulated crash)"),
    )
}

/// True when `e` is an injected simulated-crash error (torn write). Such
/// an error models the process dying mid-write: cleanup or retry code
/// that could not run after a real crash checks this to avoid masking
/// the injection.
pub fn simulates_crash(e: &dyn std::fmt::Display) -> bool {
    e.to_string().contains("simulated crash")
}

/// Fail (or panic) at a non-write fault point. `Torn` arms degrade to
/// plain errors here since there is nothing to tear.
pub fn fail_io(point: &str) -> io::Result<()> {
    match hit(point) {
        None => Ok(()),
        Some(FaultAction::Panic) => panic!("injected panic at fault point '{point}'"),
        Some(FaultAction::Error) | Some(FaultAction::Torn(_)) => Err(injected_error(point)),
    }
}

/// `write_all` through the fault point `point`: a `Torn(k)` arm writes
/// (and flushes) only the first `k` bytes before failing with a
/// simulated-crash error, an `Error` arm writes nothing.
pub fn write_all_at<W: Write>(point: &str, w: &mut W, bytes: &[u8]) -> io::Result<()> {
    write_with_action(hit(point), point, w, bytes)
}

fn write_with_action<W: Write>(
    action: Option<FaultAction>,
    point: &str,
    w: &mut W,
    bytes: &[u8],
) -> io::Result<()> {
    match action {
        None => w.write_all(bytes),
        Some(FaultAction::Error) => Err(injected_error(point)),
        Some(FaultAction::Panic) => panic!("injected panic at fault point '{point}'"),
        Some(FaultAction::Torn(k)) => {
            let k = k.min(bytes.len().saturating_sub(1));
            w.write_all(&bytes[..k])?;
            w.flush()?;
            Err(torn_error(point, k))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse(
            "seed=9; store-append:torn:25@2; checkpoint-write:error; eval:panic@3; \
             socket-read:delay:5",
        )
        .unwrap();
        assert_eq!(p.seed(), 9);
        assert_eq!(p.arms.len(), 4);
        assert_eq!(p.arms[0].kind, FaultKind::Torn(25));
        assert_eq!(p.arms[0].at, 2);
        assert_eq!(p.arms[1].kind, FaultKind::Error);
        assert_eq!(p.arms[1].at, 1);
        assert_eq!(p.arms[2].kind, FaultKind::Panic);
        assert_eq!(p.arms[3].kind, FaultKind::Delay(5));
        // Seeded torn cut points are deterministic.
        let a = FaultPlan::parse("seed=7;store-append:torn").unwrap();
        let b = FaultPlan::parse("seed=7;store-append:torn").unwrap();
        assert_eq!(a.arms[0].kind, b.arms[0].kind);
        assert!(matches!(a.arms[0].kind, FaultKind::Torn(k) if k >= 1));
    }

    #[test]
    fn rejects_typos_loudly() {
        assert!(FaultPlan::parse("store-apend:error").is_err(), "unknown point");
        assert!(FaultPlan::parse("eval:explode").is_err(), "unknown kind");
        assert!(FaultPlan::parse("eval:panic@0").is_err(), "zero ordinal");
        assert!(FaultPlan::parse("eval").is_err(), "missing kind");
        assert!(FaultPlan::parse("socket-read:delay").is_err(), "delay needs millis");
        assert!(FaultPlan::parse("seed=x").is_err(), "bad seed");
        assert!(FaultPlan::parse(" ; ; ").unwrap().is_empty(), "blank entries ignored");
    }

    #[test]
    fn arms_fire_on_their_ordinal_exactly_once() {
        let p = FaultPlan::parse("eval:panic@3").unwrap();
        assert_eq!(p.check(points::EVAL), None);
        assert_eq!(p.check(points::EVAL), None);
        assert_eq!(p.check(points::EVAL), Some(FaultAction::Panic));
        assert_eq!(p.check(points::EVAL), None, "arms fire once");
        assert_eq!(p.check(points::STORE_APPEND), None, "other points untouched");
    }

    // These exercise plan-local checks only: unit tests in this binary
    // run in parallel, and arming the *global* plan here would leak
    // injected faults into unrelated memory/service tests. Global
    // arm/disarm semantics are covered by `tests/faults.rs`, which
    // serializes itself.
    #[test]
    fn torn_write_lands_a_prefix_then_fails() {
        let p = FaultPlan::parse("store-append:torn:3").unwrap();
        let mut buf = Vec::new();
        let action = p.check(points::STORE_APPEND);
        let err =
            write_with_action(action, points::STORE_APPEND, &mut buf, b"abcdef").unwrap_err();
        assert_eq!(buf, b"abc");
        assert!(simulates_crash(&err), "{err}");
        // The arm fired; subsequent writes pass through.
        write_with_action(p.check(points::STORE_APPEND), points::STORE_APPEND, &mut buf, b"gh")
            .unwrap();
        assert_eq!(buf, b"abcgh");
    }

    #[test]
    fn torn_cut_is_clamped_below_the_payload_length() {
        let p = FaultPlan::parse("store-append:torn:9999").unwrap();
        let mut buf = Vec::new();
        let err =
            write_with_action(p.check(points::STORE_APPEND), points::STORE_APPEND, &mut buf, b"xy")
                .unwrap_err();
        assert_eq!(buf, b"x", "cut clamps to len-1 so the tear is real");
        assert!(simulates_crash(&err));
    }
}
