//! Fitting a [`DensityModel`] to a real sparse tensor file — the engine
//! behind `sparsemap inspect-tensor <file>`.
//!
//! Two text formats are accepted:
//!
//! * **COO / MatrixMarket** — `%`/`#` comment lines, an optional
//!   `rows cols nnz` header line, then one `row col [value]` entry per
//!   line (values are ignored; indices may be 0- or 1-based).
//! * **SMTX (DLMC-style CSR)** — a `rows, cols, nnz` first line (the
//!   comma marks the format), then `rows + 1` row offsets and `nnz`
//!   column indices as whitespace-separated integers.
//!
//! The fit is a deliberately simple decision cascade (band → block →
//! uniform → power-law rows → empirical histogram); the output is a
//! ready-to-paste `"density"` spec for `run-spec` scenarios.

use super::model::DensityModel;
use anyhow::{anyhow, ensure, Context, Result};

/// Shape and occupancy statistics of a parsed sparse tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorStats {
    pub rows: u64,
    pub cols: u64,
    pub nnz: u64,
    /// Nonzero count per row.
    pub row_nnz: Vec<u64>,
    /// 95th percentile of `|col - row * cols/rows|` (diagonal distance).
    pub p95_band_offset: f64,
    /// Mean length of runs of consecutive nonzero columns within rows.
    pub mean_run_len: f64,
}

impl TensorStats {
    /// Mean element density `nnz / (rows * cols)`.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Per-row densities, ascending.
    pub fn row_densities_sorted(&self) -> Vec<f64> {
        let mut d: Vec<f64> =
            self.row_nnz.iter().map(|&n| n as f64 / self.cols as f64).collect();
        d.sort_by(|a, b| a.total_cmp(b));
        d
    }
}

/// Largest dimension the inspect tool accepts (guards `Vec` allocations
/// sized from untrusted file headers).
pub const MAX_INSPECT_DIM: u64 = 1 << 24;
/// Largest nonzero count the inspect tool accepts.
pub const MAX_INSPECT_NNZ: u64 = 1 << 26;

/// Parse a sparse tensor from COO/MatrixMarket or SMTX text.
pub fn parse_tensor_text(text: &str) -> Result<TensorStats> {
    let data_lines: Vec<&str> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('%') && !l.starts_with('#'))
        .collect();
    ensure!(!data_lines.is_empty(), "tensor file has no data lines");
    let had_comments = text.lines().any(|l| {
        let t = l.trim();
        t.starts_with('%') || t.starts_with('#')
    });
    if data_lines[0].contains(',') {
        parse_smtx(&data_lines)
    } else {
        parse_coo(&data_lines, had_comments)
    }
}

fn int_token(t: &str) -> Result<u64> {
    t.parse::<u64>().map_err(|_| anyhow!("'{t}' is not a non-negative integer"))
}

/// Strict integer tokenization (headers, SMTX bodies, COO indices —
/// negative or fractional values are rejected, never coerced).
fn ints_of(line: &str) -> Result<Vec<u64>> {
    line.split([' ', '\t', ',']).filter(|t| !t.is_empty()).map(int_token).collect()
}

fn parse_smtx(lines: &[&str]) -> Result<TensorStats> {
    let header = ints_of(lines[0])?;
    ensure!(
        header.len() == 3,
        "SMTX header must be 'rows, cols, nnz', got {} fields",
        header.len()
    );
    let (rows, cols, nnz) = (header[0], header[1], header[2]);
    ensure!(rows >= 1 && cols >= 1, "SMTX dimensions must be >= 1");
    ensure!(nnz >= 1, "tensor has no nonzeros");
    ensure!(
        rows <= MAX_INSPECT_DIM && cols <= MAX_INSPECT_DIM && nnz <= MAX_INSPECT_NNZ,
        "SMTX header {rows} x {cols} with {nnz} nonzeros exceeds the inspect-tool \
         limits ({MAX_INSPECT_DIM} per dimension, {MAX_INSPECT_NNZ} nonzeros)"
    );
    let mut body: Vec<u64> = Vec::with_capacity((rows + 1 + nnz) as usize);
    for line in &lines[1..] {
        body.extend(ints_of(line)?);
    }
    ensure!(
        body.len() as u64 == rows + 1 + nnz,
        "SMTX body has {} integers, expected {} offsets + {} column indices",
        body.len(),
        rows + 1,
        nnz
    );
    let offsets = &body[..(rows + 1) as usize];
    let cols_idx = &body[(rows + 1) as usize..];
    ensure!(
        offsets[0] == 0 && *offsets.last().unwrap() == nnz,
        "SMTX row offsets must run 0..nnz"
    );
    let mut entries: Vec<(u64, u64)> = Vec::with_capacity(nnz as usize);
    for r in 0..rows as usize {
        ensure!(
            offsets[r] <= offsets[r + 1] && offsets[r + 1] <= nnz,
            "SMTX row offsets must be non-decreasing and bounded by nnz ({nnz})"
        );
        for &c in &cols_idx[offsets[r] as usize..offsets[r + 1] as usize] {
            ensure!(c < cols, "SMTX column index {c} out of range (cols = {cols})");
            entries.push((r as u64, c));
        }
    }
    Ok(stats_from_entries(rows, cols, entries))
}

fn parse_coo(lines: &[&str], had_comments: bool) -> Result<TensorStats> {
    // A `rows cols nnz` header: always present after MatrixMarket
    // comments; otherwise recognized when the first line is all-integer
    // (a float value field marks a `row col value` entry) and its third
    // field counts the remaining entry lines. An integer-valued
    // headerless first entry that happens to match the line count stays
    // inherently ambiguous — add a header or comment line.
    let first = ints_of(lines[0]);
    let has_header = matches!(
        &first,
        Ok(h) if h.len() == 3 && (had_comments || h[2] == (lines.len() - 1) as u64)
    );
    let first = if has_header { first.unwrap() } else { Vec::new() };
    let entry_lines = if has_header { &lines[1..] } else { lines };
    ensure!(!entry_lines.is_empty(), "tensor has no nonzeros");
    let mut entries: Vec<(u64, u64)> = Vec::with_capacity(entry_lines.len());
    for line in entry_lines {
        let toks: Vec<&str> =
            line.split([' ', '\t', ',']).filter(|t| !t.is_empty()).collect();
        ensure!(
            toks.len() == 2 || toks.len() == 3,
            "COO entries must be 'row col [value]', got '{line}'"
        );
        let r = int_token(toks[0]).with_context(|| format!("row index in '{line}'"))?;
        let c = int_token(toks[1]).with_context(|| format!("column index in '{line}'"))?;
        if let Some(v) = toks.get(2) {
            ensure!(v.parse::<f64>().is_ok(), "'{v}' is not a numeric entry value");
        }
        entries.push((r, c));
    }
    // MatrixMarket is 1-based; plain COO dumps are usually 0-based.
    let one_based = entries.iter().all(|&(r, c)| r >= 1 && c >= 1);
    if one_based {
        for e in &mut entries {
            e.0 -= 1;
            e.1 -= 1;
        }
    }
    let max_r = entries.iter().map(|e| e.0).max().unwrap_or(0);
    let max_c = entries.iter().map(|e| e.1).max().unwrap_or(0);
    let (rows, cols) = if has_header {
        ensure!(
            max_r < first[0] && max_c < first[1],
            "entry index ({max_r}, {max_c}) outside header shape {}x{}",
            first[0],
            first[1]
        );
        (first[0], first[1])
    } else {
        (max_r.saturating_add(1), max_c.saturating_add(1))
    };
    ensure!(
        rows <= MAX_INSPECT_DIM && cols <= MAX_INSPECT_DIM,
        "tensor shape {rows} x {cols} exceeds the inspect-tool limit of \
         {MAX_INSPECT_DIM} per dimension"
    );
    Ok(stats_from_entries(rows, cols, entries))
}

fn stats_from_entries(rows: u64, cols: u64, mut entries: Vec<(u64, u64)>) -> TensorStats {
    entries.sort_unstable();
    entries.dedup();
    let nnz = entries.len() as u64;
    let mut row_nnz = vec![0u64; rows as usize];
    let mut offsets: Vec<f64> = Vec::with_capacity(entries.len());
    let mut runs: u64 = 0;
    let mut prev: Option<(u64, u64)> = None;
    for &(r, c) in &entries {
        row_nnz[r as usize] += 1;
        // Distance from the (rectangular) main diagonal.
        let diag = r as f64 * cols as f64 / rows as f64;
        offsets.push((c as f64 - diag).abs());
        let continues = matches!(prev, Some((pr, pc)) if pr == r && pc + 1 == c);
        if !continues {
            runs += 1;
        }
        prev = Some((r, c));
    }
    offsets.sort_by(|a, b| a.total_cmp(b));
    let p95_band_offset = offsets[((offsets.len() - 1) as f64 * 0.95) as usize];
    let mean_run_len = nnz as f64 / runs.max(1) as f64;
    TensorStats { rows, cols, nnz, row_nnz, p95_band_offset, mean_run_len }
}

/// Fit the best-matching density model: band → block → uniform →
/// power-law rows → empirical histogram.
pub fn fit_model(stats: &TensorStats) -> DensityModel {
    let avg = stats.density().clamp(1e-9, 1.0);
    // Banded: 95% of nonzeros within a band much narrower than the row,
    // AND the band actually filled (a banded model's mean density is
    // bandwidth/cols, so a sparsely-populated diagonal stripe would get
    // a wildly wrong density from it — fall through to the skewed /
    // histogram fits instead).
    let bw_est = (2.0 * stats.p95_band_offset + 1.0).ceil().max(1.0) as u64;
    let band_filled = bw_est as f64 * stats.rows as f64 <= stats.nnz as f64 * 4.0;
    if stats.cols >= 8 && bw_est <= stats.cols / 4 && band_filled {
        return DensityModel::banded(bw_est, stats.cols);
    }
    // Block: long runs of consecutive nonzero columns.
    if stats.mean_run_len >= 2.5 {
        return DensityModel::block(stats.mean_run_len.round() as u64, avg);
    }
    let rd = stats.row_densities_sorted();
    let mean = avg;
    let var = rd.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / rd.len() as f64;
    let cov = var.sqrt() / mean;
    if cov < 0.25 {
        return DensityModel::uniform(avg);
    }
    // Power-law rows: match the P95/mean row-density ratio of the
    // RowSkewed law, (1 - alpha) * 0.05^(-alpha).
    let p95 = rd[((rd.len() - 1) as f64 * 0.95) as usize];
    let target = p95 / mean;
    let mut best = (f64::INFINITY, 0.0);
    for step in 1..90 {
        let alpha = step as f64 / 100.0;
        let ratio = (1.0 - alpha) * 0.05f64.powf(-alpha);
        let err = (ratio - target).abs();
        if err < best.0 {
            best = (err, alpha);
        }
    }
    if best.0 / target.max(1e-9) <= 0.25 {
        return DensityModel::row_skewed(best.1, avg);
    }
    // Fallback: keep the empirical row-density histogram (the
    // constructor quantile-downsamples to its hot-path bucket cap).
    DensityModel::measured(rd)
}

/// Parse, fit and render the full `inspect-tensor` report.
pub fn inspect(text: &str) -> Result<String> {
    let stats = parse_tensor_text(text)?;
    let model = fit_model(&stats);
    Ok(render_report(&stats, &model))
}

/// Human-readable report: shape, fitted model (with the paste-ready spec
/// JSON) and a row-density histogram.
pub fn render_report(stats: &TensorStats, model: &DensityModel) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "tensor: {} x {}, {} nonzeros, density {:.4}\n",
        stats.rows,
        stats.cols,
        stats.nnz,
        stats.density()
    ));
    out.push_str(&format!("fitted model: {}\n", model.describe()));
    out.push_str(&format!("spec JSON:    \"density\": {}\n", model.to_json().dumps()));
    out.push_str("\nrow-density histogram (16 bins over [0, max]):\n");
    let rd: Vec<f64> =
        stats.row_nnz.iter().map(|&n| n as f64 / stats.cols as f64).collect();
    let max = rd.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    let mut bins = [0usize; 16];
    for d in &rd {
        let i = ((d / max) * 16.0).min(15.0) as usize;
        bins[i] += 1;
    }
    let tallest = bins.iter().copied().max().unwrap_or(1).max(1);
    for (i, count) in bins.iter().enumerate() {
        let hi = max * (i + 1) as f64 / 16.0;
        let bar = "#".repeat((count * 40).div_ceil(tallest).min(40));
        out.push_str(&format!("  <= {hi:.4} | {bar} {count}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn coo_text(entries: &[(u64, u64)], header: Option<(u64, u64)>) -> String {
        let mut s = String::new();
        if let Some((r, c)) = header {
            s.push_str("%%MatrixMarket matrix coordinate real general\n");
            s.push_str(&format!("{r} {c} {}\n", entries.len()));
        }
        for &(r, c) in entries {
            // 1-based, MatrixMarket style.
            s.push_str(&format!("{} {} 1.0\n", r + 1, c + 1));
        }
        s
    }

    #[test]
    fn parses_coo_with_and_without_header() {
        let entries = [(0u64, 0u64), (1, 2), (3, 1)];
        for header in [Some((4, 4)), None] {
            let stats = parse_tensor_text(&coo_text(&entries, header)).unwrap();
            assert_eq!(stats.nnz, 3);
            assert_eq!(stats.rows, 4);
            assert_eq!(stats.row_nnz, vec![1, 1, 0, 1]);
        }
    }

    #[test]
    fn headerless_float_entry_is_not_mistaken_for_a_header() {
        // "3 2 1.0" truncates to [3, 2, 1] and the value field happens
        // to equal the remaining line count — the decimal point must
        // mark it as an entry, not a header.
        let stats = parse_tensor_text("3 2 1.0\n1 1 5.0\n").unwrap();
        assert_eq!(stats.nnz, 2);
        // 1-based entries (3,2) and (1,1) -> 0-based rows 0 and 2.
        assert_eq!(stats.rows, 3);
        assert_eq!(stats.row_nnz, vec![1, 0, 1]);
    }

    #[test]
    fn parses_smtx() {
        // 3x4 CSR: rows [0,2), [2,3), [3,5).
        let text = "3, 4, 5\n0 2 3 5\n0 1 2 1 3\n";
        let stats = parse_tensor_text(text).unwrap();
        assert_eq!((stats.rows, stats.cols, stats.nnz), (3, 4, 5));
        assert_eq!(stats.row_nnz, vec![2, 1, 2]);
    }

    #[test]
    fn rejects_malformed_files() {
        for src in [
            "",
            "%% only comments\n",
            "1 2 3 4 5\n",             // 5-field entry
            "3, 4, 5\n0 2 3 5\n0 1\n", // SMTX with missing column indices
            "2, 4, 5\n0 70 5\n0 1 2 1 3\n", // SMTX offset beyond nnz
            "not numbers at all\n",
            "-3 4 1.0\n",  // negative index must not coerce to 0
            "2.5 3 1.0\n", // fractional index must not truncate
        ] {
            assert!(parse_tensor_text(src).is_err(), "{src:?}");
        }
    }

    #[test]
    fn fits_banded_for_diagonal_matrix() {
        let entries: Vec<(u64, u64)> = (0..64).map(|i| (i, i)).collect();
        let stats = parse_tensor_text(&coo_text(&entries, Some((64, 64)))).unwrap();
        match fit_model(&stats) {
            DensityModel::Banded { bandwidth, cols } => {
                assert!(bandwidth <= 4, "bandwidth {bandwidth}");
                assert_eq!(cols, 64);
            }
            other => panic!("expected banded, fitted {}", other.describe()),
        }
    }

    #[test]
    fn sparse_diagonal_is_not_fitted_as_banded() {
        // Diagonal entries on only every 8th row: a banded fit would
        // claim density bandwidth/cols (~8x the truth) — must fall
        // through to a skewed/histogram fit.
        let entries: Vec<(u64, u64)> = (0..128u64).step_by(8).map(|i| (i, i)).collect();
        let stats = parse_tensor_text(&coo_text(&entries, Some((128, 128)))).unwrap();
        let model = fit_model(&stats);
        assert!(
            !matches!(model, DensityModel::Banded { .. }),
            "fitted {}",
            model.describe()
        );
    }

    #[test]
    fn rejects_oversized_headers_without_allocating() {
        // A corrupt SMTX header must produce a typed error, not an
        // allocation abort.
        let err = parse_tensor_text("999999999999999, 4, 5\n0 2 3 5\n0 1 2 1 3\n");
        assert!(err.is_err());
    }

    #[test]
    fn fits_uniform_for_scattered_matrix() {
        // Same count in every row, columns spread via a stride walk.
        let mut entries = Vec::new();
        for r in 0..32u64 {
            for j in 0..8u64 {
                entries.push((r, (r * 17 + j * 29) % 64));
            }
        }
        let stats = parse_tensor_text(&coo_text(&entries, Some((32, 64)))).unwrap();
        match fit_model(&stats) {
            DensityModel::Uniform { density } => {
                assert!((density - 8.0 / 64.0).abs() < 1e-9);
            }
            other => panic!("expected uniform, fitted {}", other.describe()),
        }
    }

    #[test]
    fn fits_blocks_for_clustered_columns() {
        // Runs of 8 consecutive columns at scattered offsets.
        let mut entries = Vec::new();
        for r in 0..32u64 {
            let start = (r * 37) % 120;
            for j in 0..8u64 {
                entries.push((r, start + j));
            }
        }
        let stats = parse_tensor_text(&coo_text(&entries, Some((32, 128)))).unwrap();
        match fit_model(&stats) {
            DensityModel::Block { block, .. } => assert!(block >= 4, "block {block}"),
            other => panic!("expected block, fitted {}", other.describe()),
        }
    }

    #[test]
    fn fits_skewed_or_measured_for_power_law_rows() {
        // Row r gets ~ c / (r+1) nonzeros — a heavy-tailed profile.
        let mut rng = Pcg64::seeded(5);
        let mut entries = Vec::new();
        for r in 0..128u64 {
            let count = (256 / (r + 1)).clamp(1, 128);
            for _ in 0..count {
                entries.push((r, rng.below(256)));
            }
        }
        let stats = parse_tensor_text(&coo_text(&entries, Some((128, 256)))).unwrap();
        let model = fit_model(&stats);
        assert!(
            matches!(
                model,
                DensityModel::RowSkewed { .. } | DensityModel::Measured { .. }
            ),
            "expected a skewed fit, got {}",
            model.describe()
        );
        assert!(model.validate().is_ok());
    }

    #[test]
    fn report_renders_model_and_histogram() {
        let entries: Vec<(u64, u64)> = (0..32).map(|i| (i, i)).collect();
        let report = inspect(&coo_text(&entries, Some((32, 32)))).unwrap();
        assert!(report.contains("32 x 32"), "{report}");
        assert!(report.contains("\"density\""), "{report}");
        assert!(report.contains('#'), "{report}");
    }
}
