//! The SparseMap search loop (§IV.H, Fig. 16) and its ablation variants.

use super::hypercube::{HshiConfig, HshiMachine, HshiStep};
use super::operators::{annealing_mutation, sensitivity_aware_crossover};
use super::population::{
    evaluate_all, lhs_init, mean_valid_edp, select_top, top_indices, Individual,
};
use super::sensitivity::{CalibConfig, CalibMachine, CalibStep, Sensitivity};
use crate::genome::{ops, Genome};
use crate::model::EvalResult;
use crate::optimizer::checkpoint::{
    f64s_from_json, f64s_to_json, genomes_from_json, genomes_to_json, indices_from_json,
    indices_to_json, rng_from_json, rng_to_json,
};
use crate::optimizer::Optimizer;
use crate::search::{EvalContext, Outcome};
use crate::util::json::{f64_bits, f64_from_bits, Json};
use crate::util::rng::Pcg64;
use anyhow::{anyhow, ensure, Result};

/// Which feature set to run — the Fig. 18 ablation arms.
///
/// * `Standard` — plain ES over the PFCE genome with LHS initialization,
///   uniform one-point crossover and uniform mutation. (The paper's
///   "standard ES" additionally uses a *direct value* encoding; that arm
///   lives in `baselines::es_direct` since it needs a different genome.)
/// * `Pfce` — `Standard` + nothing else (encoding is already PFCE here);
///   kept as an explicit alias for experiment scripts.
/// * `Full` — PFCE + high-sensitivity hypercube initialization +
///   annealing mutation + sensitivity-aware crossover (SparseMap proper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EsVariant {
    Standard,
    Pfce,
    Full,
}

impl EsVariant {
    pub fn name(self) -> &'static str {
        match self {
            EsVariant::Standard => "es-std",
            EsVariant::Pfce => "es-pfce",
            EsVariant::Full => "sparsemap",
        }
    }
}

/// ES hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct EsConfig {
    pub population: usize,
    /// Fraction of the population selected as parents.
    pub parent_frac: f64,
    /// Probability an offspring is mutated.
    pub mutation_prob: f64,
    pub variant: EsVariant,
    pub calib: CalibConfig,
    pub hshi: HshiConfig,
    /// Worker threads for population evaluation: 0 leaves the context's
    /// pool untouched (serial unless the caller attached one); `>= 2`
    /// attaches a fresh pool when the context has none. Trajectories are
    /// bit-identical across thread counts (see `crate::search`).
    pub threads: usize,
}

impl Default for EsConfig {
    fn default() -> Self {
        EsConfig {
            population: 100,
            parent_frac: 0.25,
            mutation_prob: 0.6,
            variant: EsVariant::Full,
            calib: CalibConfig::default(),
            hshi: HshiConfig::default(),
            threads: 0,
        }
    }
}

/// Live generational-loop state (the post-initialization phase).
struct GensState {
    high: Vec<usize>,
    low: Vec<usize>,
    pop: Vec<Individual>,
    gen: usize,
    total_gens: usize,
}

/// Where a suspended ES run is in its pipeline. Each phase pauses only
/// at points where nothing of the pending unit of work has consumed RNG
/// or budget, so resuming replays bit-identically.
enum EsPhase {
    Calib(CalibMachine),
    Hshi(Sensitivity, HshiMachine),
    /// Initial population assembled but not yet evaluated.
    InitEval { high: Vec<usize>, low: Vec<usize>, genomes: Vec<Genome> },
    Gens(GensState),
}

/// Everything an entered ES run carries between [`EsOpt::run`] calls.
struct EsState {
    rng: Pcg64,
    /// `ctx.remaining()` at first entry — the basis for population and
    /// initialization-overhead sizing.
    budget: usize,
    /// Resolved population size (`cfg.population` capped to budget/8,
    /// floor 8).
    population: usize,
    phase: EsPhase,
}

/// The ES family (`sparsemap`, `es-pfce`, `es-std`) as a resumable
/// [`Optimizer`]: the whole §IV pipeline — calibration → HSHI → initial
/// evaluation → generations — runs as a state machine that pauses at
/// safe points when the context requests suspension (or hits a portfolio
/// fence) and continues bit-identically on the next `run` call.
/// [`SparseMapSearch`] and [`run_sparsemap_with`] delegate here, so every
/// entry point shares one implementation.
pub struct EsOpt {
    cfg: EsConfig,
    st: Option<EsState>,
    /// Design-memory seed genomes (see [`Optimizer::warm_start`]),
    /// consumed when the initial population is assembled. Empty unless a
    /// warm-start was requested, in which case trajectories are — by
    /// design — allowed to differ from the cold-start golden ones.
    seeds: Vec<Genome>,
    seed_frac: f64,
}

impl EsOpt {
    pub fn new(cfg: EsConfig) -> EsOpt {
        EsOpt { cfg, st: None, seeds: Vec::new(), seed_frac: 0.0 }
    }
}

/// Overwrite the front of a freshly assembled initial population with the
/// memory seeds (nearest scenario first), up to `frac` of the population.
/// Replacement — never insertion or generation-skip — so the RNG stream
/// is untouched and an empty seed list leaves the population (and every
/// downstream trajectory) bit-identical. Free function so it can run
/// while `EsOpt::st` is mutably borrowed.
fn inject_seeds(seeds: &mut Vec<Genome>, frac: f64, genomes: &mut [Genome]) {
    if seeds.is_empty() || genomes.is_empty() {
        return;
    }
    let cap = ((genomes.len() as f64 * frac).ceil() as usize).clamp(1, genomes.len());
    let m = seeds.len().min(cap);
    for (slot, seed) in genomes.iter_mut().zip(seeds.drain(..m)) {
        *slot = seed;
    }
}

impl Optimizer for EsOpt {
    fn label(&self) -> &str {
        self.cfg.variant.name()
    }

    fn warm_start(&mut self, seeds: &[Genome], fraction: f64) {
        self.seeds = seeds.to_vec();
        self.seed_frac = fraction.clamp(0.0, 1.0);
    }

    fn run(&mut self, ctx: &mut EvalContext, seed: u64) {
        if self.cfg.threads > 1 && ctx.pool().is_none() {
            let pool = crate::util::threadpool::ThreadPool::new(self.cfg.threads);
            ctx.set_pool(Some(std::sync::Arc::new(pool)));
        }
        let spec = ctx.spec.clone();
        let full = self.cfg.variant == EsVariant::Full;

        if self.st.is_none() {
            // First entry: scale to what this run may actually spend —
            // identical to `ctx.budget` on a fresh context (every
            // standalone path), and to the slice allocation when a
            // portfolio fence is set. Calibration stays ≤ ~10% of it
            // (E8), HSHI ≤ ~20%.
            let mut rng = Pcg64::seeded(seed);
            let budget = ctx.remaining();
            let population = self.cfg.population.min((budget / 8).max(8));
            let phase = if full {
                let mut calib = self.cfg.calib;
                if calib.max_evals == 0 {
                    calib.max_evals = (budget / 10).max(40);
                }
                EsPhase::Calib(CalibMachine::new(ctx, calib, &mut rng))
            } else {
                let mut genomes = lhs_init(&spec, population, &mut rng);
                inject_seeds(&mut self.seeds, self.seed_frac, &mut genomes);
                EsPhase::InitEval {
                    high: Vec::new(),
                    low: (0..spec.len()).collect(),
                    genomes,
                }
            };
            self.st = Some(EsState { rng, budget, population, phase });
        }

        // What a phase dispatch decided: move to the next phase, or stop
        // running (paused, exhausted, or generation cap) with all state
        // kept for a later re-entry.
        enum Next {
            To(EsPhase),
            Stop,
        }

        let st = self.st.as_mut().expect("state initialized above");
        loop {
            let next = match &mut st.phase {
                EsPhase::Calib(m) => match m.step(ctx, &mut st.rng) {
                    CalibStep::Paused => Next::Stop,
                    CalibStep::Done(sens) => {
                        let mut h = self.cfg.hshi;
                        h.hypercubes = st.population;
                        h.tries_per_cube = h
                            .tries_per_cube
                            .min((st.budget / 5 / st.population.max(1)).max(1));
                        let m = HshiMachine::new(ctx, &sens, h);
                        Next::To(EsPhase::Hshi(sens, m))
                    }
                },
                EsPhase::Hshi(sens, m) => match m.step(ctx, sens, &mut st.rng) {
                    HshiStep::Paused => Next::Stop,
                    HshiStep::Done(r) => {
                        let mut genomes = r.population;
                        // Top up with random genomes if HSHI under-filled.
                        while genomes.len() < st.population {
                            genomes.push(spec.random(&mut st.rng));
                        }
                        if !genomes.is_empty() {
                            // Warm-start seeds: when resources are
                            // extremely tight (edge platform, huge
                            // workloads) the valid region can be too thin
                            // for stratified random search — inject the
                            // deterministic heuristic mapping (with and
                            // without the manual sparse strategy) so the
                            // population never starts fully dead.
                            let workload = ctx.workload().clone();
                            let mapping = crate::baselines::common::heuristic_mapping_genes(
                                &spec, &workload,
                            );
                            let manual = crate::baselines::common::manual_strategy_genes(
                                &spec, &workload,
                            );
                            let mut seed1 = vec![0u32; spec.len()];
                            for i in 0..spec.len() {
                                seed1[i] = spec.ranges[i].lo;
                            }
                            crate::baselines::common::apply(&mut seed1, &mapping);
                            let mut seed2 = seed1.clone();
                            crate::baselines::common::apply(&mut seed2, &manual);
                            let k = genomes.len();
                            genomes[k - 1] = seed1;
                            if k >= 2 {
                                genomes[k - 2] = seed2;
                            }
                        }
                        // Design-memory seeds take the *front* slots, so
                        // they coexist with the heuristic seeds above.
                        inject_seeds(&mut self.seeds, self.seed_frac, &mut genomes);
                        Next::To(EsPhase::InitEval {
                            high: sens.high.clone(),
                            low: sens.low.clone(),
                            genomes,
                        })
                    }
                },
                EsPhase::InitEval { high, low, genomes } => {
                    if ctx.should_pause() {
                        Next::Stop
                    } else {
                        let pop = evaluate_all(ctx, std::mem::take(genomes));
                        if let Some(m) = mean_valid_edp(&pop) {
                            ctx.telemetry.push_population_mean(m);
                        }
                        // Estimate total generations from the remaining
                        // budget so the annealing schedule spans the
                        // whole run.
                        let total_gens = (ctx.remaining() / st.population.max(1)).max(1);
                        Next::To(EsPhase::Gens(GensState {
                            high: std::mem::take(high),
                            low: std::mem::take(low),
                            pop,
                            gen: 0,
                            total_gens,
                        }))
                    }
                }
                EsPhase::Gens(g) => {
                    while !ctx.should_pause() && g.gen < g.total_gens * 4 {
                        let n_parents =
                            ((g.pop.len() as f64 * self.cfg.parent_frac) as usize).max(2);
                        // Parents are only read: select by index instead
                        // of cloning every genome per generation (same
                        // stable order as `select_top`, so the rng stream
                        // and trajectory are untouched — see
                        // `top_indices`).
                        let parents = top_indices(&g.pop, n_parents);

                        // Crossover: fill a fresh offspring pool.
                        let mut offspring = Vec::with_capacity(st.population);
                        while offspring.len() < st.population {
                            let pa = &g.pop[parents[st.rng.index(parents.len())]].genome;
                            let pb = &g.pop[parents[st.rng.index(parents.len())]].genome;
                            let (mut c1, mut c2) = if full {
                                sensitivity_aware_crossover(pa, pb, &g.high, &mut st.rng)
                            } else {
                                ops::onepoint_crossover(pa, pb, &mut st.rng)
                            };
                            // Mutation.
                            for c in [&mut c1, &mut c2] {
                                if st.rng.chance(self.cfg.mutation_prob) {
                                    if full {
                                        annealing_mutation(
                                            &spec,
                                            c,
                                            &g.high,
                                            &g.low,
                                            g.gen,
                                            g.total_gens,
                                            &mut st.rng,
                                        );
                                    } else {
                                        ops::point_mutation(&spec, c, 0.05, &mut st.rng);
                                    }
                                }
                            }
                            offspring.push(c1);
                            if offspring.len() < st.population {
                                offspring.push(c2);
                            }
                        }

                        let children = evaluate_all(ctx, offspring);
                        if children.is_empty() {
                            break; // budget exhausted mid-generation
                        }
                        // (μ+λ) survival: parents compete with offspring.
                        g.pop.extend(children);
                        g.pop = select_top(std::mem::take(&mut g.pop), st.population);
                        if let Some(m) = mean_valid_edp(&g.pop) {
                            ctx.telemetry.push_population_mean(m);
                        }
                        g.gen += 1;
                    }
                    Next::Stop
                }
            };
            match next {
                Next::To(p) => st.phase = p,
                Next::Stop => return,
            }
        }
    }

    fn suspend(&self) -> Option<Json> {
        Some(Json::obj(vec![(
            "es",
            match &self.st {
                None => Json::Null,
                Some(st) => Json::obj(vec![
                    ("rng", rng_to_json(&st.rng)),
                    ("budget", Json::num(st.budget as f64)),
                    ("population", Json::num(st.population as f64)),
                    ("phase", phase_to_json(&st.phase)),
                ]),
            },
        )]))
    }

    fn resume(&mut self, state: &Json) -> Result<()> {
        let es = match state.get("es") {
            None | Some(Json::Null) => {
                self.st = None;
                return Ok(());
            }
            Some(j) => j,
        };
        self.st = Some(EsState {
            rng: rng_from_json(
                es.get("rng").ok_or_else(|| anyhow!("es state is missing 'rng'"))?,
            )?,
            budget: usize_field(es, "budget")?,
            population: usize_field(es, "population")?,
            phase: phase_from_json(
                es.get("phase").ok_or_else(|| anyhow!("es state is missing 'phase'"))?,
            )?,
        });
        Ok(())
    }
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_u64)
        .map(|v| v as usize)
        .ok_or_else(|| anyhow!("es state is missing integer '{key}'"))
}

fn sens_to_json(s: &Sensitivity) -> Json {
    Json::obj(vec![
        ("scores", f64s_to_json(&s.scores)),
        ("high", indices_to_json(&s.high)),
        ("low", indices_to_json(&s.low)),
        ("valid_pool", genomes_to_json(&s.valid_pool)),
        ("evals_spent", Json::num(s.evals_spent as f64)),
    ])
}

fn sens_from_json(j: &Json) -> Result<Sensitivity> {
    let field = |key: &str| j.get(key).ok_or_else(|| anyhow!("sensitivity is missing '{key}'"));
    Ok(Sensitivity {
        scores: f64s_from_json(field("scores")?)?,
        high: indices_from_json(field("high")?)?,
        low: indices_from_json(field("low")?)?,
        valid_pool: genomes_from_json(field("valid_pool")?)?,
        evals_spent: usize_field(j, "evals_spent")?,
    })
}

fn individual_to_json(ind: &Individual) -> Json {
    Json::obj(vec![
        ("g", Json::Arr(ind.genome.iter().map(|&x| Json::num(x as f64)).collect())),
        (
            "r",
            Json::Arr(vec![
                f64_bits(ind.result.energy_pj),
                f64_bits(ind.result.cycles),
                f64_bits(ind.result.edp),
                Json::Bool(ind.result.valid),
            ]),
        ),
    ])
}

fn individual_from_json(j: &Json) -> Result<Individual> {
    let genome: Genome = j
        .get("g")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("individual is missing 'g'"))?
        .iter()
        .map(|x| {
            x.as_u64().map(|v| v as u32).ok_or_else(|| anyhow!("genes must be integers"))
        })
        .collect::<Result<_>>()?;
    let r = j
        .get("r")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("individual is missing 'r'"))?;
    ensure!(r.len() == 4, "individual result must have 4 entries");
    let bits = |i: usize| {
        f64_from_bits(&r[i]).ok_or_else(|| anyhow!("individual result entry {i} is not f64 bits"))
    };
    Ok(Individual {
        genome,
        result: EvalResult {
            energy_pj: bits(0)?,
            cycles: bits(1)?,
            edp: bits(2)?,
            valid: r[3].as_bool().ok_or_else(|| anyhow!("individual validity must be a bool"))?,
        },
    })
}

fn phase_to_json(p: &EsPhase) -> Json {
    match p {
        EsPhase::Calib(m) => Json::obj(vec![
            ("kind", Json::str("calib")),
            ("samples_per_gene", Json::num(m.cfg.samples_per_gene as f64)),
            ("trials", Json::num(m.cfg.trials as f64)),
            ("pairs", Json::num(m.cfg.pairs as f64)),
            ("max_evals", Json::num(m.cfg.max_evals as f64)),
            ("start_evals", Json::num(m.start_evals as f64)),
            ("gene_order", indices_to_json(&m.gene_order)),
            ("pos", Json::num(m.pos as f64)),
            ("scores", f64s_to_json(&m.scores)),
            ("valid_pool", genomes_to_json(&m.valid_pool)),
        ]),
        EsPhase::Hshi(sens, m) => Json::obj(vec![
            ("kind", Json::str("hshi")),
            ("sens", sens_to_json(sens)),
            ("hypercubes", Json::num(m.cfg.hypercubes as f64)),
            ("tries_per_cube", Json::num(m.cfg.tries_per_cube as f64)),
            (
                "strata",
                indices_to_json(&m.strata.iter().map(|&k| k as usize).collect::<Vec<_>>()),
            ),
            ("total_cubes", Json::num(m.total_cubes as f64)),
            ("n_cubes", Json::num(m.n_cubes as f64)),
            ("cube", Json::num(m.cube as f64)),
            ("start", Json::num(m.start as f64)),
            ("population", genomes_to_json(&m.population)),
        ]),
        EsPhase::InitEval { high, low, genomes } => Json::obj(vec![
            ("kind", Json::str("init")),
            ("high", indices_to_json(high)),
            ("low", indices_to_json(low)),
            ("genomes", genomes_to_json(genomes)),
        ]),
        EsPhase::Gens(g) => Json::obj(vec![
            ("kind", Json::str("gens")),
            ("high", indices_to_json(&g.high)),
            ("low", indices_to_json(&g.low)),
            ("gen", Json::num(g.gen as f64)),
            ("total_gens", Json::num(g.total_gens as f64)),
            ("pop", Json::Arr(g.pop.iter().map(individual_to_json).collect())),
        ]),
    }
}

fn phase_from_json(j: &Json) -> Result<EsPhase> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("es phase is missing 'kind'"))?;
    let field = |key: &str| j.get(key).ok_or_else(|| anyhow!("es phase is missing '{key}'"));
    match kind {
        "calib" => Ok(EsPhase::Calib(CalibMachine {
            cfg: CalibConfig {
                samples_per_gene: usize_field(j, "samples_per_gene")?,
                trials: usize_field(j, "trials")?,
                pairs: usize_field(j, "pairs")?,
                max_evals: usize_field(j, "max_evals")?,
            },
            start_evals: usize_field(j, "start_evals")?,
            gene_order: indices_from_json(field("gene_order")?)?,
            pos: usize_field(j, "pos")?,
            scores: f64s_from_json(field("scores")?)?,
            valid_pool: genomes_from_json(field("valid_pool")?)?,
        })),
        "hshi" => Ok(EsPhase::Hshi(
            sens_from_json(field("sens")?)?,
            HshiMachine {
                cfg: HshiConfig {
                    hypercubes: usize_field(j, "hypercubes")?,
                    tries_per_cube: usize_field(j, "tries_per_cube")?,
                },
                strata: indices_from_json(field("strata")?)?
                    .into_iter()
                    .map(|k| k as u32)
                    .collect(),
                total_cubes: field("total_cubes")?
                    .as_u64()
                    .ok_or_else(|| anyhow!("es phase is missing integer 'total_cubes'"))?,
                n_cubes: usize_field(j, "n_cubes")?,
                cube: usize_field(j, "cube")?,
                start: usize_field(j, "start")?,
                population: genomes_from_json(field("population")?)?,
            },
        )),
        "init" => Ok(EsPhase::InitEval {
            high: indices_from_json(field("high")?)?,
            low: indices_from_json(field("low")?)?,
            genomes: genomes_from_json(field("genomes")?)?,
        }),
        "gens" => Ok(EsPhase::Gens(GensState {
            high: indices_from_json(field("high")?)?,
            low: indices_from_json(field("low")?)?,
            gen: usize_field(j, "gen")?,
            total_gens: usize_field(j, "total_gens")?,
            pop: field("pop")?
                .as_arr()
                .ok_or_else(|| anyhow!("es phase 'pop' must be an array"))?
                .iter()
                .map(individual_from_json)
                .collect::<Result<_>>()?,
        })),
        other => Err(anyhow!("unknown es phase kind '{other}'")),
    }
}

/// The SparseMap searcher. Borrows its [`EvalContext`] so a caller (the
/// `portfolio` meta-optimizer, bespoke drivers) can run it over a slice
/// of a shared budget; [`run_sparsemap`] is the owning convenience form.
/// Thin wrapper over [`EsOpt`] (kept for source compatibility).
pub struct SparseMapSearch<'a> {
    pub ctx: &'a mut EvalContext,
    pub cfg: EsConfig,
    seed: u64,
}

impl<'a> SparseMapSearch<'a> {
    pub fn new(ctx: &'a mut EvalContext, cfg: EsConfig, seed: u64) -> SparseMapSearch<'a> {
        SparseMapSearch { ctx, cfg, seed }
    }

    /// Run until the context budget (or fence) is exhausted.
    pub fn run(self) {
        EsOpt::new(self.cfg).run(self.ctx, self.seed);
    }
}

/// Run one ES search against a borrowed context (telemetry accumulates
/// in the context; the caller finalizes the outcome). One fresh
/// [`EsOpt`] per call — bit-identical to the registry-built optimizer.
pub fn run_sparsemap_with(ctx: &mut EvalContext, cfg: &EsConfig, seed: u64) {
    EsOpt::new(*cfg).run(ctx, seed);
}

/// Convenience one-call API.
pub fn run_sparsemap(mut ctx: EvalContext, cfg: EsConfig, seed: u64) -> Outcome {
    let method = cfg.variant.name();
    run_sparsemap_with(&mut ctx, &cfg, seed);
    ctx.outcome(method)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("mm", 64, 128, 64, 0.2, 0.2);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    fn small_cfg(variant: EsVariant) -> EsConfig {
        EsConfig {
            population: 24,
            variant,
            calib: CalibConfig { samples_per_gene: 4, trials: 2, pairs: 4, max_evals: 0 },
            hshi: HshiConfig { hypercubes: 24, tries_per_cube: 6 },
            ..Default::default()
        }
    }

    #[test]
    fn full_sparsemap_finds_valid_design() {
        let o = run_sparsemap(ctx(3_000), small_cfg(EsVariant::Full), 7);
        assert!(o.found_valid(), "no valid design found");
        assert!(o.evals <= 3_000);
        assert_eq!(o.method, "sparsemap");
        assert!(!o.curve.is_empty());
    }

    #[test]
    fn standard_es_runs_too() {
        let o = run_sparsemap(ctx(2_000), small_cfg(EsVariant::Standard), 7);
        assert_eq!(o.method, "es-std");
        assert!(o.evals <= 2_000);
    }

    #[test]
    fn search_improves_over_random_sampling() {
        // Same budget: SparseMap's best should beat pure random's best
        // (with overwhelming probability at this budget).
        let budget = 3_000;
        let o = run_sparsemap(ctx(budget), small_cfg(EsVariant::Full), 11);
        let mut random_ctx = ctx(budget);
        let mut rng = Pcg64::seeded(11);
        let genomes: Vec<_> =
            (0..budget).map(|_| random_ctx.spec.random(&mut rng)).collect();
        random_ctx.eval_batch(&genomes);
        let random_best = random_ctx.outcome("random").best_edp;
        assert!(
            o.best_edp <= random_best * 1.5,
            "sparsemap {:.3e} vs random {:.3e}",
            o.best_edp,
            random_best
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_sparsemap(ctx(1_200), small_cfg(EsVariant::Full), 42);
        let b = run_sparsemap(ctx(1_200), small_cfg(EsVariant::Full), 42);
        assert_eq!(a.best_edp, b.best_edp);
        assert_eq!(a.best_genome, b.best_genome);
    }

    #[test]
    fn threads_config_does_not_change_results() {
        let serial = run_sparsemap(ctx(800), small_cfg(EsVariant::Full), 42);
        let par_cfg = EsConfig { threads: 4, ..small_cfg(EsVariant::Full) };
        let par = run_sparsemap(ctx(800), par_cfg, 42);
        assert_eq!(serial.best_edp, par.best_edp);
        assert_eq!(serial.best_genome, par.best_genome);
        assert_eq!(serial.curve, par.curve);
    }

    #[test]
    fn population_mean_curve_recorded() {
        let o = run_sparsemap(ctx(2_000), small_cfg(EsVariant::Full), 3);
        assert!(o.population_mean_curve.len() >= 2);
    }

    #[test]
    fn suspend_and_resume_reproduce_uninterrupted_run() {
        use crate::search::{Progress, SearchControl};
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        let cfg = small_cfg(EsVariant::Full);
        let a = run_sparsemap(ctx(1_200), cfg, 21);

        // Same search, but an observer raises the suspend flag halfway
        // through; the run pauses at the next safe point.
        let flag = Arc::new(AtomicBool::new(false));
        let obs_flag = flag.clone();
        let mut c = ctx(1_200).with_observer(Some(Box::new(move |p: &Progress| {
            if p.evals >= 600 {
                obs_flag.store(true, Ordering::SeqCst);
            }
            SearchControl::Continue
        })));
        c.set_suspend_flag(Some(flag.clone()));
        let mut opt = EsOpt::new(cfg);
        opt.run(&mut c, 21);
        assert!(c.used() < 1_200, "run should have paused before the budget");

        // Serialize the optimizer state through actual JSON text and
        // restore it into a fresh instance.
        let state = Json::parse(&opt.suspend().unwrap().dumps()).unwrap();
        let mut resumed = EsOpt::new(cfg);
        resumed.resume(&state).unwrap();

        flag.store(false, Ordering::SeqCst);
        c.set_observer(None);
        resumed.run(&mut c, 21);
        let b = c.outcome("sparsemap");

        assert_eq!(a.evals, b.evals);
        assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits());
        assert_eq!(a.best_genome, b.best_genome);
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.population_mean_curve, b.population_mean_curve);
    }

    #[test]
    fn warm_start_with_no_seeds_is_bit_identical() {
        // The warm-start hook replaces genomes rather than skipping
        // generation, so an empty seed list must leave the trajectory
        // bit-for-bit unchanged — the invariant the golden tests rely on.
        let a = run_sparsemap(ctx(1_200), small_cfg(EsVariant::Full), 42);
        let mut c = ctx(1_200);
        let mut opt = EsOpt::new(small_cfg(EsVariant::Full));
        opt.warm_start(&[], 0.25);
        opt.run(&mut c, 42);
        let b = c.outcome("sparsemap");
        assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits());
        assert_eq!(a.best_genome, b.best_genome);
        assert_eq!(a.curve, b.curve);
    }

    #[test]
    fn warm_start_seeds_enter_the_initial_population() {
        // Cold run buys an elite; the warm-started rerun must surface
        // that elite's cost within the very first population.
        let a = run_sparsemap(ctx(1_500), small_cfg(EsVariant::Standard), 9);
        assert!(a.found_valid());
        let elite = a.best_genome.clone().unwrap();
        let mut c = ctx(1_500);
        let mut opt = EsOpt::new(small_cfg(EsVariant::Standard));
        opt.warm_start(&[elite], 0.25);
        opt.run(&mut c, 9);
        let b = c.outcome("es-std");
        assert!(b.best_edp <= a.best_edp);
        let pop = 24usize.min((1_500 / 8).max(8));
        let reach = b
            .curve
            .iter()
            .find(|&&(_, v)| v <= a.best_edp)
            .map(|&(e, _)| e)
            .expect("warm-started run never reached the cold best");
        assert!(reach <= pop, "seed not evaluated in the initial population: {reach} > {pop}");
    }

    #[test]
    fn fresh_optimizer_suspends_to_null_state() {
        let opt = EsOpt::new(small_cfg(EsVariant::Full));
        let state = opt.suspend().unwrap();
        let mut back = EsOpt::new(small_cfg(EsVariant::Full));
        back.resume(&state).unwrap();
        assert!(back.st.is_none());
    }
}
