//! Shared search infrastructure: evaluation backends, budget accounting,
//! the parallel/memoizing evaluation pipeline and telemetry (best-so-far
//! curves, valid-point ratios — the raw data behind Fig. 17b and Fig. 18).
//!
//! ## Parallel evaluation
//!
//! An [`EvalContext`] optionally carries a shared
//! [`ThreadPool`](crate::util::threadpool::ThreadPool). Native-model
//! batches fan out as `(lo, hi)` index ranges over refcount-shared
//! buffers (`fan_out_shared`/`fan_out_indexed`, floored chunking via
//! `range_chunks`) through the order-preserving `parallel_map`; because
//! the cost model is pure and results are re-assembled in submission
//! order, search trajectories are bit-identical between 1 and N threads. The PJRT backend keeps its own internal
//! batching and ignores the pool.
//!
//! ## Evaluation cache and budget semantics
//!
//! ES populations re-produce identical offspring constantly. The context
//! memoizes results by genome: a repeated genome (within a batch or across
//! generations) is served from the cache without touching the model, but
//! **still debits one evaluation from the sample budget** — the paper's
//! 20 000-sample budget counts *submissions*, not distinct designs, so
//! cached arms stay comparable with uncached ones. Because the model is
//! deterministic, caching never changes a trajectory, only its wall-clock
//! cost. The cache is bounded by the budget (only misses insert entries).
//!
//! ## The staged cache ([`engine`])
//!
//! Beneath the per-genome result cache sits a *stage-level* one. Genomes
//! are interned to dense ids (so cache keys are never cloned on a hit),
//! and a result-cache miss does not recompute from scratch: the genome's
//! natural segments — mapping genes, per-tensor format genes, S/G genes —
//! are resolved against per-segment caches, so an offspring that mutated
//! only its strategy genes reuses its parent's decoded loop nest, traffic
//! features and compression stats, and pays only the allocation-free
//! assembly + cost arithmetic. Trajectories are bit-identical with
//! staging on or off (`EvalContext::with_staging`, pinned by
//! `rust/tests/engine_parity.rs`); `Telemetry::interned` /
//! `Telemetry::stage_hits` expose the cache effectiveness to observers
//! and JSON reports.

pub mod engine;
pub mod telemetry;

pub use engine::{Interner, StageEngine};
pub use telemetry::{MemberStats, Outcome, Telemetry};

use crate::arch::Platform;
use crate::genome::Design;
use crate::model::{EvalResult, NativeEvaluator};
use crate::obs::Metrics;
#[cfg(feature = "xla")]
use crate::runtime::{BatchEvaluator, Runtime};
use crate::util::json::{f64_bits, f64_from_bits, Json};
use crate::util::threadpool::{parallel_map, ThreadPool};
use crate::workload::Workload;
#[cfg(feature = "xla")]
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Progress snapshot streamed to a [`SearchObserver`] after every
/// evaluated batch (≈ one generation for population algorithms). Carries
/// the live telemetry the Fig. 17b/18 curves are built from.
#[derive(Clone, Debug, PartialEq)]
pub struct Progress {
    /// Batches evaluated so far — a generation proxy.
    pub batches: usize,
    /// Budget submissions spent so far.
    pub evals: usize,
    pub valid_evals: usize,
    /// Submissions served from the evaluation cache.
    pub cache_hits: usize,
    /// Distinct genomes interned so far (the result caches key on these).
    pub interned: usize,
    /// Stage-level cache hits — one per memoized decode/feature stage
    /// reused, so a single evaluation can contribute up to 4 (see
    /// [`engine`]).
    pub stage_hits: usize,
    /// Best valid EDP so far (`f64::INFINITY` until one is found).
    pub best_edp: f64,
    /// Total sample budget of the run.
    pub budget: usize,
}

/// What a [`SearchObserver`] wants the search to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchControl {
    Continue,
    /// Stop early: the context reports an exhausted budget from now on,
    /// so every algorithm winds down through its normal exit path.
    Stop,
}

/// Streaming callback attached to an [`EvalContext`] (see
/// [`EvalContext::with_observer`]). Every search algorithm funnels its
/// evaluations through the context, so observers work uniformly across
/// SparseMap and all baselines without per-algorithm wiring.
pub trait SearchObserver: Send {
    fn on_batch(&mut self, progress: &Progress) -> SearchControl;
}

impl<F: FnMut(&Progress) -> SearchControl + Send> SearchObserver for F {
    fn on_batch(&mut self, progress: &Progress) -> SearchControl {
        self(progress)
    }
}

/// Fitness backend: the native Rust model or the PJRT AOT executable.
/// Both implement the same FEATURE_SCHEMA_V1 formula. The native evaluator
/// is shared behind an `Arc` so batches can fan out across worker threads.
pub enum Backend {
    Native(Arc<NativeEvaluator>),
    #[cfg(feature = "xla")]
    Pjrt(Box<BatchEvaluator>),
}

/// Minimum items per parallel chunk. A dispatched job costs a boxed
/// closure plus two channel transfers (≈ a microsecond); the cheapest
/// evaluation stages cost a few microseconds each, so a floor of 8 items
/// keeps per-job overhead under ~10%. Without the floor, small batches on
/// many-core hosts degenerate to chunk = 1 — one dispatch round-trip per
/// item, slower than running inline.
pub(crate) const MIN_CHUNK: usize = 8;

/// Split `n` items so each of `workers` threads sees several chunks (for
/// load balancing) without paying per-item channel overhead: floored at
/// [`MIN_CHUNK`] (per-job overhead), capped at `n` (a chunk is never
/// larger than the batch).
pub(crate) fn chunk_size(n: usize, workers: usize) -> usize {
    (n / (workers * 4)).max(MIN_CHUNK).min(n.max(1))
}

/// Split `0..n` into contiguous `(lo, hi)` index ranges of
/// [`chunk_size`] items each (the last may be shorter). Range-based
/// dispatch shares the exact same [`MIN_CHUNK`] floor as per-item
/// chunking did, so tiny broods on many-core hosts never regress to
/// range-of-1 jobs (one dispatch round-trip per item).
pub(crate) fn range_chunks(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let c = chunk_size(n, workers);
    (0..n).step_by(c).map(|lo| (lo, (lo + c).min(n))).collect()
}

/// Generalized shared-state fan-out: calls `f(&state, i)` for
/// `i in 0..n` and returns `(state, results)` with results in index
/// order. With a real pool attached, `state` is shared with the workers
/// by refcount and jobs carry [`range_chunks`] `(lo, hi)` ranges —
/// nothing per-item is cloned or boxed. Serially (no pool, one worker,
/// or a trivial batch) the state never touches an `Arc`, so serial
/// steady-state evaluation stays allocation-free apart from the results
/// vector itself.
pub(crate) fn fan_out_indexed<S, R, F>(
    pool: Option<&Arc<ThreadPool>>,
    state: S,
    n: usize,
    f: F,
) -> (S, Vec<R>)
where
    S: Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&S, usize) -> R + Send + Sync + 'static,
{
    match pool {
        Some(pool) if pool.size() > 1 && n > 1 => {
            let shared = Arc::new(state);
            let worker_state = Arc::clone(&shared);
            let results: Vec<R> = parallel_map(pool, range_chunks(n, pool.size()), move |(lo, hi)| {
                (lo..hi).map(|i| f(&worker_state, i)).collect::<Vec<R>>()
            })
            .into_iter()
            .flatten()
            .collect();
            // Every job has completed (parallel_map joined all results),
            // but the worker that ran the last one may not have dropped
            // its boxed closure — and with it the state refcount — the
            // instant the result arrived. Spin the handful of cycles
            // until it does so the caller gets its scratch buffer back.
            let mut shared = shared;
            let state = loop {
                match Arc::try_unwrap(shared) {
                    Ok(s) => break s,
                    Err(again) => {
                        shared = again;
                        std::thread::yield_now();
                    }
                }
            };
            (state, results)
        }
        _ => {
            let results = (0..n).map(|i| f(&state, i)).collect();
            (state, results)
        }
    }
}

/// The one pool-dispatch idiom shared by the backend and every engine
/// phase: map `f` over `items` (order-preserving) and hand the buffer
/// back alongside the results. Callers lend a reusable scratch vector
/// via `mem::take` and restore it afterwards; the pooled path shares it
/// with workers by refcount instead of cloning `Arc` lists into per-job
/// chunks. Centralized so chunking and ordering fixes land in one place.
pub(crate) fn fan_out_shared<T, R, F>(
    pool: Option<&Arc<ThreadPool>>,
    items: Vec<T>,
    f: F,
) -> (Vec<T>, Vec<R>)
where
    T: Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    fan_out_indexed(pool, items, n, move |items, i| f(&items[i]))
}

/// A submission slot: either a cached result or an index into the
/// first-occurrence-ordered miss list.
type Slot = std::result::Result<EvalResult, usize>;

/// Reusable per-batch buffers (engine layer 3): cleared, never shrunk, so
/// steady-state batches perform no per-genome allocation — see
/// `rust/tests/alloc_steady_state.rs`.
#[derive(Default)]
struct BatchScratch {
    slots: Vec<Slot>,
    /// Interned id of each miss (`None` = interner at capacity, uncached).
    miss_ids: Vec<Option<u32>>,
    /// The miss genomes, shared by refcount with the interner.
    miss_genomes: Vec<Arc<[u32]>>,
    /// Original submission index of each miss (`eval_designs` pairs
    /// misses back to their design payloads through this).
    miss_src: Vec<usize>,
    /// Batch-local dedup stamps indexed by interned id (no hashing, no
    /// allocation on the hot path).
    seen_epoch: Vec<u32>,
    seen_miss: Vec<u32>,
    epoch: u32,
}

/// Re-assemble per-submission results from slots + evaluated misses
/// (the other half of the shared resolve/reassemble contract below).
fn reassemble(slots: &[Slot], miss_results: &[EvalResult]) -> Vec<EvalResult> {
    slots
        .iter()
        .map(|s| match s {
            Ok(r) => *r,
            Err(i) => miss_results[*i],
        })
        .collect()
}

/// Resolve a batch of cache keys against an id-indexed result table
/// (shared by `eval_batch` and `eval_designs` so the budget/hit
/// semantics cannot diverge). Fills `s.slots` (one per submission) and
/// the first-occurrence-ordered miss lists; returns the hit count.
/// Nothing is cloned on a hit; a brand-new genome is cloned exactly once
/// into the interner.
fn resolve_interned(
    interner: &mut Interner,
    results: &mut Vec<Option<EvalResult>>,
    s: &mut BatchScratch,
    enabled: bool,
    keys: &[Vec<u32>],
) -> usize {
    s.slots.clear();
    s.miss_ids.clear();
    s.miss_genomes.clear();
    s.miss_src.clear();
    s.epoch = s.epoch.wrapping_add(1);
    if s.epoch == 0 {
        // u32 wrap: invalidate all stamps instead of aliasing epoch 0.
        s.seen_epoch.fill(u32::MAX);
        s.epoch = 1;
    }
    let mut hits = 0usize;
    for (i, g) in keys.iter().enumerate() {
        if enabled {
            if let Some(id) = interner.intern(g) {
                let idx = id as usize;
                if results.len() <= idx {
                    results.resize(interner.len(), None);
                }
                if s.seen_epoch.len() <= idx {
                    s.seen_epoch.resize(interner.len(), 0);
                    s.seen_miss.resize(interner.len(), 0);
                }
                if let Some(r) = results[idx] {
                    s.slots.push(Ok(r));
                    hits += 1;
                    continue;
                }
                if s.seen_epoch[idx] == s.epoch {
                    s.slots.push(Err(s.seen_miss[idx] as usize));
                    hits += 1;
                    continue;
                }
                s.seen_epoch[idx] = s.epoch;
                s.seen_miss[idx] = s.miss_src.len() as u32;
                s.slots.push(Err(s.miss_src.len()));
                s.miss_ids.push(Some(id));
                s.miss_genomes.push(Arc::clone(interner.genome(id)));
                s.miss_src.push(i);
                continue;
            }
        }
        // Cache disabled, or interner at capacity: uncached miss.
        s.slots.push(Err(s.miss_src.len()));
        s.miss_ids.push(None);
        s.miss_genomes.push(Arc::from(g.as_slice()));
        s.miss_src.push(i);
    }
    hits
}

impl Backend {
    pub fn native(workload: Workload, platform: Platform) -> Backend {
        Backend::Native(Arc::new(NativeEvaluator::new(workload, platform)))
    }

    #[cfg(feature = "xla")]
    pub fn pjrt(rt: &Runtime, workload: Workload, platform: Platform) -> Result<Backend> {
        Ok(Backend::Pjrt(Box::new(BatchEvaluator::new(rt, workload, platform)?)))
    }

    pub fn workload(&self) -> &Workload {
        match self {
            Backend::Native(e) => &e.workload,
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => &e.workload,
        }
    }

    pub fn platform(&self) -> &Platform {
        match self {
            Backend::Native(e) => &e.platform,
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => &e.platform,
        }
    }

    /// Evaluate genomes from scratch (no stage memoization), fanning the
    /// native model out over `pool` when one is attached. Results are
    /// always in submission order. This is the reference path the staged
    /// engine is parity-tested against. The genome buffer is lent by the
    /// caller and handed back untouched: the pooled path shares it with
    /// workers by refcount instead of cloning the `Arc` list into
    /// per-job chunks.
    fn eval(
        &self,
        pool: Option<&Arc<ThreadPool>>,
        genomes: &mut Vec<Arc<[u32]>>,
    ) -> Vec<EvalResult> {
        match self {
            Backend::Native(e) => {
                let ev = Arc::clone(e);
                let (buf, results) =
                    fan_out_shared(pool, std::mem::take(genomes), move |g| ev.eval_genome(g));
                *genomes = buf;
                results
            }
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => {
                let owned: Vec<Vec<u32>> = genomes.iter().map(|g| g.to_vec()).collect();
                e.eval_genomes(&owned)
                    .expect("PJRT evaluation failed (artifact/runtime error)")
            }
        }
    }

    /// Evaluate pre-decoded designs (`None` = dead on arrival), fanning
    /// out over `pool` like [`Backend::eval`].
    fn eval_designs_batch(
        &self,
        pool: Option<&Arc<ThreadPool>>,
        designs: Vec<Option<Design>>,
    ) -> Vec<EvalResult> {
        match self {
            Backend::Native(e) => {
                let ev = Arc::clone(e);
                fan_out_shared(pool, designs, move |d| match d {
                    Some(d) => ev.eval_design(d),
                    None => EvalResult::dead(),
                })
                .1
            }
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => designs
                .iter()
                .map(|d| match d {
                    Some(d) => e
                        .eval_designs(std::slice::from_ref(d))
                        .expect("PJRT evaluation failed")
                        .pop()
                        .unwrap(),
                    None => EvalResult::dead(),
                })
                .collect(),
        }
    }
}

/// A budgeted evaluation context handed to every search algorithm.
///
/// All algorithms draw from the same sample budget (the paper's 20 000)
/// and report through the same telemetry, which keeps comparisons fair.
/// The context also owns the parallel/memoizing pipeline: attach a worker
/// pool with [`EvalContext::with_pool`] and every batch — from SparseMap
/// itself or any baseline — fans out transparently.
pub struct EvalContext {
    backend: Backend,
    pub spec: crate::genome::GenomeSpec,
    pub budget: usize,
    pub telemetry: Telemetry,
    pool: Option<Arc<ThreadPool>>,
    cache_enabled: bool,
    /// Hash-consed genome store; both result namespaces key on its ids.
    /// Capacity-bounded by the budget (distinct keys ≤ submissions).
    interner: Interner,
    /// Result tables indexed by interned id — one per key namespace
    /// (genome encoding vs. the foreign-encoding `eval_designs` records).
    genome_results: Vec<Option<EvalResult>>,
    design_results: Vec<Option<EvalResult>>,
    /// Stage-memoizing engine (native backends only).
    stage: Option<StageEngine>,
    staging: bool,
    scratch: BatchScratch,
    model_calls: usize,
    observer: Option<Box<dyn SearchObserver>>,
    /// Shared halt flag: set by an observer's [`SearchControl::Stop`] or
    /// externally (cancellation); once set, `remaining()` reports 0.
    stop_flag: Option<Arc<AtomicBool>>,
    /// Shared suspend flag: unlike `stop_flag` it does NOT affect the
    /// budget — resumable optimizers poll [`EvalContext::suspend_requested`]
    /// at safe points and return early with their state preserved.
    suspend_flag: Option<Arc<AtomicBool>>,
    stopped: bool,
    batches: usize,
    /// Temporary absolute submission ceiling below `budget` (see
    /// [`EvalContext::set_fence`]). The portfolio meta-optimizer uses it
    /// to hand each member a bounded slice of the shared budget.
    fence: Option<usize>,
    /// Metrics scope (see [`crate::obs`]): per-batch eval/validity/cache
    /// deltas, generation count, interner size and best-EDP gauge are
    /// published after every batch; the embedded stage engine shares the
    /// same scope for phase timings. `None` (the library default) makes
    /// publication a single branch — the hot path stays zero-alloc
    /// either way (`rust/tests/alloc_steady_state.rs`).
    metrics: Option<Arc<Metrics>>,
    /// Cumulative telemetry values already published to `metrics`
    /// (counters are monotone, so publication adds deltas).
    published: (usize, usize, usize),
    /// Local fault plan for this run (chaos tests via
    /// [`RunOpts::faults`](crate::api::RunOpts)); `None` falls through
    /// to the process-global plan. Disarmed cost at the top of
    /// [`EvalContext::eval_batch`]: one `None` branch plus one relaxed
    /// atomic load — the hot path stays zero-alloc.
    faults: Option<Arc<crate::util::faults::FaultPlan>>,
}

impl EvalContext {
    pub fn new(backend: Backend, budget: usize) -> EvalContext {
        let spec = crate::genome::GenomeSpec::for_workload(backend.workload());
        let stage = match &backend {
            Backend::Native(e) => Some(StageEngine::new(Arc::clone(e), budget)),
            #[cfg(feature = "xla")]
            Backend::Pjrt(_) => None,
        };
        EvalContext {
            backend,
            spec,
            budget,
            telemetry: Telemetry::new(),
            pool: None,
            cache_enabled: true,
            interner: Interner::new(budget.max(1)),
            genome_results: Vec::new(),
            design_results: Vec::new(),
            stage,
            staging: true,
            scratch: BatchScratch::default(),
            model_calls: 0,
            observer: None,
            stop_flag: None,
            suspend_flag: None,
            stopped: false,
            batches: 0,
            fence: None,
            metrics: None,
            published: (0, 0, 0),
            faults: None,
        }
    }

    /// Attach (or detach) a worker pool for native batch evaluation.
    pub fn with_pool(mut self, pool: Option<Arc<ThreadPool>>) -> EvalContext {
        self.pool = pool;
        self
    }

    /// In-place variant of [`EvalContext::with_pool`].
    pub fn set_pool(&mut self, pool: Option<Arc<ThreadPool>>) {
        self.pool = pool;
    }

    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// Worker threads evaluation fans out over (1 = serial).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.size())
    }

    /// Enable/disable the evaluation cache (on by default). Disabling is
    /// only useful for raw-throughput measurements; results never change.
    pub fn with_cache(mut self, enabled: bool) -> EvalContext {
        self.cache_enabled = enabled;
        self
    }

    /// Enable/disable the staged engine (on by default for native
    /// backends). Disabling forces every result-cache miss through the
    /// from-scratch decode → extract path — the reference the parity
    /// suite and the speedup microbenches compare against. Results and
    /// trajectories never change, only wall-clock cost.
    pub fn with_staging(mut self, enabled: bool) -> EvalContext {
        self.staging = enabled;
        self
    }

    /// Toggle the staged engine's batched SoA assembly phase (on by
    /// default for native backends). Off forces the per-genome assembly
    /// walk — the reference path the batched-parity suite compares
    /// against. Results and trajectories never change, only dispatch.
    pub fn with_batched(mut self, enabled: bool) -> EvalContext {
        if let Some(e) = &mut self.stage {
            e.set_batched(enabled);
        }
        self
    }

    /// Stage-level cache hits so far (up to 4 per evaluation: mapping +
    /// three format stages).
    pub fn stage_hits(&self) -> usize {
        self.stage.as_ref().map_or(0, |e| e.stage_hits())
    }

    /// Distinct genomes interned so far.
    pub fn interned(&self) -> usize {
        self.interner.len()
    }

    /// Attach a metrics scope ([`crate::obs`]): the context publishes
    /// eval/cache/validity counters, the generation count and the
    /// best-EDP gauge after every batch, and the embedded stage engine
    /// records its per-phase timings into the same scope. `None`
    /// detaches (the default — library callers opt in; the service
    /// attaches [`crate::obs::global`]).
    pub fn with_metrics(mut self, metrics: Option<Arc<Metrics>>) -> EvalContext {
        self.set_metrics(metrics);
        self
    }

    /// In-place variant of [`EvalContext::with_metrics`].
    pub fn set_metrics(&mut self, metrics: Option<Arc<Metrics>>) {
        if let Some(e) = &mut self.stage {
            e.set_metrics(metrics.clone());
        }
        self.metrics = metrics;
    }

    /// Attach a streaming [`SearchObserver`], called after every batch.
    /// Observers only *read* progress and can request an early stop —
    /// they never perturb a trajectory that runs to completion.
    pub fn with_observer(mut self, observer: Option<Box<dyn SearchObserver>>) -> EvalContext {
        self.observer = observer;
        self
    }

    /// In-place variant of [`EvalContext::with_observer`].
    pub fn set_observer(&mut self, observer: Option<Box<dyn SearchObserver>>) {
        self.observer = observer;
    }

    /// Attach a shared halt flag. Setting it (from any thread) cancels
    /// the search: the context reports an exhausted budget and every
    /// algorithm winds down through its normal exit path.
    pub fn with_stop_flag(mut self, flag: Option<Arc<AtomicBool>>) -> EvalContext {
        self.stop_flag = flag;
        self
    }

    /// Did an observer or the halt flag stop this run before the budget?
    pub fn stopped_early(&self) -> bool {
        self.stopped || self.stop_flag.as_ref().is_some_and(|f| f.load(Ordering::SeqCst))
    }

    /// Attach a shared suspend flag (see [`EvalContext::suspend_requested`]).
    pub fn with_suspend_flag(mut self, flag: Option<Arc<AtomicBool>>) -> EvalContext {
        self.suspend_flag = flag;
        self
    }

    /// In-place variant of [`EvalContext::with_suspend_flag`].
    pub fn set_suspend_flag(&mut self, flag: Option<Arc<AtomicBool>>) {
        self.suspend_flag = flag;
    }

    /// Attach a run-local fault plan (chaos tests). The `eval` fault
    /// point fires at the top of every [`EvalContext::eval_batch`] call;
    /// only `panic` and `delay` arms are meaningful there (the batch
    /// path has no error return).
    pub fn set_faults(&mut self, faults: Option<Arc<crate::util::faults::FaultPlan>>) {
        self.faults = faults;
    }

    /// Has a suspension been requested (from any thread)? Unlike the stop
    /// flag this never alters budget accounting: resumable optimizers poll
    /// it between batches/generations and return early with their state
    /// intact, ready for `Optimizer::suspend`. Optimizers that ignore it
    /// simply run to completion as before.
    pub fn suspend_requested(&self) -> bool {
        self.suspend_flag.as_ref().is_some_and(|f| f.load(Ordering::SeqCst))
    }

    /// The loop-top test every resumable optimizer shares: pause when the
    /// budget (or fence) is exhausted *or* a suspension is requested. Both
    /// conditions are state-preserving — post-exhaustion control flow
    /// consumes no budget and no RNG, so pausing here keeps uninterrupted
    /// trajectories bit-identical.
    pub fn should_pause(&self) -> bool {
        self.exhausted() || self.suspend_requested()
    }

    /// Batches evaluated so far (the observer's generation proxy).
    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Publish the telemetry accumulated since the last batch into the
    /// attached metrics scope (no-op without one). Counters receive
    /// deltas — they stay monotone across however many contexts share
    /// a scope (e.g. every job in the service feeding [`crate::obs::global`]).
    fn publish_metrics(&mut self) {
        let Some(m) = &self.metrics else { return };
        let (evals0, valid0, hits0) = self.published;
        m.evals.add((self.telemetry.evals - evals0) as u64);
        m.valid_evals.add((self.telemetry.valid_evals - valid0) as u64);
        m.eval_cache_hits.add((self.telemetry.cache_hits - hits0) as u64);
        self.published =
            (self.telemetry.evals, self.telemetry.valid_evals, self.telemetry.cache_hits);
        m.batches.inc();
        m.interned.set(self.interner.len() as u64);
        if self.telemetry.best_edp.is_finite() {
            m.best_edp.set(self.telemetry.best_edp);
        }
    }

    /// Bump batch count and notify the observer, honoring its verdict.
    fn finish_batch(&mut self) {
        self.batches += 1;
        self.publish_metrics();
        if let Some(obs) = self.observer.as_mut() {
            let progress = Progress {
                batches: self.batches,
                evals: self.telemetry.evals,
                valid_evals: self.telemetry.valid_evals,
                cache_hits: self.telemetry.cache_hits,
                interned: self.telemetry.interned,
                stage_hits: self.telemetry.stage_hits,
                best_edp: self.telemetry.best_edp,
                budget: self.budget,
            };
            if obs.on_batch(&progress) == SearchControl::Stop {
                self.stopped = true;
                if let Some(f) = &self.stop_flag {
                    f.store(true, Ordering::SeqCst);
                }
            }
        }
    }

    /// Number of genomes actually sent to the model so far (submissions
    /// minus cache hits minus dead-on-arrival designs).
    pub fn model_calls(&self) -> usize {
        self.model_calls
    }

    /// Submissions served from the cache so far.
    pub fn cache_hits(&self) -> usize {
        self.telemetry.cache_hits
    }

    pub fn workload(&self) -> &Workload {
        self.backend.workload()
    }

    pub fn platform(&self) -> &Platform {
        self.backend.platform()
    }

    pub fn used(&self) -> usize {
        self.telemetry.evals
    }

    /// Cap the context at an *absolute* submission count below the
    /// budget: while a fence is set, [`EvalContext::remaining`] reports
    /// `min(budget, fence) - used`, so any algorithm handed this context
    /// winds down through its normal budget-exhausted path at the fence.
    /// `None` lifts the cap. This is how the portfolio meta-optimizer
    /// runs whole member searches against one shared budget/cache/pool.
    pub fn set_fence(&mut self, fence: Option<usize>) {
        self.fence = fence;
    }

    /// Reset the per-slice best-EDP window (read back with
    /// [`EvalContext::slice_best`]). Purely observational.
    pub fn begin_slice(&mut self) {
        self.telemetry.begin_slice();
    }

    /// Best valid EDP recorded since the last [`EvalContext::begin_slice`]
    /// (`f64::INFINITY` if none).
    pub fn slice_best(&self) -> f64 {
        self.telemetry.slice_best_edp
    }

    pub fn remaining(&self) -> usize {
        if self.stopped_early() {
            return 0;
        }
        let cap = self.fence.map_or(self.budget, |f| f.min(self.budget));
        cap.saturating_sub(self.used())
    }

    pub fn exhausted(&self) -> bool {
        self.remaining() == 0
    }

    /// Evaluate a batch, truncated to the remaining budget. Returns one
    /// result per *submitted* genome that fit in the budget.
    ///
    /// Every submission debits one evaluation from the budget; duplicates
    /// (within the batch or of anything evaluated before) are served from
    /// the cache without a model call. Unique genomes are evaluated in
    /// first-occurrence order, in parallel when a pool is attached.
    pub fn eval_batch(&mut self, genomes: &[Vec<u32>]) -> Vec<EvalResult> {
        // Chaos hook: an armed `eval` fault can panic or stall here,
        // simulating a poisoned cost model; disarmed this is one branch
        // + one relaxed load (`tests/alloc_steady_state.rs` stands).
        if let Some(crate::util::faults::FaultAction::Panic) =
            crate::util::faults::check(self.faults.as_ref(), crate::util::faults::points::EVAL)
        {
            panic!("injected panic at fault point 'eval'");
        }
        let n = genomes.len().min(self.remaining());
        if n == 0 {
            return Vec::new();
        }
        let batch = &genomes[..n];

        let hits = resolve_interned(
            &mut self.interner,
            &mut self.genome_results,
            &mut self.scratch,
            self.cache_enabled,
            batch,
        );
        self.model_calls += self.scratch.miss_genomes.len();
        let miss_results = match &mut self.stage {
            Some(engine) if self.staging => {
                engine.eval_batch(&self.scratch.miss_genomes, self.pool.as_ref())
            }
            _ => self.backend.eval(self.pool.as_ref(), &mut self.scratch.miss_genomes),
        };
        if self.cache_enabled {
            for (mid, r) in self.scratch.miss_ids.iter().zip(&miss_results) {
                if let Some(id) = mid {
                    self.genome_results[*id as usize] = Some(*r);
                }
            }
        }
        self.telemetry.cache_hits += hits;
        self.telemetry.interned = self.interner.len();
        if let Some(e) = &self.stage {
            self.telemetry.stage_hits = e.stage_hits();
        }

        let results = reassemble(&self.scratch.slots, &miss_results);
        for (g, r) in batch.iter().zip(&results) {
            self.telemetry.record(g, r);
        }
        self.finish_batch();
        results
    }

    /// Evaluate one genome (budget permitting).
    pub fn eval_one(&mut self, genome: &[u32]) -> Option<EvalResult> {
        self.eval_batch(std::slice::from_ref(&genome.to_vec())).pop()
    }

    /// Evaluate pre-decoded designs from a *foreign* encoding (the
    /// direct-value ablation baseline). `None` designs are dead on
    /// arrival (tiling-constraint violations) but still consume budget —
    /// the evaluator would have rejected them. `record` pairs each design
    /// with the genome to log in telemetry; it also keys the cache, in a
    /// namespace separate from [`EvalContext::eval_batch`]'s since foreign
    /// encodings may reuse gene vectors with different meanings.
    pub fn eval_designs(
        &mut self,
        record: &[Vec<u32>],
        designs: &[Option<Design>],
    ) -> Vec<EvalResult> {
        assert_eq!(record.len(), designs.len());
        let n = designs.len().min(self.remaining());
        if n == 0 {
            return Vec::new();
        }

        let keys = &record[..n];
        let hits = resolve_interned(
            &mut self.interner,
            &mut self.design_results,
            &mut self.scratch,
            self.cache_enabled,
            keys,
        );
        let miss_designs: Vec<Option<Design>> =
            self.scratch.miss_src.iter().map(|&i| designs[i].clone()).collect();
        self.model_calls += miss_designs.iter().filter(|d| d.is_some()).count();
        let miss_results = self.backend.eval_designs_batch(self.pool.as_ref(), miss_designs);
        if self.cache_enabled {
            for (mid, r) in self.scratch.miss_ids.iter().zip(&miss_results) {
                if let Some(id) = mid {
                    self.design_results[*id as usize] = Some(*r);
                }
            }
        }
        self.telemetry.cache_hits += hits;
        self.telemetry.interned = self.interner.len();

        let results = reassemble(&self.scratch.slots, &miss_results);
        for (g, r) in keys.iter().zip(&results) {
            self.telemetry.record(g, r);
        }
        self.finish_batch();
        results
    }

    /// Snapshot everything a resumed run needs to continue bit-identically:
    /// telemetry (bit-exact floats), the interned genome store in id order,
    /// both result-cache tables, model-call/batch counters and the stage
    /// engine's hit/miss counters. Paired with
    /// [`EvalContext::restore_eval_state`]; the backend itself (workload,
    /// platform, budget) is *not* captured — the caller rebuilds the
    /// context from its original request and restores the state into it.
    pub fn capture_eval_state(&self) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.cache_enabled,
            "suspend requires the evaluation cache (cache=false contexts cannot checkpoint)"
        );
        let result_json = |r: &Option<EvalResult>| match r {
            Some(r) => Json::Arr(vec![
                f64_bits(r.energy_pj),
                f64_bits(r.cycles),
                f64_bits(r.edp),
                Json::Bool(r.valid),
            ]),
            None => Json::Null,
        };
        let genomes = Json::Arr(
            (0..self.interner.len() as u32)
                .map(|id| {
                    let g = self.interner.genome(id);
                    Json::Arr(g.iter().map(|&x| Json::num(x as f64)).collect())
                })
                .collect(),
        );
        Ok(Json::obj(vec![
            ("budget", Json::num(self.budget as f64)),
            ("telemetry", self.telemetry.to_state_json()),
            ("genomes", genomes),
            (
                "genome_results",
                Json::Arr(self.genome_results.iter().map(result_json).collect()),
            ),
            (
                "design_results",
                Json::Arr(self.design_results.iter().map(result_json).collect()),
            ),
            ("model_calls", Json::num(self.model_calls as f64)),
            ("batches", Json::num(self.batches as f64)),
            (
                "stage",
                match &self.stage {
                    Some(e) => Json::obj(vec![
                        ("hits", Json::num(e.stage_hits() as f64)),
                        ("misses", Json::num(e.stage_misses() as f64)),
                    ]),
                    None => Json::Null,
                },
            ),
        ]))
    }

    /// Restore a [`EvalContext::capture_eval_state`] snapshot into a fresh
    /// context built for the *same* request (workload/platform/budget).
    /// Genomes are re-interned in id order (dense ids are sequential, so
    /// they come back identical), the result tables are reloaded, and the
    /// stage engine is re-warmed by replaying the cached genomes through
    /// it — after which its hit/miss counters are rebased to the
    /// checkpointed values so post-resume telemetry matches an
    /// uninterrupted run.
    pub fn restore_eval_state(&mut self, state: &Json) -> anyhow::Result<()> {
        use anyhow::{anyhow, ensure};
        ensure!(self.cache_enabled, "resume requires the evaluation cache");
        ensure!(
            self.used() == 0 && self.batches == 0 && self.interner.is_empty(),
            "eval state must be restored into a fresh context"
        );
        let budget = state
            .get("budget")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("eval state is missing 'budget'"))? as usize;
        ensure!(
            budget == self.budget,
            "checkpoint budget {budget} does not match context budget {}",
            self.budget
        );
        let telemetry = Telemetry::from_state_json(
            state.get("telemetry").ok_or_else(|| anyhow!("eval state is missing 'telemetry'"))?,
        )?;
        for (i, gj) in state
            .get("genomes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("eval state is missing 'genomes'"))?
            .iter()
            .enumerate()
        {
            let g: Vec<u32> = gj
                .as_arr()
                .ok_or_else(|| anyhow!("eval state genome {i} must be an array"))?
                .iter()
                .map(|x| {
                    x.as_u64()
                        .map(|v| v as u32)
                        .ok_or_else(|| anyhow!("eval state genome {i} has a non-integer gene"))
                })
                .collect::<anyhow::Result<_>>()?;
            let id = self
                .interner
                .intern(&g)
                .ok_or_else(|| anyhow!("interner capacity exceeded restoring genome {i}"))?;
            ensure!(id as usize == i, "interner id drift restoring genome {i} (got {id})");
        }
        let results_of = |key: &str| -> anyhow::Result<Vec<Option<EvalResult>>> {
            state
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("eval state is missing '{key}'"))?
                .iter()
                .map(|r| match r {
                    Json::Null => Ok(None),
                    Json::Arr(a) if a.len() == 4 => {
                        let f = |i: usize| {
                            f64_from_bits(&a[i])
                                .ok_or_else(|| anyhow!("'{key}' entry field {i} must be f64 bits"))
                        };
                        Ok(Some(EvalResult {
                            energy_pj: f(0)?,
                            cycles: f(1)?,
                            edp: f(2)?,
                            valid: a[3]
                                .as_bool()
                                .ok_or_else(|| anyhow!("'{key}' entry field 3 must be a bool"))?,
                        }))
                    }
                    _ => Err(anyhow!("'{key}' entries must be null or 4-element arrays")),
                })
                .collect()
        };
        let genome_results = results_of("genome_results")?;
        let design_results = results_of("design_results")?;
        let interned = self.interner.len();
        ensure!(
            genome_results.len() <= interned && design_results.len() <= interned,
            "eval state result tables are longer than the genome store"
        );
        self.genome_results = genome_results;
        self.design_results = design_results;
        if self.stage.is_some() && self.staging {
            // Re-warm the stage caches: every cached genome-namespace
            // result once flowed through the stage engine, so replaying
            // them (in id order = first-miss order) rebuilds the mapping
            // and format caches the resumed search will hit.
            let warm: Vec<Arc<[u32]>> = self
                .genome_results
                .iter()
                .enumerate()
                .filter(|(_, r)| r.is_some())
                .map(|(id, _)| Arc::clone(self.interner.genome(id as u32)))
                .collect();
            if !warm.is_empty() {
                let pool = self.pool.as_ref();
                self.stage.as_mut().unwrap().eval_batch(&warm, pool);
            }
        }
        if let Some(e) = &mut self.stage {
            let hits = state
                .get("stage")
                .and_then(|s| s.get("hits"))
                .and_then(Json::as_u64)
                .unwrap_or(0) as usize;
            let misses = state
                .get("stage")
                .and_then(|s| s.get("misses"))
                .and_then(Json::as_u64)
                .unwrap_or(0) as usize;
            e.set_counters(hits, misses);
        }
        self.telemetry = telemetry;
        self.model_calls =
            state.get("model_calls").and_then(Json::as_u64).unwrap_or(0) as usize;
        self.batches = state.get("batches").and_then(Json::as_u64).unwrap_or(0) as usize;
        Ok(())
    }

    /// Finalize into an outcome.
    pub fn outcome(self, method: &str) -> Outcome {
        let (model_calls, batches) = (self.model_calls, self.batches);
        let mut o = self.telemetry.into_outcome(
            method,
            &self.backend.workload().id,
            &self.backend.platform().name,
        );
        o.model_calls = model_calls;
        o.batches = batches;
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        EvalContext::new(Backend::native(w, Platform::edge()), budget)
    }

    #[test]
    fn budget_enforced() {
        let mut c = ctx(10);
        let mut rng = Pcg64::seeded(1);
        let genomes: Vec<_> = (0..20).map(|_| c.spec.random(&mut rng)).collect();
        let r = c.eval_batch(&genomes);
        assert_eq!(r.len(), 10);
        assert!(c.exhausted());
        assert!(c.eval_batch(&genomes).is_empty());
    }

    #[test]
    fn fence_caps_and_lifts() {
        let mut c = ctx(100);
        let mut rng = Pcg64::seeded(21);
        let genomes: Vec<_> = (0..30).map(|_| c.spec.random(&mut rng)).collect();
        c.set_fence(Some(10));
        assert_eq!(c.remaining(), 10);
        assert_eq!(c.eval_batch(&genomes).len(), 10);
        assert!(c.exhausted(), "fenced context reports exhaustion at the fence");
        c.set_fence(None);
        assert_eq!(c.remaining(), 90);
        assert_eq!(c.eval_batch(&genomes).len(), 30);
        // A fence above the budget never extends it.
        c.set_fence(Some(1_000));
        assert_eq!(c.remaining(), 60);
    }

    #[test]
    fn telemetry_tracks_best() {
        let mut c = ctx(100);
        let mut rng = Pcg64::seeded(2);
        let genomes: Vec<_> = (0..50).map(|_| c.spec.random(&mut rng)).collect();
        c.eval_batch(&genomes);
        let o = c.outcome("test");
        assert_eq!(o.evals, 50);
        assert!(o.best_edp > 0.0);
        assert!(o.valid_evals <= o.evals);
        // Curve is monotone non-increasing.
        assert!(o.curve.windows(2).all(|w| w[1].1 <= w[0].1));
    }

    #[test]
    fn eval_one_consumes_budget() {
        let mut c = ctx(2);
        let mut rng = Pcg64::seeded(3);
        let g = c.spec.random(&mut rng);
        assert!(c.eval_one(&g).is_some());
        assert!(c.eval_one(&g).is_some());
        assert!(c.eval_one(&g).is_none());
    }

    #[test]
    fn parallel_matches_serial_results() {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let mut serial = EvalContext::new(Backend::native(w.clone(), Platform::edge()), 200);
        let pool = Arc::new(ThreadPool::new(4));
        let mut par =
            EvalContext::new(Backend::native(w, Platform::edge()), 200).with_pool(Some(pool));
        assert_eq!(par.threads(), 4);
        let mut rng = Pcg64::seeded(11);
        let genomes: Vec<_> = (0..100).map(|_| serial.spec.random(&mut rng)).collect();
        assert_eq!(serial.eval_batch(&genomes), par.eval_batch(&genomes));
        assert_eq!(serial.telemetry.curve, par.telemetry.curve);
    }

    #[test]
    fn duplicates_hit_cache_but_debit_budget() {
        let mut c = ctx(50);
        let mut rng = Pcg64::seeded(5);
        let g = c.spec.random(&mut rng);
        let batch = vec![g.clone(); 8];
        let r = c.eval_batch(&batch);
        assert_eq!(r.len(), 8);
        assert_eq!(c.used(), 8, "cache hits must still debit budget");
        assert_eq!(c.model_calls(), 1, "duplicates must not re-run the model");
        assert_eq!(c.cache_hits(), 7);
        assert!(r.iter().all(|x| *x == r[0]));
        // Hits persist across batches (generations) too.
        c.eval_batch(&batch);
        assert_eq!(c.model_calls(), 1);
        assert_eq!(c.used(), 16);
    }

    #[test]
    fn cache_disabled_reruns_model() {
        let mut c = ctx(50).with_cache(false);
        let mut rng = Pcg64::seeded(6);
        let g = c.spec.random(&mut rng);
        let batch = vec![g.clone(); 4];
        c.eval_batch(&batch);
        assert_eq!(c.model_calls(), 4);
        assert_eq!(c.cache_hits(), 0);
    }

    #[test]
    fn chunk_size_floor_and_grid() {
        // Per-job overhead floor: chunks are at least MIN_CHUNK items
        // (or the whole batch when smaller); large batches still produce
        // enough chunks to feed every worker.
        for n in [1usize, 2, 5, 7, 8, 9, 31, 100, 129, 1000, 20_000] {
            for workers in [1usize, 2, 4, 8, 16, 32, 64] {
                let c = chunk_size(n, workers);
                assert!(c >= 1, "n={n} w={workers}");
                assert!(c <= n.max(1), "chunk larger than batch: n={n} w={workers} c={c}");
                assert!(
                    c >= MIN_CHUNK.min(n),
                    "floor violated: n={n} w={workers} c={c}"
                );
                if n >= workers * 4 * MIN_CHUNK {
                    assert!(
                        n.div_ceil(c) >= workers,
                        "big batch under-feeds workers: n={n} w={workers} c={c}"
                    );
                }
            }
        }
        // The regression this guards: 100 items on a 32-worker pool used
        // to dispatch chunk-of-1 jobs (100 channel round-trips).
        assert_eq!(chunk_size(100, 32), MIN_CHUNK);
        assert_eq!(chunk_size(20_000, 8), 625); // big batches unchanged
    }

    #[test]
    fn range_chunks_share_the_min_chunk_floor() {
        for n in [0usize, 1, 2, 5, 7, 8, 9, 31, 100, 129, 1000, 20_000] {
            for workers in [1usize, 2, 4, 8, 16, 32, 64] {
                let ranges = range_chunks(n, workers);
                // Ordered, disjoint, covering exactly [0, n).
                let mut next = 0usize;
                for &(lo, hi) in &ranges {
                    assert_eq!(lo, next, "gap or overlap: n={n} w={workers}");
                    assert!(hi > lo, "empty range: n={n} w={workers}");
                    // Every range obeys the same floor as chunk_size
                    // (only the tail may fall short of it): tiny broods
                    // on many-core hosts must not turn into range-of-1
                    // dispatch.
                    assert!(
                        hi - lo >= MIN_CHUNK.min(n) || hi == n,
                        "floor violated: n={n} w={workers} range={lo}..{hi}"
                    );
                    assert_eq!(hi - lo, chunk_size(n, workers).min(n - lo));
                    next = hi;
                }
                assert_eq!(next, n, "ranges must cover the batch: n={n} w={workers}");
            }
        }
        // The same shape the chunk_size pins above encode: 100 items on
        // 32 workers → 12 full ranges of MIN_CHUNK + one 4-item tail.
        assert_eq!(range_chunks(100, 32).len(), 13);
        assert!(range_chunks(0, 8).is_empty());
    }

    #[test]
    fn fan_out_shared_returns_buffer_and_ordered_results() {
        let items: Vec<u32> = (0..1000).collect();
        let doubled: Vec<u32> = items.iter().map(|x| x * 2).collect();
        let pool = Arc::new(ThreadPool::new(4));
        let (back, pooled) = fan_out_shared(Some(&pool), items.clone(), |x| *x * 2);
        assert_eq!(back, items, "the lent buffer must come back intact");
        assert_eq!(pooled, doubled, "results must stay in submission order");
        let (back, serial) = fan_out_shared(None, items.clone(), |x| *x * 2);
        assert_eq!(back, items);
        assert_eq!(serial, doubled, "serial and pooled paths agree");
        let (state, indexed) =
            fan_out_indexed(Some(&pool), items.clone(), 1000, |items, i| items[i] * 2);
        assert_eq!(state, items);
        assert_eq!(indexed, doubled);
    }

    #[test]
    fn staging_off_matches_staged_bitwise() {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let mut staged = EvalContext::new(Backend::native(w.clone(), Platform::edge()), 400);
        let mut scratch =
            EvalContext::new(Backend::native(w, Platform::edge()), 400).with_staging(false);
        let mut rng = Pcg64::seeded(13);
        let genomes: Vec<_> = (0..200).map(|_| staged.spec.random(&mut rng)).collect();
        assert_eq!(staged.eval_batch(&genomes), scratch.eval_batch(&genomes));
        assert_eq!(staged.telemetry.curve, scratch.telemetry.curve);
        assert_eq!(staged.cache_hits(), scratch.cache_hits());
        assert_eq!(scratch.stage_hits(), 0, "disabled staging must not touch stages");
    }

    #[test]
    fn interned_and_stage_hits_observable() {
        let mut c = ctx(100);
        let mut rng = Pcg64::seeded(15);
        let base = c.spec.random(&mut rng);
        // 10 strategy-only offspring + the base twice (result-cache hit).
        let mut batch = vec![base.clone(), base.clone()];
        for i in 0..10u32 {
            let mut g = base.clone();
            g[c.spec.sg_start] = i % 7;
            batch.push(g);
        }
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        c.set_observer(Some(Box::new(move |p: &Progress| {
            sink.lock().unwrap().push((p.interned, p.stage_hits));
            SearchControl::Continue
        })));
        c.eval_batch(&batch);
        // Distinct keys: base + offspring with sg gene 0..6 where gene 0
        // reproduces the base (i = 0 and 7 collide with it): 7 distinct.
        assert_eq!(c.interned(), 7);
        assert_eq!(c.telemetry.interned, 7);
        // 6 distinct non-base offspring share the base's mapping + 3
        // format stages (the base itself is the one stage miss).
        assert_eq!(c.stage_hits(), 6 * 4);
        assert_eq!(c.telemetry.stage_hits, 24);
        let seen = seen.lock().unwrap();
        assert_eq!(seen.as_slice(), &[(7, 24)], "observer must see the counters");
        let o = c.outcome("probe");
        assert_eq!(o.interned, 7);
        assert_eq!(o.stage_hits, 24);
    }

    #[test]
    fn metrics_scope_publishes_per_batch_deltas() {
        let m = Arc::new(Metrics::new());
        let mut c = ctx(100).with_metrics(Some(Arc::clone(&m)));
        let mut rng = Pcg64::seeded(41);
        let g = c.spec.random(&mut rng);
        let batch = vec![g.clone(); 6];
        c.eval_batch(&batch);
        c.eval_batch(&batch);
        assert_eq!(m.evals.get(), 12, "counters accumulate deltas, not totals");
        assert_eq!(m.eval_cache_hits.get() as usize, c.cache_hits());
        assert_eq!(m.batches.get(), 2);
        assert_eq!(m.interned.get() as usize, c.interned());
        assert_eq!(m.valid_evals.get() as usize, c.telemetry.valid_evals);
        assert!(
            m.best_edp.get() == c.telemetry.best_edp || !c.telemetry.best_edp.is_finite(),
            "gauge mirrors best EDP once a valid design exists"
        );
        // Stage engine shares the scope: phase timings were sampled for
        // the one non-empty miss batch (the all-hit batch never reaches
        // the engine).
        assert_eq!(m.stage_ns[0].snapshot().count, 1);
        // Contexts without a scope touch nothing (the default path).
        let before = m.evals.get();
        ctx(50).eval_batch(&batch);
        assert_eq!(m.evals.get(), before);
    }

    #[test]
    fn observer_streams_progress() {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        let mut c = ctx(100).with_observer(Some(Box::new(move |p: &Progress| {
            sink.lock().unwrap().push(p.clone());
            SearchControl::Continue
        })));
        let mut rng = Pcg64::seeded(7);
        let genomes: Vec<_> = (0..10).map(|_| c.spec.random(&mut rng)).collect();
        c.eval_batch(&genomes[..5]);
        c.eval_batch(&genomes[5..]);
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 2);
        assert_eq!(seen[0].batches, 1);
        assert_eq!(seen[0].evals, 5);
        assert_eq!(seen[1].evals, 10);
        assert_eq!(seen[1].budget, 100);
    }

    #[test]
    fn observer_stop_halts_search() {
        let mut c = ctx(1_000).with_observer(Some(Box::new(|p: &Progress| {
            if p.evals >= 20 {
                SearchControl::Stop
            } else {
                SearchControl::Continue
            }
        })));
        let mut rng = Pcg64::seeded(8);
        loop {
            let genomes: Vec<_> = (0..10).map(|_| c.spec.random(&mut rng)).collect();
            if c.eval_batch(&genomes).is_empty() {
                break;
            }
        }
        assert!(c.stopped_early());
        assert_eq!(c.used(), 20, "stopped after the second batch");
    }

    #[test]
    fn suspend_flag_does_not_affect_budget() {
        let flag = Arc::new(AtomicBool::new(false));
        let mut c = ctx(50).with_suspend_flag(Some(Arc::clone(&flag)));
        assert!(!c.suspend_requested());
        flag.store(true, Ordering::SeqCst);
        assert!(c.suspend_requested());
        assert!(c.should_pause());
        assert_eq!(c.remaining(), 50, "suspension must not consume budget");
        let mut rng = Pcg64::seeded(33);
        let g: Vec<_> = (0..5).map(|_| c.spec.random(&mut rng)).collect();
        assert_eq!(c.eval_batch(&g).len(), 5, "in-flight batches still evaluate");
        assert!(!c.stopped_early());
    }

    #[test]
    fn eval_state_round_trip_preserves_everything() {
        let mut a = ctx(100);
        let mut rng = Pcg64::seeded(31);
        let genomes: Vec<_> = (0..30).map(|_| a.spec.random(&mut rng)).collect();
        a.eval_batch(&genomes[..20]);
        a.eval_batch(&genomes[..5]); // cache hits
        let state = Json::parse(&a.capture_eval_state().unwrap().dumps()).unwrap();
        let mut b = ctx(100);
        b.restore_eval_state(&state).unwrap();
        assert_eq!(b.used(), a.used());
        assert_eq!(b.model_calls(), a.model_calls());
        assert_eq!(b.cache_hits(), a.cache_hits());
        assert_eq!(b.interned(), a.interned());
        assert_eq!(b.batches(), a.batches());
        assert_eq!(b.telemetry.curve, a.telemetry.curve);
        assert_eq!(b.stage_hits(), a.stage_hits());
        // Continuing both contexts stays bit-identical: same results,
        // same cache behavior, same stage-counter evolution.
        let ra = a.eval_batch(&genomes);
        let rb = b.eval_batch(&genomes);
        assert_eq!(ra, rb);
        assert_eq!(a.telemetry.curve, b.telemetry.curve);
        assert_eq!(a.model_calls(), b.model_calls());
        assert_eq!(a.cache_hits(), b.cache_hits());
        assert_eq!(a.stage_hits(), b.stage_hits());
    }

    #[test]
    fn restore_rejects_bad_targets() {
        let mut a = ctx(50);
        let mut rng = Pcg64::seeded(32);
        let genomes: Vec<_> = (0..5).map(|_| a.spec.random(&mut rng)).collect();
        a.eval_batch(&genomes);
        let state = a.capture_eval_state().unwrap();
        // Budget mismatch.
        assert!(ctx(60).restore_eval_state(&state).is_err());
        // Dirty context.
        let mut dirty = ctx(50);
        dirty.eval_batch(&genomes[..1]);
        assert!(dirty.restore_eval_state(&state).is_err());
        // Cache-disabled context cannot checkpoint either way.
        assert!(ctx(50).with_cache(false).restore_eval_state(&state).is_err());
        assert!(ctx(50).with_cache(false).capture_eval_state().is_err());
        // A fresh matching context accepts it.
        assert!(ctx(50).restore_eval_state(&state).is_ok());
    }

    #[test]
    fn stop_flag_cancels_externally() {
        let flag = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut c = ctx(100).with_stop_flag(Some(Arc::clone(&flag)));
        let mut rng = Pcg64::seeded(9);
        let genomes: Vec<_> = (0..5).map(|_| c.spec.random(&mut rng)).collect();
        assert_eq!(c.eval_batch(&genomes).len(), 5);
        flag.store(true, std::sync::atomic::Ordering::SeqCst);
        assert!(c.exhausted());
        assert!(c.eval_batch(&genomes).is_empty());
        assert!(c.stopped_early());
    }
}
