//! E6/E9 / Table IV — EDP of Sparseloop-Mapper-like, SAGE-like and
//! SparseMap across all 28 Table III workloads × 3 platforms, plus the
//! headline geomean reduction ratios from the abstract.

use super::{write_csv, ExpConfig};
use crate::api::{run_batch, SearchRequest};
use crate::arch::Platform;
use crate::util::stats::geomean;
use crate::util::table::{ratio, sci, Table};
use crate::workload::table3;

pub const TABLE4_METHODS: &[&str] = &["sparseloop", "sage-like", "sparsemap"];

/// One cell of Table IV.
#[derive(Clone, Debug)]
pub struct Cell {
    pub workload: String,
    pub platform: String,
    pub method: String,
    pub edp: f64,
    pub valid_ratio: f64,
}

/// Run the full (or restricted) matrix through the batch API (arms
/// evaluate serially inside; the parallelism is across arms).
pub fn run_matrix(cfg: &ExpConfig, workloads: &[String]) -> Vec<Cell> {
    let requests: Vec<SearchRequest> = workloads
        .iter()
        .flat_map(|w| {
            Platform::all().into_iter().flat_map(move |p| {
                TABLE4_METHODS
                    .iter()
                    .map(move |m| {
                        SearchRequest::new()
                            .workload_named(w)
                            .platform(p.clone())
                            .method(m)
                            .budget(cfg.budget)
                            .seed(cfg.seed)
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    let reports = run_batch(requests, cfg.threads.max(1)).expect("table4 arms validate");
    reports
        .into_iter()
        .map(|r| {
            let o = r.into_outcome();
            Cell {
                workload: o.workload.clone(),
                platform: o.platform.clone(),
                method: o.method.clone(),
                edp: o.best_edp,
                valid_ratio: o.valid_ratio(),
            }
        })
        .collect()
}

/// Geomean EDP reduction of SparseMap vs `method` on `platform`.
pub fn reduction(cells: &[Cell], method: &str, platform: &str) -> f64 {
    let ratios: Vec<f64> = cells
        .iter()
        .filter(|c| c.method == "sparsemap" && c.platform == platform && c.edp.is_finite())
        .filter_map(|ours| {
            cells
                .iter()
                .find(|c| {
                    c.method == method
                        && c.platform == platform
                        && c.workload == ours.workload
                })
                .map(|theirs| {
                    if theirs.edp.is_finite() {
                        (theirs.edp / ours.edp).max(1e-6)
                    } else {
                        1e6 // the baseline found nothing valid
                    }
                })
        })
        .collect();
    geomean(&ratios)
}

pub fn run(
    cfg: &ExpConfig,
    subset: Option<Vec<String>>,
    summary_only: bool,
) -> anyhow::Result<String> {
    let workloads: Vec<String> = match subset {
        Some(s) => s,
        None => table3::all().iter().map(|w| w.id.clone()).collect(),
    };
    let cells = run_matrix(cfg, &workloads);

    let mut csv = String::from("workload,platform,method,best_edp,valid_ratio\n");
    for c in &cells {
        csv.push_str(&format!(
            "{},{},{},{},{:.4}\n",
            c.workload,
            c.platform,
            c.method,
            if c.edp.is_finite() { format!("{:.6e}", c.edp) } else { String::new() },
            c.valid_ratio
        ));
    }
    write_csv(&cfg.out_dir, "table4.csv", &csv)?;

    let mut out = String::new();
    if !summary_only {
        let mut table = Table::new(&[
            "workload",
            "edge:sloop",
            "edge:sage",
            "edge:ours",
            "mobile:sloop",
            "mobile:sage",
            "mobile:ours",
            "cloud:sloop",
            "cloud:sage",
            "cloud:ours",
        ]);
        for wl in &workloads {
            let mut row = vec![wl.clone()];
            for plat in ["edge", "mobile", "cloud"] {
                for m in TABLE4_METHODS {
                    let cell = cells
                        .iter()
                        .find(|c| &c.workload == wl && c.platform == plat && &c.method == m);
                    row.push(match cell {
                        Some(c) if c.edp.is_finite() => sci(c.edp),
                        _ => "-".into(),
                    });
                }
            }
            table.row(row);
        }
        out.push_str(&format!(
            "Table IV — best EDP per (workload, platform, method), budget {}\n{}",
            cfg.budget,
            table.render()
        ));
    }

    out.push_str("\nHeadline geomean EDP reductions (SparseMap vs ...):\n");
    for plat in ["edge", "mobile", "cloud"] {
        out.push_str(&format!(
            "  {:6}: vs SAGE-like {:>8}   vs Sparseloop {:>8}\n",
            plat,
            ratio(reduction(&cells, "sage-like", plat)),
            ratio(reduction(&cells, "sparseloop", plat)),
        ));
    }
    out.push_str("  (paper: 26.8x/19.2x/171.4x vs SAGE; 8.8x/4.5x/158.9x vs Sparseloop)\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_matrix_and_headline_shape() {
        let cfg = ExpConfig {
            budget: 800,
            threads: 8,
            out_dir: std::env::temp_dir().join("sparsemap_t4"),
            ..Default::default()
        };
        let cells = run_matrix(&cfg, &vec!["mm1".to_string(), "conv11".to_string()]);
        assert_eq!(cells.len(), 2 * 3 * 3);
        // Smoke-scale shape check: SparseMap must be in the same league
        // as both baselines at a 800-sample budget (its calibration +
        // HSHI overhead is amortized at the paper's 20k budget, where it
        // wins outright — EXPERIMENTS.md E6 records 6.5x/7.9x/9.3x vs
        // Sparseloop and larger vs SAGE-like).
        for plat in ["edge", "mobile", "cloud"] {
            for m in ["sage-like", "sparseloop"] {
                let r = reduction(&cells, m, plat);
                assert!(
                    r > 0.5,
                    "sparsemap lost to {m} on {plat}: geomean ratio {r}"
                );
            }
        }
    }
}
