//! Monte Carlo Tree Search baseline (§III.C), over the raw
//! direct-encoded space.
//!
//! The genome is built gene-by-gene: tree depth = gene index, actions =
//! (quantized) gene values. UCB1 selection, single-node expansion,
//! uniform random rollout completion, reward backpropagation. Rewards
//! map EDP to (0, 1] via a running-best ratio; dead individuals give 0 —
//! exactly the sparse-reward regime the paper argues MCTS struggles with
//! ("each node contains a large number of invalid branches").

use super::space::{DirectSpace, MAX_ACTIONS};
use crate::search::{EvalContext, Outcome};
use crate::util::rng::Pcg64;

struct Node {
    /// Children indexed by action index; 0 = unexpanded.
    children: Vec<usize>,
    visits: f64,
    value_sum: f64,
}

/// MCTS hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct MctsConfig {
    /// UCB1 exploration constant.
    pub c_uct: f64,
}

impl Default for MctsConfig {
    fn default() -> Self {
        MctsConfig { c_uct: 1.4 }
    }
}

/// Config-parameterized core against a borrowed context (the registry /
/// portfolio entry point; telemetry accumulates in `ctx`).
pub fn mcts_with(ctx: &mut EvalContext, cfg: &MctsConfig, seed: u64) {
    let space = DirectSpace::new(ctx, seed);
    let mut rng = Pcg64::seeded(seed);
    let c_uct = cfg.c_uct;
    let n_genes = space.len();
    // Precompute the per-depth action sets.
    let actions: Vec<Vec<u32>> =
        (0..n_genes).map(|i| space.actions(i, MAX_ACTIONS)).collect();

    let mut nodes: Vec<Node> = vec![Node {
        children: vec![0; actions[0].len()],
        visits: 0.0,
        value_sum: 0.0,
    }];
    let mut best_edp_seen = f64::INFINITY;

    while !ctx.exhausted() {
        // --- selection + expansion ---------------------------------------
        let mut genome: Vec<u32> = Vec::with_capacity(n_genes);
        let mut path: Vec<usize> = vec![0];
        let mut node = 0usize;
        let mut depth = 0usize;
        while depth < n_genes {
            let acts = &actions[depth];
            let parent_visits = nodes[node].visits.max(1.0);
            let mut best_a = 0;
            let mut best_score = f64::NEG_INFINITY;
            for a in 0..acts.len() {
                let child = nodes[node].children[a];
                let score = if child == 0 {
                    f64::INFINITY - a as f64 * 1e-9 // break ties stably
                } else {
                    let ch = &nodes[child];
                    ch.value_sum / ch.visits.max(1e-9)
                        + c_uct * (parent_visits.ln() / ch.visits.max(1e-9)).sqrt()
                };
                if score > best_score {
                    best_score = score;
                    best_a = a;
                }
            }
            genome.push(acts[best_a]);
            let child = nodes[node].children[best_a];
            if child == 0 {
                let next_width = if depth + 1 < n_genes {
                    actions[depth + 1].len()
                } else {
                    0
                };
                nodes.push(Node {
                    children: vec![0; next_width],
                    visits: 0.0,
                    value_sum: 0.0,
                });
                let new_id = nodes.len() - 1;
                nodes[node].children[best_a] = new_id;
                path.push(new_id);
                depth += 1;
                break;
            }
            node = child;
            path.push(node);
            depth += 1;
        }
        // --- rollout: random completion over the action sets ----------------
        for d in depth..n_genes {
            genome.push(space.sample_action(d, &mut rng));
        }
        // --- evaluation ---------------------------------------------------
        let results = space.eval(ctx, std::slice::from_ref(&genome));
        let Some(result) = results.first() else { break };
        let reward = if result.valid {
            best_edp_seen = best_edp_seen.min(result.edp);
            1.0 / (1.0 + (result.edp / best_edp_seen).ln().max(0.0))
        } else {
            0.0
        };
        // --- backpropagation ------------------------------------------------
        for &id in &path {
            nodes[id].visits += 1.0;
            nodes[id].value_sum += reward;
        }
    }
}

pub fn mcts(mut ctx: EvalContext, seed: u64) -> Outcome {
    mcts_with(&mut ctx, &MctsConfig::default(), seed);
    ctx.outcome("mcts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.3, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn mcts_runs_and_respects_budget() {
        let o = mcts(ctx(800), 3);
        assert_eq!(o.method, "mcts");
        assert!(o.evals <= 800);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = mcts(ctx(600), 11);
        let b = mcts(ctx(600), 11);
        assert_eq!(a.best_edp, b.best_edp);
    }

    #[test]
    fn suffers_sparse_rewards_in_raw_space() {
        let o = mcts(ctx(2_000), 4);
        assert!(o.valid_ratio() < 0.6, "valid ratio {}", o.valid_ratio());
    }
}
