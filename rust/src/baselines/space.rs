//! The *raw* search space the classical baselines operate on.
//!
//! The paper's PSO/MCTS/TBPSA/PPO/DQN baselines explore the design space
//! as characterized in §III.B — direct tile values, no prime-factor
//! encoding — which is precisely why they drown in invalid points (the
//! sparse-reward problem the paper highlights). Giving them SparseMap's
//! encoding would quietly hand them the paper's first contribution, so
//! they search [`DirectSpec`] instead.

use super::direct::DirectSpec;
use crate::genome::spec::FORMAT_GENES_PER_TENSOR;
use crate::genome::Design;
use crate::mapping::NUM_MAP_LEVELS;
use crate::model::EvalResult;
use crate::search::EvalContext;
use crate::workload::Workload;

/// Adapter bundling the direct genome spec with its workload.
pub struct DirectSpace {
    pub spec: DirectSpec,
    pub workload: Workload,
    /// Divisor sets per dimension — tile genes are snapped to divisors of
    /// their dimension (the natural discretization of a tile size; the
    /// joint product constraint still kills most combinations).
    divisors: Vec<Vec<u32>>,
}

impl DirectSpace {
    pub fn new(ctx: &EvalContext, seed: u64) -> DirectSpace {
        let workload = ctx.workload().clone();
        let spec = DirectSpec::new(&workload, seed);
        let divisors = spec
            .dim_sizes
            .iter()
            .map(|&n| (1..=n as u32).filter(|d| n as u32 % d == 0).collect())
            .collect();
        DirectSpace { spec, workload, divisors }
    }

    /// Snap a continuous tile-gene proposal to the nearest divisor of its
    /// dimension; non-tile genes round + clamp.
    pub fn snap(&self, i: usize, x: f64) -> u32 {
        let (lo, hi) = self.bounds(i);
        let v = (x.round() as i64).clamp(lo as i64, hi as i64) as u32;
        if i >= self.spec.tile_start && i < self.spec.format_start {
            let dim = (i - self.spec.tile_start) % self.spec.rank;
            *self.divisors[dim]
                .iter()
                .min_by_key(|&&d| (d as i64 - v as i64).unsigned_abs())
                .unwrap()
        } else {
            v
        }
    }

    /// Sample one action for gene `i` (used by rollouts). Tile genes are
    /// sampled with a small-divisor bias (u² index) — per-level tile
    /// factors multiply up, so unbiased sampling would overshoot the
    /// dimension almost surely and the rollout would never see a reward.
    pub fn sample_action(&self, i: usize, rng: &mut crate::util::rng::Pcg64) -> u32 {
        if i >= self.spec.tile_start && i < self.spec.format_start {
            let dim = (i - self.spec.tile_start) % self.spec.rank;
            let divs = &self.divisors[dim];
            let u = rng.f64();
            divs[((u * u * divs.len() as f64) as usize).min(divs.len() - 1)]
        } else {
            let (lo, hi) = self.bounds(i);
            rng.range_u32(lo, hi)
        }
    }

    /// Is gene `i` a tile gene?
    pub fn is_tile_gene(&self, i: usize) -> bool {
        i >= self.spec.tile_start && i < self.spec.format_start
    }

    pub fn len(&self) -> usize {
        self.spec.len
    }

    pub fn is_empty(&self) -> bool {
        self.spec.len == 0
    }

    /// Inclusive value bounds of gene `i`.
    pub fn bounds(&self, i: usize) -> (u32, u32) {
        let s = &self.spec;
        if i < NUM_MAP_LEVELS {
            (1, s.perm_table.len() as u32)
        } else if i < s.format_start {
            let dim = (i - s.tile_start) % s.rank;
            (1, s.dim_sizes[dim] as u32)
        } else if i < s.sg_start {
            (0, 4)
        } else {
            (0, 6)
        }
    }

    /// A discretized action set for tree/tabular methods (MCTS, PPO, DQN):
    /// divisors for tile genes (subsampled when plentiful), the full range
    /// for narrow genes, log-spaced values otherwise.
    pub fn actions(&self, i: usize, max_actions: usize) -> Vec<u32> {
        if i >= self.spec.tile_start && i < self.spec.format_start {
            let dim = (i - self.spec.tile_start) % self.spec.rank;
            let divs = &self.divisors[dim];
            if divs.len() <= max_actions {
                return divs.clone();
            }
            let mut out: Vec<u32> = (0..max_actions)
                .map(|k| divs[k * (divs.len() - 1) / (max_actions - 1)])
                .collect();
            out.dedup();
            return out;
        }
        let (lo, hi) = self.bounds(i);
        let width = (hi - lo + 1) as usize;
        if width <= max_actions {
            return (lo..=hi).collect();
        }
        let mut out: Vec<u32> = (0..max_actions)
            .map(|k| {
                let f = k as f64 / (max_actions - 1) as f64;
                let v = (lo as f64) * ((hi as f64) / (lo as f64).max(1.0)).powf(f);
                (v.round() as u32).clamp(lo, hi)
            })
            .collect();
        out.dedup();
        out
    }

    /// Decode with the L1_T tiles *derived* as the remainder quotient —
    /// how one actually implements a direct tiling search (choose the
    /// four inner levels, let the outermost temporal level absorb the
    /// rest). Still dead whenever the inner product doesn't divide the
    /// dimension, which is the common case.
    pub fn decode(&self, genome: &[u32]) -> Option<Design> {
        let s = &self.spec;
        let mut g = genome.to_vec();
        for dim in 0..s.rank {
            let inner: u64 = (1..NUM_MAP_LEVELS)
                .map(|l| g[s.tile_start + l * s.rank + dim] as u64)
                .product();
            let size = s.dim_sizes[dim];
            if inner == 0 || size % inner != 0 {
                return None; // tiling violation: dead individual
            }
            g[s.tile_start + dim] = (size / inner) as u32; // L1_T derived
        }
        s.decode(&self.workload, &g)
    }

    /// Evaluate direct genomes: decode (tiling violations are dead on
    /// arrival) and charge the context budget.
    pub fn eval(&self, ctx: &mut EvalContext, genomes: &[Vec<u32>]) -> Vec<EvalResult> {
        let designs: Vec<Option<Design>> =
            genomes.iter().map(|g| self.decode(g)).collect();
        ctx.eval_designs(genomes, &designs)
    }
}

/// Sanity constant shared by the discretized baselines.
pub const MAX_ACTIONS: usize = 24;
pub const FORMAT_GENES: usize = FORMAT_GENES_PER_TENSOR;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::util::rng::Pcg64;

    fn space() -> (DirectSpace, EvalContext) {
        let w = Workload::spmm("t", 16, 32, 16, 0.3, 0.3);
        let ctx = EvalContext::new(Backend::native(w, Platform::mobile()), 5_000);
        let s = DirectSpace::new(&ctx, 1);
        (s, ctx)
    }

    #[test]
    fn bounds_cover_all_segments() {
        let (s, _) = space();
        assert_eq!(s.bounds(0), (1, 6)); // 3! permutations
        let (lo, hi) = s.bounds(s.spec.tile_start);
        assert_eq!((lo, hi), (1, 16)); // M dim
        assert_eq!(s.bounds(s.spec.format_start), (0, 4));
        assert_eq!(s.bounds(s.spec.sg_start), (0, 6));
    }

    #[test]
    fn actions_quantize_wide_ranges() {
        let w = Workload::spmm("big", 12_288, 24_576, 12_288, 0.1, 0.1);
        let ctx = EvalContext::new(Backend::native(w, Platform::cloud()), 10);
        let s = DirectSpace::new(&ctx, 2);
        let acts = s.actions(s.spec.tile_start, MAX_ACTIONS);
        assert!(acts.len() <= MAX_ACTIONS);
        assert!(acts.len() >= MAX_ACTIONS / 2);
        assert_eq!(acts[0], 1);
        assert_eq!(*acts.last().unwrap(), 12_288);
        assert!(acts.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn eval_charges_budget_and_marks_dead() {
        let (s, mut ctx) = space();
        let mut rng = Pcg64::seeded(3);
        let genomes: Vec<Vec<u32>> = (0..100).map(|_| s.spec.random(&mut rng)).collect();
        let results = s.eval(&mut ctx, &genomes);
        assert_eq!(ctx.used(), 100);
        // Random direct genomes are overwhelmingly dead (tiling).
        let dead = results.iter().filter(|r| !r.valid).count();
        assert!(dead > 80, "only {dead}/100 dead");
    }
}
