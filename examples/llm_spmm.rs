//! LLM SpMM scenario: the sparseGPT-style workloads of Table III
//! (mm8–mm10: dense activations x 50%-pruned weights) searched across all
//! three platforms — the "adapting to new sparse workloads" story of the
//! paper's introduction.
//!
//! ```bash
//! cargo run --release --example llm_spmm -- [budget]
//! ```

use sparsemap::arch::Platform;
use sparsemap::baselines::run_method;
use sparsemap::search::{Backend, EvalContext};
use sparsemap::util::table::{sci, Table};
use sparsemap::workload::table3;

fn main() -> anyhow::Result<()> {
    let budget: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4_000);
    let workloads = ["mm8", "mm9", "mm10"];

    let mut table = Table::new(&["workload", "platform", "sparsemap EDP", "sage-like EDP", "gain"]);
    for wl in &workloads {
        let w = table3::by_id(wl).unwrap();
        println!(
            "{wl}: {}x{} (dense) x {}x{} @ {:.0}% weight density",
            w.dims[0].size,
            w.dims[1].size,
            w.dims[1].size,
            w.dims[2].size,
            100.0 * w.tensors[1].density
        );
        for plat in Platform::all() {
            let ours = run_method(
                "sparsemap",
                EvalContext::new(Backend::native(w.clone(), plat.clone()), budget),
                7,
            )?;
            let sage = run_method(
                "sage-like",
                EvalContext::new(Backend::native(w.clone(), plat.clone()), budget),
                7,
            )?;
            let gain = sage.best_edp / ours.best_edp;
            table.row(vec![
                wl.to_string(),
                plat.name.clone(),
                sci(ours.best_edp),
                if sage.found_valid() { sci(sage.best_edp) } else { "-".into() },
                if gain.is_finite() { format!("{gain:.2}x") } else { "inf".into() },
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "joint mapping+strategy search vs fixed-mapping format search, budget {budget}/arm"
    );
    Ok(())
}
