//! PJRT runtime integration: the AOT cost-model artifact must agree with
//! the native Rust evaluator (the FEATURE_SCHEMA_V1 contract), and the
//! gated-SpMM demo artifact must compute correct numerics.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it) and
//! a build with `--features xla` against the *real* xla-rs crate (the
//! in-tree `vendor/xla` stub errors on every call by design).

#![cfg(feature = "xla")]

use sparsemap::arch::Platform;
use sparsemap::model::NativeEvaluator;
use sparsemap::runtime::{BatchEvaluator, Runtime, SpmmDemo};
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::{table3, Workload};

fn runtime() -> Runtime {
    Runtime::from_default_dir().expect("artifacts missing — run `make artifacts`")
}

#[test]
fn meta_schema_matches_binary() {
    let rt = runtime();
    assert_eq!(rt.meta.schema_version, sparsemap::model::SCHEMA_VERSION);
    assert_eq!(rt.meta.num_features, sparsemap::model::NUM_FEATURES);
    assert_eq!(rt.meta.num_platform_features, sparsemap::model::NUM_PLATFORM_FEATURES);
}

#[test]
fn pjrt_matches_native_on_random_genomes() {
    let rt = runtime();
    for (w, plat) in [
        (Workload::spmm("t1", 16, 32, 16, 0.5, 0.25), Platform::edge()),
        (table3::by_id("mm3").unwrap(), Platform::cloud()),
        (table3::by_id("conv4").unwrap(), Platform::mobile()),
    ] {
        let pjrt = BatchEvaluator::new(&rt, w.clone(), plat.clone()).unwrap();
        let native = NativeEvaluator::new(w, plat);
        let mut rng = Pcg64::seeded(99);
        let genomes: Vec<Vec<u32>> =
            (0..300).map(|_| native.spec.random(&mut rng)).collect();
        let via_pjrt = pjrt.eval_genomes(&genomes).unwrap();
        for (g, p) in genomes.iter().zip(&via_pjrt) {
            let n = native.eval_genome(g);
            assert_eq!(n.valid, p.valid, "validity disagreement");
            if n.valid {
                let rel = (n.edp - p.edp).abs() / n.edp.max(1e-30);
                // f32 artifact vs f64 native: generous but tight enough to
                // catch any formula drift.
                assert!(rel < 2e-3, "EDP mismatch: native {} pjrt {} rel {rel}", n.edp, p.edp);
                let rel_e = (n.energy_pj - p.energy_pj).abs() / n.energy_pj.max(1e-30);
                assert!(rel_e < 2e-3, "energy mismatch rel {rel_e}");
            }
        }
    }
}

#[test]
fn pjrt_handles_partial_and_multi_chunk_batches() {
    let rt = runtime();
    let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
    let ev = BatchEvaluator::new(&rt, w, Platform::edge()).unwrap();
    let mut rng = Pcg64::seeded(5);
    for n in [1usize, 7, 255, 256, 257, 600] {
        let genomes: Vec<Vec<u32>> = (0..n).map(|_| ev.spec.random(&mut rng)).collect();
        let out = ev.eval_genomes(&genomes).unwrap();
        assert_eq!(out.len(), n, "batch size {n}");
    }
}

#[test]
fn spmm_demo_numerics() {
    let rt = runtime();
    let demo = SpmmDemo::new(&rt).unwrap();
    let (m, k, n) = (demo.m, demo.k, demo.n);
    let mut rng = Pcg64::seeded(3);
    let p: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let q: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let pm: Vec<f32> =
        (0..m * k).map(|_| if rng.chance(0.4) { 1.0 } else { 0.0 }).collect();
    let qm: Vec<f32> =
        (0..k * n).map(|_| if rng.chance(0.6) { 1.0 } else { 0.0 }).collect();

    let (z, eff) = demo.run(&p, &q, &pm, &qm).unwrap();

    // Reference on the Rust side.
    let mut z_ref = vec![0f32; m * n];
    let mut eff_ref = 0f64;
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for l in 0..k {
                acc += p[i * k + l] * pm[i * k + l] * q[l * n + j] * qm[l * n + j];
                eff_ref += (pm[i * k + l] * qm[l * n + j]) as f64;
            }
            z_ref[i * n + j] = acc;
        }
    }
    assert!((eff - eff_ref).abs() < 0.5, "effectual {eff} vs {eff_ref}");
    for (a, b) in z.iter().zip(&z_ref) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn effectual_count_matches_cost_model_gate_fraction() {
    // The demo's effectual-MAC ratio should track the cost model's
    // F_MAC_ENERGY_FRAC (= dp*dq under Gate P<->Q) for matching densities.
    let rt = runtime();
    let demo = SpmmDemo::new(&rt).unwrap();
    let (m, k, n) = (demo.m, demo.k, demo.n);
    let (dp, dq) = (0.5, 0.3);
    let mut rng = Pcg64::seeded(11);
    let p: Vec<f32> = (0..m * k).map(|_| 1.0).collect();
    let q: Vec<f32> = (0..k * n).map(|_| 1.0).collect();
    let pm: Vec<f32> =
        (0..m * k).map(|_| if rng.f64() < dp { 1.0 } else { 0.0 }).collect();
    let qm: Vec<f32> =
        (0..k * n).map(|_| if rng.f64() < dq { 1.0 } else { 0.0 }).collect();
    let (_, eff) = demo.run(&p, &q, &pm, &qm).unwrap();
    let frac = eff / (m * k * n) as f64;
    assert!((frac - dp * dq).abs() < 0.03, "effectual frac {frac} vs {}", dp * dq);
}

#[test]
fn pjrt_backend_runs_a_search() {
    use sparsemap::optimizer::run_method;
    use sparsemap::search::{Backend, EvalContext};
    let rt = runtime();
    let w = table3::by_id("conv11").unwrap();
    let backend = Backend::pjrt(&rt, w, Platform::cloud()).unwrap();
    let ctx = EvalContext::new(backend, 600);
    let o = run_method("sparsemap", ctx, 7).unwrap();
    assert!(o.evals <= 600);
    assert!(o.found_valid(), "PJRT-backed search found no valid design");
}
