//! Structural validity of a design point: sparse-strategy compatibility
//! (see [`crate::sparse::compat`]) and spatial fan-out limits. Capacity
//! checks are continuous (buffer utilization) and are computed inside the
//! cost arithmetic so the AOT evaluator can perform them too.

use crate::arch::Platform;
use crate::genome::Design;
use crate::mapping::MapLevel;
use crate::workload::Workload;

/// Why a design is structurally invalid.
#[derive(Clone, Debug, PartialEq)]
pub enum InvalidReason {
    /// Sparse-strategy internal inconsistency or strategy⇄mapping clash.
    Strategy(String),
    /// Spatial fan-out at L2_S exceeds the PE count.
    PeFanout { required: u64, available: u64 },
    /// Spatial fan-out at L3_S exceeds the MACs per PE.
    MacFanout { required: u64, available: u64 },
    /// GLB tile footprint exceeds capacity (reported by the cost model).
    GlbCapacity { words: f64, capacity: f64 },
    /// PE-buffer tile footprint exceeds capacity.
    PeCapacity { words: f64, capacity: f64 },
}

impl std::fmt::Display for InvalidReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InvalidReason::Strategy(s) => write!(f, "strategy: {s}"),
            InvalidReason::PeFanout { required, available } => {
                write!(f, "L2_S fanout {required} > {available} PEs")
            }
            InvalidReason::MacFanout { required, available } => {
                write!(f, "L3_S fanout {required} > {available} MACs/PE")
            }
            InvalidReason::GlbCapacity { words, capacity } => {
                write!(f, "GLB tile {words:.0} words > capacity {capacity:.0}")
            }
            InvalidReason::PeCapacity { words, capacity } => {
                write!(f, "PE tile {words:.0} words > capacity {capacity:.0}")
            }
        }
    }
}

/// Allocation-free twin of [`structural_problems`]:
/// `is_structurally_valid(d, w, p)` ⟺ `structural_problems(d, w, p).is_empty()`
/// (asserted over random designs by tests). This is the *whole-design
/// reference* for the validity bit; the evaluation hot path computes the
/// same predicate piecewise from stage-cached components in
/// `model::features::assemble` (fan-outs from the mapping stage,
/// stack/driver rules from the format stage + S/G genes) — the
/// equivalence of those pieces is pinned exhaustively in
/// `sparse::compat`'s tests and end-to-end by the parity suite.
pub fn is_structurally_valid(design: &Design, _w: &Workload, plat: &Platform) -> bool {
    design.strategy.check_ok()
        && design.mapping.fanout(MapLevel::L2S) <= plat.total_pes()
        && design.mapping.fanout(MapLevel::L3S) <= plat.macs_per_pe
}

/// Structural checks only (no capacity — that needs the traffic model).
pub fn structural_problems(
    design: &Design,
    _w: &Workload,
    plat: &Platform,
) -> Vec<InvalidReason> {
    let mut problems: Vec<InvalidReason> = design
        .strategy
        .check()
        .into_iter()
        .map(|p| InvalidReason::Strategy(p.to_string()))
        .collect();

    let pe_fan = design.mapping.fanout(MapLevel::L2S);
    if pe_fan > plat.total_pes() {
        problems.push(InvalidReason::PeFanout { required: pe_fan, available: plat.total_pes() });
    }
    let mac_fan = design.mapping.fanout(MapLevel::L3S);
    if mac_fan > plat.macs_per_pe {
        problems
            .push(InvalidReason::MacFanout { required: mac_fan, available: plat.macs_per_pe });
    }
    problems
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{decode, GenomeSpec};
    use crate::mapping::Mapping;
    use crate::sparse::{RankFormat, SgMechanism, SparseStrategy};

    fn base() -> (Workload, Platform) {
        (Workload::spmm("t", 16, 16, 16, 0.5, 0.5), Platform::edge())
    }

    #[test]
    fn valid_design_has_no_problems() {
        let (w, p) = base();
        let spec = GenomeSpec::for_workload(&w);
        let mut g = vec![1u32; spec.len()]; // all factors at L1_T
        for i in spec.format_start..spec.len() {
            g[i] = 0; // no compression, no S/G
        }
        let d = decode(&spec, &w, &g);
        assert!(structural_problems(&d, &w, &p).is_empty());
    }

    #[test]
    fn oversized_fanout_detected() {
        let (w, p) = base();
        let m = Mapping::trivial(&w, MapLevel::L2S); // 16*16*16 = 4096 PEs
        let d = Design { mapping: m, strategy: SparseStrategy::dense([0, 0, 0]) };
        let problems = structural_problems(&d, &w, &p);
        assert!(problems
            .iter()
            .any(|r| matches!(r, InvalidReason::PeFanout { required: 4096, available: 256 })));
    }

    #[test]
    fn mac_fanout_detected_on_edge() {
        let (w, p) = base();
        let m = Mapping::trivial(&w, MapLevel::L3S); // 4096 MACs in 1 PE
        let d = Design { mapping: m, strategy: SparseStrategy::dense([0, 0, 0]) };
        let problems = structural_problems(&d, &w, &p);
        assert!(problems.iter().any(|r| matches!(r, InvalidReason::MacFanout { .. })));
    }

    #[test]
    fn strategy_problems_propagate() {
        let (w, p) = base();
        let m = Mapping::trivial(&w, MapLevel::L3T);
        let mut s = SparseStrategy::dense([2, 2, 2]);
        s.sg[0] = SgMechanism::SkipPfromQ; // Q uncompressed
        let d = Design { mapping: m, strategy: s };
        let problems = structural_problems(&d, &w, &p);
        assert_eq!(problems.len(), 1);
        assert!(matches!(&problems[0], InvalidReason::Strategy(_)));
    }

    #[test]
    fn boolean_twin_matches_diagnostic_path() {
        // Random designs over a workload whose space contains valid and
        // invalid points in quantity: the booleans must agree everywhere.
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let p = Platform::edge();
        let spec = GenomeSpec::for_workload(&w);
        let mut rng = crate::util::rng::Pcg64::seeded(31);
        let (mut ok, mut bad) = (0, 0);
        for _ in 0..500 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            let diag = structural_problems(&d, &w, &p).is_empty();
            assert_eq!(is_structurally_valid(&d, &w, &p), diag);
            if diag {
                ok += 1;
            } else {
                bad += 1;
            }
        }
        assert!(ok > 0 && bad > 0, "sample covered only one verdict ({ok}/{bad})");
    }

    #[test]
    fn display_messages() {
        let r = InvalidReason::PeFanout { required: 512, available: 256 };
        assert!(r.to_string().contains("512"));
        let r2 = InvalidReason::GlbCapacity { words: 1e6, capacity: 65536.0 };
        assert!(r2.to_string().contains("capacity"));
        let _ = RankFormat::Bitmask; // silence unused import in some cfgs
    }
}
