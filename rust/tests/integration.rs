//! Cross-module integration tests: genome → model → search → report.

use sparsemap::arch::Platform;
use sparsemap::optimizer::{run_method, ALL_METHODS};
use sparsemap::genome::{decode, describe, GenomeSpec};
use sparsemap::model::NativeEvaluator;
use sparsemap::report::{fig2, fig7, ExpConfig};
use sparsemap::search::{Backend, EvalContext};
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::{table3, Workload};

fn ctx(w: Workload, plat: Platform, budget: usize) -> EvalContext {
    EvalContext::new(Backend::native(w, plat), budget)
}

#[test]
fn every_method_runs_on_every_platform() {
    let w = table3::by_id("conv11").unwrap();
    for plat in Platform::all() {
        for m in ALL_METHODS {
            let o = run_method(m, ctx(w.clone(), plat.clone(), 150), 3).unwrap();
            assert!(o.evals <= 150, "{m} on {} overspent", plat.name);
        }
    }
}

#[test]
fn sparsemap_beats_random_across_workload_mix() {
    // Core claim at small scale: at equal budget SparseMap's best EDP is
    // never worse than random search across a mixed workload set.
    let budget = 2_500;
    let mut wins = 0;
    let mut total = 0;
    for id in ["mm1", "mm3", "mm12", "conv11", "conv12"] {
        let w = table3::by_id(id).unwrap();
        let ours =
            run_method("sparsemap", ctx(w.clone(), Platform::mobile(), budget), 5).unwrap();
        let rand = run_method("random", ctx(w, Platform::mobile(), budget), 5).unwrap();
        total += 1;
        if ours.best_edp <= rand.best_edp {
            wins += 1;
        }
    }
    assert!(wins * 2 >= total, "sparsemap won only {wins}/{total}");
}

#[test]
fn best_genome_reproduces_reported_edp() {
    let w = table3::by_id("mm3").unwrap();
    let plat = Platform::cloud();
    let o = run_method("sparsemap", ctx(w.clone(), plat.clone(), 2_000), 9).unwrap();
    let g = o.best_genome.expect("no best genome");
    let ev = NativeEvaluator::new(w, plat);
    let r = ev.eval_genome(&g);
    assert!(r.valid);
    assert!((r.edp - o.best_edp).abs() / o.best_edp < 1e-9);
}

#[test]
fn best_design_is_renderable_and_consistent() {
    let w = table3::by_id("conv4").unwrap();
    let plat = Platform::mobile();
    let o = run_method("sparsemap", ctx(w.clone(), plat, 1_500), 2).unwrap();
    let spec = GenomeSpec::for_workload(&w);
    let g = o.best_genome.unwrap();
    let design = decode(&spec, &w, &g);
    assert!(design.mapping.respects(&w));
    let text = describe(&design, &w);
    assert!(text.contains("strategy:"), "{text}");
    // Every loop line mentions a dim of the workload.
    for line in text.lines().filter(|l| l.contains("for ")) {
        assert!(
            ["m", "k", "n"].iter().any(|d| line.trim_start().contains(&format!(" {d}"))
                || line.trim_start().starts_with("for ")
                || line.trim_start().starts_with("par-for ")),
            "odd loop line: {line}"
        );
    }
}

#[test]
fn fig2_report_generates() {
    let cfg = ExpConfig {
        out_dir: std::env::temp_dir().join("sm_it_fig2"),
        ..Default::default()
    };
    let r = fig2::run(&cfg).unwrap();
    assert!(r.contains("winner_edp"));
}

#[test]
fn fig7_sampling_is_deterministic_per_seed() {
    let cfg = ExpConfig { seed: 8, ..Default::default() };
    let a = fig7::sample(&cfg, 100);
    let b = fig7::sample(&cfg, 100);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.valid, y.valid);
        assert_eq!(x.mapping_pc.to_bits(), y.mapping_pc.to_bits());
    }
}

#[test]
fn multi_dim_workload_searches() {
    // Fig. 15: 4-dimensional batched SpMM flows through the whole stack.
    let w = Workload::spbmm("bmm", 4, 32, 64, 32, 0.3, 0.3);
    let o = run_method("sparsemap", ctx(w.clone(), Platform::mobile(), 1_500), 4).unwrap();
    assert!(o.found_valid(), "no valid design for the 4D workload");
    let spec = GenomeSpec::for_workload(&w);
    assert_eq!(spec.ranges[0].hi, 24); // 4! permutations
}

#[test]
fn table3_suite_all_evaluable() {
    // Every Table III workload must evaluate finitely on every platform
    // for at least one simple genome.
    let mut rng = Pcg64::seeded(1);
    for w in table3::all() {
        let spec = GenomeSpec::for_workload(&w);
        let ev = NativeEvaluator::new(w.clone(), Platform::cloud());
        let mut found_finite = false;
        for _ in 0..50 {
            let g = spec.random(&mut rng);
            let r = ev.eval_genome(&g);
            assert!(r.energy_pj.is_finite(), "{}: energy not finite", w.id);
            if r.valid {
                found_finite = true;
                break;
            }
        }
        // Not all workloads must yield a valid point in 50 tries, but the
        // evaluation itself must never blow up. (Validity coverage is
        // asserted per-search elsewhere.)
        let _ = found_finite;
    }
}

#[test]
fn dead_individuals_have_zero_fitness_and_infinite_edp() {
    let w = Workload::spmm("t", 256, 256, 256, 0.5, 0.5);
    let ev = NativeEvaluator::new(w, Platform::edge());
    let mut g = vec![1u32; ev.spec.len()];
    for i in ev.spec.factor_start..ev.spec.format_start {
        g[i] = 3; // all spatial at L2_S: fanout 2^24 >> 256
    }
    let r = ev.eval_genome(&g);
    assert!(!r.valid);
    assert!(r.edp.is_infinite());
    assert_eq!(r.fitness(), 0.0);
}
