//! Scenario embeddings: a fixed-length numeric fingerprint of one
//! (workload, platform) pair, comparable across searches.
//!
//! The embedding is what the design memory indexes: two scenarios whose
//! embeddings are close should find each other's elite designs useful as
//! warm-start seeds. The vector is **fixed-length** ([`EMBED_DIM`]) by
//! construction — the record store persists it as a fixed-layout segment
//! and rejects any file whose header advertises a different dimension,
//! so an embedding-layout change is a store format change, never a
//! silent misread.
//!
//! Layout (all entries finite, final vector L2-normalized):
//!
//! | slots  | content                                                  |
//! |--------|----------------------------------------------------------|
//! | 0..3   | workload kind one-hot (SpMM, SpConv, SpBMM)              |
//! | 3      | rank / MAX_RANK                                          |
//! | 4      | log2(total dense MACs)                                   |
//! | 5..17  | per-dimension log2(padded size), zero-padded to MAX_RANK |
//! | 17..26 | per-tensor density stats (P, Q, Z): mean density, P95    |
//! |        | tile occupancy ratio, tile sizing ratio                  |
//! | 26..35 | platform constants (log-scaled geometry and bandwidths)  |

use crate::arch::Platform;
use crate::workload::{Workload, WorkloadKind, MAX_RANK, NUM_TENSORS};

/// Length of every scenario embedding. Changing this (or the slot
/// layout above) requires bumping [`super::record::MEMORY_VERSION`].
pub const EMBED_DIM: usize = 35;

/// Tile size (elements) at which the per-tensor occupancy statistics are
/// probed — one inner PE-buffer-ish tile, the scale at which sparsity
/// *shape* (block/banded/skew) differentiates models with equal mean.
const PROBE_TILE_ELEMS: f64 = 256.0;

/// Compute the scenario embedding for one (workload, platform) pair.
/// Deterministic, allocation-free and total: every workload/platform
/// that passes validation embeds to a finite, L2-normalized vector.
pub fn scenario_embedding(w: &Workload, p: &Platform) -> [f64; EMBED_DIM] {
    let mut e = [0.0f64; EMBED_DIM];
    let kind_slot = match w.kind {
        WorkloadKind::SpMM => 0,
        WorkloadKind::SpConv => 1,
        WorkloadKind::SpBMM => 2,
    };
    e[kind_slot] = 1.0;
    e[3] = w.rank() as f64 / MAX_RANK as f64;
    e[4] = w.total_ops().max(1.0).log2();
    for (i, d) in w.dims.iter().take(MAX_RANK).enumerate() {
        e[5 + i] = (d.padded.max(1) as f64).log2();
    }
    for t in 0..NUM_TENSORS {
        let dm = &w.tensors[t].density;
        let base = 17 + 3 * t;
        e[base] = dm.avg();
        // Tail occupancy and provisioning ratio at a fixed probe tile:
        // these separate block/banded/skewed patterns from uniform ones
        // with the same mean density.
        let expected = (dm.avg() * PROBE_TILE_ELEMS).max(1e-12);
        e[base + 1] = dm.occupancy_quantile(PROBE_TILE_ELEMS, 0.95) / expected;
        e[base + 2] = dm.sizing_ratio(PROBE_TILE_ELEMS);
    }
    e[26] = (p.pe_rows.max(1) as f64).log2();
    e[27] = (p.pe_cols.max(1) as f64).log2();
    e[28] = (p.macs_per_pe.max(1) as f64).log2();
    e[29] = (p.pe_buf_bytes.max(1) as f64).log2();
    e[30] = (p.glb_bytes.max(1) as f64).log2();
    e[31] = p.dram_bw_bytes_per_s.max(1.0).log10();
    e[32] = p.clock_hz.max(1.0).log10();
    e[33] = p.glb_bw_words_per_cycle.max(1.0).log2();
    e[34] = p.pe_bw_words_per_cycle.max(1.0).log2();
    for x in e.iter_mut() {
        if !x.is_finite() {
            *x = 0.0;
        }
    }
    normalize(&mut e);
    e
}

/// Human-readable scenario tag persisted alongside the embedding (the
/// `seeded_from` provenance string): `workload@platform#method`.
pub fn scenario_tag(w: &Workload, p: &Platform, method: &str) -> String {
    format!("{}@{}#{}", w.id, p.name, method)
}

fn normalize(e: &mut [f64; EMBED_DIM]) {
    let norm = e.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for x in e.iter_mut() {
            *x /= norm;
        }
    }
}

/// Squared Euclidean distance between two embeddings (both normalized,
/// so this orders identically to cosine distance).
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::DensityModel;
    use crate::workload::table3;

    #[test]
    fn embedding_is_normalized_and_deterministic() {
        let w = table3::by_id("mm3").unwrap();
        let p = Platform::cloud();
        let a = scenario_embedding(&w, &p);
        let b = scenario_embedding(&w, &p);
        assert_eq!(a, b);
        let norm: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-12, "norm = {norm}");
        assert!(a.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn near_identical_scenarios_embed_closer_than_distant_ones() {
        let p = Platform::mobile();
        let base = table3::by_id("mm1").unwrap();
        // Same shape, slightly different densities — the warm-start
        // traffic pattern.
        let near = Workload::spmm("mm1b", 124, 124, 124, 0.75, 0.80);
        let far = table3::by_id("mm10").unwrap();
        let e0 = scenario_embedding(&base, &p);
        let d_near = dist2(&e0, &scenario_embedding(&near, &p));
        let d_far = dist2(&e0, &scenario_embedding(&far, &p));
        assert!(d_near < d_far, "near {d_near} vs far {d_far}");
        // A platform change also moves the embedding.
        let d_platform = dist2(&e0, &scenario_embedding(&base, &Platform::cloud()));
        assert!(d_platform > 0.0);
    }

    #[test]
    fn sparsity_shape_separates_equal_mean_densities() {
        let p = Platform::mobile();
        let uniform = Workload::spmm("u", 64, 256, 64, 0.2, 0.2);
        let blocky = Workload::custom_models(
            "b",
            WorkloadKind::SpMM,
            vec![("M".into(), 64), ("K".into(), 256), ("N".into(), 64)],
            vec![
                ("P".into(), vec![0, 1], Some(DensityModel::block(16, 0.2))),
                ("Q".into(), vec![1, 2], Some(DensityModel::uniform(0.2))),
                ("Z".into(), vec![0, 2], None),
            ],
            vec![1],
        )
        .unwrap();
        let du = scenario_embedding(&uniform, &p);
        let db = scenario_embedding(&blocky, &p);
        assert!(dist2(&du, &db) > 1e-9, "block pattern must shift the embedding");
    }
}
