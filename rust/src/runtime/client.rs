//! PJRT CPU client wrapper + artifact metadata loading.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `artifacts/meta.json` — the contract written by
/// `python/compile/aot.py`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub schema_version: u32,
    pub batch: usize,
    pub num_features: usize,
    pub num_platform_features: usize,
    pub demo_shape: (usize, usize, usize),
    pub cost_model_file: String,
    pub spmm_demo_file: String,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).context("parsing meta.json")?;
        let get_u = |k: &str| -> Result<u64> {
            json.get(k).and_then(|v| v.as_u64()).ok_or_else(|| anyhow!("meta.json missing {k}"))
        };
        let demo = json
            .get("demo_shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("meta.json missing demo_shape"))?;
        let artifacts =
            json.get("artifacts").ok_or_else(|| anyhow!("meta.json missing artifacts"))?;
        let file = |k: &str| -> Result<String> {
            artifacts
                .get(k)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow!("meta.json missing artifacts.{k}"))
        };
        Ok(ArtifactMeta {
            schema_version: get_u("schema_version")? as u32,
            batch: get_u("batch")? as usize,
            num_features: get_u("num_features")? as usize,
            num_platform_features: get_u("num_platform_features")? as usize,
            demo_shape: (
                demo[0].as_u64().unwrap_or(0) as usize,
                demo[1].as_u64().unwrap_or(0) as usize,
                demo[2].as_u64().unwrap_or(0) as usize,
            ),
            cost_model_file: file("cost_model")?,
            spmm_demo_file: file("spmm_demo")?,
        })
    }

    /// Assert the artifact matches what this binary was compiled against.
    pub fn check_schema(&self) -> Result<()> {
        use crate::model::{NUM_FEATURES, NUM_PLATFORM_FEATURES, SCHEMA_VERSION};
        if self.schema_version != SCHEMA_VERSION {
            return Err(anyhow!(
                "artifact schema v{} != binary schema v{} — re-run `make artifacts`",
                self.schema_version,
                SCHEMA_VERSION
            ));
        }
        if self.num_features != NUM_FEATURES || self.num_platform_features != NUM_PLATFORM_FEATURES
        {
            return Err(anyhow!(
                "artifact feature widths ({}, {}) != binary ({}, {})",
                self.num_features,
                self.num_platform_features,
                NUM_FEATURES,
                NUM_PLATFORM_FEATURES
            ));
        }
        Ok(())
    }
}

/// Default artifacts directory: `$SPARSEMAP_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (where Cargo runs tests/binaries).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SPARSEMAP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Tests and binaries run with CWD = workspace root; fall back to the
    // manifest dir for robustness.
    let cwd = PathBuf::from("artifacts");
    if cwd.join("meta.json").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A process-wide PJRT CPU client with compiled executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    dir: PathBuf,
}

impl Runtime {
    /// Create the CPU client and load artifact metadata from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let meta = ArtifactMeta::load(dir)?;
        meta.check_schema()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, meta, dir: dir.to_path_buf() })
    }

    /// Convenience: default artifacts location.
    pub fn from_default_dir() -> Result<Runtime> {
        Self::new(&artifacts_dir())
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let dir = std::env::temp_dir().join("sparsemap_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"schema_version":1,"batch":256,"num_features":48,
                "num_platform_features":16,"demo_shape":[64,64,64],
                "outputs":["energy_pj","cycles","edp","valid"],
                "artifacts":{"cost_model":"cost_model.hlo.txt",
                              "spmm_demo":"spmm_demo.hlo.txt"}}"#,
        )
        .unwrap();
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(meta.batch, 256);
        assert_eq!(meta.demo_shape, (64, 64, 64));
        meta.check_schema().unwrap();
    }

    #[test]
    fn stale_schema_rejected() {
        let dir = std::env::temp_dir().join("sparsemap_meta_stale");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"schema_version":99,"batch":256,"num_features":48,
                "num_platform_features":16,"demo_shape":[64,64,64],
                "artifacts":{"cost_model":"a","spmm_demo":"b"}}"#,
        )
        .unwrap();
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert!(meta.check_schema().is_err());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactMeta::load(Path::new("/nonexistent/path")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
