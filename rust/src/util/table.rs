//! ASCII table rendering + CSV writing for report generators.

/// A simple column-aligned ASCII table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for w in &widths {
                out.push('+');
                for _ in 0..w + 2 {
                    out.push('-');
                }
            }
            out.push_str("+\n");
        };
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(c);
                for _ in 0..widths[i] - c.len() + 1 {
                    out.push(' ');
                }
            }
            out.push_str("|\n");
        };
        sep(&mut out);
        line(&mut out, &self.header);
        sep(&mut out);
        for row in &self.rows {
            line(&mut out, row);
        }
        if !self.rows.is_empty() {
            sep(&mut out);
        }
        let _ = ncol;
        out
    }

    /// CSV serialization (RFC-4180 quoting for cells containing `,"\n`).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let write_row = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    out.push('"');
                    out.push_str(&c.replace('"', "\"\""));
                    out.push('"');
                } else {
                    out.push_str(c);
                }
            }
            out.push('\n');
        };
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }
}

/// Format a value in engineering/scientific style matching the paper's
/// Table IV (e.g. `1.92E+10`).
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    format!("{:.2E}", x)
}

/// Format a ratio like `26.8x`.
pub fn ratio(x: f64) -> String {
    format!("{:.1}x", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["id", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-id".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("| id      |"));
        assert!(s.contains("| long-id |"));
        assert_eq!(s.lines().filter(|l| l.starts_with('+')).count(), 3);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n\"x,y\",\"q\"\"q\"\n");
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(1.92e10), "1.92E10");
        assert_eq!(sci(0.0), "0");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
