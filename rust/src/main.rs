//! SparseMap CLI — the L3 coordinator entrypoint.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md E1–E9)
//! plus utility commands for single searches and diagnostics. Run with
//! no arguments for usage.

use sparsemap::arch::Platform;
use sparsemap::baselines::{run_method, ALL_METHODS};
use sparsemap::es::sensitivity::calibrate;
use sparsemap::es::CalibConfig;
use sparsemap::genome::{decode, describe};
use sparsemap::report::{fig10, fig17, fig18, fig2, fig7, table4, ExpConfig};
use sparsemap::util::cli::Args;
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::table3;
use std::path::PathBuf;

const USAGE: &str = "\
sparsemap — evolution-strategy DSE for sparse tensor accelerators

USAGE: sparsemap <COMMAND> [OPTIONS]

Experiment commands (one per paper table/figure):
  fig2                 E1: mapping x sparse-strategy interplay sweep
  fig7                 E2: design-space PCA scatter (1000 samples)
  fig10                E3: Cantor vs random permutation encoding
  fig17a               E4: SparseMap vs PSO/MCTS/TBPSA/PPO/DQN (VGG16, cloud)
  fig17b               E5: valid-point ratio per platform
  fig18                E7: ablation convergence (es-direct / es-pfce / full)
  table4               E6/E9: full 28x3 EDP matrix (--summary for ratios only)

Utility commands:
  search               run one search arm
                         --workload mm3 --platform cloud --method sparsemap
                         --budget 20000 --seed 42 [--pjrt] [--show-design]
  calibrate            run high-sensitivity gene calibration and print S(v)
                         --workload mm3 --platform cloud
  workloads            list the Table III workload suite
  platforms            list the Table II platforms
  demo                 run the AOT gated-SpMM artifact through PJRT
                         (needs a build with --features xla)

Common options:
  --budget N           samples per search arm (default 20000)
  --seed N             RNG seed (default 42)
  --out DIR            CSV output directory (default results/)
  --threads N          worker threads: population evaluation fans out
                       across N workers (results are bit-identical for
                       any N); matrix experiments also run N arms at once
  --pjrt               evaluate through the AOT PJRT artifact
  --workloads a,b,c    restrict table4 to a workload subset

Repeat evaluations are served from a per-arm cache: they still debit the
sample budget (submissions are what the paper counts) but skip the model
call; `search` reports both submissions and the model evals/s actually
paid for.
";

fn exp_config(args: &Args) -> anyhow::Result<ExpConfig> {
    let mut cfg = ExpConfig {
        budget: args.opt_u64("budget", 20_000)? as usize,
        seed: args.opt_u64("seed", 42)?,
        out_dir: PathBuf::from(args.opt_or("out", "results")),
        use_pjrt: args.flag("pjrt"),
        ..Default::default()
    };
    if let Some(t) = args.opt("threads") {
        cfg.threads = t.parse().map_err(|_| anyhow::anyhow!("--threads expects a number"))?;
    }
    Ok(cfg)
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let wl_id = args.opt_or("workload", "mm3");
    let platform = Platform::by_name(&args.opt_or("platform", "cloud"))?;
    let method = args.opt_or("method", "sparsemap");
    anyhow::ensure!(ALL_METHODS.contains(&method.as_str()), "unknown method {method}");
    let workload = table3::by_id(&wl_id)
        .ok_or_else(|| anyhow::anyhow!("unknown workload '{wl_id}' (see `sparsemap workloads`)"))?;

    let ctx = cfg.context(workload.clone(), platform.clone());
    let t0 = std::time::Instant::now();
    let outcome = run_method(&method, ctx, cfg.seed)?;
    let dt = t0.elapsed();

    let model_evals = outcome.evals - outcome.cache_hits;
    println!(
        "{} on {} @ {}: best EDP {:.4e}  ({} evals, {} cache hits, {:.1}% valid, {:.2}s, \
         {:.0} model evals/s, {} threads)",
        outcome.method,
        outcome.workload,
        outcome.platform,
        outcome.best_edp,
        outcome.evals,
        outcome.cache_hits,
        100.0 * outcome.valid_ratio(),
        dt.as_secs_f64(),
        model_evals as f64 / dt.as_secs_f64().max(1e-9),
        cfg.threads.max(1),
    );
    if args.flag("show-design") {
        if let Some(g) = &outcome.best_genome {
            let spec = sparsemap::genome::GenomeSpec::for_workload(&workload);
            if g.len() == spec.len() {
                let design = decode(&spec, &workload, g);
                println!("--- best design ---\n{}", describe(&design, &workload));
            } else {
                println!("(best genome uses a foreign encoding; not rendered)");
            }
        }
    }
    std::fs::create_dir_all(&cfg.out_dir)?;
    let path = cfg.out_dir.join(format!("search_{}_{}_{}.json", method, wl_id, platform.name));
    std::fs::write(&path, outcome.to_json().pretty())?;
    println!("outcome written to {}", path.display());
    Ok(())
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let workload = table3::by_id(&args.opt_or("workload", "mm3"))
        .ok_or_else(|| anyhow::anyhow!("unknown workload"))?;
    let platform = Platform::by_name(&args.opt_or("platform", "cloud"))?;
    let mut ctx = cfg.context(workload, platform);
    let mut rng = Pcg64::seeded(cfg.seed);
    let sens = calibrate(&mut ctx, CalibConfig::default(), &mut rng);
    println!(
        "gene sensitivities (E8; {} evals = {:.1}% of budget):",
        sens.evals_spent,
        100.0 * sens.evals_spent as f64 / cfg.budget as f64
    );
    for (i, s) in sens.scores.iter().enumerate() {
        let class = if sens.high.contains(&i) { "HIGH" } else { "low " };
        println!("  gene {i:3} [{class}]  S = {s:.4e}  ({:?})", ctx.spec.kinds[i]);
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_demo() -> anyhow::Result<()> {
    anyhow::bail!(
        "the demo executes AOT artifacts through PJRT; rebuild with `--features xla` \
         (and a real xla crate in rust/vendor/xla)"
    )
}

#[cfg(feature = "xla")]
fn cmd_demo() -> anyhow::Result<()> {
    let rt = sparsemap::runtime::Runtime::from_default_dir()?;
    let demo = sparsemap::runtime::SpmmDemo::new(&rt)?;
    let (m, k, n) = (demo.m, demo.k, demo.n);
    let mut rng = Pcg64::seeded(1);
    let p: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let q: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let pm: Vec<f32> = (0..m * k).map(|_| if rng.chance(0.5) { 1.0 } else { 0.0 }).collect();
    let qm: Vec<f32> = (0..k * n).map(|_| if rng.chance(0.25) { 1.0 } else { 0.0 }).collect();
    let (z, eff) = demo.run(&p, &q, &pm, &qm)?;
    println!(
        "gated SpMM {m}x{k} * {k}x{n} through PJRT: effectual MACs {eff} of {} ({:.1}%)",
        m * k * n,
        100.0 * eff / (m * k * n) as f64,
    );
    println!("z[0..4] = {:?}", &z[..4]);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cfg = exp_config(&args)?;

    match args.subcommand.as_str() {
        "fig2" => println!("{}", fig2::run(&cfg)?),
        "fig7" => println!("{}", fig7::run(&cfg)?),
        "fig10" => println!("{}", fig10::run(&cfg)?),
        "fig17a" => println!("{}", fig17::run_a(&cfg)?),
        "fig17b" => println!("{}", fig17::run_b(&cfg)?),
        "fig18" => println!("{}", fig18::run(&cfg)?),
        "table4" => {
            let subset = args
                .opt("workloads")
                .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
            println!("{}", table4::run(&cfg, subset, args.flag("summary"))?);
        }
        "search" => cmd_search(&args)?,
        "calibrate" => cmd_calibrate(&args)?,
        "demo" => cmd_demo()?,
        "workloads" => {
            for w in table3::all() {
                let dims: Vec<String> =
                    w.dims.iter().map(|d| format!("{}={}", d.name, d.size)).collect();
                println!(
                    "{:8} {:7} {}  dP={:.3} dQ={:.3}",
                    w.id,
                    w.kind.as_str(),
                    dims.join(" "),
                    w.tensors[0].density,
                    w.tensors[1].density
                );
            }
        }
        "platforms" => {
            for p in Platform::all() {
                println!(
                    "{:7} {}x{} PEs, {} MACs/PE, PE buf {} KB, GLB {} KB, DRAM {:.3} GB/s",
                    p.name,
                    p.pe_rows,
                    p.pe_cols,
                    p.macs_per_pe,
                    p.pe_buf_bytes >> 10,
                    p.glb_bytes >> 10,
                    p.dram_bw_bytes_per_s / 1e9
                );
            }
        }
        "" | "help" => println!("{USAGE}"),
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
