"""L1 Pallas kernel: bitmask-gated SpMM — the "instantiated design" demo.

Fig. 14 of the paper walks through the hardware behaviour of one decoded
design: operand tiles stream into the PE array and a `Gate P<->Q`
mechanism keeps a MAC idle whenever either operand is zero. This kernel
executes that computation (functionally) for a tile that fits in VMEM:

    Z = (P ⊙ maskP) @ (Q ⊙ maskQ),  effectual = Σ maskP @ maskQ

`effectual` is the number of MACs that actually fire — the same quantity
the cost model charges MAC energy for (`F_MAC_ENERGY_FRAC` with a
double-sided gate is exactly effectual/total). The end-to-end example
(`examples/end_to_end.rs`) runs this artifact through PJRT to execute the
winning design's workload tile and cross-checks the effectual-MAC count
against the cost model's prediction.

TPU mapping: M is the grid axis; each step keeps a (BLOCK_M, K) strip of P
and the whole (K, N) Q panel in VMEM and drives the MXU with a dense
matmul on the masked operands — gating on a systolic array is an operand
zero-out (datapath enable), not control flow, which is why the masked-
matmul formulation is the faithful TPU analogue of Fig. 14.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 32


def _spmm_kernel(p_ref, q_ref, pm_ref, qm_ref, z_ref, eff_ref):
    p = p_ref[...] * pm_ref[...]
    q = q_ref[...] * qm_ref[...]
    z_ref[...] = jnp.dot(p, q, preferred_element_type=jnp.float32)
    # Effectual MACs of this strip: ones where both operands are nonzero.
    eff = jnp.dot(pm_ref[...], qm_ref[...], preferred_element_type=jnp.float32)
    eff_ref[...] = jnp.sum(eff, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmm_gated_pallas(p, q, pmask, qmask, *, interpret=True):
    """Gated SpMM over VMEM-resident tiles.

    Args:
      p: f32[M, K]; q: f32[K, N]; pmask: f32[M, K]; qmask: f32[K, N]
      (masks are 0/1 occupancy).

    Returns:
      (z, effectual): f32[M, N] result and f32[] effectual-MAC count.
    """
    m, k = p.shape
    k2, n = q.shape
    assert k == k2 and pmask.shape == p.shape and qmask.shape == q.shape
    assert m % BLOCK_M == 0, f"M={m} not a multiple of {BLOCK_M}"
    grid = (m // BLOCK_M,)
    z, eff_rows = pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_M, n), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_M, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(p, q, pmask, qmask)
    return z, jnp.sum(eff_rows)
