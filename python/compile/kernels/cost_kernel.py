"""L1 Pallas kernel: fused batched cost evaluation.

The search hot-spot is evaluating EDP/validity for a whole population per
generation. This kernel fuses the entire FEATURE_SCHEMA_V1 cost formula —
traffic scaling, energy accumulation, bandwidth-bound latency max, capacity
validity — into one pass over the feature matrix: one HBM read of
f32[B, 48], one HBM write of f32[B, 4], everything else in VMEM.

TPU mapping notes (see DESIGN.md §Hardware-Adaptation):
* the batch dimension B is tiled into BLOCK_B-row blocks via the
  `BlockSpec` grid — each block's working set (BLOCK_B×48 + 16 + BLOCK_B×4
  f32 ≈ 53 KB at BLOCK_B=256) sits comfortably in a TPU core's ~16 MB VMEM,
  leaving headroom for double buffering;
* the feature axis (48) and output axis (4) are lane-dimension friendly
  (padded to 128 lanes by Mosaic); all ops are VPU elementwise/reduce, no
  MXU work — the kernel is bandwidth-bound by design, which is exactly why
  fusing it to a single pass matters;
* `interpret=True` everywhere in this repo: the CPU PJRT plugin cannot run
  Mosaic custom-calls; interpret mode lowers to plain HLO (and is also the
  numerics oracle path for the AOT artifact).

Correctness: must match `ref.cost_eval_ref` bit-for-bit-ish (same op
order); pytest sweeps shapes and value magnitudes via hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_B = 128  # batch rows per grid step


def _cost_kernel(feat_ref, plat_ref, out_ref):
    """One grid step: evaluate BLOCK_B designs entirely in VMEM."""
    f = feat_ref[...]          # [BLOCK_B, NUM_FEATURES]
    plat = plat_ref[...]       # [NUM_PLATFORM_FEATURES]
    # The arithmetic is shared with the pure-jnp oracle — the kernel's job
    # is the fusion/tiling structure, not a different formula. Keeping one
    # definition guarantees the Rust <-> JAX contract has a single source
    # of truth on the Python side.
    out_ref[...] = ref.cost_eval_ref(f, plat)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cost_eval_pallas(feats, plat, *, interpret=True):
    """Fused batched cost evaluation.

    Args:
      feats: f32[B, NUM_FEATURES]; B must be a multiple of BLOCK_B.
      plat: f32[NUM_PLATFORM_FEATURES].
      interpret: lower via the Pallas interpreter (required for CPU PJRT).

    Returns:
      f32[B, 4] — (energy_pj, cycles, edp, valid) per design.
    """
    b, nf = feats.shape
    assert nf == ref.NUM_FEATURES, f"feature width {nf} != {ref.NUM_FEATURES}"
    assert b % BLOCK_B == 0, f"batch {b} not a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, nf), lambda i: (i, 0)),
            pl.BlockSpec((ref.NUM_PLATFORM_FEATURES,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 4), jnp.float32),
        interpret=interpret,
    )(feats, plat)


def vmem_footprint_bytes(block_b=BLOCK_B):
    """Static VMEM footprint estimate of one grid step (for DESIGN.md
    §Perf): input block + platform vector + output block, f32."""
    return 4 * (block_b * ref.NUM_FEATURES + ref.NUM_PLATFORM_FEATURES + block_b * 4)
