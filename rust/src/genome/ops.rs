//! Elementary genetic operators shared by SparseMap and the baselines:
//! point mutation, segment-boundary crossover, neighborhood moves.
//! (The *customized* operators — annealing mutation and sensitivity-aware
//! crossover — live in `es::operators` and build on these.)

use super::spec::GenomeSpec;
use crate::util::rng::Pcg64;

/// Mutate `rate·len` genes (at least one) uniformly within their ranges.
pub fn point_mutation(spec: &GenomeSpec, genome: &mut [u32], rate: f64, rng: &mut Pcg64) {
    let n = ((spec.len() as f64 * rate).round() as usize).max(1);
    for _ in 0..n {
        let i = rng.index(spec.len());
        genome[i] = spec.ranges[i].sample(rng);
    }
}

/// Mutate exactly the gene at `i` to a *different* in-range value when the
/// range allows it.
pub fn mutate_gene(spec: &GenomeSpec, genome: &mut [u32], i: usize, rng: &mut Pcg64) {
    let r = spec.ranges[i];
    if r.width() <= 1 {
        return;
    }
    loop {
        let v = r.sample(rng);
        if v != genome[i] {
            genome[i] = v;
            return;
        }
    }
}

/// Local move: nudge gene `i` by ±1 within range (wrapping). Preserves the
/// Cantor-locality property for permutation genes.
pub fn nudge_gene(spec: &GenomeSpec, genome: &mut [u32], i: usize, rng: &mut Pcg64) {
    let r = spec.ranges[i];
    if r.width() <= 1 {
        return;
    }
    let delta: i64 = if rng.chance(0.5) { 1 } else { -1 };
    let span = r.width() as i64;
    let cur = (genome[i] - r.lo) as i64;
    genome[i] = r.lo + ((cur + delta).rem_euclid(span)) as u32;
}

/// Single-point crossover at a uniformly random cut.
pub fn onepoint_crossover(a: &[u32], b: &[u32], rng: &mut Pcg64) -> (Vec<u32>, Vec<u32>) {
    assert_eq!(a.len(), b.len());
    let cut = 1 + rng.index(a.len() - 1);
    let mut c1 = a[..cut].to_vec();
    c1.extend_from_slice(&b[cut..]);
    let mut c2 = b[..cut].to_vec();
    c2.extend_from_slice(&a[cut..]);
    (c1, c2)
}

/// Crossover cutting only at the provided boundaries (used by
/// sensitivity-aware crossover with high-sensitivity segment boundaries).
pub fn boundary_crossover(
    a: &[u32],
    b: &[u32],
    boundaries: &[usize],
    rng: &mut Pcg64,
) -> (Vec<u32>, Vec<u32>) {
    assert_eq!(a.len(), b.len());
    let valid: Vec<usize> =
        boundaries.iter().copied().filter(|&c| c > 0 && c < a.len()).collect();
    if valid.is_empty() {
        return onepoint_crossover(a, b, rng);
    }
    let cut = *rng.choose(&valid);
    let mut c1 = a[..cut].to_vec();
    c1.extend_from_slice(&b[cut..]);
    let mut c2 = b[..cut].to_vec();
    c2.extend_from_slice(&a[cut..]);
    (c1, c2)
}

/// Uniform crossover (per-gene coin flip) — used by some baselines.
pub fn uniform_crossover(a: &[u32], b: &[u32], rng: &mut Pcg64) -> Vec<u32> {
    a.iter().zip(b).map(|(&x, &y)| if rng.chance(0.5) { x } else { y }).collect()
}

/// Hamming distance between genomes (diversity metric for telemetry).
pub fn hamming(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Workload;

    fn setup() -> (GenomeSpec, Pcg64) {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        (GenomeSpec::for_workload(&w), Pcg64::seeded(3))
    }

    #[test]
    fn point_mutation_stays_in_range() {
        let (spec, mut rng) = setup();
        let mut g = spec.random(&mut rng);
        for _ in 0..100 {
            point_mutation(&spec, &mut g, 0.2, &mut rng);
            assert!(spec.in_range(&g));
        }
    }

    #[test]
    fn mutate_gene_changes_value() {
        let (spec, mut rng) = setup();
        let mut g = spec.random(&mut rng);
        for i in 0..spec.len() {
            let before = g[i];
            mutate_gene(&spec, &mut g, i, &mut rng);
            if spec.ranges[i].width() > 1 {
                assert_ne!(g[i], before, "gene {i}");
            }
            assert!(spec.in_range(&g));
        }
    }

    #[test]
    fn nudge_moves_by_one_mod_range() {
        let (spec, mut rng) = setup();
        let mut g = spec.random(&mut rng);
        for _ in 0..200 {
            let i = rng.index(spec.len());
            let before = g[i] as i64;
            nudge_gene(&spec, &mut g, i, &mut rng);
            let r = spec.ranges[i];
            if r.width() > 1 {
                let after = g[i] as i64;
                let diff = (after - before).rem_euclid(r.width() as i64);
                assert!(diff == 1 || diff == r.width() as i64 - 1);
            }
            assert!(spec.in_range(&g));
        }
    }

    #[test]
    fn crossover_children_mix_parents() {
        let (spec, mut rng) = setup();
        let a = vec![spec.ranges[0].lo; spec.len()]
            .iter()
            .zip(&spec.ranges)
            .map(|(_, r)| r.lo)
            .collect::<Vec<_>>();
        let b = spec.ranges.iter().map(|r| r.hi).collect::<Vec<_>>();
        let (c1, c2) = onepoint_crossover(&a, &b, &mut rng);
        assert_eq!(c1.len(), a.len());
        // Each child gene comes from one of the parents at that locus.
        for i in 0..a.len() {
            assert!(c1[i] == a[i] || c1[i] == b[i]);
            assert!(c2[i] == a[i] || c2[i] == b[i]);
            // And the two children are complementary.
            assert!((c1[i] == a[i]) != (c1[i] == b[i]) || a[i] == b[i]);
        }
    }

    #[test]
    fn boundary_crossover_cuts_at_boundaries() {
        let (spec, mut rng) = setup();
        let a: Vec<u32> = spec.ranges.iter().map(|r| r.lo).collect();
        let b: Vec<u32> = spec.ranges.iter().map(|r| r.hi).collect();
        let bounds = spec.segment_boundaries();
        for _ in 0..50 {
            let (c1, _) = boundary_crossover(&a, &b, &bounds, &mut rng);
            // Find the switch point: must be one of the boundaries.
            let cut = (0..a.len()).find(|&i| c1[i] != a[i]);
            if let Some(cut) = cut {
                assert!(bounds.contains(&cut), "cut at {cut}, bounds {bounds:?}");
            }
        }
    }

    #[test]
    fn hamming_metric() {
        assert_eq!(hamming(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(hamming(&[1, 2, 3], &[3, 2, 1]), 2);
    }
}
