//! The optimizer subsystem — every search method behind one trait, one
//! registry, one dispatch path.
//!
//! The paper's contribution *is* the search method, so methods are
//! first-class here rather than a string `match` over free functions:
//!
//! * [`Optimizer`] — a built, configured search method. It runs against a
//!   borrowed [`EvalContext`] until the budget (or a portfolio fence) is
//!   exhausted; telemetry accumulates in the context and the caller
//!   finalizes the [`Outcome`].
//! * [`MethodSpec`] — per-method metadata: canonical name, aliases, a
//!   one-line description, the schema of its tunables (typed, ranged,
//!   documented) and the builder that turns a JSON options object into a
//!   runnable [`Optimizer`].
//! * [`registry()`] — the static table of every method. It is the single
//!   source of truth behind [`ALL_METHODS`], [`run_method`],
//!   `api::SearchSession` validation and the CLI (`sparsemap methods`
//!   prints it).
//! * [`portfolio`] — the first method only expressible on top of the
//!   trait: round-based successive-halving racing of member optimizers
//!   over one shared budget/cache/pool.
//!
//! Method hyper-parameters travel as a JSON object (`method_opts` on an
//! [`crate::api::SearchRequest`], `--method-opts` on the CLI) and are
//! validated against the method's tunable schema: unknown keys are
//! rejected with a nearest-match suggestion, values are type- and
//! range-checked. An empty object means "paper defaults", and every
//! method's default-config trajectory is bit-for-bit identical to the
//! pre-registry dispatch (pinned by `rust/tests/golden_trajectories.rs`).

pub mod checkpoint;
pub mod portfolio;
mod registry;

pub use checkpoint::{Checkpoint, CHECKPOINT_SCHEMA};
pub use registry::{registry, ALL_METHODS};

use crate::search::{EvalContext, Outcome};
use crate::util::cli::nearest;
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Result};

/// A built, configured search method. Implementations run their whole
/// search loop against the borrowed context; they never finalize the
/// outcome themselves (that is the dispatcher's job), which is what lets
/// the portfolio re-enter the same shared context with every member.
pub trait Optimizer {
    /// The method label stamped into the [`Outcome`] (the registry name,
    /// e.g. `"sparsemap"`).
    fn label(&self) -> &str;

    /// Run until the context reports an exhausted budget.
    fn run(&mut self, ctx: &mut EvalContext, seed: u64);

    /// Post-process the finalized outcome (the portfolio attaches its
    /// per-member telemetry here; plain methods do nothing).
    fn annotate(&self, _outcome: &mut Outcome) {}

    /// Offer design-memory seed genomes (already validated against the
    /// scenario's [`crate::genome::GenomeSpec`], nearest scenario first)
    /// to occupy up to `fraction` of the initial population. Called
    /// before [`Optimizer::run`]; methods without a seedable population
    /// ignore the offer (the default), so warm-start degrades to a no-op
    /// rather than an error on non-ES methods.
    fn warm_start(&mut self, _seeds: &[crate::genome::Genome], _fraction: f64) {}

    /// Capture the optimizer's internal state as versioned JSON for a
    /// later [`Optimizer::resume`]. `None` means the method does not
    /// support suspension (the registry's [`MethodSpec::resumable`] flag
    /// advertises which do). Call after [`Optimizer::run`] returned early
    /// because the context's suspend flag was raised (see
    /// `EvalContext::suspend_requested`); calling `run` again on the same
    /// instance also continues in place — `suspend`/`resume` exist to
    /// carry that continuation across processes.
    fn suspend(&self) -> Option<Json> {
        None
    }

    /// Restore state captured by [`Optimizer::suspend`] into a freshly
    /// built optimizer of the same method and options. The next
    /// [`Optimizer::run`] continues exactly where the suspended run left
    /// off (against a context restored with
    /// `EvalContext::restore_eval_state`). The default errors: only
    /// methods advertising [`MethodSpec::resumable`] implement it.
    fn resume(&mut self, _state: &Json) -> Result<()> {
        bail!("method '{}' does not support suspend/resume", self.label())
    }
}

/// The type and valid range of one tunable.
#[derive(Clone, Copy, Debug)]
pub enum TunableKind {
    /// Integer in `[min, max]`.
    Int { min: u64, max: u64 },
    /// Finite float in `[min, max]`.
    Float { min: f64, max: f64 },
    /// One string out of a fixed option set (e.g. the portfolio's
    /// budget-allocation policy).
    Choice { options: &'static [&'static str] },
    /// Non-empty array of registry method names (the portfolio's
    /// `members`); entries may be aliases, and may not name the owning
    /// method itself (no nested portfolios).
    MethodList,
    /// Object mapping member method names to *their* options objects
    /// (the portfolio's `member_opts`); each value is validated against
    /// that member's own tunable schema, recursively.
    OptsByMethod,
}

/// One schema'd hyper-parameter of a method.
#[derive(Clone, Copy, Debug)]
pub struct Tunable {
    /// JSON key inside `method_opts`.
    pub key: &'static str,
    pub kind: TunableKind,
    /// Human-readable default, shown by `sparsemap methods`.
    pub default: &'static str,
    pub help: &'static str,
}

/// Registry metadata + constructor for one method.
pub struct MethodSpec {
    /// Canonical name (what `Outcome::method` reports).
    pub name: &'static str,
    /// Accepted spellings beside the canonical name.
    pub aliases: &'static [&'static str],
    /// One-line description for `sparsemap methods`.
    pub summary: &'static str,
    /// Schema of the method's `method_opts` keys.
    pub tunables: &'static [Tunable],
    /// Whether built instances support [`Optimizer::suspend`] /
    /// [`Optimizer::resume`] (and therefore service-side checkpointing).
    pub resumable: bool,
    /// Turn a *validated* options object into a runnable optimizer.
    pub(crate) builder: fn(&Json) -> Result<Box<dyn Optimizer>>,
}

impl MethodSpec {
    /// Check an options object against this method's tunable schema:
    /// must be a JSON object, every key a known tunable (unknown keys
    /// get a nearest-match suggestion), every value in type and range.
    pub fn validate_opts(&self, opts: &Json) -> Result<()> {
        let obj = opts
            .as_obj()
            .ok_or_else(|| anyhow!("method_opts for '{}' must be a JSON object", self.name))?;
        for (key, val) in obj {
            let Some(t) = self.tunables.iter().find(|t| t.key == key.as_str()) else {
                let hint = nearest(key, self.tunables.iter().map(|t| t.key))
                    .map(|k| format!(" (did you mean '{k}'?)"))
                    .unwrap_or_default();
                bail!(
                    "method '{}' has no tunable '{key}'{hint}; \
                     run `sparsemap methods` for the schema",
                    self.name
                );
            };
            match t.kind {
                TunableKind::Int { min, max } => {
                    let v = val.as_u64().ok_or_else(|| {
                        anyhow!("tunable '{key}' of '{}' must be an integer", self.name)
                    })?;
                    ensure!(
                        v >= min && v <= max,
                        "tunable '{key}' of '{}' must be in [{min}, {max}], got {v}",
                        self.name
                    );
                }
                TunableKind::Float { min, max } => {
                    let v = val.as_f64().ok_or_else(|| {
                        anyhow!("tunable '{key}' of '{}' must be a number", self.name)
                    })?;
                    ensure!(
                        v.is_finite() && v >= min && v <= max,
                        "tunable '{key}' of '{}' must be in [{min}, {max}], got {v}",
                        self.name
                    );
                }
                TunableKind::Choice { options } => {
                    let v = val.as_str().ok_or_else(|| {
                        anyhow!("tunable '{key}' of '{}' must be a string", self.name)
                    })?;
                    ensure!(
                        options.contains(&v),
                        "tunable '{key}' of '{}' must be one of {options:?}, got '{v}'",
                        self.name
                    );
                }
                TunableKind::MethodList => {
                    let arr = val.as_arr().ok_or_else(|| {
                        anyhow!(
                            "tunable '{key}' of '{}' must be an array of method names",
                            self.name
                        )
                    })?;
                    ensure!(
                        !arr.is_empty(),
                        "'{key}' of '{}' needs at least one method",
                        self.name
                    );
                    for entry in arr {
                        let name = entry.as_str().ok_or_else(|| {
                            anyhow!(
                                "'{key}' of '{}' entries must be method-name strings",
                                self.name
                            )
                        })?;
                        let member = resolve(name)?;
                        ensure!(
                            member.name != self.name,
                            "'{}' cannot race itself as a member",
                            self.name
                        );
                    }
                }
                TunableKind::OptsByMethod => {
                    let map = val.as_obj().ok_or_else(|| {
                        anyhow!(
                            "tunable '{key}' of '{}' must map method names to options objects",
                            self.name
                        )
                    })?;
                    for (mname, mopts) in map {
                        let member = resolve(mname)?;
                        ensure!(
                            member.name != self.name,
                            "'{}' cannot carry options for itself as a member",
                            self.name
                        );
                        member
                            .validate_opts(mopts)
                            .map_err(|e| e.context(format!("in '{key}' for member '{mname}'")))?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Validate `opts` and construct the runnable optimizer.
    pub fn build(&self, opts: &Json) -> Result<Box<dyn Optimizer>> {
        self.validate_opts(opts)?;
        (self.builder)(opts)
    }

    /// Machine-readable form of this spec (name, aliases, summary, the
    /// `resumable` flag and the full tunable schema) — the per-method
    /// entry of `api::methods_json()`, so clients introspect the registry
    /// without shelling out to the `sparsemap methods` CLI.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("aliases", Json::arr_str(self.aliases)),
            ("summary", Json::str(self.summary)),
            ("resumable", Json::Bool(self.resumable)),
            (
                "tunables",
                Json::Arr(
                    self.tunables
                        .iter()
                        .map(|t| {
                            let (kind, range) = match t.kind {
                                TunableKind::Int { min, max } => (
                                    "int",
                                    Some(Json::arr_f64(&[min as f64, max as f64])),
                                ),
                                TunableKind::Float { min, max } => {
                                    ("float", Some(Json::arr_f64(&[min, max])))
                                }
                                TunableKind::Choice { options } => {
                                    ("choice", Some(Json::arr_str(options)))
                                }
                                TunableKind::MethodList => ("method_list", None),
                                TunableKind::OptsByMethod => ("opts_by_method", None),
                            };
                            Json::obj(vec![
                                ("key", Json::str(t.key)),
                                ("kind", Json::str(kind)),
                                ("range", range.unwrap_or(Json::Null)),
                                ("default", Json::str(t.default)),
                                ("help", Json::str(t.help)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Look a method up by canonical name or alias. Unknown names fail with
/// the full method list and a nearest-match suggestion (the same
/// levenshtein the CLI's `reject_unknown` uses for flags) — this is the
/// one validation path shared by [`run_method`], the API session and the
/// CLI.
pub fn resolve(name: &str) -> Result<&'static MethodSpec> {
    registry()
        .iter()
        .find(|m| m.name == name || m.aliases.contains(&name))
        .ok_or_else(|| {
            let all = registry()
                .iter()
                .flat_map(|m| std::iter::once(m.name).chain(m.aliases.iter().copied()));
            let hint = nearest(name, all)
                .map(|k| format!(" (did you mean '{k}'?)"))
                .unwrap_or_default();
            anyhow!("unknown method '{name}' (one of {ALL_METHODS:?}){hint}")
        })
}

/// Run a method by name with default (paper) hyper-parameters — the
/// internal engine behind [`crate::api::SearchSession::run`]. Downstream
/// users should go through [`crate::api::SearchRequest`]; this stays
/// public for drivers that assemble their own [`EvalContext`].
///
/// Every method evaluates through the [`EvalContext`] it is handed, so
/// all arms inherit the context's worker pool, evaluation cache and
/// observer equally — attach a pool with `EvalContext::with_pool` (or
/// via a request's `threads`) and the comparison stays fair.
pub fn run_method(name: &str, ctx: EvalContext, seed: u64) -> Result<Outcome> {
    run_method_with(name, &Json::Obj(Default::default()), ctx, seed)
}

/// [`run_method`] with a `method_opts` object (validated against the
/// method's tunable schema — see [`MethodSpec::validate_opts`]).
pub fn run_method_with(
    name: &str,
    opts: &Json,
    mut ctx: EvalContext,
    seed: u64,
) -> Result<Outcome> {
    let spec = resolve(name)?;
    let mut opt = spec.build(opts)?;
    opt.run(&mut ctx, seed);
    let label = opt.label().to_string();
    let mut outcome = ctx.outcome(&label);
    opt.annotate(&mut outcome);
    Ok(outcome)
}

/// Typed getter for a validated options object (absent key = default).
pub(crate) fn opt_usize(opts: &Json, key: &str, default: usize) -> usize {
    opts.get(key).and_then(Json::as_u64).map(|v| v as usize).unwrap_or(default)
}

/// Typed getter for a validated options object (absent key = default).
pub(crate) fn opt_f64(opts: &Json, key: &str, default: f64) -> f64 {
    opts.get(key).and_then(Json::as_f64).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 16, 16, 0.5, 0.5);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn all_registry_methods_dispatch_and_respect_budget() {
        for m in ALL_METHODS {
            let o = run_method(m, ctx(60), 1).unwrap();
            assert!(o.evals <= 60, "{m} overspent");
        }
    }

    #[test]
    fn all_methods_is_exactly_the_registry() {
        let names: Vec<&str> = registry().iter().map(|m| m.name).collect();
        assert_eq!(ALL_METHODS, names.as_slice());
    }

    #[test]
    fn aliases_resolve_to_their_method_and_never_collide() {
        for m in registry() {
            for a in m.aliases {
                assert_eq!(resolve(a).unwrap().name, m.name, "alias {a}");
                assert!(!ALL_METHODS.contains(a), "alias {a} shadows a canonical name");
            }
        }
        // Aliases are unique across the registry.
        let mut seen = std::collections::BTreeSet::new();
        for m in registry() {
            for key in std::iter::once(&m.name).chain(m.aliases) {
                assert!(seen.insert(*key), "duplicate method key '{key}'");
            }
        }
    }

    #[test]
    fn unknown_method_rejected_with_suggestion() {
        let err = resolve("spasemap").unwrap_err().to_string();
        assert!(err.contains("did you mean 'sparsemap'"), "{err}");
        assert!(resolve("gradient-descent").is_err());
    }

    #[test]
    fn alias_runs_under_canonical_label() {
        let spec = resolve("sm").unwrap();
        assert_eq!(spec.name, "sparsemap");
        let o = run_method("sm", ctx(60), 1).unwrap();
        assert_eq!(o.method, "sparsemap");
    }

    #[test]
    fn unknown_tunable_rejected_with_suggestion() {
        let spec = resolve("sparsemap").unwrap();
        let opts = Json::parse(r#"{"populaton": 40}"#).unwrap();
        let err = spec.validate_opts(&opts).unwrap_err().to_string();
        assert!(err.contains("no tunable 'populaton'"), "{err}");
        assert!(err.contains("did you mean 'population'"), "{err}");
    }

    #[test]
    fn tunable_type_and_range_checked() {
        let spec = resolve("pso").unwrap();
        assert!(spec.validate_opts(&Json::parse(r#"{"swarm": "big"}"#).unwrap()).is_err());
        assert!(spec.validate_opts(&Json::parse(r#"{"swarm": 0}"#).unwrap()).is_err());
        assert!(spec.validate_opts(&Json::parse(r#"{"inertia": 1e9}"#).unwrap()).is_err());
        assert!(spec
            .validate_opts(&Json::parse(r#"{"swarm": 16, "inertia": 0.5}"#).unwrap())
            .is_ok());
        // method_opts must be an object.
        assert!(spec.validate_opts(&Json::parse("[1,2]").unwrap()).is_err());
    }

    #[test]
    fn method_opts_change_the_search() {
        // A 4-particle vs 40-particle swarm at the same tiny budget
        // produces different trajectories — the knob demonstrably
        // reaches the algorithm.
        let small = run_method_with("pso", &Json::parse(r#"{"swarm": 4}"#).unwrap(), ctx(120), 5)
            .unwrap();
        let default = run_method("pso", ctx(120), 5).unwrap();
        assert_eq!(small.method, "pso");
        assert!(small.evals <= 120 && default.evals <= 120);
        assert_ne!(
            (small.valid_evals, small.curve.clone()),
            (default.valid_evals, default.curve.clone()),
            "swarm size must alter the trajectory"
        );
    }

    #[test]
    fn every_tunable_documents_itself() {
        for m in registry() {
            assert!(!m.summary.is_empty(), "{} has no summary", m.name);
            for t in m.tunables {
                assert!(!t.help.is_empty(), "{}/{} has no help", m.name, t.key);
                assert!(!t.default.is_empty(), "{}/{} has no default", m.name, t.key);
                if let TunableKind::Int { min, max } = t.kind {
                    assert!(min <= max, "{}/{} empty range", m.name, t.key);
                }
                if let TunableKind::Float { min, max } = t.kind {
                    assert!(min <= max, "{}/{} empty range", m.name, t.key);
                }
                if let TunableKind::Choice { options } = t.kind {
                    assert!(!options.is_empty(), "{}/{} empty option set", m.name, t.key);
                    assert!(
                        options.contains(&t.default),
                        "{}/{} default '{}' not in {options:?}",
                        m.name,
                        t.key,
                        t.default
                    );
                }
            }
        }
    }
}
