//! Principal component analysis via power iteration with deflation.
//!
//! Used by the Fig. 7 reproduction: 1000 random design points are encoded
//! as numeric vectors, the mapping-gene block and the sparse-strategy-gene
//! block are each reduced to one principal component, and the scatter of
//! (PC_mapping, PC_sparse, EDP, valid) is written out.

/// Result of a PCA fit: principal axes (row-major, `k × d`) and the
/// per-feature mean that was subtracted.
#[derive(Clone, Debug)]
pub struct Pca {
    pub mean: Vec<f64>,
    pub components: Vec<Vec<f64>>,
    pub explained: Vec<f64>,
}

/// Fit `k` principal components of `data` (n samples × d features) using
/// power iteration on the covariance matrix with Hotelling deflation.
/// Deterministic: the iteration starts from a fixed vector.
pub fn fit(data: &[Vec<f64>], k: usize, iters: usize) -> Pca {
    let n = data.len();
    assert!(n > 1, "need at least 2 samples");
    let d = data[0].len();
    assert!(data.iter().all(|r| r.len() == d), "ragged data");
    let k = k.min(d);

    // Center.
    let mut mean = vec![0.0; d];
    for row in data {
        for (m, x) in mean.iter_mut().zip(row) {
            *m += x;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let centered: Vec<Vec<f64>> = data
        .iter()
        .map(|row| row.iter().zip(&mean).map(|(x, m)| x - m).collect())
        .collect();

    // Covariance (d × d). d is small (tens of genes), dense is fine.
    let mut cov = vec![vec![0.0; d]; d];
    for row in &centered {
        for i in 0..d {
            if row[i] == 0.0 {
                continue;
            }
            for j in i..d {
                cov[i][j] += row[i] * row[j];
            }
        }
    }
    for i in 0..d {
        for j in i..d {
            cov[i][j] /= (n - 1) as f64;
            cov[j][i] = cov[i][j];
        }
    }

    let mut components = Vec::with_capacity(k);
    let mut explained = Vec::with_capacity(k);
    for c in 0..k {
        // Deterministic start: e_c + small ramp avoids being orthogonal to
        // the dominant eigenvector in pathological symmetric cases.
        let mut v: Vec<f64> = (0..d).map(|i| 1.0 + 0.01 * ((i + c) as f64)).collect();
        normalize(&mut v);
        let mut lambda = 0.0;
        for _ in 0..iters {
            let mut w = matvec(&cov, &v);
            lambda = norm(&w);
            if lambda < 1e-300 {
                break;
            }
            for x in &mut w {
                *x /= lambda;
            }
            v = w;
        }
        // Deflate: cov -= λ v vᵀ
        for i in 0..d {
            for j in 0..d {
                cov[i][j] -= lambda * v[i] * v[j];
            }
        }
        components.push(v);
        explained.push(lambda);
    }
    Pca { mean, components, explained }
}

/// Project a sample onto the fitted components.
pub fn project(pca: &Pca, row: &[f64]) -> Vec<f64> {
    let centered: Vec<f64> = row.iter().zip(&pca.mean).map(|(x, m)| x - m).collect();
    pca.components.iter().map(|c| dot(c, &centered)).collect()
}

fn matvec(m: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
    m.iter().map(|row| dot(row, v)).collect()
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 0.0 {
        for x in v {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_dominant_axis() {
        // Points spread along (1, 2, 0)/√5 with small isotropic noise.
        let mut rng = Pcg64::seeded(3);
        let axis = [1.0 / 5f64.sqrt(), 2.0 / 5f64.sqrt(), 0.0];
        let data: Vec<Vec<f64>> = (0..500)
            .map(|_| {
                let t = rng.normal() * 10.0;
                (0..3).map(|i| axis[i] * t + rng.normal() * 0.1).collect()
            })
            .collect();
        let pca = fit(&data, 1, 100);
        let c = &pca.components[0];
        let cos = (c[0] * axis[0] + c[1] * axis[1] + c[2] * axis[2]).abs();
        assert!(cos > 0.999, "cos={cos}");
        assert!(pca.explained[0] > 50.0);
    }

    #[test]
    fn components_orthogonal() {
        let mut rng = Pcg64::seeded(5);
        let data: Vec<Vec<f64>> = (0..200)
            .map(|_| (0..4).map(|_| rng.normal()).collect())
            .collect();
        let pca = fit(&data, 3, 200);
        for i in 0..3 {
            for j in (i + 1)..3 {
                let d: f64 = pca.components[i]
                    .iter()
                    .zip(&pca.components[j])
                    .map(|(a, b)| a * b)
                    .sum();
                assert!(d.abs() < 1e-6, "components {i},{j} not orthogonal: {d}");
            }
        }
    }

    #[test]
    fn projection_centers() {
        let data = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let pca = fit(&data, 1, 50);
        // Projection of the mean point is 0.
        let p = project(&pca, &[3.0, 4.0]);
        assert!(p[0].abs() < 1e-9);
    }
}
