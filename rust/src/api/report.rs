//! [`SearchReport`] — the typed result of one search arm, with a full
//! JSON round-trip.

use super::request::SearchRequest;
use crate::search::Outcome;
use crate::util::json::Json;
use anyhow::{anyhow, ensure, Result};

/// Schema tag stamped into every serialized report.
pub const REPORT_SCHEMA: &str = "sparsemap.search_report.v1";

/// The result of one search arm: the validated request it answered, the
/// full search outcome (best EDP/genome, convergence curve, budget
/// accounting) and run metadata. Serializes losslessly with
/// [`SearchReport::to_json`] / [`SearchReport::from_json`].
#[derive(Clone, Debug)]
pub struct SearchReport {
    /// The request this report answers (echoed for provenance).
    pub request: SearchRequest,
    pub outcome: Outcome,
    /// Wall-clock seconds the run took.
    pub wall_s: f64,
    /// Whether an observer or cancel token ended the run before the
    /// budget was spent.
    pub stopped_early: bool,
}

impl SearchReport {
    /// Genomes actually sent to the cost model (submissions minus cache
    /// hits).
    pub fn model_evals(&self) -> usize {
        self.outcome.evals - self.outcome.cache_hits
    }

    /// Model evaluations per second actually paid for.
    pub fn model_evals_per_s(&self) -> f64 {
        self.model_evals() as f64 / self.wall_s.max(1e-9)
    }

    /// Distinct genomes the evaluation engine interned — the cache-key
    /// working set of the run.
    pub fn distinct_genomes(&self) -> usize {
        self.outcome.interned
    }

    /// Stage-level cache hits (see `search::engine`): how much of the
    /// population's structure the staged cache exploited. One evaluation
    /// can contribute up to 4 hits (its mapping stage + three per-tensor
    /// format stages), so this can legitimately exceed `evals`.
    pub fn stage_hits(&self) -> usize {
        self.outcome.stage_hits
    }

    /// Per-member budget/best breakdown — non-empty only for the
    /// `portfolio` meta-method (see `crate::optimizer::portfolio`).
    pub fn members(&self) -> &[crate::search::MemberStats] {
        &self.outcome.members
    }

    pub fn into_outcome(self) -> Outcome {
        self.outcome
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(REPORT_SCHEMA)),
            ("request", self.request.to_json()),
            ("outcome", self.outcome.to_json_full()),
            ("wall_s", Json::num(self.wall_s)),
            ("stopped_early", Json::Bool(self.stopped_early)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SearchReport> {
        if let Some(schema) = j.get("schema").and_then(Json::as_str) {
            ensure!(schema == REPORT_SCHEMA, "unsupported report schema '{schema}'");
        }
        Ok(SearchReport {
            request: SearchRequest::from_json(
                j.get("request").ok_or_else(|| anyhow!("report JSON is missing 'request'"))?,
            )?,
            outcome: Outcome::from_json(
                j.get("outcome").ok_or_else(|| anyhow!("report JSON is missing 'outcome'"))?,
            )?,
            wall_s: j.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0),
            stopped_early: j.get("stopped_early").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_round_trips() {
        let report = SearchRequest::new()
            .workload_named("mm1")
            .platform_named("edge")
            .method("random")
            .budget(80)
            .seed(5)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let dumped = report.to_json().pretty();
        let parsed = SearchReport::from_json(&Json::parse(&dumped).unwrap()).unwrap();
        assert_eq!(parsed.request, report.request);
        assert_eq!(parsed.outcome.best_edp, report.outcome.best_edp);
        assert_eq!(parsed.outcome.best_genome, report.outcome.best_genome);
        assert_eq!(parsed.outcome.curve, report.outcome.curve);
        assert_eq!(parsed.stopped_early, report.stopped_early);
        assert_eq!(parsed.distinct_genomes(), report.distinct_genomes());
        assert_eq!(parsed.stage_hits(), report.stage_hits());
        assert_eq!(parsed.to_json(), report.to_json());
    }

    #[test]
    fn portfolio_report_round_trips_with_members() {
        let report = SearchRequest::new()
            .workload_named("mm1")
            .platform_named("edge")
            .method("portfolio")
            .method_opts(Json::parse(r#"{"members": ["random", "pso"], "rounds": 2}"#).unwrap())
            .budget(200)
            .seed(3)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.outcome.method, "portfolio");
        assert_eq!(report.members().len(), 2);
        assert_eq!(report.members().iter().map(|m| m.evals).sum::<usize>(), report.outcome.evals);
        let parsed =
            SearchReport::from_json(&Json::parse(&report.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(parsed.request, report.request);
        assert_eq!(parsed.outcome.members, report.outcome.members);
        assert_eq!(parsed.to_json(), report.to_json());
    }

    #[test]
    fn wrong_schema_rejected() {
        let j = Json::obj(vec![("schema", Json::str("bogus.v9"))]);
        assert!(SearchReport::from_json(&j).is_err());
    }
}
