//! End-to-end driver — proves all three layers compose on a real small
//! workload:
//!
//!   1. **L3 search** through the `sparsemap::api` front door finds the
//!      best accelerator design for a pruned-VGG16 conv layer, with
//!      fitness evaluated through the **AOT PJRT cost-model artifact**
//!      (L2 JAX graph + L1 Pallas kernel, lowered at build time by
//!      `make artifacts`).
//!   2. The evaluation is cross-checked against the native Rust model.
//!   3. The winning design is **functionally instantiated**: the gated-
//!      SpMM Pallas artifact executes a tile of the actual workload with
//!      the design's Gate P<->Q semantics through PJRT, and the measured
//!      effectual-MAC count is compared with the cost model's prediction.
//!
//! ```bash
//! make artifacts && cargo run --release --features xla --example end_to_end
//! ```

use sparsemap::api::SearchRequest;
use sparsemap::genome::{decode, describe, GenomeSpec};
use sparsemap::model::NativeEvaluator;
use sparsemap::runtime::{Runtime, SpmmDemo};
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::table3;

fn main() -> anyhow::Result<()> {
    let budget: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5_000);
    let workload = table3::by_id("conv4").expect("conv4");

    // --- 1. search through the PJRT-evaluated hot path -------------------
    let rt = Runtime::from_default_dir()?;
    println!(
        "[1/3] searching {} on mobile via PJRT artifact ({}, batch {})",
        workload.id, rt.meta.cost_model_file, rt.meta.batch
    );
    let report = SearchRequest::new()
        .workload_named("conv4")
        .platform_named("mobile")
        .budget(budget)
        .seed(42)
        .pjrt(true)
        .build()?
        .run()?;
    let outcome = &report.outcome;
    println!(
        "      best EDP {:.4e}  ({} evals in {:.2}s -> {:.0} evals/s, {:.1}% valid)",
        outcome.best_edp,
        outcome.evals,
        report.wall_s,
        outcome.evals as f64 / report.wall_s.max(1e-9),
        100.0 * outcome.valid_ratio()
    );

    // --- 2. cross-check PJRT fitness against the native model -------------
    let genome = outcome.best_genome.clone().expect("no valid design");
    let platform = sparsemap::arch::Platform::mobile();
    let native = NativeEvaluator::new(workload.clone(), platform);
    let nres = native.eval_genome(&genome);
    let rel = (nres.edp - outcome.best_edp).abs() / nres.edp;
    println!(
        "[2/3] native cross-check: EDP {:.4e} (relative deviation {:.2e})",
        nres.edp, rel
    );
    anyhow::ensure!(rel < 1e-2, "PJRT and native evaluators disagree");

    let spec = GenomeSpec::for_workload(&workload);
    let design = decode(&spec, &workload, &genome);
    println!("--- winning design ---\n{}", describe(&design, &workload));

    // --- 3. functionally instantiate: run the workload tile ----------------
    let demo = SpmmDemo::new(&rt)?;
    let (m, k, n) = (demo.m, demo.k, demo.n);
    let (dp, dq) = (workload.tensors[0].density.avg(), workload.tensors[1].density.avg());
    let mut rng = Pcg64::seeded(7);
    let p: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let q: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let pm: Vec<f32> =
        (0..m * k).map(|_| if rng.f64() < dp { 1.0 } else { 0.0 }).collect();
    let qm: Vec<f32> =
        (0..k * n).map(|_| if rng.f64() < dq { 1.0 } else { 0.0 }).collect();
    let (z, eff) = demo.run(&p, &q, &pm, &qm)?;
    let measured_frac = eff / (m * k * n) as f64;
    let predicted_frac = dp * dq; // Gate P<->Q effectual fraction
    println!(
        "[3/3] instantiated {}x{}x{} tile through PJRT: {:.1}% effectual MACs \
         (cost model predicts {:.1}%), z checksum {:.3}",
        m,
        k,
        n,
        100.0 * measured_frac,
        100.0 * predicted_frac,
        z.iter().map(|x| *x as f64).sum::<f64>()
    );
    anyhow::ensure!(
        (measured_frac - predicted_frac).abs() < 0.05,
        "effectual-MAC measurement diverges from the cost model"
    );
    println!("end-to-end OK: search -> AOT evaluation -> instantiation all agree");
    Ok(())
}
