//! The `portfolio` meta-optimizer: round-based successive-halving racing
//! of member methods over one **shared** budget, evaluation cache and
//! worker pool — the first method only expressible because every search
//! arm now runs behind the [`Optimizer`] trait against a borrowed
//! [`EvalContext`].
//!
//! ## How the race works
//!
//! The portfolio never evaluates a genome itself. Each round it divides
//! an equal share of the remaining shared budget among the surviving
//! members and runs each member *to that fence*
//! ([`EvalContext::set_fence`]): the member sees an ordinary
//! budget-exhausted context and winds down through its normal exit path.
//! After every round but the last, the worst `1 - 1/eta` of survivors
//! (by their own per-slice best EDP) are eliminated. Rounding leftovers
//! go to the best survivor at the end.
//!
//! Members are deterministic and re-run **with the same seed** each
//! round. For methods whose trajectory does not depend on the remaining
//! budget (pso, random, sparseloop, sage-like, es-direct, mcts, tbpsa,
//! ppo, dqn), the round-`r+1` run therefore repeats its round-`r`
//! trajectory as a prefix, and the shared evaluation cache serves that
//! prefix without model calls (still debiting the budget, like every
//! cache hit: the paper counts submissions) — classic restart-based
//! successive halving. The ES family (sparsemap / es-pfce / es-std) is
//! deliberately different: it sizes its population, calibration and
//! annealing schedule to the budget it can actually spend
//! (`ctx.remaining()` at entry), so each round it launches a *fresh,
//! better-proportioned* search over the larger share instead of
//! replaying an undersized one. Either way the shared telemetry
//! accumulates in the one context, so the portfolio's [`Outcome`]
//! carries the global best across all members, and [`Outcome::members`]
//! breaks the spend down per member — their `evals` sum to the
//! outcome's `evals` exactly.

use super::{opt_usize, resolve, MethodSpec, Optimizer};
use crate::search::{EvalContext, MemberStats, Outcome};
use crate::util::json::Json;
use anyhow::{bail, Result};

/// Default member set: the flagship ES, its encoding-only ablation, and
/// the two strongest non-ES baselines at small budgets.
pub const DEFAULT_MEMBERS: &[&str] = &["sparsemap", "es-pfce", "pso", "random"];

struct Member {
    spec: &'static MethodSpec,
    opts: Json,
    evals: usize,
    best_edp: f64,
    rounds: usize,
    eliminated_round: Option<usize>,
}

/// The meta-optimizer. Construct through the registry:
/// `resolve("portfolio")?.build(&opts)`.
pub struct Portfolio {
    members: Vec<Member>,
    rounds: usize,
    eta: usize,
}

/// Registry builder (opts pre-validated against the portfolio tunables).
pub(crate) fn build(opts: &Json) -> Result<Box<dyn Optimizer>> {
    let names: Vec<String> = match opts.get("members") {
        Some(Json::Arr(a)) => {
            a.iter().map(|m| m.as_str().unwrap_or_default().to_string()).collect()
        }
        _ => DEFAULT_MEMBERS.iter().map(|s| s.to_string()).collect(),
    };
    let mut members = Vec::with_capacity(names.len());
    for name in &names {
        let spec = resolve(name)?;
        if members.iter().any(|m: &Member| std::ptr::eq(m.spec, spec)) {
            bail!("portfolio member '{}' listed twice", spec.name);
        }
        members.push(Member {
            spec,
            opts: Json::Obj(Default::default()),
            evals: 0,
            best_edp: f64::INFINITY,
            rounds: 0,
            eliminated_round: None,
        });
    }
    // `member_opts` keys resolve through the registry like any method
    // name (aliases welcome), and each must name an actual member —
    // silently dropping a user's tuning would be the worst failure mode.
    if let Some(map) = opts.get("member_opts").and_then(Json::as_obj) {
        let mut assigned = vec![false; members.len()];
        for (key, val) in map {
            let kspec = resolve(key)?;
            let Some(i) = members.iter().position(|m| std::ptr::eq(m.spec, kspec)) else {
                bail!(
                    "member_opts entry '{key}' does not match any portfolio member \
                     (members: {names:?})"
                );
            };
            if assigned[i] {
                bail!("member_opts sets '{}' twice (via different spellings)", kspec.name);
            }
            assigned[i] = true;
            members[i].opts = val.clone();
        }
    }
    Ok(Box::new(Portfolio {
        members,
        rounds: opt_usize(opts, "rounds", 3).max(1),
        eta: opt_usize(opts, "eta", 2).max(2),
    }))
}

impl Portfolio {
    /// Run `member` until `fence` (an absolute submission count), folding
    /// the slice's spend and per-slice best into its stats. `round` is
    /// the portfolio-level round index (the same number the halving path
    /// records in `eliminated_round`).
    fn run_slice(
        member: &mut Member,
        ctx: &mut EvalContext,
        fence: Option<usize>,
        seed: u64,
        round: usize,
    ) {
        let before = ctx.used();
        ctx.begin_slice();
        ctx.set_fence(fence);
        // Validated at build time, so this only fails if a member's
        // semantic invariants break — eliminate it (loudly) rather than
        // poison the whole race.
        match member.spec.build(&member.opts) {
            Ok(mut opt) => opt.run(ctx, seed),
            Err(e) => {
                eprintln!("warning: portfolio member '{}' failed to build: {e}", member.spec.name);
                member.eliminated_round = Some(round);
            }
        }
        ctx.set_fence(None);
        member.evals += ctx.used() - before;
        member.best_edp = member.best_edp.min(ctx.slice_best());
        member.rounds += 1;
    }

    fn alive(&self) -> Vec<usize> {
        (0..self.members.len())
            .filter(|&i| self.members[i].eliminated_round.is_none())
            .collect()
    }
}

impl Optimizer for Portfolio {
    fn label(&self) -> &str {
        "portfolio"
    }

    fn run(&mut self, ctx: &mut EvalContext, seed: u64) {
        for round in 0..self.rounds {
            let alive = self.alive();
            if alive.is_empty() || ctx.exhausted() {
                break;
            }
            // This round's pot: an equal share of what's left for each
            // remaining round, split evenly across survivors.
            let pot = ctx.remaining() / (self.rounds - round);
            let share = (pot / alive.len()).max(1);
            for &i in &alive {
                if ctx.exhausted() {
                    break;
                }
                let alloc = share.min(ctx.remaining());
                let fence = ctx.used() + alloc;
                // Same member seed every round: budget-independent
                // methods resume by cache-served replay, the ES family
                // restarts proportioned to the new share (module docs).
                Self::run_slice(&mut self.members[i], ctx, Some(fence), seed, round);
            }
            // Successive halving after every round but the last: rank
            // survivors by their own best and keep ceil(alive/eta),
            // stable on ties (registry order).
            if round + 1 < self.rounds {
                let mut ranked = self.alive();
                ranked.sort_by(|&a, &b| {
                    self.members[a].best_edp.total_cmp(&self.members[b].best_edp)
                });
                let keep = ranked.len().div_ceil(self.eta).max(1);
                for &i in &ranked[keep..] {
                    self.members[i].eliminated_round = Some(round);
                }
            }
        }
        // Rounding leftovers go to the best survivor, unfenced.
        if !ctx.exhausted() {
            let best = self
                .alive()
                .into_iter()
                .min_by(|&a, &b| self.members[a].best_edp.total_cmp(&self.members[b].best_edp));
            if let Some(i) = best {
                let last_round = self.rounds.saturating_sub(1);
                Self::run_slice(&mut self.members[i], ctx, None, seed, last_round);
            }
        }
    }

    fn annotate(&self, outcome: &mut Outcome) {
        outcome.members = self
            .members
            .iter()
            .map(|m| MemberStats {
                method: m.spec.name.to_string(),
                evals: m.evals,
                best_edp: m.best_edp,
                rounds: m.rounds,
                eliminated_round: m.eliminated_round,
            })
            .collect();
    }
}

#[cfg(test)]
mod tests {
    use super::super::{run_method, run_method_with, ALL_METHODS};
    use crate::arch::Platform;
    use crate::search::{Backend, EvalContext};
    use crate::util::json::Json;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.4, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn portfolio_spends_exactly_its_budget_across_members() {
        let o = run_method("portfolio", ctx(900), 11).unwrap();
        assert_eq!(o.method, "portfolio");
        assert!(o.evals <= 900, "overspent: {}", o.evals);
        assert_eq!(o.members.len(), super::DEFAULT_MEMBERS.len());
        let member_sum: usize = o.members.iter().map(|m| m.evals).sum();
        assert_eq!(member_sum, o.evals, "member evals must sum to the outcome's");
        // The global best is at least as good as every member's own best.
        for m in &o.members {
            assert!(o.best_edp <= m.best_edp, "{} beat the portfolio best", m.method);
        }
        // With rounds=3 over 4 members someone must have been eliminated.
        assert!(o.members.iter().any(|m| m.eliminated_round.is_some()));
        assert!(o.members.iter().any(|m| m.eliminated_round.is_none()));
    }

    #[test]
    fn portfolio_is_deterministic_per_seed() {
        let a = run_method("portfolio", ctx(600), 4).unwrap();
        let b = run_method("portfolio", ctx(600), 4).unwrap();
        assert_eq!(a.best_edp.to_bits(), b.best_edp.to_bits());
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.members, b.members);
    }

    #[test]
    fn custom_members_and_member_opts() {
        let opts = Json::parse(
            r#"{"members": ["pso", "random"], "rounds": 2,
                "member_opts": {"pso": {"swarm": 12}}}"#,
        )
        .unwrap();
        let o = run_method_with("portfolio", &opts, ctx(400), 3).unwrap();
        assert_eq!(o.members.len(), 2);
        assert_eq!(o.members[0].method, "pso");
        assert_eq!(o.members[1].method, "random");
        assert_eq!(o.members.iter().map(|m| m.evals).sum::<usize>(), o.evals);
    }

    #[test]
    fn member_opts_resolve_aliases_and_reject_non_members() {
        // Opts keyed by an alias must reach the member named canonically
        // in `members`: if the alias failed to resolve onto the member,
        // build would reject it as a non-member entry and this unwrap
        // would fail.
        let aliased = Json::parse(
            r#"{"members": ["random"], "rounds": 1,
                "member_opts": {"rand": {"batch": 1}}}"#,
        )
        .unwrap();
        let o = run_method_with("portfolio", &aliased, ctx(40), 5).unwrap();
        assert_eq!(o.members[0].method, "random");
        assert_eq!(o.evals, 40);

        // Opts for a method that is not a member must fail loudly, not
        // be silently dropped.
        let stray = Json::parse(
            r#"{"members": ["pso"], "member_opts": {"random": {"batch": 8}}}"#,
        )
        .unwrap();
        let err = run_method_with("portfolio", &stray, ctx(40), 5).unwrap_err().to_string();
        assert!(err.contains("does not match any portfolio member"), "{err}");

        // Two spellings of the same member cannot both carry opts.
        let twice = Json::parse(
            r#"{"members": ["random"],
                "member_opts": {"random": {"batch": 8}, "rand": {"batch": 9}}}"#,
        )
        .unwrap();
        assert!(run_method_with("portfolio", &twice, ctx(40), 5).is_err());
    }

    #[test]
    fn nested_portfolio_and_duplicates_rejected() {
        let nested = Json::parse(r#"{"members": ["portfolio"]}"#).unwrap();
        assert!(run_method_with("portfolio", &nested, ctx(50), 1).is_err());
        // An alias duplicating a canonical member is caught too.
        let dup = Json::parse(r#"{"members": ["pso", "pso"]}"#).unwrap();
        assert!(run_method_with("portfolio", &dup, ctx(50), 1).is_err());
        let alias_dup = Json::parse(r#"{"members": ["random", "rand"]}"#).unwrap();
        assert!(run_method_with("portfolio", &alias_dup, ctx(50), 1).is_err());
    }

    #[test]
    fn tiny_budget_degrades_gracefully() {
        // Far fewer samples than members x rounds: must terminate, never
        // overspend, and still account every eval to a member.
        for budget in [1usize, 3, 7, 11] {
            let o = run_method("portfolio", ctx(budget), 2).unwrap();
            assert!(o.evals <= budget, "budget {budget} overspent: {}", o.evals);
            assert_eq!(o.members.iter().map(|m| m.evals).sum::<usize>(), o.evals);
        }
    }

    #[test]
    fn portfolio_listed_in_registry() {
        assert!(ALL_METHODS.contains(&"portfolio"));
    }
}
