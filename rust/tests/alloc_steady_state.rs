//! The engine's scratch-reuse invariant, asserted with a counting
//! allocator: **steady-state evaluation performs no per-genome heap
//! allocation**.
//!
//! Two windows are measured:
//!
//! 1. *Warm batches* through `EvalContext::eval_batch` (every submission
//!    a result-cache hit): the allocation count is a small constant —
//!    independent of the population size — dominated by the returned
//!    results `Vec`.
//! 2. *Stage-warm batches* through `StageEngine::eval_batch` (no result
//!    cache; every genome re-assembled from memoized stages): likewise a
//!    small constant, so per-genome assembly + cost is allocation-free.
//!
//! Each integration test binary owns its `#[global_allocator]`, so the
//! counter cannot leak into other suites.

use sparsemap::arch::Platform;
use sparsemap::model::NativeEvaluator;
use sparsemap::search::{Backend, EvalContext, StageEngine};
use sparsemap::util::rng::Pcg64;
use sparsemap::workload::Workload;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Count heap allocations performed by `f`.
fn count_allocs<R>(f: impl FnOnce() -> R) -> (usize, R) {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    let r = f();
    COUNTING.store(false, Ordering::SeqCst);
    (ALLOCS.load(Ordering::SeqCst), r)
}

fn setup(budget: usize) -> (EvalContext, Pcg64) {
    let w = Workload::spmm("t", 64, 128, 64, 0.2, 0.2);
    (
        EvalContext::new(Backend::native(w, Platform::mobile()), budget),
        Pcg64::seeded(1),
    )
}

/// Both steady-state windows in ONE test function: the counter is
/// process-global, so concurrent tests in this binary would pollute each
/// other's windows. Scenario 1: warm result-cache batches through
/// `EvalContext`. Scenario 2: stage-warm assembly through `StageEngine`.
#[test]
fn steady_state_evaluation_is_allocation_free_per_genome() {
    warm_batches_allocate_constant_not_per_genome();
    stage_warm_assembly_is_allocation_free_per_genome();
    batched_soa_path_is_allocation_free_per_genome();
}

/// Warm result-cache batches: the allocation count is a small constant
/// and does NOT scale with the number of genomes evaluated.
fn warm_batches_allocate_constant_not_per_genome() {
    let (mut c, mut rng) = setup(100_000);
    let big: Vec<Vec<u32>> = (0..400).map(|_| c.spec.random(&mut rng)).collect();
    let small = big[..100].to_vec();

    // Warm everything: results cached, scratch buffers at capacity.
    c.eval_batch(&big);
    c.eval_batch(&big);

    let (small_allocs, r1) = count_allocs(|| c.eval_batch(&small));
    assert_eq!(r1.len(), 100);
    let (big_allocs, r2) = count_allocs(|| c.eval_batch(&big));
    assert_eq!(r2.len(), 400);

    assert_eq!(
        small_allocs, big_allocs,
        "warm-batch allocations must not scale with population size \
         (100 genomes: {small_allocs}, 400 genomes: {big_allocs})"
    );
    // The constant itself is tiny: the returned results Vec plus a
    // couple of collection internals at most.
    assert!(
        big_allocs <= 8,
        "warm batch of 400 genomes performed {big_allocs} allocations; \
         expected a small constant (scratch reuse broken?)"
    );
}

/// Stage-warm assembly through the engine directly (no result cache in
/// the way): re-evaluating a population whose mapping/format stages are
/// memoized allocates a small constant, i.e. zero per genome.
fn stage_warm_assembly_is_allocation_free_per_genome() {
    let w = Workload::spmm("t", 64, 128, 64, 0.2, 0.2);
    let eval = Arc::new(NativeEvaluator::new(w, Platform::mobile()));
    let mut engine = StageEngine::new(Arc::clone(&eval), 1_000_000);
    let mut rng = Pcg64::seeded(5);
    let spec = eval.spec.clone();

    let mk_pop = |n: usize, rng: &mut Pcg64| -> Vec<Arc<[u32]>> {
        let parents: Vec<Vec<u32>> = (0..10).map(|_| spec.random(rng)).collect();
        (0..n)
            .map(|i| {
                let mut g = parents[i % parents.len()].clone();
                for j in spec.sg_start..spec.len() {
                    g[j] = rng.range_u32(spec.ranges[j].lo, spec.ranges[j].hi);
                }
                Arc::from(g.as_slice())
            })
            .collect()
    };
    let pop100 = mk_pop(100, &mut rng);
    let pop400: Vec<Arc<[u32]>> = {
        let mut v = pop100.clone();
        v.extend(pop100.iter().cycle().take(300).cloned());
        v
    };

    // Warm the stage caches and the engine's scratch buffers.
    engine.eval_batch(&pop400, None);
    engine.eval_batch(&pop400, None);

    let (a100, r100) = count_allocs(|| engine.eval_batch(&pop100, None));
    assert_eq!(r100.len(), 100);
    let (a400, r400) = count_allocs(|| engine.eval_batch(&pop400, None));
    assert_eq!(r400.len(), 400);

    // One allocation scales with n by design: the returned results Vec.
    // Everything else is reused scratch, so the *count* stays flat.
    assert_eq!(
        a100, a400,
        "stage-warm allocations must not scale with population size \
         (100: {a100}, 400: {a400})"
    );
    assert!(
        a400 <= 4,
        "stage-warm batch performed {a400} allocations; expected ≲ the \
         single results Vec (per-genome allocation crept back in?)"
    );
}

/// The batched SoA assembly path specifically (the engine default) vs
/// the per-genome walk: both stay flat in the number of genomes once
/// warm — the SoA tables, the group-sort order buffer and the word-pack
/// probe scratch are all reused across batches.
fn batched_soa_path_is_allocation_free_per_genome() {
    let w = Workload::spmm("t", 64, 128, 64, 0.2, 0.2);
    let eval = Arc::new(NativeEvaluator::new(w, Platform::mobile()));
    let mut rng = Pcg64::seeded(6);
    let spec = eval.spec.clone();
    let parents: Vec<Vec<u32>> = (0..10).map(|_| spec.random(&mut rng)).collect();
    let pop: Vec<Arc<[u32]>> = (0..300)
        .map(|i| {
            let mut g = parents[i % parents.len()].clone();
            for j in spec.sg_start..spec.len() {
                g[j] = rng.range_u32(spec.ranges[j].lo, spec.ranges[j].hi);
            }
            Arc::from(g.as_slice())
        })
        .collect();

    let mut batched = StageEngine::new(Arc::clone(&eval), 1_000_000);
    let mut pergenome = StageEngine::new(Arc::clone(&eval), 1_000_000).with_batched(false);

    // Warm stage caches and scratch (SoA tables / AsmItem list) in both.
    let warm_b = batched.eval_batch(&pop, None);
    let warm_p = pergenome.eval_batch(&pop, None);
    assert_eq!(warm_b, warm_p, "modes must agree before counting");
    batched.eval_batch(&pop, None);
    pergenome.eval_batch(&pop, None);

    let (ab, rb) = count_allocs(|| batched.eval_batch(&pop, None));
    let (ap, rp) = count_allocs(|| pergenome.eval_batch(&pop, None));
    assert_eq!(rb, rp);
    assert!(
        ab <= 4,
        "batched SoA warm batch performed {ab} allocations; expected ≲ the \
         single results Vec (SoA scratch reuse broken?)"
    );
    assert!(ap <= 4, "per-genome warm batch performed {ap} allocations");
}
