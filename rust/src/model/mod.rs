//! The analytical sparse-accelerator cost model (the paper's "HW
//! evaluation environment", a Sparseloop/TimeloopV2-class substrate).
//!
//! Pipeline: genome → [`crate::genome::decode`] → [`features::extract`]
//! (combinatorial analysis) → [`cost::evaluate_features`] (shared
//! arithmetic, mirrored in `python/compile/model.py` for the AOT path).

pub mod cost;
pub mod features;
pub mod validity;

pub use cost::{evaluate_features, platform_vector, CostBreakdown};
pub use features::{
    assemble, extract, format_stage, mapping_stage, to_f32_row, Features, MapFeats,
    MappingStage, TensorCompression, WorkloadConsts, NUM_FEATURES, NUM_PLATFORM_FEATURES,
    SCHEMA_VERSION,
};
pub use validity::{is_structurally_valid, structural_problems, InvalidReason};

use crate::arch::Platform;
use crate::genome::{decode, Design, GenomeSpec};
use crate::workload::Workload;

/// Evaluation verdict for one genome/design.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub energy_pj: f64,
    pub cycles: f64,
    /// EDP in pJ·cycles; `f64::INFINITY` when invalid (dead individual —
    /// the paper assigns these fitness 0).
    pub edp: f64,
    pub valid: bool,
}

impl EvalResult {
    pub fn from_breakdown(cb: &CostBreakdown) -> EvalResult {
        let valid = cb.valid > 0.5;
        EvalResult {
            energy_pj: cb.energy_pj,
            cycles: cb.cycles,
            edp: if valid { cb.edp } else { f64::INFINITY },
            valid,
        }
    }

    /// The canonical dead-individual verdict (structurally invalid or
    /// dead-on-arrival designs; fitness 0).
    pub fn dead() -> EvalResult {
        EvalResult { energy_pj: 0.0, cycles: 0.0, edp: f64::INFINITY, valid: false }
    }

    /// Fitness for maximizing searches: 1/EDP, 0 for dead individuals.
    pub fn fitness(&self) -> f64 {
        if self.valid && self.edp.is_finite() && self.edp > 0.0 {
            1.0 / self.edp
        } else {
            0.0
        }
    }
}

/// A reusable native evaluator for a (workload, platform) pair.
///
/// This is the reference implementation; the PJRT-backed
/// `runtime::BatchEvaluator` (behind the `xla` feature) executes the
/// same formula from the AOT artifact and is the default search hot path.
pub struct NativeEvaluator {
    pub workload: Workload,
    pub platform: Platform,
    pub spec: GenomeSpec,
    platform_vec: Vec<f64>,
}

impl NativeEvaluator {
    pub fn new(workload: Workload, platform: Platform) -> NativeEvaluator {
        let spec = GenomeSpec::for_workload(&workload);
        let platform_vec = platform_vector(&platform);
        NativeEvaluator { workload, platform, spec, platform_vec }
    }

    /// Decode + evaluate one genome.
    pub fn eval_genome(&self, genome: &[u32]) -> EvalResult {
        let design = decode(&self.spec, &self.workload, genome);
        self.eval_design(&design)
    }

    /// Evaluate an already-decoded design.
    pub fn eval_design(&self, design: &Design) -> EvalResult {
        let f = extract(design, &self.workload, &self.platform);
        let cb = evaluate_features(&f, &self.platform_vec);
        EvalResult::from_breakdown(&cb)
    }

    /// Finish an evaluation from an already-assembled feature vector —
    /// the staged engine's last step. Same arithmetic as
    /// [`NativeEvaluator::eval_design`]; allocation-free.
    pub fn eval_features(&self, f: &Features) -> EvalResult {
        EvalResult::from_breakdown(&evaluate_features(f, &self.platform_vec))
    }

    /// Full breakdown (reports, Fig. 2).
    pub fn breakdown(&self, design: &Design) -> CostBreakdown {
        let f = extract(design, &self.workload, &self.platform);
        evaluate_features(&f, &self.platform_vec)
    }

    /// Diagnostics: why is this genome invalid (empty if valid).
    pub fn explain_invalid(&self, genome: &[u32]) -> Vec<InvalidReason> {
        let design = decode(&self.spec, &self.workload, genome);
        let mut problems = structural_problems(&design, &self.workload, &self.platform);
        let cb = self.breakdown(&design);
        if cb.glb_util > 1.0 {
            problems.push(InvalidReason::GlbCapacity {
                words: cb.glb_util * self.platform.glb_words(),
                capacity: self.platform.glb_words(),
            });
        }
        if cb.pe_util > 1.0 {
            problems.push(InvalidReason::PeCapacity {
                words: cb.pe_util * self.platform.pe_buf_words(),
                capacity: self.platform.pe_buf_words(),
            });
        }
        problems
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn native_evaluator_roundtrip() {
        let ev = NativeEvaluator::new(
            Workload::spmm("t", 16, 32, 16, 0.5, 0.25),
            Platform::edge(),
        );
        let mut g = vec![1u32; ev.spec.len()];
        for i in ev.spec.format_start..ev.spec.len() {
            g[i] = 0;
        }
        let r = ev.eval_genome(&g);
        assert!(r.valid);
        assert!(r.edp.is_finite());
        assert!(r.fitness() > 0.0);
    }

    #[test]
    fn invalid_genome_explained() {
        let ev = NativeEvaluator::new(
            Workload::spmm("t", 1024, 1024, 1024, 0.9, 0.9),
            Platform::edge(),
        );
        let mut g = vec![1u32; ev.spec.len()];
        for i in ev.spec.factor_start..ev.spec.format_start {
            g[i] = 3; // everything spatial at L2_S: massive fanout
        }
        let r = ev.eval_genome(&g);
        assert!(!r.valid);
        assert_eq!(r.fitness(), 0.0);
        assert!(!ev.explain_invalid(&g).is_empty());
    }

    #[test]
    fn some_random_genomes_valid_some_not() {
        // The defining property of the joint design space (Fig. 7): it
        // contains both valid and invalid points in quantity.
        let ev = NativeEvaluator::new(
            Workload::spmm("mm3", 730, 730, 730, 0.118, 0.118),
            Platform::cloud(),
        );
        let mut rng = Pcg64::seeded(7);
        let mut valid = 0;
        let n = 400;
        for _ in 0..n {
            let g = ev.spec.random(&mut rng);
            if ev.eval_genome(&g).valid {
                valid += 1;
            }
        }
        assert!(valid > 0, "no valid designs in {n} samples");
        assert!(valid < n, "every design valid — invalid structure missing");
    }

    #[test]
    fn better_hardware_lower_edp() {
        // The same modest design should not be slower on cloud than edge.
        let w = Workload::spmm("t", 64, 64, 64, 0.3, 0.3);
        let spec = GenomeSpec::for_workload(&w);
        let mut g = vec![1u32; spec.len()];
        for i in spec.format_start..spec.len() {
            g[i] = 0;
        }
        let edge = NativeEvaluator::new(w.clone(), Platform::edge()).eval_genome(&g);
        let cloud = NativeEvaluator::new(w, Platform::cloud()).eval_genome(&g);
        assert!(cloud.cycles <= edge.cycles);
    }
}
