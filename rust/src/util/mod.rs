//! Self-contained utility substrates.
//!
//! The build environment is fully offline and the vendored crate set only
//! provides `xla` + `anyhow`, so the conveniences a project would normally
//! pull from crates.io are implemented here: a PCG64 RNG ([`rng`]), a JSON
//! codec ([`json`]), a CLI parser ([`cli`]), a thread pool ([`threadpool`]),
//! descriptive statistics ([`stats`]), power-iteration PCA ([`pca`]),
//! ASCII/CSV table rendering ([`table`]), plus the fault-tolerance
//! substrate: deterministic fault injection ([`faults`]), durable
//! atomic file replacement ([`fsio`]), bounded jittered retry
//! ([`retry`]) and poison-recovering locks ([`sync`]).

pub mod cli;
pub mod faults;
pub mod fsio;
pub mod hash;
pub mod json;
pub mod pca;
pub mod retry;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
pub mod threadpool;

pub use fsio::{atomic_write, sync_dir};
pub use sync::relock;
