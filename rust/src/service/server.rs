//! The service runtime: TCP listener, request routing, the worker pool
//! that drains the job queue, and checkpoint persistence across
//! restarts.
//!
//! Concurrency model: one accept thread spawns a short-lived thread per
//! connection (bounded by [`ServerConfig::max_conns`]; above the cap
//! connections are shed with `503` + `Retry-After`); a fixed pool of
//! worker threads pops jobs off the priority queue. All state lives in
//! one `Mutex<State>` guarded map — searches themselves run outside the
//! lock, touching it only from the progress observer and at state
//! transitions.
//!
//! Fault posture: every accepted socket carries read/write timeouts so
//! a stalled client cannot pin its thread; worker job execution runs
//! under `catch_unwind`, landing a panicked search in the `failed`
//! terminal state instead of wedging `running`; all lock takes recover
//! from poisoning ([`crate::util::relock`]); checkpoint writes are
//! atomic + fsynced with bounded retries; and SIGTERM/SIGINT trigger a
//! graceful [`drain`] — stop accepting, suspend running resumable jobs
//! to their checkpoints, flush, exit.

use super::http;
use super::job::{Job, JobState};
use super::queue::{JobQueue, QueueEntry, QuotaBook};
use crate::api::{RunOpts, SearchReport, SearchRequest};
use crate::obs::{self, metrics};
use crate::optimizer::{self, Checkpoint};
use crate::search::{Progress, SearchControl};
use crate::util::faults::{self, points};
use crate::util::json::Json;
use crate::util::retry::{retry, Backoff};
use crate::util::sync::{relock, rewait, rewait_timeout};
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::io::{self, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How the daemon runs: where to listen, how many concurrent searches,
/// the per-tenant quota (0 = unlimited) and where suspended jobs
/// persist (None = in-memory only, checkpoints do not survive
/// restarts).
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub quota: usize,
    pub checkpoint_dir: Option<PathBuf>,
    /// When set, every request (except `GET /health`) must carry a
    /// matching `Authorization: Bearer <token>` header or it is refused
    /// with 401 — the actual trust boundary, replacing the honor-system
    /// `tenant` field.
    pub auth_token: Option<String>,
    /// Shared design-memory store file: completed jobs deposit their
    /// elite designs, and jobs whose request carries a `warm_start`
    /// block seed from it (None = no memory).
    pub memory_store: Option<PathBuf>,
    /// Record cap enforced on the memory store at startup (see
    /// `MemoryStore::compact`).
    pub memory_cap: usize,
    /// Maximum concurrently-open connections; above it new connections
    /// are refused with `503` + `Retry-After` (load shedding) instead of
    /// spawning an unbounded thread each.
    pub max_conns: usize,
    /// Per-socket read/write timeouts: a client that stalls mid-request
    /// (or stops draining its response) gets its connection closed
    /// instead of pinning a thread and a connection slot forever.
    pub read_timeout: Duration,
    pub write_timeout: Duration,
    /// How long a graceful drain waits for running jobs to suspend or
    /// finish before giving up on them.
    pub drain_grace: Duration,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 1,
            quota: 0,
            checkpoint_dir: None,
            auth_token: None,
            memory_store: None,
            memory_cap: crate::memory::DEFAULT_CAP,
            max_conns: 64,
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            drain_grace: Duration::from_secs(10),
        }
    }
}

/// Everything behind the mutex: the job map, the pending queue and the
/// quota ledger.
struct State {
    jobs: BTreeMap<String, Job>,
    queue: JobQueue,
    quotas: QuotaBook,
    next_seq: u64,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
    checkpoint_dir: Option<PathBuf>,
    auth_token: Option<String>,
    /// The one store every worker shares: sequenced by its own mutex so
    /// appends from concurrent jobs serialize (it is only touched
    /// outside the state lock — never hold both).
    memory: Option<Arc<Mutex<crate::memory::MemoryStore>>>,
    /// The bound address (drain wakes the blocked accept loop by
    /// connecting to it).
    addr: SocketAddr,
    max_conns: usize,
    read_timeout: Duration,
    write_timeout: Duration,
    drain_grace: Duration,
    /// Connections currently open (accept loop increments, connection
    /// threads decrement on exit) — the load-shedding ledger.
    live_conns: AtomicUsize,
    /// Set once by [`drain`]: stop accepting, refuse non-public
    /// requests, wind workers down.
    draining: AtomicBool,
}

/// A started service: the bound address plus a handle into its state,
/// for embedding callers and tests. Threads are detached — dropping the
/// handle does not stop the server.
pub struct ServiceHandle {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
}

impl ServiceHandle {
    /// Snapshot of every tracked job's `(id, state)`, in id order.
    pub fn job_states(&self) -> Vec<(String, JobState)> {
        let st = relock(&self.shared.state);
        st.jobs.iter().map(|(id, j)| (id.clone(), j.state)).collect()
    }

    /// Connections currently open.
    pub fn live_connections(&self) -> usize {
        self.shared.live_conns.load(Ordering::SeqCst)
    }

    /// Gracefully drain this service (see [`drain`]): stop accepting,
    /// suspend running resumable jobs to their checkpoints, cancel the
    /// rest, wait up to the configured grace, flush. Idempotent; blocks
    /// until the drain completes.
    pub fn drain(&self) {
        drain(&self.shared);
    }
}

/// Bind, rescan the checkpoint directory, spawn workers and the accept
/// loop, and return immediately. Use `addr: "127.0.0.1:0"` to let the
/// OS pick a free port (the handle reports the real one).
pub fn start(cfg: ServerConfig) -> Result<ServiceHandle> {
    let listener =
        TcpListener::bind(&cfg.addr).map_err(|e| anyhow!("cannot bind {}: {e}", cfg.addr))?;
    let addr = listener.local_addr()?;
    if let Some(dir) = &cfg.checkpoint_dir {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow!("cannot create checkpoint dir {}: {e}", dir.display()))?;
    }
    let mut state = State {
        jobs: BTreeMap::new(),
        queue: JobQueue::new(),
        quotas: QuotaBook::new(cfg.quota),
        next_seq: 0,
    };
    if let Some(dir) = &cfg.checkpoint_dir {
        let n = rescan_checkpoints(&mut state, dir);
        if n > 0 {
            eprintln!("restored {n} suspended job(s) from {}", dir.display());
        }
    }
    // Open the shared design memory and enforce the record cap up front,
    // mirroring the checkpoint rescan: the store is bounded on every
    // startup, so it cannot grow without limit across service restarts.
    let memory = match &cfg.memory_store {
        Some(path) => {
            let mut store = crate::memory::MemoryStore::open(path)
                .map_err(|e| anyhow!("cannot open memory store: {e}"))?;
            let evicted = store
                .compact(cfg.memory_cap.max(1))
                .map_err(|e| anyhow!("cannot compact memory store: {e}"))?;
            if evicted > 0 {
                eprintln!("memory store compacted: evicted {evicted} record(s)");
            }
            Some(Arc::new(Mutex::new(store)))
        }
        None => None,
    };
    let shared = Arc::new(Shared {
        state: Mutex::new(state),
        cv: Condvar::new(),
        checkpoint_dir: cfg.checkpoint_dir,
        auth_token: cfg.auth_token,
        memory,
        addr,
        max_conns: cfg.max_conns.max(1),
        read_timeout: cfg.read_timeout,
        write_timeout: cfg.write_timeout,
        drain_grace: cfg.drain_grace,
        live_conns: AtomicUsize::new(0),
        draining: AtomicBool::new(false),
    });
    for _ in 0..cfg.workers.max(1) {
        let s = Arc::clone(&shared);
        std::thread::spawn(move || worker_loop(&s));
    }
    let accept_shared = Arc::clone(&shared);
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            if accept_shared.draining.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let live = accept_shared.live_conns.fetch_add(1, Ordering::SeqCst) + 1;
                    obs::global().live_connections.set(live as u64);
                    if live > accept_shared.max_conns {
                        // Load shedding: refuse with 503 + Retry-After
                        // instead of spawning yet another thread. The
                        // refusal is written inline — it is one small
                        // write and the accept loop must never block on
                        // a slow client, hence the write timeout.
                        obs::global().conns_shed.inc();
                        let mut w = stream;
                        let _ = w.set_write_timeout(Some(accept_shared.write_timeout));
                        let _ = http::unavailable(&mut w, "server at connection capacity", 1);
                        let live = accept_shared.live_conns.fetch_sub(1, Ordering::SeqCst) - 1;
                        obs::global().live_connections.set(live as u64);
                        continue;
                    }
                    let s = Arc::clone(&accept_shared);
                    std::thread::spawn(move || {
                        handle_connection(&s, stream);
                        let live = s.live_conns.fetch_sub(1, Ordering::SeqCst) - 1;
                        obs::global().live_connections.set(live as u64);
                    });
                }
                Err(e) => eprintln!("warning: accept failed: {e}"),
            }
        }
    });
    Ok(ServiceHandle { addr, shared })
}

/// [`start`], then block until a shutdown signal arrives and the
/// service has drained. The `sparsemap serve` entry point: on SIGTERM
/// or SIGINT it stops accepting, suspends running resumable jobs to
/// their checkpoints, flushes, and returns — so an orchestrator's
/// ordinary stop is a clean suspend, not a kill.
pub fn serve(cfg: ServerConfig) -> Result<()> {
    let handle = start(cfg)?;
    println!("sparsemap service listening on http://{}", handle.addr);
    install_shutdown_handler();
    loop {
        if SHUTDOWN.load(Ordering::SeqCst) {
            eprintln!("shutdown signal received; draining");
            handle.drain();
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// Set by the SIGTERM/SIGINT handler; polled by [`serve`].
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_shutdown_handler() {
    // Raw `signal(2)` via the C runtime already linked into every Rust
    // binary — no libc crate in a std-only tree. The handler only flips
    // an atomic (async-signal-safe); all real work happens in `serve`.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

#[cfg(not(unix))]
fn install_shutdown_handler() {}

/// Graceful drain: stop accepting, ask every running resumable job to
/// suspend to its checkpoint (non-resumable ones are cancelled), wait
/// up to `drain_grace` for workers to land them, then fsync the
/// checkpoint directory. Idempotent — the second caller returns
/// immediately.
fn drain(shared: &Arc<Shared>) {
    if shared.draining.swap(true, Ordering::SeqCst) {
        return;
    }
    // The accept loop blocks in `incoming()`; a throwaway connection
    // wakes it so it can observe the draining flag and exit.
    let _ = TcpStream::connect(shared.addr);
    {
        let mut st = relock(&shared.state);
        for job in st.jobs.values_mut() {
            if job.state != JobState::Running {
                continue;
            }
            let resumable =
                optimizer::resolve(&job.request.method).map(|s| s.resumable).unwrap_or(false);
            if resumable {
                if let Some(f) = &job.suspend {
                    f.store(true, Ordering::SeqCst);
                }
            } else if let Some(f) = &job.cancel {
                f.store(true, Ordering::SeqCst);
            }
        }
    }
    shared.cv.notify_all();
    let deadline = Instant::now() + shared.drain_grace;
    loop {
        let running = {
            let st = relock(&shared.state);
            st.jobs.values().filter(|j| j.state == JobState::Running).count()
        };
        if running == 0 {
            break;
        }
        if Instant::now() >= deadline {
            eprintln!("warning: drain grace expired with {running} job(s) still running");
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    if let Some(dir) = &shared.checkpoint_dir {
        let _ = crate::util::sync_dir(dir);
    }
    eprintln!("service drained");
}

fn handle_connection(shared: &Arc<Shared>, stream: TcpStream) {
    // Timeouts first: a client that stalls mid-request or stops
    // draining its response gets an I/O error here instead of pinning
    // this thread (and its connection slot) forever.
    let _ = stream.set_read_timeout(Some(shared.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.write_timeout));
    let reader_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_half);
    let mut w = stream;
    // Chaos seam: a planned socket-read fault models the peer dying (or
    // the timeout firing) before a full request arrived.
    if faults::fail_io(points::SOCKET_READ).is_err() {
        return;
    }
    let req = match http::read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            let _ = http::error_json(&mut w, 400, &format!("bad request: {e}"));
            return;
        }
    };
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    // Bearer auth when configured. `GET /health` and `GET /metrics`
    // stay open so load balancers and Prometheus scrapers never need
    // the secret (neither endpoint leaks request contents).
    let public =
        req.method == "GET" && matches!(segs.as_slice(), ["health"] | ["metrics"]);
    let authorized = match &shared.auth_token {
        Some(token) if !public => bearer_matches(req.authorization.as_deref(), token),
        _ => true,
    };
    if !authorized {
        let _ = http::error_json(&mut w, 401, "missing or invalid bearer token");
        return;
    }
    // While draining, only the public probes keep answering (so an
    // orchestrator sees `"state":"draining"` on /health); everything
    // else is told to come back to the replacement instance.
    if shared.draining.load(Ordering::SeqCst) && !public {
        let _ = http::unavailable(&mut w, "service is draining", 5);
        return;
    }
    if faults::fail_io(points::SOCKET_WRITE).is_err() {
        // Models the response write failing: the request was read but
        // the client never hears back.
        return;
    }
    let t0 = Instant::now();
    let route = route_index(req.method.as_str(), &segs);
    let result = match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["health"]) => http::respond_json(&mut w, 200, &health_json(shared)),
        ("GET", ["metrics"]) => {
            refresh_service_gauges(shared);
            http::respond(
                &mut w,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                obs::global().render_prometheus().as_bytes(),
            )
        }
        ("GET", ["methods"]) => http::respond_json(&mut w, 200, &crate::api::methods_json()),
        ("POST", ["jobs"]) => submit_job(shared, &req.body, &mut w),
        ("GET", ["jobs"]) => list_jobs(shared, &mut w),
        ("GET", ["jobs", id]) => job_detail(shared, id, &mut w),
        ("GET", ["jobs", id, "events"]) => stream_events(shared, id, &mut w),
        ("POST", ["jobs", id, "cancel"]) => cancel_job(shared, id, &mut w),
        ("POST", ["jobs", id, "resume"]) => resume_job(shared, id, &mut w),
        _ => http::error_json(&mut w, 404, "no such endpoint"),
    };
    // Response latency per route. For `/jobs/<id>/events` this is the
    // whole stream lifetime (the handler holds the connection open),
    // which is the honest number for a streaming endpoint.
    obs::global().http_ns[route].record(t0.elapsed().as_nanos() as u64);
    // A failed write means the client went away; nothing left to do.
    let _ = result;
}

/// Classify a request into one of [`metrics::HTTP_ROUTES`] for the
/// per-endpoint latency histograms — ids collapse into their route so
/// label cardinality stays fixed.
fn route_index(method: &str, segs: &[&str]) -> usize {
    let name = match (method, segs) {
        ("GET", ["health"]) => "health",
        ("GET", ["metrics"]) => "metrics",
        ("GET", ["methods"]) => "methods",
        ("POST", ["jobs"]) => "jobs_submit",
        ("GET", ["jobs"]) => "jobs_list",
        ("GET", ["jobs", _]) => "jobs_get",
        ("GET", ["jobs", _, "events"]) => "jobs_events",
        ("POST", ["jobs", _, "cancel"]) => "jobs_cancel",
        ("POST", ["jobs", _, "resume"]) => "jobs_resume",
        _ => "other",
    };
    metrics::HTTP_ROUTES.iter().position(|r| *r == name).unwrap_or(metrics::HTTP_ROUTES.len() - 1)
}

/// Snapshot the queue/job/memory state, push it into the service gauges
/// (so a `/metrics` scrape and `/health` always agree) and return the
/// counts as `(queue_depth, running, suspended, jobs_total, memory)`.
fn refresh_service_gauges(shared: &Arc<Shared>) -> (usize, usize, usize, usize, Option<usize>) {
    let (depth, running, suspended, total) = {
        let st = relock(&shared.state);
        let mut running = 0;
        let mut suspended = 0;
        for j in st.jobs.values() {
            match j.state {
                JobState::Running => running += 1,
                JobState::Suspended => suspended += 1,
                _ => {}
            }
        }
        (st.queue.len(), running, suspended, st.jobs.len())
    };
    let memory_records = shared.memory.as_ref().map(|s| relock(s).len());
    let m = obs::global();
    m.queue_depth.set(depth as u64);
    m.jobs_running.set(running as u64);
    m.jobs_suspended.set(suspended as u64);
    m.memory_records.set(memory_records.unwrap_or(0) as u64);
    (depth, running, suspended, total, memory_records)
}

/// The enriched `/health` body: liveness plus the load picture an
/// operator wants first — queue depth, running/suspended job counts and
/// the design-memory size (`null` when no store is configured).
fn health_json(shared: &Arc<Shared>) -> Json {
    let (depth, running, suspended, total, memory_records) = refresh_service_gauges(shared);
    let state = if shared.draining.load(Ordering::SeqCst) { "draining" } else { "ok" };
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("state", Json::str(state)),
        ("queue_depth", Json::num(depth as f64)),
        ("jobs_running", Json::num(running as f64)),
        ("jobs_suspended", Json::num(suspended as f64)),
        ("jobs_total", Json::num(total as f64)),
        (
            "memory_records",
            memory_records.map_or(Json::Null, |n| Json::num(n as f64)),
        ),
    ])
}

/// `Authorization: Bearer <token>` check: scheme case-insensitive (RFC
/// 7235), credential compared exactly.
fn bearer_matches(header: Option<&str>, token: &str) -> bool {
    let Some(value) = header else { return false };
    let mut parts = value.splitn(2, char::is_whitespace);
    let scheme = parts.next().unwrap_or_default();
    let credential = parts.next().unwrap_or_default().trim();
    scheme.eq_ignore_ascii_case("bearer") && credential == token
}

fn submit_job<W: Write>(shared: &Arc<Shared>, body: &[u8], w: &mut W) -> io::Result<()> {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return http::error_json(w, 400, "body is not UTF-8"),
    };
    let parsed = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return http::error_json(w, 400, &format!("bad JSON: {e}")),
    };
    let request = match SearchRequest::from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return http::error_json(w, 400, &format!("bad request: {e}")),
    };
    // Validate eagerly so a bad workload/platform/method rejects at
    // submission, not inside a worker thread.
    if let Err(e) = request.clone().build() {
        return http::error_json(w, 400, &format!("invalid request: {e}"));
    }
    let tenant = parsed.get("tenant").and_then(Json::as_str).unwrap_or("default").to_string();
    let priority = parsed.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i64;
    let summary = {
        let mut st = relock(&shared.state);
        if let Err(e) = st.quotas.try_charge(&tenant, request.budget) {
            drop(st);
            return http::error_json(w, 429, &e);
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        let id = format!("job-{seq:06}");
        let job = Job::new(id.clone(), tenant, priority, request);
        let summary = job.summary_json();
        st.jobs.insert(id.clone(), job);
        st.queue.push(QueueEntry { priority, seq, job_id: id });
        summary
    };
    obs::global().job_events[metrics::JOB_SUBMITTED].inc();
    shared.cv.notify_all();
    http::respond_json(w, 202, &summary)
}

fn list_jobs<W: Write>(shared: &Arc<Shared>, w: &mut W) -> io::Result<()> {
    let rows = {
        let st = relock(&shared.state);
        Json::Arr(st.jobs.values().map(Job::summary_json).collect())
    };
    http::respond_json(w, 200, &rows)
}

fn job_detail<W: Write>(shared: &Arc<Shared>, id: &str, w: &mut W) -> io::Result<()> {
    let detail = {
        let st = relock(&shared.state);
        st.jobs.get(id).map(Job::detail_json)
    };
    match detail {
        Some(d) => http::respond_json(w, 200, &d),
        None => http::error_json(w, 404, "no such job"),
    }
}

fn cancel_job<W: Write>(shared: &Arc<Shared>, id: &str, w: &mut W) -> io::Result<()> {
    let mut st = relock(&shared.state);
    let Some(job) = st.jobs.get_mut(id) else {
        drop(st);
        return http::error_json(w, 404, "no such job");
    };
    match job.state {
        JobState::Queued => {
            job.state = JobState::Cancelled;
            push_event(job, "cancelled", vec![]);
            job.events_done = true;
            let summary = job.summary_json();
            drop(st);
            obs::global().job_events[metrics::JOB_CANCELLED].inc();
            shared.cv.notify_all();
            http::respond_json(w, 202, &summary)
        }
        JobState::Running => {
            // Resumable methods suspend into a checkpoint; the rest
            // hard-stop through the session's cancel token.
            let resumable =
                optimizer::resolve(&job.request.method).map(|s| s.resumable).unwrap_or(false);
            if resumable {
                if let Some(f) = &job.suspend {
                    f.store(true, Ordering::SeqCst);
                }
            } else if let Some(f) = &job.cancel {
                f.store(true, Ordering::SeqCst);
            }
            let summary = job.summary_json();
            drop(st);
            http::respond_json(w, 202, &summary)
        }
        s => {
            let msg = format!("job is {}, cannot cancel", s.as_str());
            drop(st);
            http::error_json(w, 409, &msg)
        }
    }
}

fn resume_job<W: Write>(shared: &Arc<Shared>, id: &str, w: &mut W) -> io::Result<()> {
    let mut st = relock(&shared.state);
    let Some(job) = st.jobs.get_mut(id) else {
        drop(st);
        return http::error_json(w, 404, "no such job");
    };
    if job.state != JobState::Suspended {
        let msg = format!("job is {}, only suspended jobs resume", job.state.as_str());
        drop(st);
        return http::error_json(w, 409, &msg);
    }
    if job.checkpoint.is_none() {
        drop(st);
        return http::error_json(w, 409, "suspended job has no checkpoint");
    }
    job.state = JobState::Queued;
    job.events_done = false;
    push_event(job, "resubmitted", vec![]);
    let priority = job.priority;
    let summary = job.summary_json();
    let seq = st.next_seq;
    st.next_seq += 1;
    st.queue.push(QueueEntry { priority, seq, job_id: id.to_string() });
    drop(st);
    obs::global().job_events[metrics::JOB_RESUMED].inc();
    shared.cv.notify_all();
    http::respond_json(w, 202, &summary)
}

fn stream_events<W: Write>(shared: &Arc<Shared>, id: &str, w: &mut W) -> io::Result<()> {
    {
        let st = relock(&shared.state);
        if !st.jobs.contains_key(id) {
            drop(st);
            return http::error_json(w, 404, "no such job");
        }
    }
    http::start_ndjson(w)?;
    let mut cursor = 0usize;
    loop {
        let (lines, done) = {
            let mut st = relock(&shared.state);
            loop {
                let (len, done) = match st.jobs.get(id) {
                    Some(j) => (j.events.len(), j.events_done),
                    None => return Ok(()),
                };
                if len > cursor || done {
                    break (st.jobs[id].events[cursor..].to_vec(), done);
                }
                st = rewait_timeout(&shared.cv, st, Duration::from_secs(30));
            }
        };
        for line in &lines {
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        w.flush()?;
        cursor += lines.len();
        if done {
            return Ok(());
        }
    }
}

/// Worker: pop the highest-priority queued job, skipping stale entries
/// (jobs cancelled while still queued), and run it.
fn worker_loop(shared: &Arc<Shared>) {
    loop {
        let job_id = {
            let mut st = relock(&shared.state);
            loop {
                if shared.draining.load(Ordering::SeqCst) {
                    return;
                }
                match st.queue.pop() {
                    Some(e) => {
                        let runnable = st
                            .jobs
                            .get(&e.job_id)
                            .is_some_and(|j| j.state == JobState::Queued);
                        if runnable {
                            break e.job_id;
                        }
                    }
                    None => st = rewait(&shared.cv, st),
                }
            }
        };
        run_job(shared, &job_id);
    }
}

enum DiskAction {
    Write(Json),
    Remove,
}

fn run_job(shared: &Arc<Shared>, id: &str) {
    // The suspend flag is installed under the same lock that marks the
    // job Running, so a cancel can never observe Running without it.
    let suspend = Arc::new(AtomicBool::new(false));
    let (request, resume_json) = {
        let mut st = relock(&shared.state);
        let Some(job) = st.jobs.get_mut(id) else { return };
        if job.state != JobState::Queued {
            return;
        }
        job.state = JobState::Running;
        job.suspend = Some(suspend.clone());
        push_event(job, "started", vec![("method", Json::str(&job.request.method))]);
        (job.request.clone(), job.checkpoint.take())
    };
    obs::global().job_events[metrics::JOB_STARTED].inc();
    shared.cv.notify_all();
    // A panic inside the search engine must not wedge the job in
    // `running` (or kill the worker thread): catch it and land the job
    // in `failed` with the panic message, exactly like an error return.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        execute(shared, id, request, resume_json, suspend)
    }))
    .unwrap_or_else(|p| {
        obs::global().panics_caught.inc();
        Err(anyhow!("worker panicked: {}", panic_msg(&p)))
    });
    let mut st = relock(&shared.state);
    let Some(job) = st.jobs.get_mut(id) else { return };
    let was_cancelled = job.cancel.as_ref().is_some_and(|f| f.load(Ordering::SeqCst));
    let disk;
    let mut remember = None;
    let m = obs::global();
    match result {
        Ok(report) => {
            if let Some(cp) = &report.checkpoint {
                job.checkpoint = Some(cp.clone());
                job.state = JobState::Suspended;
                push_event(
                    job,
                    "suspended",
                    vec![("evals", Json::num(report.outcome.evals as f64))],
                );
                m.job_events[metrics::JOB_SUSPENDED].inc();
                disk = Some(DiskAction::Write(job_file_json(job)));
            } else if was_cancelled {
                job.state = JobState::Cancelled;
                push_event(job, "cancelled", vec![]);
                m.job_events[metrics::JOB_CANCELLED].inc();
                disk = Some(DiskAction::Remove);
            } else {
                job.state = JobState::Done;
                push_event(job, "done", vec![("best_edp", finite_num(report.outcome.best_edp))]);
                m.job_events[metrics::JOB_DONE].inc();
                disk = Some(DiskAction::Remove);
                // Only completed runs feed the design memory — a
                // suspended or cancelled search's best is provisional.
                if shared.memory.is_some() {
                    remember = Some((report.request.clone(), report.outcome.clone()));
                }
            }
            // Per-tenant accounting of evaluations actually spent —
            // partial (suspended/cancelled) spend counts too.
            m.tenant_evals.add(&job.tenant, report.outcome.evals as u64);
            job.report = Some(report.to_json());
        }
        Err(e) => {
            job.state = JobState::Failed;
            job.error = Some(e.to_string());
            push_event(job, "failed", vec![("error", Json::str(&e.to_string()))]);
            m.job_events[metrics::JOB_FAILED].inc();
            disk = Some(DiskAction::Remove);
        }
    }
    job.cancel = None;
    job.suspend = None;
    job.events_done = true;
    drop(st);
    shared.cv.notify_all();
    apply_disk(shared, id, disk);
    // Deposit the elite outside the state lock; memory failures never
    // fail the job itself.
    if let (Some(store), Some((request, outcome))) = (&shared.memory, remember) {
        // Transient append failures retry with backoff; a torn write
        // (simulated crash) does not — the store salvages it on the
        // next open instead.
        let recorded = request.resolve().and_then(|(w, p)| {
            retry("memory deposit", &Backoff::default(), || {
                let mut s = relock(store);
                s.remember(&w, &p, &request.method, &outcome, request.seed)
            })
        });
        if let Err(e) = recorded {
            eprintln!("warning: could not record job {id} in design memory: {e}");
        }
    }
}

/// Best-effort panic payload extraction for the `failed` job detail.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

/// Build the session, wire its cancel token and the suspend flag into
/// the job, attach a progress observer that buffers NDJSON events, and
/// run — resuming from the taken checkpoint when there is one.
fn execute(
    shared: &Arc<Shared>,
    id: &str,
    request: SearchRequest,
    resume_json: Option<Json>,
    suspend: Arc<AtomicBool>,
) -> Result<SearchReport> {
    let session = request.build()?;
    let cancel = session.cancel_token();
    {
        let mut st = relock(&shared.state);
        if let Some(job) = st.jobs.get_mut(id) {
            job.cancel = Some(cancel);
        }
    }
    let resume = match &resume_json {
        Some(j) => Some(Checkpoint::from_json(j)?),
        None => None,
    };
    let observer_shared = Arc::clone(shared);
    let observer_id = id.to_string();
    let observer = Box::new(move |p: &Progress| {
        {
            let mut st = relock(&observer_shared.state);
            if let Some(job) = st.jobs.get_mut(&observer_id) {
                push_event(job, "progress", progress_fields(p));
            }
        }
        observer_shared.cv.notify_all();
        SearchControl::Continue
    });
    session.run_opts(RunOpts {
        observer: Some(observer),
        suspend: Some(suspend),
        resume,
        memory: shared.memory.clone(),
        trace: None,
        // Every job records into the process-global registry; that is
        // what `GET /metrics` serves.
        metrics: Some(obs::global()),
        // Service jobs take chaos from the process-global fault plan
        // (`--fault-plan` / SPARSEMAP_FAULTS), not a per-run one.
        faults: None,
    })
}

/// Append one NDJSON event to a job's buffer, stamped with a monotone
/// per-job sequence number (`seq` = buffer index): consumers of
/// `/jobs/<id>/events` can order lines and drop duplicates after a
/// reconnect, since a replay carries the same seqs it did the first
/// time.
fn push_event(job: &mut Job, kind: &str, fields: Vec<(&str, Json)>) {
    let mut all = vec![("seq", Json::num(job.events.len() as f64)), ("type", Json::str(kind))];
    all.extend(fields);
    job.events.push(Json::obj(all).dumps());
}

fn finite_num(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

fn progress_fields(p: &Progress) -> Vec<(&'static str, Json)> {
    vec![
        ("evals", Json::num(p.evals as f64)),
        ("valid_evals", Json::num(p.valid_evals as f64)),
        ("cache_hits", Json::num(p.cache_hits as f64)),
        ("best_edp", finite_num(p.best_edp)),
        ("budget", Json::num(p.budget as f64)),
    ]
}

const JOB_FILE_SCHEMA: &str = "sparsemap.service_job.v1";

fn job_file_json(job: &Job) -> Json {
    Json::obj(vec![
        ("schema", Json::str(JOB_FILE_SCHEMA)),
        ("id", Json::str(&job.id)),
        ("tenant", Json::str(&job.tenant)),
        ("priority", Json::num(job.priority as f64)),
        ("request", job.request.to_json()),
        ("checkpoint", job.checkpoint.clone().unwrap_or(Json::Null)),
    ])
}

fn apply_disk(shared: &Shared, id: &str, action: Option<DiskAction>) {
    let (Some(dir), Some(action)) = (&shared.checkpoint_dir, action) else {
        return;
    };
    let path = dir.join(format!("{id}.json"));
    match action {
        DiskAction::Write(j) => {
            // Atomic + fsynced, with bounded retries for transient
            // failures: a half-written checkpoint must never be what a
            // restarted service finds.
            let bytes = format!("{}\n", j.pretty()).into_bytes();
            let wrote = retry("persist checkpoint", &Backoff::default(), || {
                crate::util::atomic_write(&path, &bytes)
            });
            if let Err(e) = wrote {
                eprintln!("warning: could not persist checkpoint for {id}: {e}");
            }
        }
        DiskAction::Remove => {
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// Reload every suspended job recorded in `dir`. Unreadable or
/// unrecognized files are skipped with a warning, never fatal.
fn rescan_checkpoints(state: &mut State, dir: &Path) -> usize {
    let mut paths: Vec<PathBuf> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("json"))
            .collect(),
        Err(e) => {
            eprintln!("warning: cannot read checkpoint dir {}: {e}", dir.display());
            return 0;
        }
    };
    paths.sort();
    let mut loaded = 0;
    for path in paths {
        match parse_job_file(&path) {
            Ok(job) => {
                if let Some(n) = job.id.strip_prefix("job-").and_then(|s| s.parse::<u64>().ok()) {
                    state.next_seq = state.next_seq.max(n + 1);
                }
                // Re-book the quota the job was granted originally; a
                // shrunken limit must not strand a restored job.
                let _ = state.quotas.try_charge(&job.tenant, job.request.budget);
                state.jobs.insert(job.id.clone(), job);
                loaded += 1;
            }
            Err(e) => eprintln!("warning: skipping checkpoint file {}: {e}", path.display()),
        }
    }
    loaded
}

fn parse_job_file(path: &Path) -> Result<Job> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow!("bad JSON: {e}"))?;
    ensure!(
        j.get("schema").and_then(Json::as_str) == Some(JOB_FILE_SCHEMA),
        "not a {JOB_FILE_SCHEMA} file"
    );
    let id = j
        .get("id")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing id"))?
        .to_string();
    let tenant = j.get("tenant").and_then(Json::as_str).unwrap_or("default").to_string();
    let priority = j.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i64;
    let request =
        SearchRequest::from_json(j.get("request").ok_or_else(|| anyhow!("missing request"))?)?;
    let checkpoint = j.get("checkpoint").cloned().ok_or_else(|| anyhow!("missing checkpoint"))?;
    ensure!(!matches!(checkpoint, Json::Null), "null checkpoint");
    let mut job = Job::new(id, tenant, priority, request);
    job.state = JobState::Suspended;
    push_event(&mut job, "restored", vec![]);
    job.events_done = true;
    job.checkpoint = Some(checkpoint);
    Ok(job)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn start_on_loopback(workers: usize, quota: usize, dir: Option<PathBuf>) -> ServiceHandle {
        start(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers,
            quota,
            checkpoint_dir: dir,
            ..Default::default()
        })
        .unwrap()
    }

    /// Raw one-shot HTTP exchange: returns (status, body).
    fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
        request_with(addr, method, path, body, None)
    }

    fn request_with(
        addr: SocketAddr,
        method: &str,
        path: &str,
        body: &str,
        auth: Option<&str>,
    ) -> (u16, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        let auth_line = match auth {
            Some(v) => format!("Authorization: {v}\r\n"),
            None => String::new(),
        };
        let msg = format!(
            "{method} {path} HTTP/1.1\r\nHost: test\r\n{auth_line}Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(msg.as_bytes()).unwrap();
        let mut text = String::new();
        stream.read_to_string(&mut text).unwrap();
        let status: u16 = text.split_whitespace().nth(1).unwrap().parse().unwrap();
        let body = match text.find("\r\n\r\n") {
            Some(i) => text[i + 4..].to_string(),
            None => String::new(),
        };
        (status, body)
    }

    fn submit_body(method: &str, budget: usize, tenant: &str, priority: i64) -> String {
        let req = SearchRequest::new()
            .workload_named("mm1")
            .platform_named("mobile")
            .method(method)
            .budget(budget)
            .seed(7);
        let mut j = req.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("tenant".to_string(), Json::str(tenant));
            o.insert("priority".to_string(), Json::num(priority as f64));
        }
        j.dumps()
    }

    fn poll_state(addr: SocketAddr, id: &str, want: &str, tries: usize) -> Json {
        for _ in 0..tries {
            let (s, b) = request(addr, "GET", &format!("/jobs/{id}"), "");
            assert_eq!(s, 200, "{b}");
            let j = Json::parse(&b).unwrap();
            let state = j.get("state").and_then(Json::as_str).unwrap().to_string();
            if state == want {
                return j;
            }
            assert_ne!(state, "failed", "job failed: {b}");
            std::thread::sleep(Duration::from_millis(20));
        }
        panic!("job {id} never reached state '{want}'");
    }

    #[test]
    fn submit_runs_to_done_and_streams_events() {
        let handle = start_on_loopback(1, 0, None);
        let addr = handle.addr;
        let (s, b) = request(addr, "GET", "/health", "");
        assert_eq!(s, 200);
        assert!(b.contains("true"), "{b}");
        let (s, b) = request(addr, "GET", "/methods", "");
        assert_eq!(s, 200);
        assert!(b.contains("resumable"), "{b}");
        let (s, b) = request(addr, "POST", "/jobs", &submit_body("random", 60, "acme", 2));
        assert_eq!(s, 202, "{b}");
        let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
        let detail = poll_state(addr, &id, "done", 500);
        let report = detail.get("report").expect("done job carries its report");
        let evals = report.get("outcome").and_then(|o| o.get("evals")).and_then(Json::as_u64);
        assert_eq!(evals, Some(60));
        // The events stream replays the whole buffer and terminates;
        // every line is standalone JSON.
        let (s, b) = request(addr, "GET", &format!("/jobs/{id}/events"), "");
        assert_eq!(s, 200);
        let kinds: Vec<String> = b
            .lines()
            .map(|l| {
                Json::parse(l).unwrap().get("type").and_then(Json::as_str).unwrap().to_string()
            })
            .collect();
        assert_eq!(kinds.first().map(String::as_str), Some("started"), "{kinds:?}");
        assert_eq!(kinds.last().map(String::as_str), Some("done"), "{kinds:?}");
        assert!(kinds.iter().any(|k| k == "progress"), "{kinds:?}");
        assert_eq!(handle.job_states(), vec![(id, JobState::Done)]);
    }

    #[test]
    fn quota_rejects_over_limit_and_bad_requests_400() {
        let handle = start_on_loopback(1, 100, None);
        let addr = handle.addr;
        let (s, _) = request(addr, "POST", "/jobs", &submit_body("random", 80, "acme", 0));
        assert_eq!(s, 202);
        let (s, b) = request(addr, "POST", "/jobs", &submit_body("random", 80, "acme", 0));
        assert_eq!(s, 429, "{b}");
        assert!(b.contains("over quota"), "{b}");
        // Other tenants have their own ledger.
        let (s, _) = request(addr, "POST", "/jobs", &submit_body("random", 80, "other", 0));
        assert_eq!(s, 202);
        let (s, b) = request(addr, "POST", "/jobs", "{not json");
        assert_eq!(s, 400, "{b}");
        let (s, b) = request(addr, "POST", "/jobs", &submit_body("no-such-method", 10, "t", 0));
        assert_eq!(s, 400, "{b}");
        let (s, b) = request(addr, "GET", "/jobs", "");
        assert_eq!(s, 200);
        assert_eq!(Json::parse(&b).unwrap().as_arr().unwrap().len(), 2);
        let (s, _) = request(addr, "GET", "/nope", "");
        assert_eq!(s, 404);
        let (s, _) = request(addr, "GET", "/jobs/job-999999", "");
        assert_eq!(s, 404);
        let (s, _) = request(addr, "POST", "/jobs/job-999999/cancel", "");
        assert_eq!(s, 404);
    }

    #[test]
    fn cancel_suspends_resume_completes_across_restart() {
        let dir = std::env::temp_dir()
            .join(format!("sparsemap-service-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let handle = start_on_loopback(1, 0, Some(dir.clone()));
        let addr = handle.addr;
        // A budget this size takes long enough that the cancel below
        // lands mid-run with huge margin.
        let budget = 12_000;
        let (s, b) = request(addr, "POST", "/jobs", &submit_body("sparsemap", budget, "t", 0));
        assert_eq!(s, 202, "{b}");
        let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
        poll_state(addr, &id, "running", 500);
        let (s, _) = request(addr, "POST", &format!("/jobs/{id}/cancel"), "");
        assert_eq!(s, 202);
        let detail = poll_state(addr, &id, "suspended", 1500);
        assert_eq!(detail.get("has_checkpoint").and_then(Json::as_bool), Some(true));
        let partial = detail.get("report").expect("suspension stores the partial report");
        let partial_evals =
            partial.get("outcome").and_then(|o| o.get("evals")).and_then(Json::as_u64).unwrap();
        assert!(partial_evals < budget as u64, "suspended before exhausting the budget");
        let file = dir.join(format!("{id}.json"));
        assert!(file.exists(), "suspension persisted to {}", file.display());
        // Cancelling a suspended job is a conflict, resuming it is not.
        let (s, _) = request(addr, "POST", &format!("/jobs/{id}/cancel"), "");
        assert_eq!(s, 409);

        // A second server on the same checkpoint dir — a restart — sees
        // the suspended job and finishes it from the checkpoint.
        let restarted = start_on_loopback(1, 0, Some(dir.clone()));
        assert_eq!(restarted.job_states(), vec![(id.clone(), JobState::Suspended)]);
        let (s, b) = request(restarted.addr, "POST", &format!("/jobs/{id}/resume"), "");
        assert_eq!(s, 202, "{b}");
        let detail = poll_state(restarted.addr, &id, "done", 3000);
        let report = detail.get("report").unwrap();
        let evals =
            report.get("outcome").and_then(|o| o.get("evals")).and_then(Json::as_u64).unwrap();
        assert_eq!(evals, budget as u64, "resumed run finishes the full budget");
        assert!(
            report.get("resumed_from").and_then(Json::as_u64).is_some(),
            "final report records the resume point"
        );
        for _ in 0..100 {
            if !file.exists() {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(!file.exists(), "finished job's checkpoint file is removed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auth_token_guards_every_endpoint_but_health() {
        let handle = start(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            auth_token: Some("s3cret".to_string()),
            ..Default::default()
        })
        .unwrap();
        let addr = handle.addr;
        // Health stays open so probes never need the secret, and
        // metrics stays open for Prometheus scrapers.
        let (s, _) = request(addr, "GET", "/health", "");
        assert_eq!(s, 200);
        let (s, b) = request(addr, "GET", "/metrics", "");
        assert_eq!(s, 200, "{b}");
        assert!(b.contains("sparsemap_"), "{b}");
        // Missing header, wrong token, wrong scheme: all 401.
        let (s, b) = request(addr, "GET", "/jobs", "");
        assert_eq!(s, 401, "{b}");
        assert!(b.contains("bearer token"), "{b}");
        let (s, _) = request_with(addr, "GET", "/jobs", "", Some("Bearer wrong"));
        assert_eq!(s, 401);
        let (s, _) = request_with(addr, "GET", "/jobs", "", Some("Basic s3cret"));
        assert_eq!(s, 401);
        let body = submit_body("random", 20, "t", 0);
        let (s, _) = request(addr, "POST", "/jobs", &body);
        assert_eq!(s, 401);
        // The matching token gets through; the scheme word is
        // case-insensitive even though the credential is not.
        let (s, b) = request_with(addr, "GET", "/jobs", "", Some("bearer s3cret"));
        assert_eq!(s, 200, "{b}");
        let (s, b) = request_with(addr, "POST", "/jobs", &body, Some("Bearer s3cret"));
        assert_eq!(s, 202, "{b}");
    }

    /// Open an events stream, read until `n` body lines arrived, then
    /// drop the connection — a consumer that goes away mid-stream.
    fn read_body_lines(addr: SocketAddr, path: &str, n: usize) -> Vec<String> {
        let mut stream = TcpStream::connect(addr).unwrap();
        let msg = format!("GET {path} HTTP/1.1\r\nHost: test\r\nContent-Length: 0\r\n\r\n");
        stream.write_all(msg.as_bytes()).unwrap();
        let mut buf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 1024];
        loop {
            if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let body = &buf[i + 4..];
                if body.iter().filter(|&&c| c == b'\n').count() >= n {
                    return String::from_utf8_lossy(body)
                        .lines()
                        .take(n)
                        .map(str::to_string)
                        .collect();
                }
            }
            let k = stream.read(&mut chunk).unwrap();
            assert!(k > 0, "stream ended before {n} event lines arrived");
            buf.extend_from_slice(&chunk[..k]);
        }
    }

    #[test]
    fn event_stream_has_monotone_seqs_and_replays_identically_on_reconnect() {
        let handle = start_on_loopback(1, 0, None);
        let addr = handle.addr;
        let (s, b) = request(addr, "POST", "/jobs", &submit_body("sparsemap", 2_000, "t", 0));
        assert_eq!(s, 202, "{b}");
        let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
        // First consumer reads two lines, then drops the connection.
        let early = read_body_lines(addr, &format!("/jobs/{id}/events"), 2);
        poll_state(addr, &id, "done", 1500);
        let (s, full1) = request(addr, "GET", &format!("/jobs/{id}/events"), "");
        assert_eq!(s, 200);
        let (_, full2) = request(addr, "GET", &format!("/jobs/{id}/events"), "");

        // Every line carries a seq; the seqs are exactly 0..n — ordered,
        // gap-free and duplicate-free.
        let seqs: Vec<u64> = full1
            .lines()
            .map(|l| {
                Json::parse(l)
                    .unwrap()
                    .get("seq")
                    .and_then(Json::as_u64)
                    .expect("every event line carries a seq")
            })
            .collect();
        assert!(seqs.len() >= 3, "started + progress + done at minimum: {full1}");
        assert_eq!(seqs, (0..seqs.len() as u64).collect::<Vec<_>>(), "{full1}");
        assert_eq!(full1, full2, "a replay is byte-identical");
        // The dropped consumer's prefix matches the replay line for
        // line, so deduplicating by seq after a reconnect loses nothing.
        let replayed: Vec<&str> = full1.lines().collect();
        for (i, line) in early.iter().enumerate() {
            assert_eq!(line, replayed[i], "reconnect prefix diverged at line {i}");
        }
    }

    #[test]
    fn metrics_endpoint_serves_prometheus_and_health_is_enriched() {
        let handle = start_on_loopback(1, 0, None);
        let addr = handle.addr;
        let (s, b) =
            request(addr, "POST", "/jobs", &submit_body("random", 50, "metrics-tenant", 0));
        assert_eq!(s, 202, "{b}");
        let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
        poll_state(addr, &id, "done", 500);

        let (s, b) = request(addr, "GET", "/health", "");
        assert_eq!(s, 200);
        let h = Json::parse(&b).unwrap();
        assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));
        assert!(h.get("jobs_total").and_then(Json::as_u64).unwrap() >= 1, "{b}");
        for k in ["queue_depth", "jobs_running", "jobs_suspended"] {
            assert!(h.get(k).and_then(Json::as_u64).is_some(), "missing {k}: {b}");
        }
        // No memory store configured: the count is null, not zero.
        assert_eq!(h.get("memory_records"), Some(&Json::Null), "{b}");

        let (s, text) = request(addr, "GET", "/metrics", "");
        assert_eq!(s, 200);
        // Engine, service and memory families are all present, and the
        // job above drove the engine counters through the global scope.
        for series in [
            "sparsemap_evals_total",
            "sparsemap_stage_seconds_bucket",
            "sparsemap_http_request_seconds_bucket{route=\"jobs_submit\"",
            "sparsemap_queue_depth",
            "sparsemap_jobs_total{event=\"done\"}",
            "sparsemap_memory_records",
            "sparsemap_tenant_evals_total{tenant=\"metrics-tenant\"} 50",
        ] {
            assert!(text.contains(series), "missing `{series}` in:\n{text}");
        }
        let evals: f64 = text
            .lines()
            .find_map(|l| l.strip_prefix("sparsemap_evals_total "))
            .expect("evals_total series")
            .parse()
            .unwrap();
        assert!(evals >= 50.0, "the finished job's evals are visible: {evals}");
    }

    /// Raw exchange that also returns the response head, for asserting
    /// on headers (`Retry-After`).
    fn raw_request(addr: SocketAddr, msg: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(msg.as_bytes()).unwrap();
        let mut text = String::new();
        let _ = stream.read_to_string(&mut text);
        text
    }

    #[test]
    fn connection_cap_sheds_with_503_and_retry_after() {
        let handle = start(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 1,
            ..Default::default()
        })
        .unwrap();
        let addr = handle.addr;
        // First connection occupies the only slot by stalling silently;
        // its handler sits in read_request until we hang up (the read
        // timeout is the backstop, not what this test waits on).
        let hog = TcpStream::connect(addr).unwrap();
        for _ in 0..100 {
            if handle.live_connections() >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // Second connection is shed at the accept loop: full 503
        // response with a Retry-After hint, before any request parsing.
        let text = raw_request(addr, "GET /health HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Retry-After:"), "{text}");
        assert!(text.contains("connection capacity"), "{text}");
        drop(hog);
        // Once the stalled client's slot frees (timeout or hangup), the
        // service serves normally again.
        for _ in 0..200 {
            if handle.live_connections() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(handle.live_connections(), 0, "slots drain back to zero");
        let (s, _) = request(addr, "GET", "/health", "");
        assert_eq!(s, 200);
    }

    #[test]
    fn parser_edges_close_cleanly_without_leaking_slots() {
        let handle = start(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            read_timeout: Duration::from_millis(200),
            ..Default::default()
        })
        .unwrap();
        let addr = handle.addr;
        // POST with no Content-Length: parsed as an empty body, which is
        // not valid JSON — a clean 400, not a hang.
        let text = raw_request(addr, "POST /jobs HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        assert!(text.contains("bad JSON"), "{text}");
        // Body shorter than Content-Length promises, then FIN: the
        // read_exact hits EOF and the connection closes with a 400.
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"POST /jobs HTTP/1.1\r\nHost: t\r\nContent-Length: 100\r\n\r\nshort")
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut text = String::new();
        let _ = stream.read_to_string(&mut text);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        // Stalling mid-header trips the read timeout; the server closes
        // the connection (a 400 reaches us if the write still works).
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /health HT").unwrap();
        let mut text = String::new();
        let _ = stream.read_to_string(&mut text);
        assert!(
            text.is_empty() || text.starts_with("HTTP/1.1 400"),
            "timed-out connection closes cleanly: {text:?}"
        );
        // No slot leaked by any of the three misbehaving clients.
        for _ in 0..200 {
            if handle.live_connections() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(handle.live_connections(), 0);
        let (s, _) = request(addr, "GET", "/health", "");
        assert_eq!(s, 200, "service unaffected by malformed clients");
    }

    #[test]
    fn drain_suspends_running_jobs_and_refuses_new_work() {
        let dir =
            std::env::temp_dir().join(format!("sparsemap-service-drain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let handle = start_on_loopback(1, 0, Some(dir.clone()));
        let addr = handle.addr;
        let (s, b) = request(addr, "POST", "/jobs", &submit_body("sparsemap", 12_000, "t", 0));
        assert_eq!(s, 202, "{b}");
        let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
        poll_state(addr, &id, "running", 500);
        // Drain blocks until the running job lands in a terminal-ish
        // state; for a resumable method that is `suspended`.
        handle.drain();
        let states = handle.job_states();
        assert_eq!(states, vec![(id.clone(), JobState::Suspended)], "{states:?}");
        // The health probe stays up and reports draining; new work is
        // refused with 503.
        let (s, b) = request(addr, "GET", "/health", "");
        assert_eq!(s, 200, "{b}");
        assert!(b.contains("draining"), "{b}");
        let text = raw_request(
            addr,
            &format!(
                "POST /jobs HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
                submit_body("random", 10, "t", 0).len(),
                submit_body("random", 10, "t", 0)
            ),
        );
        assert!(text.starts_with("HTTP/1.1 503"), "{text}");
        assert!(text.contains("draining"), "{text}");
        // The suspension was persisted, so a restart resumes it.
        let file = dir.join(format!("{id}.json"));
        for _ in 0..200 {
            if file.exists() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(file.exists(), "drained job checkpoint persisted");
        let restarted = start_on_loopback(1, 0, Some(dir.clone()));
        assert_eq!(restarted.job_states(), vec![(id.clone(), JobState::Suspended)]);
        let (s, _) = request(restarted.addr, "POST", &format!("/jobs/{id}/resume"), "");
        assert_eq!(s, 202);
        poll_state(restarted.addr, &id, "done", 3000);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn completed_jobs_feed_memory_and_warm_start_seeds_from_it() {
        let dir =
            std::env::temp_dir().join(format!("sparsemap-service-mem-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = dir.join("memory.bin");
        let handle = start(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            memory_store: Some(store.clone()),
            ..Default::default()
        })
        .unwrap();
        let addr = handle.addr;
        // First job runs cold; on completion its elite is deposited in
        // the shared store.
        let (s, b) = request(addr, "POST", "/jobs", &submit_body("es-std", 400, "t", 0));
        assert_eq!(s, 202, "{b}");
        let id = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
        poll_state(addr, &id, "done", 1500);
        for _ in 0..200 {
            if store.exists() {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(store.exists(), "completed job persisted to the memory store");

        // Second job opts into warm-start with no store path of its own:
        // the service's shared store supplies the seeds, and the report
        // records the provenance.
        let req = SearchRequest::new()
            .workload_named("mm1")
            .platform_named("mobile")
            .method("es-std")
            .budget(400)
            .seed(8)
            .warm_start(crate::api::WarmStart::default());
        let (s, b) = request(addr, "POST", "/jobs", &req.to_json().dumps());
        assert_eq!(s, 202, "{b}");
        let id2 = Json::parse(&b).unwrap().get("id").and_then(Json::as_str).unwrap().to_string();
        let detail = poll_state(addr, &id2, "done", 1500);
        let outcome = detail.get("report").and_then(|r| r.get("outcome")).unwrap();
        let hits = outcome.get("memory_hits").and_then(Json::as_u64).unwrap_or(0);
        assert!(hits > 0, "warm-started job found no seeds: {}", outcome.pretty());
        let tags = outcome.get("seeded_from").and_then(Json::as_arr).unwrap();
        assert!(
            tags.iter().any(|t| t.as_str().is_some_and(|s| s.starts_with("mm1@mobile"))),
            "{}",
            outcome.pretty()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
