//! Search telemetry: per-evaluation bookkeeping and final outcomes.

use crate::model::EvalResult;
use crate::util::json::{f64_bits, f64_from_bits, Json};

/// Rolling statistics recorded by [`crate::search::EvalContext`].
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    pub evals: usize,
    pub valid_evals: usize,
    /// Submissions served from the evaluation cache (they still debit the
    /// sample budget — see `crate::search` module docs).
    pub cache_hits: usize,
    /// Distinct genomes interned by the evaluation engine (the result
    /// caches key on their dense ids — see `crate::search::engine`).
    pub interned: usize,
    /// Stage-level cache hits: one per memoized decode/feature stage
    /// reused (a single evaluation can contribute up to 4 — its mapping
    /// stage plus three per-tensor format stages).
    pub stage_hits: usize,
    /// Best-so-far (evals, edp) checkpoints; appended whenever the best
    /// improves (the Fig. 18 convergence-curve data).
    pub curve: Vec<(usize, f64)>,
    pub best_edp: f64,
    pub best_genome: Option<Vec<u32>>,
    /// Sum of per-generation mean-EDP snapshots pushed by algorithms that
    /// track population averages (optional).
    pub population_mean_curve: Vec<(usize, f64)>,
    /// Best valid EDP since the last `begin_slice` — a resettable window
    /// the portfolio meta-optimizer uses to score each member's own
    /// progress (the global `best_edp` only moves on *global* improvement,
    /// so a member re-finding another member's design would look idle).
    pub slice_best_edp: f64,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry {
            best_edp: f64::INFINITY,
            slice_best_edp: f64::INFINITY,
            ..Default::default()
        }
    }

    /// Reset the per-slice best (see `slice_best_edp`). Purely
    /// observational: never feeds back into any trajectory.
    pub fn begin_slice(&mut self) {
        self.slice_best_edp = f64::INFINITY;
    }

    pub fn record(&mut self, genome: &[u32], r: &EvalResult) {
        self.evals += 1;
        if r.valid {
            self.valid_evals += 1;
            if r.edp < self.slice_best_edp {
                self.slice_best_edp = r.edp;
            }
            if r.edp < self.best_edp {
                self.best_edp = r.edp;
                self.best_genome = Some(genome.to_vec());
                self.curve.push((self.evals, r.edp));
            }
        }
    }

    /// Fraction of evaluated points that were valid (Fig. 17b metric).
    pub fn valid_ratio(&self) -> f64 {
        if self.evals == 0 {
            0.0
        } else {
            self.valid_evals as f64 / self.evals as f64
        }
    }

    pub fn push_population_mean(&mut self, mean_edp: f64) {
        self.population_mean_curve.push((self.evals, mean_edp));
    }

    /// Bit-exact snapshot for checkpoints (see
    /// `EvalContext::capture_eval_state`). Unlike [`Outcome::to_json`],
    /// floats travel as IEEE-754 bit patterns so non-finite best-EDP
    /// sentinels and every curve point restore exactly.
    pub fn to_state_json(&self) -> Json {
        let curve_json = |curve: &[(usize, f64)]| {
            Json::Arr(
                curve
                    .iter()
                    .map(|&(e, v)| Json::Arr(vec![Json::num(e as f64), f64_bits(v)]))
                    .collect(),
            )
        };
        Json::obj(vec![
            ("evals", Json::num(self.evals as f64)),
            ("valid_evals", Json::num(self.valid_evals as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("interned", Json::num(self.interned as f64)),
            ("stage_hits", Json::num(self.stage_hits as f64)),
            ("curve", curve_json(&self.curve)),
            ("best_edp", f64_bits(self.best_edp)),
            (
                "best_genome",
                match &self.best_genome {
                    Some(g) => Json::Arr(g.iter().map(|&x| Json::num(x as f64)).collect()),
                    None => Json::Null,
                },
            ),
            ("population_mean_curve", curve_json(&self.population_mean_curve)),
            ("slice_best_edp", f64_bits(self.slice_best_edp)),
        ])
    }

    /// Inverse of [`Telemetry::to_state_json`].
    pub fn from_state_json(j: &Json) -> anyhow::Result<Telemetry> {
        use anyhow::anyhow;
        let n = |key: &str| -> anyhow::Result<usize> {
            j.get(key)
                .and_then(Json::as_u64)
                .map(|x| x as usize)
                .ok_or_else(|| anyhow!("telemetry state is missing count field '{key}'"))
        };
        let f = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(f64_from_bits)
                .ok_or_else(|| anyhow!("telemetry state field '{key}' must be f64 bits"))
        };
        let curve_of = |key: &str| -> anyhow::Result<Vec<(usize, f64)>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("telemetry state is missing curve '{key}'"))?
                .iter()
                .map(|pt| {
                    let pt = pt.as_arr().filter(|a| a.len() == 2);
                    let e = pt.and_then(|a| a[0].as_u64());
                    let v = pt.and_then(|a| f64_from_bits(&a[1]));
                    match (e, v) {
                        (Some(e), Some(v)) => Ok((e as usize, v)),
                        _ => Err(anyhow!("telemetry curve '{key}' must hold [evals, bits] pairs")),
                    }
                })
                .collect()
        };
        let best_genome = match j.get("best_genome") {
            Some(Json::Arr(a)) => Some(
                a.iter()
                    .map(|g| {
                        g.as_u64()
                            .map(|x| x as u32)
                            .ok_or_else(|| anyhow!("best_genome entries must be integers"))
                    })
                    .collect::<anyhow::Result<Vec<u32>>>()?,
            ),
            _ => None,
        };
        Ok(Telemetry {
            evals: n("evals")?,
            valid_evals: n("valid_evals")?,
            cache_hits: n("cache_hits")?,
            interned: n("interned")?,
            stage_hits: n("stage_hits")?,
            curve: curve_of("curve")?,
            best_edp: f("best_edp")?,
            best_genome,
            population_mean_curve: curve_of("population_mean_curve")?,
            slice_best_edp: f("slice_best_edp")?,
        })
    }

    pub fn into_outcome(self, method: &str, workload: &str, platform: &str) -> Outcome {
        Outcome {
            method: method.to_string(),
            workload: workload.to_string(),
            platform: platform.to_string(),
            evals: self.evals,
            valid_evals: self.valid_evals,
            cache_hits: self.cache_hits,
            interned: self.interned,
            stage_hits: self.stage_hits,
            best_edp: self.best_edp,
            best_genome: self.best_genome,
            curve: self.curve,
            population_mean_curve: self.population_mean_curve,
            members: Vec::new(),
            memory_hits: 0,
            seeded_from: Vec::new(),
            model_calls: 0,
            batches: 0,
        }
    }
}

/// Per-member accounting attached to a `portfolio` outcome (see
/// `crate::optimizer::portfolio`): how the shared budget was split across
/// the racing member methods and how far each one got on its own.
#[derive(Clone, Debug, PartialEq)]
pub struct MemberStats {
    /// Canonical registry name of the member method.
    pub method: String,
    /// Budget submissions spent inside this member's slices. Summed over
    /// all members this equals the portfolio outcome's `evals` exactly —
    /// the meta-level performs no evaluations of its own.
    pub evals: usize,
    /// Best valid EDP the member found *itself* (min over its slices'
    /// windows; `f64::INFINITY` if it never found a valid design).
    pub best_edp: f64,
    /// Rounds the member participated in.
    pub rounds: usize,
    /// Completed bandit pulls granted to the member (0 under the
    /// successive-halving allocator, and absent from the wire when 0 —
    /// pre-bandit reports parse unchanged).
    pub pulls: usize,
    /// Round after which successive halving dropped the member
    /// (`None` = survived to the end).
    pub eliminated_round: Option<usize>,
}

impl MemberStats {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("method", Json::str(&self.method)),
            ("evals", Json::num(self.evals as f64)),
            (
                "best_edp",
                if self.best_edp.is_finite() { Json::num(self.best_edp) } else { Json::Null },
            ),
            ("rounds", Json::num(self.rounds as f64)),
        ];
        if self.pulls > 0 {
            fields.push(("pulls", Json::num(self.pulls as f64)));
        }
        fields.push((
            "eliminated_round",
            match self.eliminated_round {
                Some(r) => Json::num(r as f64),
                None => Json::Null,
            },
        ));
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<MemberStats> {
        use anyhow::anyhow;
        Ok(MemberStats {
            method: j
                .get("method")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("member stats JSON is missing 'method'"))?
                .to_string(),
            evals: j.get("evals").and_then(Json::as_u64).unwrap_or(0) as usize,
            best_edp: j.get("best_edp").and_then(Json::as_f64).unwrap_or(f64::INFINITY),
            rounds: j.get("rounds").and_then(Json::as_u64).unwrap_or(0) as usize,
            pulls: j.get("pulls").and_then(Json::as_u64).unwrap_or(0) as usize,
            eliminated_round: j
                .get("eliminated_round")
                .and_then(Json::as_u64)
                .map(|r| r as usize),
        })
    }
}

/// Final result of one search run.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub method: String,
    pub workload: String,
    pub platform: String,
    pub evals: usize,
    pub valid_evals: usize,
    /// Submissions served from the evaluation cache.
    pub cache_hits: usize,
    /// Distinct genomes interned (cache-key working set).
    pub interned: usize,
    /// Stage-level cache hits (up to 4 per evaluation: mapping + three
    /// format stages).
    pub stage_hits: usize,
    /// Best valid EDP found (`f64::INFINITY` if none).
    pub best_edp: f64,
    pub best_genome: Option<Vec<u32>>,
    pub curve: Vec<(usize, f64)>,
    pub population_mean_curve: Vec<(usize, f64)>,
    /// Per-member telemetry, only populated by the `portfolio`
    /// meta-optimizer (empty for every plain method).
    pub members: Vec<MemberStats>,
    /// Warm-start provenance: how many validated design-memory genomes
    /// seeded the initial population (0 when warm-start is off).
    pub memory_hits: usize,
    /// Scenario tags of the memory records those seeds came from
    /// (deduplicated, nearest first; empty when warm-start is off).
    pub seeded_from: Vec<String>,
    /// Genomes actually sent to the cost model (submissions minus cache
    /// hits minus dead-on-arrival designs) — observability revision;
    /// 0 in reports serialized before it.
    pub model_calls: usize,
    /// Batches (≈ generations) evaluated — observability revision; 0 in
    /// reports serialized before it.
    pub batches: usize,
}

impl Outcome {
    pub fn valid_ratio(&self) -> f64 {
        if self.evals == 0 {
            0.0
        } else {
            self.valid_evals as f64 / self.evals as f64
        }
    }

    pub fn found_valid(&self) -> bool {
        self.best_edp.is_finite()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("workload", Json::str(&self.workload)),
            ("platform", Json::str(&self.platform)),
            ("evals", Json::num(self.evals as f64)),
            ("valid_evals", Json::num(self.valid_evals as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("interned", Json::num(self.interned as f64)),
            ("stage_hits", Json::num(self.stage_hits as f64)),
            (
                "best_edp",
                if self.best_edp.is_finite() {
                    Json::num(self.best_edp)
                } else {
                    Json::Null
                },
            ),
            (
                "curve",
                Json::Arr(
                    self.curve
                        .iter()
                        .map(|&(e, v)| Json::arr_f64(&[e as f64, v]))
                        .collect(),
                ),
            ),
        ])
    }

    /// Superset of [`Outcome::to_json`] that also carries the winning
    /// genome and the population-mean curve, so an outcome can be
    /// reconstructed losslessly with [`Outcome::from_json`]. Used by
    /// [`crate::api::SearchReport`].
    pub fn to_json_full(&self) -> Json {
        let mut j = self.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert(
                "best_genome".to_string(),
                match &self.best_genome {
                    Some(g) => Json::Arr(g.iter().map(|&x| Json::num(x as f64)).collect()),
                    None => Json::Null,
                },
            );
            o.insert(
                "population_mean_curve".to_string(),
                Json::Arr(
                    self.population_mean_curve
                        .iter()
                        .map(|&(e, v)| Json::arr_f64(&[e as f64, v]))
                        .collect(),
                ),
            );
            // Only the portfolio meta-optimizer populates members; plain
            // methods keep their serialized form byte-identical to the
            // pre-portfolio schema.
            if !self.members.is_empty() {
                o.insert(
                    "members".to_string(),
                    Json::Arr(self.members.iter().map(MemberStats::to_json).collect()),
                );
            }
            // Same discipline for warm-start provenance: absent unless a
            // design-memory seed actually landed, so non-warm-started
            // reports stay byte-identical to the pre-memory schema.
            if self.memory_hits > 0 || !self.seeded_from.is_empty() {
                o.insert("memory_hits".to_string(), Json::num(self.memory_hits as f64));
                o.insert(
                    "seeded_from".to_string(),
                    Json::Arr(self.seeded_from.iter().map(|t| Json::str(t)).collect()),
                );
            }
            // Observability-revision metric fields: absent when zero, so
            // pre-revision byte streams (and synthetic outcomes) are
            // reproduced exactly.
            if self.model_calls > 0 {
                o.insert("model_calls".to_string(), Json::num(self.model_calls as f64));
            }
            if self.batches > 0 {
                o.insert("batches".to_string(), Json::num(self.batches as f64));
            }
        }
        j
    }

    /// Parse an outcome from either JSON form (`to_json` or
    /// `to_json_full`); fields only the full form carries default to
    /// empty.
    pub fn from_json(j: &Json) -> anyhow::Result<Outcome> {
        use anyhow::anyhow;
        let s = |key: &str| -> anyhow::Result<String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("outcome JSON is missing string field '{key}'"))
        };
        let n = |key: &str| -> anyhow::Result<usize> {
            j.get(key)
                .and_then(Json::as_u64)
                .map(|x| x as usize)
                .ok_or_else(|| anyhow!("outcome JSON is missing count field '{key}'"))
        };
        let curve_of = |key: &str| -> anyhow::Result<Vec<(usize, f64)>> {
            j.get(key)
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|pt| {
                    let pt = pt.as_arr().filter(|a| a.len() == 2);
                    let e = pt.and_then(|a| a[0].as_u64());
                    let v = pt.and_then(|a| a[1].as_f64());
                    match (e, v) {
                        (Some(e), Some(v)) => Ok((e as usize, v)),
                        _ => {
                            Err(anyhow!("outcome JSON field '{key}' must hold [evals, edp] pairs"))
                        }
                    }
                })
                .collect()
        };
        let best_genome = match j.get("best_genome") {
            Some(Json::Arr(a)) => Some(
                a.iter()
                    .map(|g| {
                        g.as_u64()
                            .map(|x| x as u32)
                            .ok_or_else(|| anyhow!("best_genome entries must be integers"))
                    })
                    .collect::<anyhow::Result<Vec<u32>>>()?,
            ),
            _ => None,
        };
        Ok(Outcome {
            method: s("method")?,
            workload: s("workload")?,
            platform: s("platform")?,
            evals: n("evals")?,
            valid_evals: n("valid_evals")?,
            cache_hits: n("cache_hits")?,
            // Added in the staged-engine schema revision; default 0 so
            // reports serialized before it still parse.
            interned: j.get("interned").and_then(Json::as_u64).unwrap_or(0) as usize,
            stage_hits: j.get("stage_hits").and_then(Json::as_u64).unwrap_or(0) as usize,
            best_edp: j.get("best_edp").and_then(Json::as_f64).unwrap_or(f64::INFINITY),
            best_genome,
            curve: curve_of("curve")?,
            population_mean_curve: curve_of("population_mean_curve")?,
            // Absent everywhere except portfolio outcomes (and in reports
            // serialized before the optimizer-registry revision).
            members: j
                .get("members")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(MemberStats::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
            // Warm-start provenance (design-memory revision); absent in
            // older reports and in any run without `warm_start` set.
            memory_hits: j.get("memory_hits").and_then(Json::as_u64).unwrap_or(0) as usize,
            seeded_from: j
                .get("seeded_from")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_str)
                .map(str::to_string)
                .collect(),
            // Observability-revision metric fields; absent (and zero) in
            // every report serialized before it.
            model_calls: j.get("model_calls").and_then(Json::as_u64).unwrap_or(0) as usize,
            batches: j.get("batches").and_then(Json::as_u64).unwrap_or(0) as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok(edp: f64) -> EvalResult {
        EvalResult { energy_pj: 1.0, cycles: edp, edp, valid: true }
    }

    fn dead() -> EvalResult {
        EvalResult { energy_pj: 0.0, cycles: 0.0, edp: f64::INFINITY, valid: false }
    }

    #[test]
    fn best_tracking_and_curve() {
        let mut t = Telemetry::new();
        t.record(&[1], &ok(100.0));
        t.record(&[2], &dead());
        t.record(&[3], &ok(50.0));
        t.record(&[4], &ok(70.0)); // no improvement
        assert_eq!(t.best_edp, 50.0);
        assert_eq!(t.best_genome, Some(vec![3]));
        assert_eq!(t.curve, vec![(1, 100.0), (3, 50.0)]);
        assert_eq!(t.valid_evals, 3);
        assert!((t.valid_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn outcome_json_serializes() {
        let mut t = Telemetry::new();
        t.record(&[1, 2], &ok(10.0));
        let o = t.into_outcome("sparsemap", "mm3", "cloud");
        let j = o.to_json().dumps();
        assert!(j.contains("\"sparsemap\""));
        assert!(j.contains("\"best_edp\""));
    }

    #[test]
    fn full_json_round_trips() {
        let mut t = Telemetry::new();
        t.record(&[1, 2, 3], &ok(10.0));
        t.record(&[4, 5, 6], &ok(4.0));
        t.push_population_mean(7.5);
        t.interned = 2;
        t.stage_hits = 5;
        let o = t.into_outcome("sparsemap", "mm3", "cloud");
        let parsed = Json::parse(&o.to_json_full().dumps()).unwrap();
        let o2 = Outcome::from_json(&parsed).unwrap();
        assert_eq!(o2.method, o.method);
        assert_eq!(o2.interned, 2);
        assert_eq!(o2.stage_hits, 5);
        assert_eq!(o2.best_edp, o.best_edp);
        assert_eq!(o2.best_genome, o.best_genome);
        assert_eq!(o2.curve, o.curve);
        assert_eq!(o2.population_mean_curve, o.population_mean_curve);
        assert_eq!(o2.to_json_full(), o.to_json_full());
    }

    #[test]
    fn legacy_json_without_counters_still_parses() {
        // Reports serialized before the staged-engine revision lack the
        // interned/stage_hits fields, and everything before the
        // observability revision lacks model_calls/batches; all must
        // default to 0.
        let legacy = r#"{"method":"x","workload":"w","platform":"p",
            "evals":3,"valid_evals":2,"cache_hits":1,"best_edp":5.0,
            "curve":[[1,5.0]]}"#;
        let o = Outcome::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(o.interned, 0);
        assert_eq!(o.stage_hits, 0);
        assert_eq!(o.cache_hits, 1);
        assert_eq!(o.model_calls, 0);
        assert_eq!(o.batches, 0);
        // And zeroed metric fields stay *off* the wire on re-serialize:
        // a legacy report round-trips to its legacy shape.
        let dumped = o.to_json_full().dumps();
        assert!(!dumped.contains("model_calls"));
        assert!(!dumped.contains("batches"));
    }

    #[test]
    fn observability_metric_fields_round_trip_when_set() {
        let mut t = Telemetry::new();
        t.record(&[1, 2], &ok(10.0));
        let mut o = t.into_outcome("sparsemap", "mm3", "cloud");
        o.model_calls = 9;
        o.batches = 4;
        let parsed = Json::parse(&o.to_json_full().dumps()).unwrap();
        assert_eq!(parsed.get("model_calls").and_then(Json::as_u64), Some(9));
        assert_eq!(parsed.get("batches").and_then(Json::as_u64), Some(4));
        let o2 = Outcome::from_json(&parsed).unwrap();
        assert_eq!(o2.model_calls, 9);
        assert_eq!(o2.batches, 4);
        assert_eq!(o2.to_json_full(), o.to_json_full());
    }

    #[test]
    fn slice_best_resets_independently_of_global_best() {
        let mut t = Telemetry::new();
        t.record(&[1], &ok(10.0));
        assert_eq!(t.slice_best_edp, 10.0);
        t.begin_slice();
        assert!(t.slice_best_edp.is_infinite());
        // A worse-than-global result still registers in the fresh slice.
        t.record(&[2], &ok(40.0));
        assert_eq!(t.slice_best_edp, 40.0);
        assert_eq!(t.best_edp, 10.0);
        assert_eq!(t.curve, vec![(1, 10.0)]);
    }

    #[test]
    fn member_stats_round_trip_through_full_json() {
        let mut t = Telemetry::new();
        t.record(&[1], &ok(3.0));
        let mut o = t.into_outcome("portfolio", "mm3", "cloud");
        o.members = vec![
            MemberStats {
                method: "sparsemap".into(),
                evals: 1,
                best_edp: 3.0,
                rounds: 2,
                pulls: 2,
                eliminated_round: None,
            },
            MemberStats {
                method: "pso".into(),
                evals: 0,
                best_edp: f64::INFINITY,
                rounds: 1,
                pulls: 0,
                eliminated_round: Some(0),
            },
        ];
        let o2 = Outcome::from_json(&Json::parse(&o.to_json_full().dumps()).unwrap()).unwrap();
        assert_eq!(o2.members, o.members);
        assert_eq!(o2.to_json_full(), o.to_json_full());
        // Plain methods serialize without the field entirely.
        let mut t2 = Telemetry::new();
        t2.record(&[1], &ok(3.0));
        let plain = t2.into_outcome("random", "mm3", "cloud");
        assert!(!plain.to_json_full().dumps().contains("members"));
    }

    #[test]
    fn state_json_round_trips_bit_exactly() {
        let mut t = Telemetry::new();
        t.record(&[1, 2], &ok(10.0));
        t.record(&[3, 4], &dead());
        t.record(&[5, 6], &ok(2.5));
        t.push_population_mean(6.25);
        t.interned = 3;
        t.stage_hits = 7;
        t.cache_hits = 1;
        t.begin_slice();
        let j = Json::parse(&t.to_state_json().dumps()).unwrap();
        let t2 = Telemetry::from_state_json(&j).unwrap();
        assert_eq!(t2.evals, t.evals);
        assert_eq!(t2.valid_evals, t.valid_evals);
        assert_eq!(t2.cache_hits, t.cache_hits);
        assert_eq!(t2.interned, t.interned);
        assert_eq!(t2.stage_hits, t.stage_hits);
        assert_eq!(t2.curve, t.curve);
        assert_eq!(t2.best_edp.to_bits(), t.best_edp.to_bits());
        assert_eq!(t2.best_genome, t.best_genome);
        assert_eq!(t2.population_mean_curve, t.population_mean_curve);
        // Both slice bests are the INFINITY sentinel — only bit encoding
        // can carry it through JSON.
        assert_eq!(t2.slice_best_edp.to_bits(), f64::INFINITY.to_bits());
    }

    #[test]
    fn no_valid_outcome() {
        let mut t = Telemetry::new();
        t.record(&[1], &dead());
        let o = t.into_outcome("x", "w", "p");
        assert!(!o.found_valid());
        assert_eq!(o.to_json().get("best_edp"), Some(&Json::Null));
    }
}
