//! Poison-recovering lock acquisition.
//!
//! A mutex poisons when a holder panics. In a long-running service a
//! single panicked worker (e.g. an injected eval panic, or a cost-model
//! bug on one pathological request) must not cascade `PoisonError` into
//! every subsequent request until restart. All state guarded by the
//! service's locks is kept *transition-consistent*: writers complete a
//! state transition before calling anything panic-prone, so the data
//! behind a poisoned lock is still valid and [`relock`] simply takes the
//! guard back.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait` with the same poison recovery as [`relock`].
pub fn rewait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait_timeout` with the same poison recovery as [`relock`].
/// The timed-out flag is dropped — callers re-check their predicate and
/// deadline anyway.
pub fn rewait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    cv.wait_timeout(guard, dur).map(|(g, _)| g).unwrap_or_else(|e| e.into_inner().0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn relock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*relock(&m), 7, "state survives the panic");
        *relock(&m) = 8;
        assert_eq!(*relock(&m), 8);
    }
}
