//! Standard ES over the *direct-value* encoding — the paper's "standard
//! ES (with latin hypercube sampling initialization)" ablation baseline
//! (Fig. 18) and the "random encoding" arm of Fig. 10.
//!
//! Uses [`super::direct::DirectSpec`]: genes carry tile values directly
//! and permutations decode through a scrambled table, so crossover and
//! mutation routinely violate dimension-tiling constraints and produce
//! dead offspring — the behaviour the PFCE encoding eliminates.

use super::direct::DirectSpec;
use crate::genome::Design;
use crate::search::{EvalContext, Outcome};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct EsDirectConfig {
    pub population: usize,
    pub parent_frac: f64,
    pub mutation_prob: f64,
}

impl Default for EsDirectConfig {
    fn default() -> Self {
        EsDirectConfig { population: 100, parent_frac: 0.25, mutation_prob: 0.6 }
    }
}

/// LHS over the direct gene ranges.
fn lhs_direct(spec: &DirectSpec, n: usize, rng: &mut Pcg64) -> Vec<Vec<u32>> {
    // Reuse the random sampler per-stratum: direct ranges are wide, so a
    // simple per-gene stratified shuffle suffices.
    let mut pop: Vec<Vec<u32>> = (0..n).map(|_| spec.random(rng)).collect();
    // Stratify the tile genes (the widest ranges) across the population.
    for gene in spec.tile_start..spec.format_start {
        let dim = (gene - spec.tile_start) % spec.rank;
        let width = spec.dim_sizes[dim].max(1);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for (stratum, &who) in order.iter().enumerate() {
            let lo = 1 + stratum as u64 * width / n as u64;
            let hi = (1 + (stratum as u64 + 1) * width / n as u64).clamp(lo, width);
            pop[who][gene] = rng.range_u32(lo as u32, hi as u32);
        }
    }
    pop
}

/// Config-parameterized core against a borrowed context (the registry /
/// portfolio entry point; telemetry accumulates in `ctx`).
pub fn es_direct_with(ctx: &mut EvalContext, cfg: &EsDirectConfig, seed: u64) {
    // The registry schema enforces population >= 2; floor it here too so
    // a direct caller can't hit the empty-parent indexing below.
    let cfg = EsDirectConfig { population: cfg.population.max(2), ..*cfg };
    let workload = ctx.workload().clone();
    let spec = DirectSpec::new(&workload, seed);
    let mut rng = Pcg64::seeded(seed);

    let decode_all = |genomes: &[Vec<u32>]| -> Vec<Option<Design>> {
        genomes.iter().map(|g| spec.decode(&workload, g)).collect()
    };

    let genomes = lhs_direct(&spec, cfg.population, &mut rng);
    let designs = decode_all(&genomes);
    let results = ctx.eval_designs(&genomes, &designs);
    let mut pop: Vec<(Vec<u32>, f64)> = genomes
        .into_iter()
        .zip(&results)
        .map(|(g, r)| (g, if r.valid { 1.0 / r.edp } else { 0.0 }))
        .collect();

    while !ctx.exhausted() {
        pop.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let parents = ((pop.len() as f64 * cfg.parent_frac) as usize).max(2);
        pop.truncate(parents);

        // Never breed (and decode) more offspring than the budget can
        // evaluate. Children are drawn sequentially from the rng, so the
        // evaluated prefix — and with it the trajectory — is bit-identical
        // to generating the full population and letting `eval_designs`
        // truncate; only the wasted tail goes away.
        let brood = cfg.population.min(ctx.remaining());
        let mut children: Vec<Vec<u32>> = Vec::with_capacity(brood);
        while children.len() < brood {
            let pa = &pop[rng.index(pop.len())].0;
            let pb = &pop[rng.index(pop.len())].0;
            let cut = 1 + rng.index(spec.len - 1);
            let mut c = pa[..cut].to_vec();
            c.extend_from_slice(&pb[cut..]);
            if rng.chance(cfg.mutation_prob) {
                spec.mutate(&mut c, &mut rng);
            }
            children.push(c);
        }
        let designs = decode_all(&children);
        let results = ctx.eval_designs(&children, &designs);
        if results.is_empty() {
            break;
        }
        for (g, r) in children.into_iter().zip(&results) {
            pop.push((g, if r.valid { 1.0 / r.edp } else { 0.0 }));
        }
    }
}

pub fn es_direct(mut ctx: EvalContext, seed: u64) -> Outcome {
    es_direct_with(&mut ctx, &EsDirectConfig::default(), seed);
    ctx.outcome("es-direct")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.3, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn runs_within_budget() {
        let o = es_direct(ctx(1_000), 3);
        assert_eq!(o.method, "es-direct");
        assert!(o.evals <= 1_000);
    }

    #[test]
    fn suffers_from_dead_offspring() {
        // The defining property: most direct-encoding evaluations are
        // dead (tiling violations), so the valid ratio is far below the
        // PFCE encoding's.
        let o = es_direct(ctx(2_000), 4);
        assert!(o.valid_ratio() < 0.5, "valid ratio {}", o.valid_ratio());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = es_direct(ctx(600), 9);
        let b = es_direct(ctx(600), 9);
        assert_eq!(a.best_edp, b.best_edp);
        assert_eq!(a.valid_evals, b.valid_evals);
    }
}
