//! TBPSA baseline — Test-Based Population Size Adaptation (the
//! noisy-optimization evolution strategy from Nevergrad, used as a
//! baseline in Fig. 17a), over the raw direct-encoded space.

use super::space::DirectSpace;
use crate::search::{EvalContext, Outcome};
use crate::util::rng::Pcg64;

/// TBPSA hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TbpsaConfig {
    /// Samples drawn per iteration.
    pub lambda: usize,
    /// Elites the distribution recenters on.
    pub mu: usize,
}

impl Default for TbpsaConfig {
    fn default() -> Self {
        TbpsaConfig { lambda: 30, mu: 8 }
    }
}

/// Config-parameterized core against a borrowed context (the registry /
/// portfolio entry point; telemetry accumulates in `ctx`).
pub fn tbpsa_with(ctx: &mut EvalContext, cfg: &TbpsaConfig, seed: u64) {
    let space = DirectSpace::new(ctx, seed);
    let mut rng = Pcg64::seeded(seed);
    let n = space.len();
    let lambda = cfg.lambda.max(1);
    let mu = cfg.mu.clamp(1, lambda);

    let lo: Vec<f64> = (0..n).map(|i| space.bounds(i).0 as f64).collect();
    let hi: Vec<f64> = (0..n).map(|i| space.bounds(i).1 as f64).collect();
    // Means start at feasible-looking points (see pso.rs — uniform
    // starts are dead).
    let mut mean: Vec<f64> =
        (0..n).map(|i| space.sample_action(i, &mut rng) as f64).collect();
    // Tile genes explore in small absolute steps (a few divisor hops);
    // wide Gaussians there land on dead products almost surely.
    let mut sigma: Vec<f64> = (0..n)
        .map(|i| {
            let base = (hi[i] - lo[i]).max(1.0);
            if space.is_tile_gene(i) { (base / 64.0).clamp(1.0, 8.0) } else { base / 3.0 }
        })
        .collect();

    let mut dead_iters = 0usize;
    while !ctx.exhausted() {
        let samples: Vec<Vec<f64>> = (0..lambda)
            .map(|_| {
                (0..n)
                    .map(|i| (mean[i] + sigma[i] * rng.normal()).clamp(lo[i], hi[i]))
                    .collect()
            })
            .collect();
        let genomes: Vec<Vec<u32>> = samples
            .iter()
            .map(|s| (0..n).map(|i| space.snap(i, s[i])).collect())
            .collect();
        let results = space.eval(ctx, &genomes);
        if results.is_empty() {
            break;
        }
        // Restart: if the distribution has drifted into an all-dead
        // region for several iterations, re-seed the mean (standard
        // restart heuristic for noisy ES).
        if results.iter().all(|r| !r.valid) {
            dead_iters += 1;
            if dead_iters >= 5 {
                for (d, m) in mean.iter_mut().enumerate() {
                    *m = space.sample_action(d, &mut rng) as f64;
                }
                dead_iters = 0;
                continue;
            }
        } else {
            dead_iters = 0;
        }
        let mut scored: Vec<(f64, usize)> = results
            .iter()
            .enumerate()
            .map(|(i, r)| (if r.valid { r.edp } else { f64::INFINITY }, i))
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let elites: Vec<&Vec<f64>> =
            scored.iter().take(mu).map(|&(_, i)| &samples[i]).collect();

        // Recenter on the elite mean; adapt sigma toward elite spread
        // (floored so the search never collapses while invalids dominate).
        for d in 0..n {
            let m = elites.iter().map(|e| e[d]).sum::<f64>() / elites.len() as f64;
            let var = elites.iter().map(|e| (e[d] - m) * (e[d] - m)).sum::<f64>()
                / elites.len() as f64;
            mean[d] = m;
            let floor = if space.is_tile_gene(d) {
                0.5
            } else {
                (hi[d] - lo[d]).max(1.0) * 0.02
            };
            sigma[d] = (0.7 * sigma[d] + 0.3 * var.sqrt()).max(floor);
        }
    }
}

pub fn tbpsa(mut ctx: EvalContext, seed: u64) -> Outcome {
    tbpsa_with(&mut ctx, &TbpsaConfig::default(), seed);
    ctx.outcome("tbpsa")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.3, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn tbpsa_runs_within_budget() {
        let o = tbpsa(ctx(900), 3);
        assert_eq!(o.method, "tbpsa");
        assert!(o.evals <= 900);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = tbpsa(ctx(600), 7);
        let b = tbpsa(ctx(600), 7);
        assert_eq!(a.best_edp, b.best_edp);
        assert_eq!(a.valid_evals, b.valid_evals);
    }

    #[test]
    fn mostly_dead_in_raw_space() {
        let o = tbpsa(ctx(1_500), 4);
        assert!(o.valid_ratio() < 0.7, "valid ratio {}", o.valid_ratio());
    }
}
