//! Suspend/resume parity across every resumable method.
//!
//! For each method whose [`sparsemap::optimizer::MethodSpec`] advertises
//! `resumable`, this suite suspends a run at roughly half its budget,
//! round-trips the checkpoint through its JSON wire format, resumes in a
//! completely fresh session, and requires the final [`Outcome`] to be
//! **bit-identical** to an uninterrupted run — at 1 and at 4 threads.

use sparsemap::api::{RunOpts, SearchReport, SearchRequest};
use sparsemap::optimizer::Checkpoint;
use sparsemap::search::{Outcome, Progress, SearchControl};
use sparsemap::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const BUDGET: usize = 400;

fn req(method: &str, threads: usize) -> SearchRequest {
    SearchRequest::new()
        .workload_named("mm1")
        .platform_named("mobile")
        .method(method)
        .budget(BUDGET)
        .seed(23)
        .threads(threads)
}

fn run_full(method: &str, threads: usize) -> SearchReport {
    req(method, threads).build().unwrap().run_opts(RunOpts::default()).unwrap()
}

/// Suspend at ~half budget, round-trip the checkpoint, resume fresh.
fn run_interrupted(method: &str, threads: usize) -> SearchReport {
    let flag = Arc::new(AtomicBool::new(false));
    let observer_flag = Arc::clone(&flag);
    let observer = Box::new(move |p: &Progress| {
        if p.evals >= BUDGET / 2 {
            observer_flag.store(true, Ordering::SeqCst);
        }
        SearchControl::Continue
    });
    let half = req(method, threads)
        .build()
        .unwrap()
        .run_opts(RunOpts {
            observer: Some(observer),
            suspend: Some(flag),
            ..Default::default()
        })
        .unwrap();
    assert!(half.stopped_early, "{method}: a raised suspend flag marks the report");
    assert!(
        half.outcome.evals < BUDGET,
        "{method}: suspended run must stop short of the budget, spent {}",
        half.outcome.evals
    );
    let cp_json = half
        .checkpoint
        .as_ref()
        .unwrap_or_else(|| panic!("{method}: resumable method must emit a checkpoint"));
    let wire = Json::parse(&cp_json.dumps()).unwrap();
    let cp = Checkpoint::from_json(&wire).unwrap();
    let resumed = req(method, threads)
        .build()
        .unwrap()
        .run_opts(RunOpts { resume: Some(cp), ..Default::default() })
        .unwrap();
    assert!(!resumed.stopped_early, "{method}: resumed run finishes normally");
    assert!(resumed.checkpoint.is_none(), "{method}: finished run carries no checkpoint");
    assert_eq!(
        resumed.resumed_from,
        Some(half.outcome.evals),
        "{method}: report records where the resume picked up"
    );
    resumed
}

fn assert_outcomes_identical(method: &str, threads: usize, full: &Outcome, resumed: &Outcome) {
    let tag = format!("{method} @ {threads} thread(s)");
    assert_eq!(full.evals, resumed.evals, "{tag}: evals");
    assert_eq!(full.valid_evals, resumed.valid_evals, "{tag}: valid_evals");
    assert_eq!(
        full.best_edp.to_bits(),
        resumed.best_edp.to_bits(),
        "{tag}: best EDP must match bit for bit ({} vs {})",
        full.best_edp,
        resumed.best_edp
    );
    assert_eq!(full.best_genome, resumed.best_genome, "{tag}: best genome");
    assert_eq!(full.curve.len(), resumed.curve.len(), "{tag}: curve length");
    for ((xe, ye), (xr, yr)) in full.curve.iter().zip(&resumed.curve) {
        assert_eq!(xe, xr, "{tag}: curve x");
        assert_eq!(ye.to_bits(), yr.to_bits(), "{tag}: curve y bits");
    }
}

/// The method list comes from the registry itself, so a new resumable
/// method is covered here automatically.
fn check_all(threads: usize) {
    let resumable: Vec<&str> =
        sparsemap::api::methods().iter().filter(|m| m.resumable).map(|m| m.name).collect();
    assert!(!resumable.is_empty());
    for method in resumable {
        let full = run_full(method, threads);
        let resumed = run_interrupted(method, threads);
        assert_outcomes_identical(method, threads, &full.outcome, &resumed.outcome);
    }
}

#[test]
fn every_resumable_method_resumes_bit_identically_at_1_thread() {
    check_all(1);
}

#[test]
fn every_resumable_method_resumes_bit_identically_at_4_threads() {
    check_all(4);
}

/// The portfolio's per-member ledgers stay exact across suspend/resume:
/// no budget is re-debited for replayed prefixes, and member evals still
/// sum to the outcome's total.
#[test]
fn resumed_portfolio_member_evals_sum_exactly() {
    let full = run_full("portfolio", 1);
    let full_sum: usize = full.outcome.members.iter().map(|m| m.evals).sum();
    assert_eq!(full_sum, full.outcome.evals, "uninterrupted: members sum to the total");
    let resumed = run_interrupted("portfolio", 1);
    let resumed_sum: usize = resumed.outcome.members.iter().map(|m| m.evals).sum();
    assert_eq!(resumed_sum, resumed.outcome.evals, "resumed: members sum to the total");
    assert_eq!(resumed.outcome.evals, BUDGET, "the full budget was spent exactly once");
}
