//! Versioned search checkpoints — a suspended optimizer plus the
//! evaluation state it was running against, serialized without `serde`
//! through the in-tree [`Json`] writer.
//!
//! A checkpoint pairs two snapshots taken at a safe point (between
//! batches/generations):
//!
//! * the optimizer's own state from [`crate::optimizer::Optimizer::suspend`]
//!   (RNG, population, phase cursor — whatever the method needs), and
//! * the context state from `EvalContext::capture_eval_state` (telemetry,
//!   interned genomes, result caches, counters).
//!
//! Restoring both into a freshly built optimizer/context of the same
//! request continues the search **bit-identically**: the resumed run's
//! final `Outcome` equals an uninterrupted run's, which
//! `rust/tests/checkpoints.rs` pins for every method advertising
//! `resumable`. Floats inside the snapshots travel as IEEE-754 bit
//! patterns ([`crate::util::json::f64_bits`]) and 128-bit RNG state as hex
//! strings ([`rng_to_json`]), so nothing is lost to decimal formatting.

use crate::util::json::{f64_bits, f64_from_bits, Json};
use crate::util::rng::Pcg64;
use anyhow::{anyhow, ensure, Result};

/// Schema tag stamped into every serialized checkpoint.
pub const CHECKPOINT_SCHEMA: &str = "sparsemap.checkpoint.v1";

/// A suspended search: which method was running, its internal state, and
/// the evaluation state of the context it ran against.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Canonical registry name of the suspended method.
    pub method: String,
    /// Opaque optimizer state from [`crate::optimizer::Optimizer::suspend`].
    pub state: Json,
    /// Context snapshot from `EvalContext::capture_eval_state`.
    pub eval: Json,
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(CHECKPOINT_SCHEMA)),
            ("method", Json::str(&self.method)),
            ("state", self.state.clone()),
            ("eval", self.eval.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Checkpoint> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("checkpoint JSON is missing 'schema'"))?;
        ensure!(
            schema == CHECKPOINT_SCHEMA,
            "unsupported checkpoint schema '{schema}' (expected '{CHECKPOINT_SCHEMA}')"
        );
        Ok(Checkpoint {
            method: j
                .get("method")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("checkpoint JSON is missing 'method'"))?
                .to_string(),
            state: j.get("state").cloned().unwrap_or(Json::Null),
            eval: j.get("eval").cloned().ok_or_else(|| anyhow!("checkpoint JSON is missing 'eval'"))?,
        })
    }
}

/// Serialize a [`Pcg64`] exactly: the 128-bit LCG state and stream as
/// 32-hex-digit strings (`Json::Num` is an f64 and cannot carry them).
pub fn rng_to_json(rng: &Pcg64) -> Json {
    let (state, inc) = rng.to_parts();
    Json::obj(vec![
        ("state", Json::Str(format!("{state:032x}"))),
        ("inc", Json::Str(format!("{inc:032x}"))),
    ])
}

/// Inverse of [`rng_to_json`].
pub fn rng_from_json(j: &Json) -> Result<Pcg64> {
    let part = |key: &str| -> Result<u128> {
        let s = j
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("rng state is missing '{key}'"))?;
        ensure!(s.len() == 32, "rng '{key}' must be 32 hex digits");
        u128::from_str_radix(s, 16).map_err(|_| anyhow!("rng '{key}' is not hex"))
    };
    Ok(Pcg64::from_parts(part("state")?, part("inc")?))
}

/// Serialize a list of genomes (`Vec<Vec<u32>>`) — shared by every
/// population-carrying optimizer state.
pub fn genomes_to_json(genomes: &[Vec<u32>]) -> Json {
    Json::Arr(
        genomes
            .iter()
            .map(|g| Json::Arr(g.iter().map(|&x| Json::num(x as f64)).collect()))
            .collect(),
    )
}

/// Inverse of [`genomes_to_json`].
pub fn genomes_from_json(j: &Json) -> Result<Vec<Vec<u32>>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("genome list must be an array"))?
        .iter()
        .map(|g| {
            g.as_arr()
                .ok_or_else(|| anyhow!("genome must be an array"))?
                .iter()
                .map(|x| {
                    x.as_u64()
                        .map(|v| v as u32)
                        .ok_or_else(|| anyhow!("genes must be integers"))
                })
                .collect()
        })
        .collect()
}

/// Serialize a float vector bit-exactly (each entry via
/// [`crate::util::json::f64_bits`]).
pub fn f64s_to_json(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| f64_bits(x)).collect())
}

/// Inverse of [`f64s_to_json`].
pub fn f64s_from_json(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("float list must be an array"))?
        .iter()
        .map(|x| f64_from_bits(x).ok_or_else(|| anyhow!("float entries must be f64 bits")))
        .collect()
}

/// Serialize an index list (`Vec<usize>`).
pub fn indices_to_json(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

/// Inverse of [`indices_to_json`].
pub fn indices_from_json(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("index list must be an array"))?
        .iter()
        .map(|x| {
            x.as_u64().map(|v| v as usize).ok_or_else(|| anyhow!("indices must be integers"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_round_trips() {
        let cp = Checkpoint {
            method: "random".into(),
            state: Json::obj(vec![("k", Json::num(3.0))]),
            eval: Json::obj(vec![("budget", Json::num(10.0))]),
        };
        let j = Json::parse(&cp.to_json().dumps()).unwrap();
        assert_eq!(Checkpoint::from_json(&j).unwrap(), cp);
    }

    #[test]
    fn wrong_schema_rejected() {
        let mut j = Checkpoint {
            method: "random".into(),
            state: Json::Null,
            eval: Json::Null,
        }
        .to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("schema".into(), Json::str("sparsemap.checkpoint.v9"));
        }
        assert!(Checkpoint::from_json(&j).is_err());
        assert!(Checkpoint::from_json(&Json::Null).is_err());
    }

    #[test]
    fn rng_state_round_trips_exactly() {
        let mut rng = Pcg64::seeded(99);
        for _ in 0..23 {
            rng.next_u64();
        }
        let j = Json::parse(&rng_to_json(&rng).dumps()).unwrap();
        let mut back = rng_from_json(&j).unwrap();
        let mut orig = rng;
        for _ in 0..64 {
            assert_eq!(orig.next_u64(), back.next_u64());
        }
    }

    #[test]
    fn genome_and_index_lists_round_trip() {
        let gs = vec![vec![1u32, 2, 3], vec![], vec![7, 0]];
        let j = Json::parse(&genomes_to_json(&gs).dumps()).unwrap();
        assert_eq!(genomes_from_json(&j).unwrap(), gs);
        let xs = vec![0usize, 5, 2];
        let j = Json::parse(&indices_to_json(&xs).dumps()).unwrap();
        assert_eq!(indices_from_json(&j).unwrap(), xs);
    }

    #[test]
    fn f64_lists_round_trip_bit_exactly() {
        let xs = vec![0.1, f64::INFINITY, -3.25, 1e300];
        let j = Json::parse(&f64s_to_json(&xs).dumps()).unwrap();
        let back = f64s_from_json(&j).unwrap();
        assert_eq!(xs.len(), back.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
