//! Durable filesystem primitives: the one tmp+fsync+rename implementation.
//!
//! Both the service's job checkpoints and `memory compact`'s store
//! rewrite previously hand-rolled tmp+rename — without ever syncing the
//! file *or* the parent directory, so a power loss could leave an empty
//! tmp, a half-written target, or a rename that never reached the
//! journal. [`atomic_write`] is the single shared implementation: write
//! the tmp, `sync_all` the file, rename over the target, `sync_all` the
//! parent directory handle. It also carries the `checkpoint-write` fault
//! point, so every durability write in the tree is chaos-testable from
//! one seam.

use crate::util::faults::{self, points};
use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

/// fsync a directory handle so a rename inside it survives power loss.
/// Directories cannot be opened for reading on some platforms
/// (e.g. Windows); there this is a no-op, matching the weaker guarantees
/// those filesystems give anyway.
pub fn sync_dir(dir: &Path) -> io::Result<()> {
    if dir.as_os_str().is_empty() {
        return sync_dir(Path::new("."));
    }
    #[cfg(unix)]
    {
        File::open(dir)?.sync_all()
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
        Ok(())
    }
}

/// Atomically and durably replace `path` with `bytes`:
/// tmp write → file fsync → rename → parent-dir fsync. On any failure
/// the original file is untouched (the tmp is removed best-effort).
/// Honors the `checkpoint-write` fault point (errors and torn writes
/// surface as `io::Error`; a torn tmp never reaches the target name).
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = path.with_extension("tmp");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    let write_tmp = || -> io::Result<()> {
        let mut f = File::create(&tmp)?;
        faults::write_all_at(points::CHECKPOINT_WRITE, &mut f, bytes)?;
        f.sync_all()
    };
    if let Err(e) = write_tmp() {
        let _ = fs::remove_file(&tmp);
        return Err(e);
    }
    fs::rename(&tmp, path)?;
    sync_dir(path.parent().unwrap_or_else(|| Path::new(".")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_path(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("sparsemap_fsio_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}.bin", std::process::id()))
    }

    // Fault-injected atomic_write behavior (failed/torn tmp never reaches
    // the target) is covered by `tests/faults.rs`, which owns the
    // process-global fault plan; unit tests here must not arm it because
    // sibling tests run in parallel against the same seam.
    #[test]
    fn replaces_contents_atomically() {
        let path = tmp_path("replace");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer contents").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second, longer contents");
        assert!(!path.with_extension("tmp").exists(), "tmp cleaned up");
        let _ = fs::remove_file(&path);
    }
}
